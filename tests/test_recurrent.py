"""RWKV-6 chunked wkv and RG-LRU scan vs naive step recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import rglru as rgl
from repro.models import rwkv6 as rw


def _naive_wkv(r, k, v, logw, u, s0):
    b, t, h, d = r.shape
    s = np.asarray(s0, np.float64).copy()
    outs = np.zeros((b, t, h, d))
    r_, k_, v_, w_ = (np.asarray(x, np.float64) for x in (r, k, v, logw))
    for ti in range(t):
        kv = np.einsum("bhd,bhe->bhde", k_[:, ti], v_[:, ti])
        outs[:, ti] = np.einsum(
            "bhd,bhde->bhe", r_[:, ti],
            s + u[None, :, :, None] * kv)
        s = np.exp(w_[:, ti])[..., None] * s + kv
    return outs, s


@pytest.mark.parametrize("t,chunk", [(32, 8), (48, 16), (16, 16)])
def test_chunked_wkv_matches_recurrence(rng, t, chunk):
    b, h, d = 2, 3, 8
    r = jnp.asarray(rng.normal(size=(b, t, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, d)), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(b, t, h, d)) * 0.5, jnp.float32))
    u = np.asarray(rng.normal(size=(h, d)), np.float32)
    s0 = jnp.asarray(rng.normal(size=(b, h, d, d)) * 0.1, jnp.float32)
    o, s_fin = rw.chunked_wkv(r, k, v, logw, jnp.asarray(u), s0, chunk)
    o_ref, s_ref = _naive_wkv(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o), o_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), s_ref, rtol=1e-4, atol=1e-4)


def test_wkv_decode_continues_chunked(rng):
    b, t, h, d = 1, 16, 2, 8
    mk = lambda: jnp.asarray(rng.normal(size=(b, t, h, d)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    logw = -jnp.exp(mk() * 0.3)
    u = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    o_all, _ = rw.chunked_wkv(r, k, v, logw, u, s0, 8)
    o_pre, s_mid = rw.chunked_wkv(
        r[:, :8], k[:, :8], v[:, :8], logw[:, :8], u, s0, 8)
    o_step, _ = rw.wkv_decode_step(
        r[:, 8, :, :], k[:, 8], v[:, 8], logw[:, 8], u, s_mid)
    np.testing.assert_allclose(np.asarray(o_step), np.asarray(o_all[:, 8]),
                               rtol=1e-4, atol=1e-4)


def test_rglru_scan_matches_steps(rng):
    b, t, r_dim = 2, 24, 16
    p = rgl.init_rglru_block(jax.random.PRNGKey(0), 32, r_dim, 4, jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, t, r_dim)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(b, r_dim)) * 0.1, jnp.float32)
    y, h_last = rgl.rglru_scan(p, x, h0)
    h = h0
    for ti in range(t):
        h, _ = rgl.rglru_step(p, x[:, ti], h)
        np.testing.assert_allclose(np.asarray(y[:, ti]), np.asarray(h),
                                   rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=1e-4, atol=1e-5)


def test_rglru_block_decode_continues(rng):
    b, t, d, r_dim = 1, 12, 16, 16
    p = rgl.init_rglru_block(jax.random.PRNGKey(1), d, r_dim, 4, jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, t + 1, d)), jnp.float32)
    st0 = {"h": jnp.zeros((b, r_dim), jnp.float32),
           "conv": jnp.zeros((b, 3, r_dim), jnp.float32)}
    full, _ = rgl.apply_rglru_block(p, x, st0)
    pre, st = rgl.apply_rglru_block(p, x[:, :t], st0)
    step, _ = rgl.apply_rglru_block_decode(p, x[:, t:t + 1], st)
    np.testing.assert_allclose(np.asarray(step[:, 0]), np.asarray(full[:, t]),
                               rtol=1e-4, atol=1e-4)


def test_rglru_decay_in_unit_interval(rng):
    p = rgl.init_rglru_block(jax.random.PRNGKey(2), 8, 8, 4, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 5, 8)) * 3, jnp.float32)
    a, _ = rgl._rglru_gates(p, x)
    a = np.asarray(a)
    assert (a > 0).all() and (a < 1).all()
