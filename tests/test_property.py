"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # image without hypothesis: deterministic local shim
    from _hypothesis_mini import given, settings, strategies as st

from repro.core.moe_spade import build_dispatch, plan_capacity
from repro.core.schedule import (
    schedule_lpt,
    schedule_naive,
    schedule_round_robin_sorted,
)
from repro.sparse.tensor import linear_key
from repro.training.grad_compress import _dequantize, _quantize_int8

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(st.integers(2, 64), st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_linear_key_bijective_on_grid(res, n, seed):
    rng = np.random.default_rng(seed)
    coords = rng.integers(0, res, (n, 3)).astype(np.int32)
    keys = np.asarray(linear_key(jnp.asarray(coords), res))
    back = np.stack([keys // (res * res), (keys // res) % res, keys % res], 1)
    np.testing.assert_array_equal(back, coords)
    # padding maps to sentinel
    pad = np.full((1, 3), -1, np.int32)
    assert int(linear_key(jnp.asarray(pad), res)[0]) == res**3


@settings(**SETTINGS)
@given(st.integers(1, 64), st.integers(1, 8), st.integers(2, 32),
       st.integers(0, 2**31 - 1))
def test_moe_dispatch_invariants(tokens, k, n_experts, seed):
    rng = np.random.default_rng(seed)
    # real top-k routing picks distinct experts per token
    kk = min(k, n_experts)
    idx = np.stack([rng.permutation(n_experts)[:kk] for _ in range(tokens)])
    idx = jnp.asarray(idx, jnp.int32)
    k = kk
    cap = max(4, tokens)
    slot, table = build_dispatch(idx, n_experts, cap)
    slot, table = np.asarray(slot), np.asarray(table)
    # every kept assignment is inverted by the table
    for t in range(tokens):
        for j in range(k):
            if slot[t, j] >= 0:
                assert table[int(idx[t, j]), slot[t, j]] == t
    # table entries are unique tokens per expert slot
    for e in range(n_experts):
        vals = table[e][table[e] >= 0]
        assert len(np.unique(vals)) == len(vals)
    # no expert exceeds capacity (structural)
    assert table.shape == (n_experts, cap)


@settings(**SETTINGS)
@given(st.integers(1, 16), st.integers(1, 6), st.floats(0.5, 0.99),
       st.integers(0, 2**31 - 1))
def test_rst_capacity_at_least_uniform(n_experts, k, q, seed):
    rng = np.random.default_rng(seed)
    tokens = 128
    loads = rng.multinomial(tokens * k, np.ones(n_experts) / n_experts,
                            size=8)
    cap = plan_capacity(loads, n_experts, tokens, k, "RST", quantile=q)
    assert cap >= tokens * k / n_experts
    cap_sst = plan_capacity(loads, n_experts, tokens, k, "SST")
    assert cap_sst >= loads.max()


@settings(**SETTINGS)
@given(st.lists(st.floats(1.0, 1e6), min_size=1, max_size=200),
       st.integers(1, 16))
def test_schedule_conservation_and_bounds(work, cores):
    w = np.asarray(work)
    for fn in (schedule_naive, schedule_round_robin_sorted, schedule_lpt):
        a = fn(w, cores)
        assert np.isclose(a.per_core_work.sum(), w.sum(), rtol=1e-9)
        # relative tolerance: summation order perturbs large sums at ~1e-16
        assert a.makespan >= w.sum() / cores * (1 - 1e-9) - 1e-9
        assert a.makespan >= w.max() * (1 - 1e-9) - 1e-9
        got = np.concatenate([o for o in a.order_within if len(o)])
        assert sorted(got) == list(range(len(w)))


@settings(**SETTINGS)
@given(st.integers(1, 2000), st.floats(1e-6, 1e6), st.integers(0, 2**31 - 1))
def test_int8_quantization_error_bound(n, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s = _quantize_int8(x)
    back = _dequantize(q, s, x.shape)
    blockmax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(back - x))) <= blockmax / 127 + 1e-6


@settings(**SETTINGS)
@given(st.integers(2, 6), st.integers(2, 50), st.integers(0, 2**31 - 1))
def test_lm_loss_matches_reference(vocab_mult, seq, seed):
    from repro.configs import get_config
    from repro.models.transformer import lm_loss

    cfg = get_config("stablelm-1.6b").reduced()
    rng = np.random.default_rng(seed)
    v = cfg.vocab_padded
    logits = jnp.asarray(rng.normal(size=(2, seq, v)), jnp.float32)
    tgt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, seq)), jnp.int32)
    ref = -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1), tgt[..., None], -1))
    got = lm_loss(logits, tgt, cfg)
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-4)


@settings(**SETTINGS)
@given(st.integers(8, 28), st.integers(2, 96), st.integers(0, 2**31 - 1))
def test_soar_order_is_chunked_permutation(res, chunk, seed):
    """SOAR output is a permutation of the active set, partitioned into
    contiguous chunks of positive size bounded by the chunk budget."""
    from repro.core.hashgrid import build_neighbor_table, kernel_offsets
    from repro.core.soar import soar_order

    rng = np.random.default_rng(seed)
    coords = np.unique(
        rng.integers(0, res, (150, 3)).astype(np.int32), axis=0)
    mask = rng.random(len(coords)) < 0.8
    nbr = np.asarray(build_neighbor_table(
        jnp.asarray(coords), jnp.asarray(mask),
        jnp.asarray(kernel_offsets(3)), int(res)))
    r = soar_order(nbr, mask, chunk)
    active = np.flatnonzero(mask)
    assert sorted(r.order) == sorted(active)
    starts = r.chunk_starts
    assert starts[0] == 0 and starts[-1] == len(r.order)
    sizes = np.diff(starts)
    assert np.all(sizes > 0) and np.all(sizes <= chunk)
