"""repro.dist: hints are no-ops without a mesh, rules produce valid specs,
compressed collectives round-trip, pipeline stage lib validates shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.collectives import compressed_psum, expert_all_to_all
from repro.dist.compat import make_mesh
from repro.dist.hints import DP, active_mesh, constrain, use_mesh
from repro.dist.pipeline import pipeline_apply, stack_stages
from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_host_mesh
from repro.training import grad_compress
from repro.training.optimizer import OptHParams
from repro.training.train_loop import init_train_state


# ---------------------------------------------------------------- hints

def test_constrain_is_identity_without_mesh(rng):
    x = jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)
    assert constrain(x, DP, None, "model") is x
    assert active_mesh() is None

    @jax.jit
    def f(x):
        return constrain(x, DP, "model", None) * 2

    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x) * 2)


def test_use_mesh_sets_and_restores_context():
    mesh = make_host_mesh()
    assert active_mesh() is None
    with use_mesh(mesh, dp=("data",)) as m:
        assert m is mesh
        got_mesh, dp = active_mesh()
        assert got_mesh is mesh and dp == ("data",)
    assert active_mesh() is None
    with pytest.raises(ValueError):
        with use_mesh(mesh, dp=("nonexistent",)):
            pass


def test_constrain_applies_and_drops_indivisible_axes(rng):
    mesh = make_host_mesh()  # (n_dev, 1): "data" axis only is >1
    n_data = mesh.shape["data"]
    if n_data < 2:
        pytest.skip("needs >=2 devices")
    with use_mesh(mesh, dp=("data",)):
        x = jnp.zeros((n_data * 2, 8, 16))
        spec = constrain(x, DP, None, "model").sharding.spec
        assert spec[0] == "data"          # divisible batch -> DP sharded
        assert spec[2] is None            # model axis has size 1 -> dropped
        y = jnp.zeros((n_data + 1, 8))    # indivisible batch -> unsharded
        assert constrain(y, DP, None).sharding.spec[0] is None


# ------------------------------------------------------------ sharding

def test_sharding_rules_valid_on_host_mesh():
    cfg = get_config("stablelm-1.6b").reduced()
    mesh = make_host_mesh()
    rules = ShardingRules(cfg, mesh)
    state = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg, OptHParams()))
    sh = rules.state_shardings(state)
    for s in jax.tree.leaves(sh, is_leaf=lambda lf: hasattr(lf, "spec")):
        for entry in s.spec:
            axes = (entry,) if isinstance(entry, str) else (entry or ())
            assert all(a in mesh.axis_names for a in axes)
    # shardings are consumable by jit on this mesh
    params_sh = rules.params_shardings(state["params"])
    jitted = jax.jit(lambda p: p, in_shardings=(params_sh,))
    jitted.lower(state["params"]).compile()

    batch = {"tokens": jax.ShapeDtypeStruct(
        (4 * mesh.shape["data"], 65), jnp.int32)}
    spec = rules.batch_shardings(batch)["tokens"].spec
    assert spec[0] is not None  # divisible global batch shards over DP


def test_sharding_rules_model_axis_and_full_dp():
    if len(jax.devices()) < 2 or len(jax.devices()) % 2:
        pytest.skip("needs an even device count")
    cfg = get_config("stablelm-1.6b").reduced()
    mesh = make_host_mesh(model=2)
    rules = ShardingRules(cfg, mesh)
    # 2D weight with a model-divisible last dim -> TP on the last dim
    w = jax.ShapeDtypeStruct((cfg.d_model, cfg.d_ff), jnp.float32)
    assert rules.params_shardings(w).spec == ("model",) or \
        rules.params_shardings(w).spec[-1] == "model"
    # stacked per-cycle leaf never shards the leading scan axis
    stacked = jax.ShapeDtypeStruct((4, cfg.d_model, cfg.d_ff), jnp.float32)
    assert rules.params_shardings(stacked).spec[0] is None
    # KV cache prefers the kv-heads dim for the model axis, batch for DP
    kv = jax.ShapeDtypeStruct((mesh.shape["data"] * 2, 64,
                               cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    spec = rules.cache_shardings(kv).spec
    assert spec[2] == "model" and spec[0] is not None
    # batch dim coinciding in size with the kv-head count still picks heads
    kv2 = jax.ShapeDtypeStruct((cfg.n_kv_heads, 64,
                                cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    assert rules.cache_shardings(kv2).spec[2] == "model"
    # full-mesh DP: params replicate, batches shard over every axis
    full = ShardingRules(cfg, mesh, full_dp=True)
    assert full.params_shardings(w).spec == ()
    b = jax.ShapeDtypeStruct((mesh.size * 2, 65), jnp.int32)
    entry = full.batch_shardings(b).spec[0]
    assert set((entry,) if isinstance(entry, str) else entry) == \
        {a for a in mesh.axis_names if mesh.shape[a] > 1}


# ---------------------------------------------------------- collectives

def test_compressed_psum_sums_and_bounds_error(rng):
    n = len(jax.devices())
    mesh = make_mesh((n,), ("pod",))
    g = {"w": jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    out = compressed_psum(mesh, g, axis="pod")
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    # each of the n replicated contributions carries at most one block-scale
    # of quantization error — the wire-compression ratio costs nothing more
    assert float(jnp.max(jnp.abs(out["w"] - n * g["w"]))) < n * 1.5 * scale
    assert grad_compress.compression_ratio(g, 4) > 3.5


def test_compressed_psum_error_feedback_converges(rng):
    mesh = make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    err = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), g)
    acc = jnp.zeros_like(g["w"])
    for _ in range(20):
        out, err = compressed_psum(mesh, g, axis="pod", error_state=err)
        acc = acc + out["w"]
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    np.testing.assert_allclose(np.asarray(acc / 20), np.asarray(g["w"]),
                               rtol=0, atol=scale * 1.2)


def test_expert_all_to_all_identity_and_roundtrip():
    m1 = make_mesh((1,), ("model",))
    x = jnp.arange(2 * 8 * 4 * 3, dtype=jnp.float32).reshape(2, 8, 4, 3)
    np.testing.assert_array_equal(np.asarray(expert_all_to_all(m1, x)),
                                  np.asarray(x))
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >=2 devices")
    mesh = make_mesh((n,), ("model",))
    t = jnp.arange(n * 8 * 4 * 3, dtype=jnp.float32).reshape(n, 8, 4, 3)
    fwd = expert_all_to_all(mesh, t)             # group-major -> expert-major
    back = expert_all_to_all(mesh, fwd, split_axis=0, concat_axis=1)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(t))


# ------------------------------------------------------------- pipeline

def test_pipeline_validates_stage_count_and_shapes(rng):
    mesh = make_mesh((1,), ("pipe",))
    w = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    x = jnp.zeros((2, 4, 8))
    with pytest.raises(ValueError):
        stack_stages([])
    with pytest.raises(ValueError):  # 2 stages on a 1-wide pipe axis
        pipeline_apply(mesh, lambda p, t: t @ p["w"],
                       stack_stages([{"w": w}, {"w": w}]), x)
    if len(jax.devices()) >= 2:
        mesh2 = make_mesh((2,), ("pipe",))
        wide = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
        with pytest.raises(ValueError):  # stage changes activation shape
            pipeline_apply(mesh2, lambda p, t: t @ p["w"],
                           stack_stages([{"w": wide}, {"w": wide}]), x)


def test_pipeline_single_stage_allows_shape_change(rng):
    mesh = make_mesh((1,), ("pipe",))
    w = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(5, 4, 8)), jnp.float32)
    out = pipeline_apply(mesh, lambda p, t: t @ p["w"],
                         stack_stages([{"w": w}]), x)
    assert out.shape == (5, 4, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w), rtol=1e-5)


# ------------------------------------------------------------------ mesh

def test_make_host_mesh_rejects_non_divisor():
    n = len(jax.devices())
    with pytest.raises(ValueError):
        make_host_mesh(n + 1)
    with pytest.raises(ValueError):
        make_host_mesh(0)
    mesh = make_host_mesh(1)
    assert mesh.shape["data"] == n and mesh.shape["model"] == 1
