"""HLO cost walker: verified against known-flop modules (incl. nested scans),
and against xla cost_analysis' known while-loop undercount."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, xla_cost_dict


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_walker_exact_on_scan():
    w = jnp.ones((128, 64), jnp.float32)

    def body(x, _):
        return (x @ w) @ w.T, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(y)

    c = _compile(f, jnp.ones((32, 128), jnp.float32))
    cost = analyze(c.as_text())
    expect = 7 * (2 * 32 * 128 * 64 + 2 * 32 * 64 * 128)
    assert abs(cost.flops - expect) / expect < 1e-6
    # xla cost_analysis undercounts the loop (documents why the walker exists)
    xla = xla_cost_dict(c).get("flops", 0.0)
    assert xla < expect / 2


def test_walker_nested_scan():
    w = jnp.ones((64, 64), jnp.float32)

    def inner(x, _):
        return x @ w, None

    def outer(x, _):
        y, _ = jax.lax.scan(inner, x, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return jnp.sum(y)

    c = _compile(f, jnp.ones((16, 64), jnp.float32))
    cost = analyze(c.as_text())
    expect = 15 * 2 * 16 * 64 * 64
    assert abs(cost.flops - expect) / expect < 1e-6


def test_walker_counts_collectives_in_loops():
    # needs >1 device to emit collectives; with 1 device psum is free
    def f(x):
        def body(c, _):
            return c * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y

    c = _compile(f, jnp.ones((8, 8), jnp.float32))
    cost = analyze(c.as_text())
    # XLA may fully fold this loop; either way no flops and no crash
    assert cost.flops == 0  # elementwise only
    assert cost.bytes >= 0


def test_walker_bytes_reasonable_for_single_matmul():
    a = jnp.ones((256, 256), jnp.bfloat16)

    def f(x):
        return x @ a

    c = _compile(f, jnp.ones((256, 256), jnp.bfloat16))
    cost = analyze(c.as_text())
    assert cost.flops == 2 * 256**3
    # in+out bytes of the dot (2 operands + 1 output, w/ possible converts)
    lo = 3 * 256 * 256 * 2
    assert lo * 0.5 <= cost.bytes <= lo * 6
