"""HLO cost walker: verified against known-flop modules (incl. nested scans),
and against xla cost_analysis' known while-loop undercount. Plus the
compiled-artifact gates (``repro.analysis.hlo_gates``) applied to the real
execution paths: fused single-device, sharded (``shard_map``), streaming."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_gates import (
    compiled_text,
    forbidden_ops,
    gate_compile_budget,
    gate_plan_vmem,
)
from repro.launch.hlo_analysis import analyze, parse_hlo, xla_cost_dict


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_walker_exact_on_scan():
    w = jnp.ones((128, 64), jnp.float32)

    def body(x, _):
        return (x @ w) @ w.T, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(y)

    c = _compile(f, jnp.ones((32, 128), jnp.float32))
    cost = analyze(c.as_text())
    expect = 7 * (2 * 32 * 128 * 64 + 2 * 32 * 64 * 128)
    assert abs(cost.flops - expect) / expect < 1e-6
    # xla cost_analysis undercounts the loop (documents why the walker exists)
    xla = xla_cost_dict(c).get("flops", 0.0)
    assert xla < expect / 2


def test_walker_nested_scan():
    w = jnp.ones((64, 64), jnp.float32)

    def inner(x, _):
        return x @ w, None

    def outer(x, _):
        y, _ = jax.lax.scan(inner, x, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return jnp.sum(y)

    c = _compile(f, jnp.ones((16, 64), jnp.float32))
    cost = analyze(c.as_text())
    expect = 15 * 2 * 16 * 64 * 64
    assert abs(cost.flops - expect) / expect < 1e-6


def test_walker_counts_collectives_in_loops():
    # needs >1 device to emit collectives; with 1 device psum is free
    def f(x):
        def body(c, _):
            return c * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y

    c = _compile(f, jnp.ones((8, 8), jnp.float32))
    cost = analyze(c.as_text())
    # XLA may fully fold this loop; either way no flops and no crash
    assert cost.flops == 0  # elementwise only
    assert cost.bytes >= 0


def test_walker_bytes_reasonable_for_single_matmul():
    a = jnp.ones((256, 256), jnp.bfloat16)

    def f(x):
        return x @ a

    c = _compile(f, jnp.ones((256, 256), jnp.bfloat16))
    cost = analyze(c.as_text())
    assert cost.flops == 2 * 256**3
    # in+out bytes of the dot (2 operands + 1 output, w/ possible converts)
    lo = 3 * 256 * 256 * 2
    assert lo * 0.5 <= cost.bytes <= lo * 6


# ---------------------------------------------------------------------------
# compiled-artifact gates on the real execution paths
# ---------------------------------------------------------------------------

def _scene(res=16, cap=512):
    from repro.data.scenes import N_CLASSES, make_scene
    from repro.models.scn import UNetConfig
    from repro.sparse.tensor import SparseVoxelTensor
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=res, capacity=cap,
                     n_classes=N_CLASSES)
    coords, feats, _, mask = make_scene(0, resolution=res, capacity=cap)
    t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                          jnp.asarray(mask))
    return t, cfg


def _gate_fused_conv(plan):
    """No gather/scatter in the fused SSpNNA kernel of ``plan``'s first
    tiled conv; exactly one compiled signature."""
    from repro.kernels.sspnna.ops import run_sspnna_conv
    lvl = next(l for l in plan.levels if l.sub.tiles is not None)
    v = int(np.asarray(lvl.mask).shape[0])
    tl = lvl.sub.tiles
    orow, irow = jnp.asarray(tl.out_rows), jnp.asarray(tl.in_rows)
    li, pcnt = jnp.asarray(tl.local_idx), jnp.asarray(tl.pair_counts)
    rng = np.random.default_rng(0)
    c = 8
    feats = jnp.asarray(rng.normal(size=(v, c)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(27, c, c)) * 0.1, jnp.float32)

    def fused(f, ww):
        return run_sspnna_conv(f, ww, orow, irow, li, n_out=v,
                               pair_counts=pcnt, use_kernel=True)

    jf = jax.jit(fused)
    assert forbidden_ops(compiled_text(jf, feats, w), where="fused") == []
    assert gate_compile_budget(jf, 1, where="fused") == []


def test_streaming_path_fused_kernel_gates():
    """The fused kernel compiled off a *streaming* plan (frame 1, patched
    under an ego shift) contains no gather/scatter, and the plan's modeled
    VMEM stays within budget."""
    from repro import engine
    from repro.engine.plan import StreamPlanState
    t, cfg = _scene()
    spec = engine.build_plan_spec([t], cfg, mem_budget=64 * 1024)
    state = StreamPlanState(cfg, spec=spec, wait_s=30.0)
    state.plan_frame(t, 0)
    _, plan, _, _ = state.plan_frame(t, 1, (1, 0, 0))
    assert gate_plan_vmem(plan, cfg.widths) == []
    _gate_fused_conv(plan)


def test_sharded_path_gates_exact_opcode_match():
    """The sharded (``shard_map``) scene program: no scatter anywhere (the
    plane accumulation is dense matmuls), and its collective ``all-gather``
    ops are distinct opcodes that must never trip a ``gather`` gate."""
    from repro import engine
    from repro.dist.compat import make_mesh
    from repro.models.scn import init_unet
    t, cfg = _scene()
    params = init_unet(jax.random.PRNGKey(0), cfg)
    splan = engine.build_sharded_scene_plan(
        t, cfg, layout=engine.ShardLayout(n_shards=2))
    mesh = make_mesh((2,), ("shard",), devices=jax.devices()[:2])
    ctx = engine.ExecutionContext(mesh=mesh)
    jf = jax.jit(lambda p, f, pl: engine.apply_unet(p, f, pl, ctx=ctx))
    text = compiled_text(jf, params, t.feats, splan)
    assert forbidden_ops(text, ("scatter",), where="sharded") == []
    n_ag = sum(1 for comp in parse_hlo(text).values()
               for inst in comp.instructions.values()
               if inst.opcode == "all-gather")
    assert n_ag > 0  # real collectives are present on the 2-device mesh
    # exact-match: gating "all-gather" finds them...
    assert forbidden_ops(text, ("all-gather",), where="sharded") != []
    # ...but a "gather" gate only ever reports plain gathers, never the
    # collective (the sharded local conv is gather-based by design)
    for f in forbidden_ops(text, ("gather",), where="sharded"):
        assert "'gather'" in f.message and "all-gather" not in f.message
