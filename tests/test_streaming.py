"""Streaming scene engine: incremental plans for LiDAR sweeps.

The contract under test: a *patched* stream plan is bitwise-identical to
the plan a from-scratch build would produce on the stream's canonical row
layout — for any churn, any aligned ego shift, across fallbacks (unaligned
shift, empty frame, sub-threshold overlap). On top sit the serving-layer
guarantees: per-stream FIFO admission under an urgency policy, shed frames
never wedging their successors, and plan-reuse stats on ``WaveStats``.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # image without hypothesis: deterministic local shim
    from _hypothesis_mini import given, settings, strategies as st

from repro.core.hashgrid import UpdatableSortedGrid, kernel_offsets
from repro.core.host_meta import (
    StreamMetaState,
    build_cirf_np,
    diff_scene_np,
    downsample_coords_np,
    linear_key_np,
    pack_stream_frame_np,
    transposed_coir_np,
)
from repro.data.scenes import N_CLASSES, make_lidar_sweep
from repro.engine.plan import PlanCache, StreamPlanState, build_scene_plan_host
from repro.models.scn import UNetConfig, init_unet
from repro.serving.api import AdmissionPolicy, ServeRequest
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.serving.scheduler import WaveScheduler
from repro.sparse.tensor import PAD_COORD, SparseVoxelTensor

RES, CAP, LEVELS = 16, 256, 3
OFFS3 = kernel_offsets(3)
OFFS2 = kernel_offsets(2, centered=False)


# -- helpers ----------------------------------------------------------------


def _scratch_pyramid(coords, mask, res, n_levels):
    """From-scratch reference: geometry + sub/down/up COIRs per level."""
    geo, c, m, r = [], coords, mask, res
    for li in range(n_levels):
        geo.append((c, m, r))
        if li < n_levels - 1:
            c, m = downsample_coords_np(c, m, r, 2)
            r //= 2
    subs = [build_cirf_np(c, m, c, m, OFFS3, r) for c, m, r in geo]
    downs, ups = [], []
    for li in range(n_levels - 1):
        fc, fm, fr = geo[li]
        cc, cm, _ = geo[li + 1]
        downs.append(build_cirf_np(cc, cm, fc, fm, OFFS2, fr, stride=2))
        ups.append(transposed_coir_np(cc, cm, fc, fm, fr, 2, 2))
    return geo, subs, downs, ups


def _pack_frame(coords, mask, frame_rows, cap):
    """Re-pack a caller-layout frame into the stream's canonical rows."""
    act = np.flatnonzero(mask)
    assert (frame_rows[act] >= 0).all()
    pc = np.full((cap, 3), PAD_COORD, np.int32)
    pm = np.zeros(cap, bool)
    pc[frame_rows[act]] = coords[act]
    pm[frame_rows[act]] = True
    return pc, pm


def _assert_meta_matches_scratch(meta, st_meta, res, n_levels, ctx=""):
    cap = st_meta.capacity
    coords, mask = st_meta.coords[0], st_meta.mask[0]
    geo, subs, downs, ups = _scratch_pyramid(coords, mask, res, n_levels)
    for li in range(n_levels):
        gc, gm, _ = geo[li]
        sc, sm, scoir = meta.levels[li]
        np.testing.assert_array_equal(sc, gc, err_msg=f"coords L{li} {ctx}")
        np.testing.assert_array_equal(sm, gm, err_msg=f"mask L{li} {ctx}")
        for leaf in ("indices", "bitmask", "mask"):
            np.testing.assert_array_equal(
                getattr(scoir, leaf), getattr(subs[li], leaf),
                err_msg=f"sub.{leaf} L{li} {ctx} mode={meta.mode}")
    for li in range(n_levels - 1):
        d, u = meta.pairs[li]
        for leaf in ("indices", "bitmask", "mask"):
            np.testing.assert_array_equal(
                getattr(d, leaf), getattr(downs[li], leaf),
                err_msg=f"down.{leaf} L{li} {ctx}")
            np.testing.assert_array_equal(
                getattr(u, leaf), getattr(ups[li], leaf),
                err_msg=f"up.{leaf} L{li} {ctx}")
    assert cap == len(meta.frame_rows)


def _assert_plans_equal(a, b, ctx=""):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"plan treedefs diverged {ctx}"
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"plan leaf {i} {ctx}")


# -- core invariants --------------------------------------------------------


def test_kernel_offsets_reciprocal():
    # the incremental level-0 patch scatters removals/additions into
    # *neighbours'* rows via k -> 26-k; that needs exact offset negation
    assert np.array_equal(OFFS3[::-1], -OFFS3)


def test_updatable_grid_matches_membership():
    rng = np.random.default_rng(0)
    res = 16
    keys = np.sort(rng.choice(res**3, size=120, replace=False)).astype(np.int32)
    rows = rng.permutation(120).astype(np.int32)
    grid = UpdatableSortedGrid(res, keys, rows)
    table = dict(zip(keys.tolist(), rows.tolist()))

    # delete a third, shift by a uniform key offset, insert fresh keys
    drop = np.sort(rng.choice(keys, size=40, replace=False))
    grid.delete(drop)
    for k in drop.tolist():
        del table[k]
    koff = -(4 * res * res)  # ego shift of (-4, 0, 0)
    table = {k + koff: v for k, v in table.items()
             if 0 <= k + koff < res**3}
    oob = np.array([k for k in grid.keys if not 0 <= k + koff < res**3],
                   np.int32)
    grid.delete(oob)
    grid.shift(koff)
    fresh = np.sort(np.setdiff1d(
        rng.choice(res**3, size=50, replace=False),
        np.fromiter(table.keys(), np.int64, len(table)))).astype(np.int32)
    frows = (1000 + np.arange(len(fresh))).astype(np.int32)
    grid.insert(fresh, frows)
    table.update(zip(fresh.tolist(), frows.tolist()))

    q = rng.integers(0, res, (500, 3)).astype(np.int32)
    got = grid.lookup(q, np.ones(500, bool))
    want = np.array([table.get(int((c[0] * res + c[1]) * res + c[2]), -1)
                     for c in q], np.int32)
    np.testing.assert_array_equal(got, want)
    assert np.all(np.diff(grid.keys) > 0)  # stays strictly sorted


def test_diff_scene_basics():
    res, cap = 16, 32
    prev_c = np.full((cap, 3), PAD_COORD, np.int32)
    prev_m = np.zeros(cap, bool)
    prev_c[[2, 5, 7]] = [[4, 4, 4], [5, 4, 4], [1, 0, 0]]
    prev_m[[2, 5, 7]] = True
    new_c = np.full((cap, 3), PAD_COORD, np.int32)
    new_m = np.zeros(cap, bool)
    # after ego shift (1,0,0): (4,4,4)->(3,4,4) retained, (5,4,4)->(4,4,4)
    # retained, (1,0,0)->(0,0,0) dropped; (9,9,9) appears
    new_c[[0, 4, 9]] = [[3, 4, 4], [4, 4, 4], [9, 9, 9]]
    new_m[[0, 4, 9]] = True
    d = diff_scene_np(prev_c, prev_m, new_c, new_m, res, ego_shift=(1, 0, 0))
    assert d.n_prev == 3 and d.n_new == 3
    np.testing.assert_array_equal(np.sort(d.removed_prev_rows), [7])
    np.testing.assert_array_equal(np.sort(d.added_new_rows), [9])
    # retained pairs align: same voxel identity on both sides
    got = {(tuple(new_c[n]), tuple(prev_c[p]))
           for p, n in zip(d.retained_prev_rows, d.retained_new_rows)}
    assert got == {((3, 4, 4), (4, 4, 4)), ((4, 4, 4), (5, 4, 4))}
    assert d.overlap == pytest.approx(2 / 3)
    # out-of-bounds after re-basing counts as removed
    d2 = diff_scene_np(prev_c, prev_m, new_c, new_m, res, ego_shift=(2, 0, 0))
    assert 7 in d2.removed_prev_rows.tolist()


# -- bitwise equality: patched vs from-scratch ------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.0, 0.6), st.integers(0, 2))
def test_patched_meta_bitwise_under_churn(seed, churn, step_ix):
    """Property: every patched frame's metadata is bitwise-equal to a
    from-scratch pyramid built on the canonical packed layout."""
    step = (0, 4, 8)[step_ix]
    frames, shifts = make_lidar_sweep(seed % 100_000, 3, resolution=RES,
                                      capacity=CAP, step=step, churn=churn)
    state = StreamMetaState(RES, CAP, LEVELS)
    for t, ((c, _, _, m), shift) in enumerate(zip(frames, shifts)):
        meta = state.step(c, m, ego_shift=shift)
        pc, pm = _pack_frame(c, m, meta.frame_rows, CAP)
        np.testing.assert_array_equal(state.coords[0], pc)
        np.testing.assert_array_equal(state.mask[0], pm)
        _assert_meta_matches_scratch(
            meta, state, RES, LEVELS,
            ctx=f"t={t} churn={churn:.2f} step={step}")


def test_stream_meta_fallbacks():
    frames, shifts = make_lidar_sweep(3, 2, resolution=RES, capacity=CAP,
                                      step=4, churn=0.05)
    (c0, _, _, m0), (c1, _, _, m1) = [(f[0], f[1], f[2], f[3])
                                      for f in frames]
    # unaligned ego shift (not divisible by 2^(L-1)) -> full rebuild
    state = StreamMetaState(RES, CAP, LEVELS)
    state.step(c0, m0)
    meta = state.step(c1, m1, ego_shift=(3, 0, 0))
    assert meta.mode == "rebuilt"
    assert meta.info["fallback"] == "ego_shift_alignment"
    _assert_meta_matches_scratch(meta, state, RES, LEVELS, "unaligned")

    # empty frame -> rebuild (and a later non-empty frame recovers)
    state = StreamMetaState(RES, CAP, LEVELS)
    state.step(c0, m0)
    empty_c = np.full((CAP, 3), PAD_COORD, np.int32)
    meta = state.step(empty_c, np.zeros(CAP, bool))
    assert meta.mode == "rebuilt" and meta.info["fallback"] == "empty_frame"
    meta = state.step(c1, m1, ego_shift=(4, 0, 0))
    assert meta.mode == "rebuilt"  # base was empty
    _assert_meta_matches_scratch(meta, state, RES, LEVELS, "post-empty")

    # zero overlap (disjoint frame) -> churn fallback, still bitwise-right
    state = StreamMetaState(RES, CAP, LEVELS)
    state.step(c0, m0)
    far_c = np.full((CAP, 3), PAD_COORD, np.int32)
    far_m = np.zeros(CAP, bool)
    far_c[:4] = [[15, 15, 15], [15, 15, 14], [15, 14, 15], [14, 15, 15]]
    far_m[:4] = True
    # make the far frame disjoint from frame 0's active set
    k0 = set(linear_key_np(c0[m0], RES).tolist())
    assert not set(linear_key_np(far_c[:4], RES).tolist()) & k0
    meta = state.step(far_c, far_m)
    assert meta.mode == "rebuilt" and meta.info["fallback"] == "churn"
    assert meta.overlap == 0.0
    _assert_meta_matches_scratch(meta, state, RES, LEVELS, "disjoint")

    # identical frame, no shift -> reused
    state = StreamMetaState(RES, CAP, LEVELS)
    state.step(c0, m0)
    meta = state.step(c0, m0)
    assert meta.mode == "reused" and meta.overlap == 1.0


def test_stream_plan_state_bitwise_and_reuse():
    cfg = UNetConfig(widths=(8, 16, 16), reps=1, resolution=RES,
                     capacity=CAP, n_classes=N_CLASSES)
    frames, shifts = make_lidar_sweep(11, 4, resolution=RES, capacity=CAP,
                                      step=4, churn=0.05)
    state = StreamPlanState(cfg, min_overlap=0.3)
    prev_plan = None
    for fno, ((c, f, _, m), shift) in enumerate(zip(frames, shifts)):
        t = SparseVoxelTensor(c, f.astype(np.float32), m)
        key, plan, frame_rows, info = state.plan_frame(t, fno, shift)
        pc, pm = _pack_frame(c, m, frame_rows, CAP)
        packed = SparseVoxelTensor(pc, np.zeros_like(f), pm)
        want = build_scene_plan_host(packed, cfg, spec=None,
                                     plan_tiles=False)
        _assert_plans_equal(plan, want, ctx=f"frame {fno} ({info['mode']})")
        if fno > 0:
            assert info["mode"] == "patched"
            # untouched levels reuse the previous ConvPlan object outright
            # (that identity is what the device-upload memo keys on)
            shared = sum(a.sub is b.sub for a, b in
                         zip(plan.levels, prev_plan.levels))
            assert shared == 0 or info["overlap"] < 1.0  # sanity only
        prev_plan = plan
    st_agg = state.stats()
    assert st_agg["frames"] == 4 and st_agg["patched"] == 3


def test_stream_feature_packing():
    rng = np.random.default_rng(0)
    frame_rows = np.full(8, -1, np.int32)
    frame_rows[[1, 4, 6]] = [5, 0, 2]
    vals = rng.normal(size=(8, 3)).astype(np.float32)
    out = pack_stream_frame_np(frame_rows, vals)
    assert out.shape == vals.shape
    np.testing.assert_array_equal(out[5], vals[1])
    np.testing.assert_array_equal(out[0], vals[4])
    np.testing.assert_array_equal(out[2], vals[6])
    assert np.all(out[[1, 3, 4, 6, 7]] == 0)


# -- PlanCache LRU bound ----------------------------------------------------


def test_plan_cache_max_entries():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=64,
                     n_classes=N_CLASSES)
    rng = np.random.default_rng(0)

    def scene(i):
        c = np.full((64, 3), PAD_COORD, np.int32)
        m = np.zeros(64, bool)
        pts = rng.choice(RES**3, size=20, replace=False)
        c[:20] = np.stack([pts // (RES * RES), (pts // RES) % RES,
                           pts % RES], 1)
        m[:20] = True
        return SparseVoxelTensor(c, np.ones((64, 2), np.float32), m)

    cache = PlanCache(capacity=2)
    assert cache.max_entries == 2
    for i in range(4):
        cache.get_or_build(scene(i), cfg, device=False, plan_tiles=False)
    assert len(cache._plans) == 2  # LRU-bounded, oldest evicted

    # adopt() (the stream path) honours the same bound
    plan = build_scene_plan_host(scene(0), cfg, plan_tiles=False)
    for i in range(5):
        cache.adopt(f"stream|k{i}", plan, device=False)
    assert len(cache._plans) == 2

    # max_entries overrides capacity; a degenerate bound is rejected
    assert PlanCache(capacity=8, max_entries=3).max_entries == 3
    with pytest.raises(ValueError):
        PlanCache(max_entries=0)


# -- serving layer ----------------------------------------------------------


def test_stream_fifo_admission_under_policy():
    """An urgency policy must not reorder frames *within* a stream."""
    order = []
    sched = WaveScheduler(
        batch=2, plan=lambda r: None,
        dispatch=lambda reqs, p, st: order.extend(r.rid for r in reqs),
        drain=lambda reqs, h: None,
        policy=AdmissionPolicy())
    reqs = []
    for fno, prio in [(0, 0), (1, 5), (2, 10)]:  # later frames more urgent
        r = ServeRequest(fno, priority=prio)
        r._stream_key = "s"
        r._stream_frame = fno
        reqs.append(r)
    loner = ServeRequest(99, priority=7)
    sched.submit(reqs + [loner])
    sched.run()
    assert [rid for rid in order if rid != 99] == [0, 1, 2]
    assert sorted(order) == [0, 1, 2, 99]


def test_skip_frame_unblocks_successors():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    frames, _ = make_lidar_sweep(5, 1, resolution=RES, capacity=CAP)
    c, f, _, m = frames[0]
    t = SparseVoxelTensor(c, f.astype(np.float32), m)
    state = StreamPlanState(cfg, wait_s=30.0)
    state.plan_frame(t, 0)
    state.skip_frame(1)  # what the engine does when admission sheds it
    t0 = time.perf_counter()
    _, _, _, info = state.plan_frame(t, 2)
    assert time.perf_counter() - t0 < 5.0  # no wait_s stall
    # the delta base died with the skipped frame: identical coords must
    # NOT short-circuit to "reused"
    assert info["mode"] == "rebuilt"


def test_serve_stream_end_to_end():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    frames, shifts = make_lidar_sweep(7, 4, resolution=RES, capacity=CAP,
                                      step=4, churn=0.05)
    scenes = [SparseVoxelTensor(jnp.asarray(c), jnp.asarray(f),
                                jnp.asarray(m)) for c, f, _, m in frames]
    eng = SceneEngine(cfg, params, batch=2, sync=True)
    reqs = eng.serve_stream(scenes, shifts)
    modes = [r.plan_info["mode"] for r in reqs]
    assert modes[0] == "rebuilt" and set(modes[1:]) == {"patched"}

    # bitwise vs one-shot serving of the canonical-layout packing (logits
    # are only layout-invariant up to BN rounding, so compare like layouts)
    packed = []
    for (c, f, _, m), r in zip(frames, reqs):
        fr = r._frame_rows
        pc, pm = _pack_frame(c, m, fr, CAP)
        pf = np.zeros_like(f)
        pf[fr[np.flatnonzero(m)]] = f[m]
        packed.append(SparseVoxelTensor(jnp.asarray(pc), jnp.asarray(pf),
                                        jnp.asarray(pm)))
    ref_eng = SceneEngine(cfg, params, batch=2, sync=True)
    handles = ref_eng.submit([SceneRequest(i, t)
                              for i, t in enumerate(packed)])
    ref_eng.serve()
    for h, r in zip(handles, reqs):
        ref = np.asarray(h.result().logits)
        fr = r._frame_rows
        act = fr >= 0
        exp = np.zeros_like(ref)
        exp[act] = ref[fr[act]]
        np.testing.assert_array_equal(exp, np.asarray(r.logits),
                                      err_msg=f"frame {r.frame_no}")
        assert r.done and r.pred is not None

    # per-wave stream notes + handle stats
    noted = [w.notes for w in eng.wave_stats if w.notes]
    assert noted and any(n.get("stream_patched") for n in noted)
    for n in noted:
        assert {"stream_reused", "stream_patched", "stream_rebuilt",
                "stream_overlap", "stream_plan_ms"} <= set(n)
    handle = next(iter(eng._streams.values()))
    agg = handle.stats()
    assert agg["frames"] == 4 and agg["patched"] == 3

    # streams are incompatible with bucketed/sharded modes
    with pytest.raises(ValueError):
        eng.open_stream(stream_id=handle.stream_id)


def test_serve_stream_async_matches_sync():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    frames, shifts = make_lidar_sweep(9, 4, resolution=RES, capacity=CAP,
                                      step=4, churn=0.1)
    scenes = [SparseVoxelTensor(jnp.asarray(c), jnp.asarray(f),
                                jnp.asarray(m)) for c, f, _, m in frames]
    by_sync = SceneEngine(cfg, params, batch=2, sync=True).serve_stream(
        scenes, shifts)
    by_async = SceneEngine(cfg, params, batch=2, sync=False, depth=2,
                           planner_threads=2).serve_stream(scenes, shifts)
    for a, b in zip(by_sync, by_async):
        np.testing.assert_array_equal(np.asarray(a.logits),
                                      np.asarray(b.logits))
        assert a.plan_info["mode"] == b.plan_info["mode"]


def test_concurrent_streams_are_independent():
    """Two interleaved streams keep separate delta bases and both stay
    bitwise-correct (the planner threads gate frames per stream)."""
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    fa, sa = make_lidar_sweep(21, 3, resolution=RES, capacity=CAP,
                              step=4, churn=0.05)
    fb, sb = make_lidar_sweep(22, 3, resolution=RES, capacity=CAP,
                              step=8, churn=0.2)
    state_a = StreamPlanState(cfg, stream_id="a")
    state_b = StreamPlanState(cfg, stream_id="b")
    results = {}

    def drive(state, frames, shifts, tag):
        for fno, ((c, f, _, m), shift) in enumerate(zip(frames, shifts)):
            t = SparseVoxelTensor(c, f.astype(np.float32), m)
            out = state.plan_frame(t, fno, shift)
            results[(tag, fno)] = (out, c, m)

    th = [threading.Thread(target=drive, args=(state_a, fa, sa, "a")),
          threading.Thread(target=drive, args=(state_b, fb, sb, "b"))]
    for x in th:
        x.start()
    for x in th:
        x.join()
    for (tag, fno), ((key, plan, frame_rows, info), c, m) in results.items():
        pc, pm = _pack_frame(c, m, frame_rows, CAP)
        packed = SparseVoxelTensor(pc, np.zeros((CAP, 4), np.float32), pm)
        want = build_scene_plan_host(packed, cfg, spec=None,
                                     plan_tiles=False)
        _assert_plans_equal(plan, want, ctx=f"stream {tag} frame {fno}")
        assert key.startswith(f"stream|{tag}|")
