"""engine.autotune: measured cost tables, persistent cache, re-profiling."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.engine.autotune import (
    CostTable,
    Measurement,
    ShapeSig,
    _bin_density,
    autotune_block_n,
    density_bin,
    measure,
    measure_backends,
    profile_group,
    reprofile,
    seed_cost_table,
    signature,
)
from repro.engine.plan import REFERENCE_DISPATCH, Dispatch
from repro.models.scn import UNetConfig, init_unet
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.sparse.tensor import SparseVoxelTensor

RES, CAP = 24, 2048
BUDGET = 16 * 1024  # small L1 budget: SPADE picks an actual tiling


def _scene(seed):
    coords, feats, labels, mask = make_scene(seed, resolution=RES,
                                             capacity=CAP)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


@pytest.fixture(scope="module")
def setup():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    return cfg, params, _scene(0)


# -- timing harness ----------------------------------------------------------

def test_measure_median_of_k():
    calls = []
    m = measure(lambda: calls.append(1), warmup=2, k=5)
    assert isinstance(m, Measurement)
    assert len(calls) == 7  # warmup included
    assert m.k == 5 and len(m.times_us) == 5
    assert m.times_us == tuple(sorted(m.times_us))
    assert m.median_us == m.times_us[2]
    assert m.spread_us >= 0.0


def test_time_fn_wraps_measure():
    from benchmarks.common import time_fn
    assert time_fn(lambda: 1 + 1, iters=2) > 0.0


# -- signatures --------------------------------------------------------------

def test_signature_buckets_and_roundtrip():
    a = signature(1800, 1700, 16, 16, density=0.011, backend="sspnna",
                  block_n=8)
    b = signature(2048, 1025, 16, 16, density=0.02, backend="sspnna",
                  block_n=8)
    # row counts bucket to powers of two, densities to log-spaced bins
    assert a == b
    assert a.group() == signature(1100, 1030, 16, 16, density=0.015)
    assert ShapeSig.decode(a.encode()) == a
    with pytest.raises(ValueError):
        ShapeSig.decode("1:2:3")
    assert density_bin(0.0) == 0 and density_bin(1.0) == len(
        (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1))
    for b_ in range(9):
        assert density_bin(_bin_density(b_)) == b_


# -- persistence -------------------------------------------------------------

def _filled_table():
    t = CostTable(fingerprint="test-rig")
    t.record(signature(500, 500, 8, 8, density=0.05, backend="reference"),
             100.0, k=3)
    t.record(signature(500, 500, 8, 8, density=0.05, backend="sspnna"),
             50.0, delta_o=32, delta_i=123, k=3)
    return t


def test_cache_round_trip(tmp_path):
    t = _filled_table()
    path = t.save(str(tmp_path / "sub" / "autotune.json"))
    back = CostTable.load(path, fingerprint="test-rig")
    assert back.load_status == "ok"
    assert len(back) == len(t) == 2
    assert back.generation == t.generation
    best = back.best(signature(512, 512, 8, 8, density=0.05))
    assert best.sig.backend == "sspnna"
    assert (best.delta_o, best.delta_i) == (32, 123)


def test_cache_missing_and_corrupt(tmp_path):
    missing = CostTable.load(str(tmp_path / "nope.json"), fingerprint="x")
    assert missing.load_status == "missing" and len(missing) == 0

    bad = tmp_path / "bad.json"
    bad.write_text("{truncated")
    t = CostTable.load(str(bad), fingerprint="x")
    assert t.load_status == "corrupt" and len(t) == 0

    # valid JSON, garbled entries: also falls back to an empty table
    payload = _filled_table().to_payload()
    payload["entries"][0]["sig"] = "not-a-sig"
    bad.write_text(json.dumps(payload))
    t = CostTable.load(str(bad), fingerprint="test-rig")
    assert t.load_status == "corrupt" and len(t) == 0


def test_cache_version_and_fingerprint_mismatch(tmp_path):
    src = _filled_table()
    path = src.save(str(tmp_path / "autotune.json"))

    t = CostTable.load(path, fingerprint="another-machine")
    assert t.load_status == "fingerprint-mismatch" and len(t) == 0

    payload = json.loads(open(path).read())
    payload["plan_version"] = -999
    open(path, "w").write(json.dumps(payload))
    t = CostTable.load(path, fingerprint="test-rig")
    assert t.load_status == "version-mismatch" and len(t) == 0

    payload["plan_version"] = -999
    payload["schema"] = "something-else"
    open(path, "w").write(json.dumps(payload))
    t = CostTable.load(path, fingerprint="test-rig")
    assert t.load_status == "version-mismatch" and len(t) == 0


def test_env_override_cache_path(monkeypatch, tmp_path):
    from repro.engine.autotune import default_cache_path
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "x.json"))
    assert default_cache_path() == str(tmp_path / "x.json")
    assert _filled_table().save() == str(tmp_path / "x.json")


# -- dispatch consult --------------------------------------------------------

def test_adjust_dispatch_cold_is_identity_and_records_miss():
    t = CostTable(fingerprint="f")
    analytical = Dispatch("sspnna", "CIRF", "OS", 32, 123, 4)
    out = t.adjust_dispatch(analytical, n_in=500, n_out=500, c_in=8,
                            c_out=8, density=0.05)
    assert out is analytical  # bitwise-identical: the very same object
    assert t.miss_count == 1
    (gk, m), = t.hottest_misses()
    assert (m["delta_o"], m["delta_i"], m["backend"]) == (32, 123, "sspnna")


def test_adjust_dispatch_flips_both_ways():
    t = CostTable(fingerprint="f")
    t.record(signature(500, 500, 8, 8, density=0.05, backend="reference"),
             50.0)
    t.record(signature(500, 500, 8, 8, density=0.05, backend="sspnna"),
             100.0, delta_o=32, delta_i=123)
    analytical = Dispatch("sspnna", "CIRF", "OS", 32, 123, 4)
    out = t.adjust_dispatch(analytical, n_in=500, n_out=500, c_in=8,
                            c_out=8, density=0.05)
    assert out == REFERENCE_DISPATCH  # measured: reference wins

    # flip the measurement: sspnna now cheaper -> reference flips to tiled
    t.record(signature(500, 500, 8, 8, density=0.05, backend="sspnna"),
             10.0, delta_o=16, delta_i=64)
    out = t.adjust_dispatch(REFERENCE_DISPATCH, n_in=500, n_out=500,
                            c_in=8, c_out=8, density=0.05)
    assert out.backend == "sspnna"
    assert (out.delta_o, out.delta_i) == (16, 64)

    # same-backend win with a measured block_n: adopted when unpinned
    t2 = CostTable(fingerprint="f")
    t2.record(signature(500, 500, 8, 8, density=0.05, backend="sspnna",
                        block_n=8), 10.0, delta_o=16, delta_i=64)
    got = t2.adjust_dispatch(analytical, n_in=500, n_out=500, c_in=8,
                             c_out=8, density=0.05)
    assert got.block_n == 8 and got.backend == "sspnna"


def test_winner_flip_bumps_generation_and_invalidates_plan_cache():
    t = CostTable(fingerprint="f")
    ctx = engine.ExecutionContext(autotune=t)
    ctx.plan_cache._plans["k"] = {"host": None, "device": None}
    r0 = repr(t)
    sig_r = signature(500, 500, 8, 8, density=0.05, backend="reference")
    sig_s = signature(500, 500, 8, 8, density=0.05, backend="sspnna")
    assert t.record(sig_r, 100.0) is False  # first entry, no prior miss
    assert ctx.plan_cache.invalidations == 0
    assert t.record(sig_s, 50.0) is True    # winner flips
    assert t.generation == 1 and repr(t) != r0
    assert ctx.plan_cache.invalidations == 1
    assert len(ctx.plan_cache._plans) == 0
    # cheaper same-winner sample: no flip, no invalidation
    assert t.record(sig_s, 40.0) is False
    assert ctx.plan_cache.invalidations == 1


def test_first_measurement_after_miss_counts_as_flip():
    t = CostTable(fingerprint="f")
    d = t.adjust_dispatch(REFERENCE_DISPATCH, n_in=500, n_out=500, c_in=8,
                          c_out=8, density=0.05)
    assert d == REFERENCE_DISPATCH and t.miss_count == 1
    flipped = t.record(
        signature(500, 500, 8, 8, density=0.05, backend="reference"), 9.0)
    assert flipped is True  # plans were built on the analytical fallback
    assert t.miss_count == 0


# -- plan-build integration --------------------------------------------------

def test_cold_table_builds_bitwise_identical_plans(setup):
    cfg, params, t = setup
    table = CostTable(fingerprint="f")
    p0 = engine.build_scene_plan_host(t, cfg, mem_budget=BUDGET)
    p1 = engine.build_scene_plan_host(t, cfg, mem_budget=BUDGET,
                                      autotune=table)
    l0 = jax.tree_util.tree_leaves(p0)
    l1 = jax.tree_util.tree_leaves(p1)
    assert len(l0) == len(l1)
    for a, b in zip(l0, l1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [lvl.sub.dispatch for lvl in p0.levels] == \
           [lvl.sub.dispatch for lvl in p1.levels]
    assert table.miss_count > 0  # the consults were recorded

    s0 = engine.build_plan_spec([t], cfg, mem_budget=BUDGET)
    s1 = engine.build_plan_spec([t], cfg, mem_budget=BUDGET, autotune=table)
    assert s0 == s1


def test_measured_winner_redirects_adaptive_build(setup):
    cfg, params, t = setup
    table = CostTable(fingerprint="f")
    base = engine.build_scene_plan_host(t, cfg, mem_budget=BUDGET)
    assert any(lvl.sub.dispatch.backend == "sspnna" for lvl in base.levels)
    # measure "reference" as the across-the-board winner for every level
    for li, lvl in enumerate(base.levels):
        n = int(np.asarray(lvl.mask).sum())
        den = n / float(max(cfg.resolution >> li, 1)) ** 3
        c = cfg.widths[li]
        table.record(signature(n, n, c, c, density=den,
                               backend="reference"), 1.0)
        table.record(signature(n, n, c, c, density=den, backend="sspnna"),
                     100.0, delta_o=32, delta_i=123)
    tuned = engine.build_scene_plan_host(t, cfg, mem_budget=BUDGET,
                                         autotune=table)
    assert all(lvl.sub.dispatch.backend == "reference"
               for lvl in tuned.levels)
    assert all(lvl.sub.tiles is None for lvl in tuned.levels)
    assert table.hits >= len(tuned.levels)
    # and the tuned plan still computes the same conv
    ref = engine.apply_unet(params, t.feats,
                            engine.upload_scene_plan(base),
                            backend="reference")
    got = engine.apply_unet(params, t.feats,
                            engine.upload_scene_plan(tuned),
                            backend="auto")
    m = np.asarray(t.mask)
    np.testing.assert_allclose(np.asarray(got)[m], np.asarray(ref)[m],
                               rtol=1e-4, atol=1e-4)


# -- profiling ---------------------------------------------------------------

def test_measure_backends_walks_registry(setup):
    cfg, params, t = setup
    plan = engine.build_scene_plan(t, cfg, mem_budget=BUDGET)
    lvl = next(lvl for lvl in plan.levels
               if lvl.sub.dispatch.backend == "sspnna")
    times = measure_backends(lvl.sub, t.feats, params["stem"], k=1)
    assert set(times) >= {"reference", "sspnna"}
    assert all(m.median_us > 0 for m in times.values())


def test_profile_group_resolves_miss():
    table = CostTable(fingerprint="f")
    sig = signature(256, 256, 8, 8, density=0.05)
    table.note_miss(sig, delta_o=32, delta_i=123, backend="sspnna")
    results = profile_group(table, sig, delta_o=32, delta_i=123, k=1)
    assert set(results) >= {"reference", "sspnna"}
    assert table.miss_count == 0 and len(table) >= 2
    assert table.best(sig) is not None


def test_profile_group_unsynthesizable_drops_miss():
    table = CostTable(fingerprint="f")
    sig = ShapeSig(0, 0, 8, 8, 27, 3)  # zero rows: cannot be realized
    table.note_miss(sig)
    assert profile_group(table, sig) == {}
    assert table.miss_count == 0 and len(table) == 0


def test_reprofile_budget_gates():
    table = CostTable(fingerprint="f")
    table.note_miss(signature(256, 256, 8, 8, density=0.05),
                    delta_o=32, delta_i=123)
    assert reprofile(table, budget_ms=0.0) == 0  # off by default
    assert table.miss_count == 1
    done = reprofile(table, budget_ms=60_000.0, max_sigs=1, k=1)
    assert done == 1
    assert table.miss_count == 0 and len(table) >= 2


# -- serving idle-gap hook ---------------------------------------------------

def test_scene_engine_idle_hook_reprofiles(setup):
    cfg, params, t = setup
    table = CostTable(fingerprint="f")
    table.note_miss(signature(256, 256, 8, 8, density=0.05),
                    delta_o=32, delta_i=123, backend="sspnna")
    ctx = engine.ExecutionContext(autotune=table,
                                  autotune_reprofile_ms=60_000.0)
    eng = SceneEngine(cfg, params, batch=1, ctx=ctx)
    try:
        eng.submit([SceneRequest(0, t)])
        eng.serve()
    finally:
        eng.close()
    assert eng.scheduler.idle_ticks >= 1
    assert table.miss_count == 0 and len(table) >= 2  # profiled in the gap


def test_scene_engine_default_installs_no_idle_hook(setup):
    cfg, params, t = setup
    # budget 0 (the default): no hook, even with a table on the context
    ctx = engine.ExecutionContext(autotune=CostTable(fingerprint="f"))
    eng = SceneEngine(cfg, params, batch=1, ctx=ctx)
    try:
        assert eng.scheduler.on_idle is None
    finally:
        eng.close()
    eng2 = SceneEngine(cfg, params, batch=1)
    try:
        assert eng2.scheduler.on_idle is None
    finally:
        eng2.close()


# -- seeding from bench artifacts -------------------------------------------

def test_seed_cost_table(tmp_path):
    rows = [
        # canonical: bench_dispatch row with an explicit sig token
        {"name": "dispatch/r16_c8_reference", "us_per_call": 1000.0,
         "derived": "sig=512:512:8:8:27:7:reference:0 delta_o=128 "
                    "delta_i=225 spread_us=3.0"},
        # bench_sspnna sweep rows: fused -> sspnna, xla -> reference
        {"name": "sspnna/r24_c16_fused", "us_per_call": 900.0,
         "derived": "density=0.0750 T=12 alive=9 dO=32 dI=128 C=16 N=16 "
                    "modeled_hbm_mb=0.50"},
        {"name": "sspnna/r24_c16_xla", "us_per_call": 400.0,
         "derived": "density=0.0750 T=12 alive=9 dO=32 dI=128 C=16 N=16 "
                    "modeled_hbm_mb=0.75"},
        # skipped: no engine backend corresponds to the pre-gathered arm
        {"name": "sspnna/r24_c16_pregathered", "us_per_call": 1800.0,
         "derived": "density=0.0750 dO=32 dI=128 C=16 N=16"},
        # skipped: analytical row
        {"name": "tableIII/L2-like/uops_saving", "us_per_call": 0.0,
         "derived": "512x"},
    ]
    art = tmp_path / "BENCH_x.json"
    art.write_text(json.dumps({"schema": "bench-rows/v1", "rows": rows}))
    table = CostTable(fingerprint="f")
    n = seed_cost_table(table, [str(art), str(tmp_path / "missing.json")])
    assert n == 3 and len(table) == 3
    # the sspnna sweep rows land in one group; xla (reference) wins it
    n_active = round(0.075 * 24 ** 3)
    best = table.best(signature(n_active, n_active, 16, 16, density=0.075))
    assert best.sig.backend == "reference"
    d = table.adjust_dispatch(
        Dispatch("sspnna", "CIRF", "OS", 32, 128, 4),
        n_in=n_active, n_out=n_active, c_in=16, c_out=16, density=0.075)
    assert d == REFERENCE_DISPATCH


# -- moved block_n sweep -----------------------------------------------------

def test_autotune_block_n_moved_to_engine():
    bn = autotune_block_n(4, 8, 4, 16, n_tiles=2, iters=1)
    assert 8 % bn == 0 or bn == 8


def test_benchmarks_common_shim_warns():
    import benchmarks.common as common
    with pytest.warns(DeprecationWarning, match="deprecated.*repro.engine"):
        bn = common.autotune_block_n(4, 8, 4, 16, n_tiles=2, iters=1)
    assert 8 % bn == 0 or bn == 8
