"""Per-arch smoke tests (required): reduced config, one forward/train step on
CPU, output shapes + no NaNs; plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models.transformer import (
    decode_step,
    forward,
    init_lm,
    lm_loss,
)


def _inputs(cfg, rng, b, s):
    kw = {}
    if cfg.frontend == "vision":
        kw["frontend_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32)
    if cfg.is_encdec:
        kw["enc_frames"] = jnp.asarray(
            rng.normal(size=(b, 32, cfg.d_model)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return toks, kw


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    b, s = 2, 64
    toks, kw = _inputs(cfg, rng, b, s)
    logits, _, _ = jax.jit(
        lambda p, t: forward(p, cfg, t, mode="train", **kw))(params, toks)
    assert logits.shape == (b, s, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits)))

    def loss_fn(p):
        lg, _, _ = forward(p, cfg, toks, mode="train", **kw)
        return lm_loss(lg, toks, cfg)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_decode_matches_forward(arch, rng):
    cfg = get_config(arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    b, s, extra = 2, 48, 3
    toks, kw = _inputs(cfg, rng, b, s + extra)
    full, _, _ = forward(params, cfg, toks, mode="train", **kw)
    _, cache, _ = forward(params, cfg, toks[:, :s], mode="prefill",
                          cache_pad=extra, **kw)
    for i in range(extra):
        logit, cache = decode_step(params, cfg, toks[:, s + i:s + i + 1], cache)
        err = float(jnp.max(jnp.abs(logit[:, 0] - full[:, s + i])))
        assert err < 5e-2, (arch, i, err)


def test_vocab_padding_masked(rng):
    import dataclasses
    # full seamless config pads 256206 -> 256256
    full = get_config("seamless-m4t-medium")
    assert full.vocab_padded == 256256 and full.vocab_padded % 16 == 0
    # force an unaligned vocab on the reduced config to exercise masking
    cfg = dataclasses.replace(get_config("seamless-m4t-medium").reduced(),
                              vocab_size=509)
    assert cfg.vocab_padded > cfg.vocab_size
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks, kw = _inputs(cfg, rng, 2, 16)
    logits, _, _ = forward(params, cfg, toks, mode="train", **kw)
    pad_logits = np.asarray(logits[..., cfg.vocab_size:])
    assert (pad_logits < -1e20).all()


def test_gemma2_softcap_bounds_logits(rng):
    cfg = get_config("gemma2-2b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks, kw = _inputs(cfg, rng, 2, 32)
    logits, _, _ = forward(params, cfg, toks, mode="train", **kw)
    real = np.asarray(logits[..., :cfg.vocab_size])
    assert np.abs(real).max() <= cfg.final_softcap + 1e-3


def test_moe_aux_losses_present(rng):
    cfg = get_config("llama4-maverick-400b-a17b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks, kw = _inputs(cfg, rng, 2, 32)
    _, _, aux = forward(params, cfg, toks, mode="train")
    assert {"moe_lb_loss", "moe_z_loss", "moe_dropped"} <= set(aux)
    assert float(aux["moe_lb_loss"]) > 0


def test_moe_a2a_dispatch_matches_gather(rng):
    """The a2a exchange is a layout permutation: numerics must be identical
    to the collective-free group-local gather (ROADMAP hillclimb arm)."""
    from repro.dist.compat import make_mesh
    from repro.models.moe import apply_moe, init_moe

    n = len(jax.devices())  # 4 virtual CPU devices (conftest)
    mesh = make_mesh((n,), ("model",))
    e = 8 if 8 % n == 0 else 8 * n
    params = init_moe(jax.random.PRNGKey(0), 32, 64, e, "swiglu", jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, 64, 32)), jnp.float32)
    out_g, aux_g = apply_moe(params, x, top_k=2, capacity=24, act="swiglu")
    out_a, aux_a = apply_moe(params, x, top_k=2, capacity=24, act="swiglu",
                             mesh=mesh, dispatch="a2a")
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_a),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_g["moe_dropped"]),
                               float(aux_a["moe_dropped"]))
    with pytest.raises(ValueError):
        apply_moe(params, x, top_k=2, capacity=24, act="swiglu",
                  dispatch="a2a")  # a2a without a mesh
