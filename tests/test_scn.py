"""SCN U-Net end-to-end: the paper's own workload."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.models.scn import (
    UNetConfig,
    init_unet,
    miou,
    segmentation_loss,
)
from repro.sparse.tensor import SparseVoxelTensor


def _setup(res=24, cap=3000):
    coords, feats, labels, mask = make_scene(0, resolution=res, capacity=cap)
    t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                          jnp.asarray(mask))
    cfg = UNetConfig(widths=(8, 16, 24), reps=1, resolution=res,
                     capacity=cap, n_classes=N_CLASSES)
    plan = engine.build_scene_plan(t, cfg, plan_tiles=False)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    return cfg, t, plan, params, jnp.asarray(labels)


def test_unet_forward_shapes_no_nan():
    cfg, t, plan, params, labels = _setup()
    logits = jax.jit(
        lambda p, x: engine.apply_unet(p, x, plan))(params, t.feats)
    assert logits.shape == (t.capacity, cfg.n_classes)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_unet_learns_scene():
    cfg, t, plan, params, labels = _setup()

    def loss_fn(p):
        l, acc = segmentation_loss(engine.apply_unet(p, t.feats, plan),
                                   labels, t.mask)
        return l, acc

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
    losses = []
    for _ in range(15):
        (l, acc), g = grad_fn(params)
        params = jax.tree.map(lambda p, gr: p - 0.3 * gr, params, g)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.5
    pred = np.asarray(
        jnp.argmax(engine.apply_unet(params, t.feats, plan), -1))
    m = miou(pred, np.asarray(labels), np.asarray(t.mask), cfg.n_classes)
    assert m > 0.15


def test_scene_generator_properties():
    coords, feats, labels, mask = make_scene(3, resolution=32, capacity=6000)
    n = mask.sum()
    assert n > 500
    occ_frac = n / 32**3
    assert occ_frac < 0.2  # spatially sparse (surfaces)
    assert set(np.unique(labels[mask])) <= set(range(N_CLASSES))
    # deterministic
    c2, f2, l2, m2 = make_scene(3, resolution=32, capacity=6000)
    np.testing.assert_array_equal(coords, c2)
