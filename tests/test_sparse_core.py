"""Core sparse-3D stack: AdMAC neighbours, COIR, sparse conv vs dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_shell_scene
from repro.core import sparse_conv as sc
from repro.core.coir import (
    build_cirf,
    build_corf,
    coir_size_words,
    rulebook_size_words,
    transpose_flavor,
)
from repro.core.hashgrid import (
    build_neighbor_table,
    downsample_coords,
    kernel_offsets,
)
from repro.sparse.tensor import from_dense, to_dense
from repro.sparse.voxelize import voxelize


@pytest.fixture
def scene(rng):
    dense = make_shell_scene(rng, 20, 5)
    return dense, from_dense(dense)


def test_neighbor_table_vs_bruteforce(rng):
    R = 12
    coords = rng.integers(0, R, (60, 3)).astype(np.int32)
    coords = np.unique(coords, axis=0)
    v = len(coords)
    mask = np.ones(v, bool)
    offs = kernel_offsets(3)
    table = np.asarray(build_neighbor_table(
        jnp.asarray(coords), jnp.asarray(mask), jnp.asarray(offs), R))
    lut = {tuple(c): i for i, c in enumerate(coords)}
    for i in range(v):
        for k, off in enumerate(offs):
            probe = tuple(coords[i] + off)
            expect = lut.get(probe, -1)
            if any(p < 0 or p >= R for p in probe):
                expect = -1
            assert table[i, k] == expect, (i, k, probe)


def test_kernel_offsets_conventions():
    o3 = kernel_offsets(3)
    assert o3.shape == (27, 3) and o3.min() == -1 and o3.max() == 1
    o2 = kernel_offsets(2)
    assert o2.shape == (8, 3) and o2.min() == 0 and o2.max() == 1


def test_downsample_unique_sorted(scene):
    dense, t = scene
    out_c, out_m = downsample_coords(t.coords, t.mask, 20, 2)
    out_c, out_m = np.asarray(out_c), np.asarray(out_m)
    act = out_c[out_m]
    assert len(np.unique(act, axis=0)) == len(act)
    expect = np.unique(np.asarray(t.coords)[np.asarray(t.mask)] // 2, axis=0)
    assert len(act) == len(expect)


def test_submanifold_conv_matches_dense_oracle(rng, scene):
    dense, t = scene
    params = sc.init_sparse_conv(jax.random.PRNGKey(0), 27, 5, 7)
    coir = sc.submanifold_coir(t, 20, 3)
    out = sc.submanifold_conv(t, coir, params)
    oracle = sc.dense_submanifold_reference(
        dense, np.asarray(params.weight), np.asarray(params.bias))
    np.testing.assert_allclose(to_dense(out, 20), oracle, rtol=1e-4, atol=1e-4)


def test_corf_equals_cirf(scene):
    dense, t = scene
    params = sc.init_sparse_conv(jax.random.PRNGKey(1), 27, 5, 6)
    offs = jnp.asarray(kernel_offsets(3))
    cirf = build_cirf(t.coords, t.mask, t.coords, t.mask, offs, 20)
    corf = build_corf(t.coords, t.mask, t.coords, t.mask, offs, 20)
    out_cirf = sc.reference_conv_cirf(t.feats, cirf, params)
    out_corf = sc.sparse_conv_corf(t.feats, corf, params, t.capacity)
    np.testing.assert_allclose(np.asarray(out_corf), np.asarray(out_cirf),
                               rtol=1e-4, atol=1e-4)
    # transpose_flavor reproduces build_corf for submanifold metadata
    np.testing.assert_array_equal(
        np.asarray(transpose_flavor(cirf, t.capacity).indices),
        np.asarray(corf.indices))


def test_strided_and_transposed_conv(rng, scene):
    dense, t = scene
    p_dn = sc.init_sparse_conv(jax.random.PRNGKey(2), 8, 5, 6)
    down, r2, _ = sc.strided_conv(t, 20, p_dn)
    assert r2 == 10
    # oracle
    offs = kernel_offsets(2, centered=False)
    occ = np.any(dense != 0, axis=-1)
    exp = np.zeros((10, 10, 10, 6), np.float32)
    occ_o = np.zeros((10, 10, 10), bool)
    for ki, (dx, dy, dz) in enumerate(offs):
        exp += (dense[dx::2, dy::2, dz::2].astype(np.float32)
                @ np.asarray(p_dn.weight)[ki])
        occ_o |= occ[dx::2, dy::2, dz::2]
    exp = (exp + np.asarray(p_dn.bias)) * occ_o[..., None]
    np.testing.assert_allclose(to_dense(down, 10), exp, rtol=1e-4, atol=1e-4)
    # transposed conv restores the fine active set
    p_up = sc.init_sparse_conv(jax.random.PRNGKey(3), 8, 6, 5)
    coir_t = sc.transposed_coir(down, t.coords, t.mask, 20)
    up = sc.transposed_conv(down, coir_t, t.coords, t.mask, p_up)
    assert bool(jnp.all(up.mask == t.mask))
    assert not bool(jnp.any(jnp.isnan(up.feats)))


def test_coir_compression_accounting(scene):
    dense, t = scene
    coir = sc.submanifold_coir(t, 20, 3)
    cw, rw = int(coir_size_words(coir)), int(rulebook_size_words(coir))
    arf = float(coir.arf())
    # COIR beats the rulebook whenever ARF > 2 (paper's compression claim)
    if arf > 2.5:
        assert cw < rw


def test_voxelize_roundtrip(rng):
    pts = rng.random((500, 3)).astype(np.float32)
    feats = rng.normal(size=(500, 3)).astype(np.float32)
    coords, vf, mask = voxelize(pts, feats, 16, capacity=600)
    n = mask.sum()
    assert n > 0
    act = coords[mask]
    assert act.min() >= 0 and act.max() < 16
    assert len(np.unique(
        (act[:, 0] * 16 + act[:, 1]) * 16 + act[:, 2])) == n
