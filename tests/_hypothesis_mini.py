"""Deterministic mini-shim for the slice of hypothesis the suite uses.

The property tests prefer real hypothesis (CI installs it); on images
without it this shim keeps them running instead of dying at collection.
It draws ``max_examples`` pseudo-random samples per test from a seed
derived from the test name, biased toward the strategy boundaries (where
off-by-ones live). Supported surface: ``given``, ``settings`` with
``max_examples``/``deadline``, and ``strategies.integers/floats/lists``.
"""
from __future__ import annotations

import inspect
import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25
_BOUNDARY_P = 0.15


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


def _integers(min_value, max_value):
    def draw(rng):
        if rng.random() < _BOUNDARY_P:
            return int(min_value if rng.random() < 0.5 else max_value)
        return int(rng.integers(min_value, max_value + 1))

    return _Strategy(draw)


def _floats(min_value, max_value):
    def draw(rng):
        if rng.random() < _BOUNDARY_P:
            return float(min_value if rng.random() < 0.5 else max_value)
        # log-uniform when the range spans decades, like hypothesis explores
        if min_value > 0 and max_value / min_value > 1e3:
            lo, hi = np.log(min_value), np.log(max_value)
            return float(np.exp(rng.uniform(lo, hi)))
        return float(rng.uniform(min_value, max_value))

    return _Strategy(draw)


def _lists(elements, *, min_size=0, max_size=20):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw)


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, lists=_lists)


def settings(**kwargs):
    max_examples = kwargs.get("max_examples", _DEFAULT_MAX_EXAMPLES)

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for i in range(n):
                drawn = tuple(s.draw(rng) for s in strats)
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} falsified on example {i}: "
                        f"{drawn!r}") from e

        # hide the strategy params from pytest's fixture resolution
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = inspect.Signature()
        wrapper._max_examples = getattr(fn, "_max_examples",
                                        _DEFAULT_MAX_EXAMPLES)
        return wrapper

    return deco
