"""repro.engine: plan building, backend dispatch, shims, batched serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.models import scn
from repro.models.scn import UNetConfig, init_unet
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.sparse.tensor import SparseVoxelTensor

RES, CAP = 24, 2048
# small L1 budget so SPADE picks an actual tiling (sspnna) on these scenes
BUDGET = 16 * 1024


def _scene(seed):
    coords, feats, labels, mask = make_scene(seed, resolution=RES, capacity=CAP)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


@pytest.fixture(scope="module")
def setup():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    t = _scene(0)
    plan = engine.build_scene_plan(t, cfg, mem_budget=BUDGET)
    return cfg, params, t, plan


def test_backends_agree_on_unet(setup):
    cfg, params, t, plan = setup
    # the plan must actually exercise the tiled path for this to mean much
    assert any(lvl.sub.tiles is not None for lvl in plan.levels)
    ref = engine.apply_unet(params, t.feats, plan, backend="reference")
    ssp = engine.apply_unet(params, t.feats, plan, backend="sspnna",
                            use_kernel=True)
    m = np.asarray(t.mask)
    np.testing.assert_allclose(np.asarray(ref)[m], np.asarray(ssp)[m],
                               rtol=1e-4, atol=1e-4)


def test_auto_follows_spade_plan(setup):
    cfg, params, t, plan = setup
    for lvl in plan.levels:
        assert engine.resolve_backend(lvl.sub, "auto") == lvl.sub.dispatch.backend
        # resolution-changing convs stay on the coarse reference dispatch
        for cp in (lvl.down, lvl.up):
            if cp is not None:
                assert engine.resolve_backend(cp, "auto") == engine.REFERENCE
    auto = engine.apply_unet(params, t.feats, plan, backend="auto",
                             use_kernel=False)
    ref = engine.apply_unet(params, t.feats, plan, backend="reference")
    m = np.asarray(t.mask)
    np.testing.assert_allclose(np.asarray(auto)[m], np.asarray(ref)[m],
                               rtol=1e-4, atol=1e-4)


def test_single_conv_pallas_backend_agrees(setup):
    cfg, params, t, plan = setup
    lvl0 = plan.levels[0]
    assert lvl0.sub.dispatch.backend == engine.SSPNNA
    ref = engine.sparse_conv(t.feats, params["stem"], lvl0.sub,
                             backend="reference")
    ssp = engine.sparse_conv(t.feats, params["stem"], lvl0.sub,
                             backend="sspnna", use_kernel=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ssp),
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        engine.sparse_conv(t.feats, params["stem"], lvl0.sub, backend="bogus")


def test_plan_cache_hits_by_scene_content(setup):
    cfg, params, t, plan = setup
    cache = engine.PlanCache(capacity=4)
    p1 = cache.get_or_build(t, cfg, plan_tiles=False)
    p2 = cache.get_or_build(_scene(0), cfg, plan_tiles=False)  # same content
    assert p1 is p2 and cache.hits == 1 and cache.misses == 1
    cache.get_or_build(_scene(1), cfg, plan_tiles=False)
    assert cache.misses == 2


def test_deprecated_shims_numerically_identical(setup):
    cfg, params, t, plan = setup
    with pytest.warns(DeprecationWarning):
        meta = scn.build_unet_metadata(t, cfg)
    with pytest.warns(DeprecationWarning):
        old = scn.apply_unet(params, t.feats, meta)
    new = engine.apply_unet(
        params, t.feats, engine.build_scene_plan(t, cfg, plan_tiles=False),
        backend="reference")
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))

    from repro.core.sparse_conv import reference_conv_cirf, sparse_conv_cirf
    with pytest.warns(DeprecationWarning):
        old_conv = sparse_conv_cirf(t.feats, plan.levels[0].sub.coir,
                                    params["stem"])
    np.testing.assert_array_equal(
        np.asarray(old_conv),
        np.asarray(reference_conv_cirf(t.feats, plan.levels[0].sub.coir,
                                       params["stem"])))

    from repro.core.tiles import build_tile_plan
    from repro.kernels.sspnna.ops import sspnna_conv_from_plan
    lvl0 = plan.levels[0]
    tp = build_tile_plan(np.asarray(lvl0.sub.coir.indices),
                         np.flatnonzero(np.asarray(t.mask)), 64, 256)
    with pytest.warns(DeprecationWarning):
        old_tiled = sspnna_conv_from_plan(
            t.feats, params["stem"].weight, tp, n_out=CAP, use_kernel=False)
    ref = np.asarray(reference_conv_cirf(t.feats, lvl0.sub.coir,
                                         params["stem"]))
    got = np.asarray(old_tiled) + np.asarray(params["stem"].bias)
    m = np.asarray(t.mask)
    np.testing.assert_allclose(got[m], ref[m], rtol=1e-4, atol=1e-4)


def test_scene_engine_serves_batches_with_one_compilation():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    spec = engine.build_plan_spec([_scene(100), _scene(101)], cfg,
                                  mem_budget=BUDGET)
    assert any(d.backend == engine.SSPNNA for d in spec.levels)
    eng = SceneEngine(cfg, params, batch=4, spec=spec, use_kernel=False)
    scenes = [_scene(200 + i) for i in range(6)]
    handles = eng.submit([SceneRequest(i, s)
                          for i, s in enumerate(scenes[:4])])
    eng.serve()
    handles += eng.submit([SceneRequest(4 + i, s)
                           for i, s in enumerate(scenes[4:])])
    eng.serve()  # short wave: exercises padding
    assert eng.n_compilations == 1
    assert len(handles) == 6 and all(h.done() for h in handles)
    for h in handles:
        r = h.result()
        assert r.logits.shape == (CAP, N_CLASSES)
        assert not np.any(np.isnan(r.logits))
    # batched result == single-scene engine apply off the cached plan
    r0 = handles[0].result()
    plan0 = eng.cache.get_or_build(r0.scene, cfg, spec=spec)
    single = engine.apply_unet(params, r0.scene.feats, plan0,
                               use_kernel=False)
    np.testing.assert_allclose(r0.logits, np.asarray(single),
                               rtol=1e-5, atol=1e-5)
    # resubmitting a known scene hits the plan cache and the jit cache
    eng.submit(SceneRequest(99, scenes[0])).result()
    assert eng.cache.hits >= 1 and eng.n_compilations == 1


def test_host_meta_numpy_mirrors_match_jax_builders(setup):
    """The host plan pass must be bit-identical to the jitted AdMAC ops."""
    from repro.core import host_meta
    from repro.core.coir import build_cirf
    from repro.core.hashgrid import downsample_coords, kernel_offsets
    from repro.core.sparse_conv import transposed_coir

    cfg, params, t, plan = setup
    coords, mask = np.asarray(t.coords), np.asarray(t.mask)
    offs3 = kernel_offsets(3)
    got = host_meta.build_cirf_np(coords, mask, coords, mask, offs3, RES)
    want = build_cirf(t.coords, t.mask, t.coords, t.mask,
                      jnp.asarray(offs3), RES)
    np.testing.assert_array_equal(got.indices, np.asarray(want.indices))
    np.testing.assert_array_equal(got.bitmask, np.asarray(want.bitmask))

    dn_c, dn_m = host_meta.downsample_coords_np(coords, mask, RES, 2)
    jn_c, jn_m = downsample_coords(t.coords, t.mask, RES, 2)
    np.testing.assert_array_equal(dn_c, np.asarray(jn_c))
    np.testing.assert_array_equal(dn_m, np.asarray(jn_m))

    offs2 = kernel_offsets(2, centered=False)
    got2 = host_meta.build_cirf_np(dn_c, dn_m, coords, mask, offs2, RES,
                                   stride=2)
    want2 = build_cirf(jn_c, jn_m, t.coords, t.mask, jnp.asarray(offs2),
                       RES, stride=2)
    np.testing.assert_array_equal(got2.indices, np.asarray(want2.indices))

    got3 = host_meta.transposed_coir_np(dn_c, dn_m, coords, mask, RES, 2, 2)
    coarse = SparseVoxelTensor(jn_c, jnp.zeros((jn_c.shape[0], 1)), jn_m)
    want3 = transposed_coir(coarse, t.coords, t.mask, RES, 2, 2)
    np.testing.assert_array_equal(got3.indices, np.asarray(want3.indices))
    np.testing.assert_array_equal(got3.bitmask, np.asarray(want3.bitmask))


# ---------------------------------------------------------------------------
# Backend registry + ExecutionContext (the PR-5 API seam)
# ---------------------------------------------------------------------------

class _DoubledBackend(engine.Backend):
    """Toy backend: reference numerics times two (distinguishable)."""

    name = "doubled"

    def run(self, x, params, plan, *, ctx, **kw):
        from repro.core.sparse_conv import reference_conv_cirf
        return 2.0 * reference_conv_cirf(x, plan.coir, params)


def test_new_backend_registers_without_touching_the_dispatcher(setup):
    """The acceptance seam: a backend defined here — no engine.api edits —
    is routable by explicit name AND via a plan's Dispatch decision."""
    cfg, params, t, plan = setup
    ctx = engine.ExecutionContext()  # scoped registry view
    ctx.registry.register("doubled", _DoubledBackend())
    lvl0 = plan.levels[0].sub
    ref = engine.sparse_conv(t.feats, params["stem"], lvl0,
                             backend="reference")
    got = engine.sparse_conv(t.feats, params["stem"], lvl0,
                             backend="doubled", ctx=ctx)
    np.testing.assert_array_equal(np.asarray(got), 2.0 * np.asarray(ref))
    # SPADE/Dispatch emit a *name*; the registry resolves it under "auto"
    named = engine.ConvPlan(lvl0.coir, None,
                            engine.Dispatch(backend="doubled"))
    got_auto = engine.sparse_conv(t.feats, params["stem"], named,
                                  backend="auto", ctx=ctx)
    np.testing.assert_array_equal(np.asarray(got_auto), 2.0 * np.asarray(ref))
    assert engine.resolve_backend(named, "auto", ctx=ctx) == "doubled"
    # the scoped registration never leaked into the process default
    assert "doubled" not in engine.default_registry()
    with pytest.raises(ValueError):
        engine.sparse_conv(t.feats, params["stem"], lvl0, backend="doubled")
    # global registration path (+ cleanup) works too
    engine.register_backend("doubled", _DoubledBackend())
    try:
        assert "doubled" in engine.available_backends()
        assert "doubled" in engine.BACKENDS  # legacy alias stays live
    finally:
        engine.default_registry().unregister("doubled")
    assert "doubled" not in engine.available_backends()


def test_backend_fallback_chain_and_errors(setup):
    cfg, params, t, plan = setup
    bare = engine.reference_plan(plan.levels[0].sub.coir)
    # sspnna without tile metadata degrades along its declared fallback
    assert engine.resolve_backend(bare, "sspnna") == "reference"
    reg = engine.default_registry().view()
    with pytest.raises(ValueError, match="not one of"):
        reg.resolve(bare, "bogus")
    with pytest.raises(ValueError):
        reg.register("auto", _DoubledBackend())  # reserved name
    with pytest.raises(ValueError):
        reg.register("reference", _DoubledBackend())  # no silent shadowing


def test_use_context_scopes_ambient_resolution(setup):
    cfg, params, t, plan = setup
    ctx = engine.ExecutionContext()
    ctx.registry.register("doubled", _DoubledBackend())
    lvl0 = plan.levels[0].sub
    ref = engine.sparse_conv(t.feats, params["stem"], lvl0,
                             backend="reference")
    with engine.use_context(ctx):
        assert engine.current_context() is ctx
        got = engine.sparse_conv(t.feats, params["stem"], lvl0,
                                 backend="doubled")  # no ctx= needed
    np.testing.assert_array_equal(np.asarray(got), 2.0 * np.asarray(ref))
    assert engine.current_context() is engine.default_context()
    with pytest.raises(ValueError):
        engine.sparse_conv(t.feats, params["stem"], lvl0, backend="doubled")


def test_scene_engine_accepts_shared_context(setup):
    """Two engines on one context share its plan cache."""
    cfg, params, t, plan = setup
    ctx = engine.ExecutionContext()
    e1 = SceneEngine(cfg, params, batch=2, ctx=ctx)
    e2 = SceneEngine(cfg, params, batch=2, ctx=ctx)
    assert e1.cache is ctx.plan_cache and e2.cache is ctx.plan_cache
    e1.submit([SceneRequest(0, t)])
    e1.serve()
    e2.submit([SceneRequest(1, t)])
    e2.serve()
    assert ctx.plan_cache.hits >= 1  # e2 hit e1's plan
    e1.close(), e2.close()
