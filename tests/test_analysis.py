"""repro.analysis: every rule class catches a seeded violation, the real
repo is clean, and the runtime lock-order asserter works in-process.

Each static rule (REPRO-L*, C*, P*, H*) gets at least one deliberately
broken input that must produce the right finding id, plus a matching clean
input that must not. The repo-wide passes double as regression guards: the
codebase itself stays violation-free.
"""
import json
import textwrap
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.analysis.concurrency as conc
import repro.analysis.hlo_gates as hg
import repro.analysis.plan_check as pc
import repro.analysis.runtime as rt
from repro.analysis.lint import lint_repo, lint_source

REPO = Path(__file__).resolve().parents[1]


class NS:
    """Ad-hoc record standing in for a plan/tile/dispatch object."""

    def __init__(self, **kw):
        self.__dict__.update(kw)


def _rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# lint (REPRO-L001..L005)
# ---------------------------------------------------------------------------

def _lint(src, rel="src/repro/mod.py"):
    return lint_source(textwrap.dedent(src), rel)


def test_l001_deprecated_shim_import():
    f = _lint("from repro.models.scn import apply_unet\n")
    assert _rules(f) == ["REPRO-L001"]


def test_l001_deprecated_shim_attribute():
    f = _lint("""
        import repro.kernels.sspnna.ops as ops
        y = ops.sspnna_conv(0)
    """)
    assert _rules(f) == ["REPRO-L001"]


def test_l001_defining_module_exempt():
    f = _lint("from repro.models.scn import apply_unet\n",
              rel="src/repro/models/scn.py")
    assert f == []


def test_l002_host_sync_in_dispatch_stage():
    f = _lint("""
        import numpy as np
        class S:
            def _dispatch_stage(self, x):
                x.block_until_ready()
                return np.asarray(x)
    """)
    assert _rules(f) == ["REPRO-L002", "REPRO-L002"]


def test_l002_outside_hot_path_is_fine():
    f = _lint("""
        import numpy as np
        def plain(x):
            return np.asarray(x)
    """)
    assert f == []


def test_l003_unnamed_non_daemon_thread():
    f = _lint("""
        import threading
        t = threading.Thread(target=print)
        ok = threading.Thread(target=print, name="w", daemon=True)
    """)
    assert _rules(f) == ["REPRO-L003", "REPRO-L003"]  # name + daemon


def test_l003_executor_needs_name_prefix():
    f = _lint("""
        from concurrent.futures import ThreadPoolExecutor
        ex = ThreadPoolExecutor(2)
    """)
    assert _rules(f) == ["REPRO-L003"]


def test_l004_contextvars_only_banned_in_serving():
    src = "import contextvars\n"
    assert _rules(lint_source(src, "src/repro/serving/mod.py")) == \
        ["REPRO-L004"]
    assert lint_source(src, "src/repro/engine/mod.py") == []


def test_l005_readback_in_timed_closure():
    f = _lint("""
        import numpy as np
        from benchmarks.common import time_fn
        r = time_fn(lambda: np.asarray(0), iters=3)
    """)
    assert _rules(f) == ["REPRO-L005"]


def test_l005_block_until_ready_is_the_correct_fence():
    f = _lint("""
        from benchmarks.common import time_fn
        r = time_fn(lambda: f(0).block_until_ready())
    """)
    assert f == []


def test_l005_resolves_local_function_closures():
    f = _lint("""
        from benchmarks.common import measure
        def step():
            return f(0).item()
        r = measure(step)
    """)
    assert _rules(f) == ["REPRO-L005"]


def test_allow_comment_suppresses():
    f = _lint("""
        import threading
        t = threading.Thread(target=print)  # analysis: allow[REPRO-L003]
    """)
    assert f == []


def test_lint_repo_is_clean():
    assert lint_repo(REPO) == []


# ---------------------------------------------------------------------------
# concurrency (REPRO-C001..C003)
# ---------------------------------------------------------------------------

def _extract(tmp_path, source, name="mod.py"):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True, exist_ok=True)
    (pkg / name).write_text(textwrap.dedent(source))
    return conc.extract(tmp_path)


def test_c001_backward_acquisition(tmp_path):
    findings, graph = _extract(tmp_path, """
        from repro.analysis.runtime import ordered_lock
        A = ordered_lock("autotune")
        B = ordered_lock("plan_cache")
        def f():
            with A:
                with B:
                    pass
    """)
    assert ("autotune", "plan_cache") in {(s, d) for s, d, _ in graph.edges}
    assert "REPRO-C001" in _rules(findings)


def test_c001_via_call_closure(tmp_path):
    findings, graph = _extract(tmp_path, """
        from repro.analysis.runtime import ordered_lock
        A = ordered_lock("plan_cache")
        B = ordered_lock("autotune")
        def inner():
            with B:
                pass
        def outer():
            with A:
                inner()
    """)
    # forward in rank: edge extracted through the call graph, no finding
    assert ("plan_cache", "autotune") in {(s, d) for s, d, _ in graph.edges}
    assert findings == []


def test_c001_unknown_lock_name(tmp_path):
    findings, _ = _extract(tmp_path, """
        from repro.analysis.runtime import ordered_lock
        X = ordered_lock("not-in-the-order")
    """)
    assert "REPRO-C001" in _rules(findings)


def test_subscript_lock_defined_after_use(tmp_path):
    # the definition pass runs before the uses pass, so a dict-literal
    # lock defined *below* its acquisition site still resolves
    findings, graph = _extract(tmp_path, """
        from repro.analysis.runtime import ordered_lock
        A = ordered_lock("plan_cache")
        def use(entry):
            with A:
                with entry["dev_lock"]:
                    pass
        def make():
            return {"dev_lock": ordered_lock("plan_cache.dev")}
    """)
    assert ("plan_cache", "plan_cache.dev") in \
        {(s, d) for s, d, _ in graph.edges}
    assert findings == []


def test_c002_blocking_call_under_lock(tmp_path):
    findings, _ = _extract(tmp_path, """
        import threading
        from repro.analysis.runtime import ordered_lock
        L = ordered_lock("plan_cache")
        EV = threading.Event()
        def f():
            with L:
                EV.wait()
    """)
    assert "REPRO-C002" in _rules(findings)


def test_c002_condvar_wait_exempt(tmp_path):
    findings, _ = _extract(tmp_path, """
        from repro.analysis.runtime import ordered_condition
        C = ordered_condition("stream.plan")
        def f():
            with C:
                C.wait()
    """)
    assert findings == []


def test_c003_raw_threading_lock(tmp_path):
    findings, _ = _extract(tmp_path, """
        import threading
        L = threading.Lock()
    """)
    assert _rules(findings) == ["REPRO-C003"]


def test_repo_lock_graph_is_clean_and_live():
    findings, graph = conc.extract(REPO)
    assert findings == []
    # the extractor is not a no-op: the known stream->cache edge exists
    pairs = {(s, d) for s, d, _ in graph.edges}
    assert ("stream.plan", "plan_cache") in pairs
    assert ("stream.plan", "plan_cache.dev") in pairs  # via adopt->_resolve
    assert set(graph.locks) == set(rt.LOCK_ORDER)


# ---------------------------------------------------------------------------
# runtime lock-order asserter
# ---------------------------------------------------------------------------

def test_checked_lock_rejects_backward_acquire():
    lo = rt._CheckedLock("plan_cache")
    hi = rt._CheckedLock("autotune")
    with hi:
        with pytest.raises(rt.LockOrderViolation):
            lo.acquire()
    with lo:  # forward order is fine
        with hi:
            pass


def test_checked_lock_self_deadlock_and_reentrancy():
    lk = rt._CheckedLock("plan_cache")
    with lk:
        with pytest.raises(rt.LockOrderViolation):
            lk.acquire()
    r = rt._CheckedLock("breakers", reentrant=True)
    with r:
        with r:
            pass


def test_checked_lock_same_rank_distinct_objects():
    a = rt._CheckedLock("plan_cache.dev")
    b = rt._CheckedLock("plan_cache.dev")
    with a:
        with pytest.raises(rt.LockOrderViolation):
            b.acquire()


def test_checked_lock_is_per_thread():
    hi = rt._CheckedLock("autotune")
    lo = rt._CheckedLock("plan_cache")
    errs = []

    def other():
        try:
            with lo:
                pass
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    with hi:
        th = threading.Thread(target=other, name="order-test", daemon=True)
        th.start()
        th.join()
    assert errs == []


def test_factories_respect_env(monkeypatch):
    monkeypatch.delenv("REPRO_LOCK_CHECK", raising=False)
    assert not isinstance(rt.ordered_lock("plan_cache"), rt._CheckedLock)
    monkeypatch.setenv("REPRO_LOCK_CHECK", "1")
    assert isinstance(rt.ordered_lock("plan_cache"), rt._CheckedLock)
    assert isinstance(rt.ordered_rlock("breakers"), rt._CheckedLock)
    with pytest.raises(ValueError):
        rt.ordered_lock("not-a-lock")


def test_checked_condition_wait(monkeypatch):
    monkeypatch.setenv("REPRO_LOCK_CHECK", "1")
    cond = rt.ordered_condition("stream.plan")
    with cond:
        assert cond.wait(timeout=0.01) is False  # releases + re-acquires
    # after the wait round-trip the order state is intact
    with rt._CheckedLock("plan_cache"):
        pass


# ---------------------------------------------------------------------------
# plan invariants (REPRO-P001..P006)
# ---------------------------------------------------------------------------

def test_p001_coir_out_of_range():
    coir = NS(indices=np.array([[0], [5]], np.int32), bitmask=None)
    assert _rules(pc.check_coir(coir, 2, "c")) == ["REPRO-P001"]


def test_p001_bitmask_disagrees():
    idx = np.array([[0, -1], [1, 0]], np.int32)
    bad = NS(indices=idx, bitmask=np.array([3, 3], np.uint32))
    f = pc.check_coir(bad, 2, "c")
    assert _rules(f) == ["REPRO-P001"] and "bitmask" in f[0].where
    good = NS(indices=idx, bitmask=np.array([1, 3], np.uint32))
    assert pc.check_coir(good, 2, "c") == []


def _tiles(orow, irow, li, counts):
    return NS(out_rows=np.asarray(orow, np.int32),
              in_rows=np.asarray(irow, np.int32),
              local_idx=np.asarray(li, np.int32),
              pair_counts=np.asarray(counts, np.int64))


# 2 active rows, K=1, COIR row i reads input row i
_COIR2 = NS(indices=np.array([[0], [1]], np.int32), bitmask=None)
_MASK2 = np.array([True, True])


def test_tiles_clean_baseline():
    t = _tiles([[0, 1]], [[0, 1]], [[[0], [1]]], [2])
    assert pc.check_tiles(t, _COIR2, _MASK2, 2, 2, None, "t") == []


def test_p002_pair_executed_twice():
    t = _tiles([[0, 1], [0, 2]], [[0, 1], [0, 0]],
               [[[0], [1]], [[0], [-1]]], [2, 1])
    assert "REPRO-P002" in _rules(
        pc.check_tiles(t, _COIR2, _MASK2, 2, 2, None, "t"))


def test_p003_out_rows_beyond_trash():
    t = _tiles([[0, 9]], [[0, 1]], [[[0], [1]]], [2])
    assert _rules(pc.check_tiles(t, _COIR2, _MASK2, 2, 2, None, "t")) == \
        ["REPRO-P003"]


def test_p003_dispatch_mismatch():
    t = _tiles([[0, 1]], [[0, 1]], [[[0], [1]]], [2])
    d = NS(n_tiles=4, delta_o=2, delta_i=2)
    f = pc.check_tiles(t, _COIR2, _MASK2, 2, 2, d, "t")
    assert _rules(f) == ["REPRO-P003"] and "n_tiles" in f[0].message


def test_p004_pair_counts_disagree():
    t = _tiles([[0, 1]], [[0, 1]], [[[0], [1]]], [1])
    assert "REPRO-P004" in _rules(
        pc.check_tiles(t, _COIR2, _MASK2, 2, 2, None, "t"))


def test_p004_dropped_pair():
    t = _tiles([[0, 1]], [[0, 1]], [[[0], [-1]]], [1])
    f = pc.check_tiles(t, _COIR2, _MASK2, 2, 2, None, "t")
    assert "REPRO-P004" in _rules(f)
    assert any("dropped" in x.message for x in f)


def test_p004_dma_chain_wrong_source():
    t = _tiles([[0, 1]], [[1, 0]], [[[0], [1]]], [2])
    f = pc.check_tiles(t, _COIR2, _MASK2, 2, 2, None, "t")
    assert any("wrong" in x.message and x.rule == "REPRO-P004" for x in f)


def _sharded(idx, send):
    return NS(indices=np.asarray(idx, np.int32),
              send_rows=np.asarray(send, np.int32))


def test_p005_sharded_clean_and_violations():
    s, vs, h = 2, 4, 2
    send = np.full((s, s, h), -1, np.int32)
    send[1, 0, 1] = 2  # shard 1 sends its row 2 into shard 0's slot 1
    own = np.zeros((s, vs, 1), np.int32)
    # clean: shard 0 reads halo slot d=1,j=1 -> coded vs + 1*h + 1 = 7
    idx = own.copy()
    idx[0, 0, 0] = vs + 1 * h + 1
    assert pc.check_sharded_conv(_sharded(idx, send), vs, vs, s, "p") == []
    # self-halo: shard 0 referencing a slot it would send itself
    idx_self = own.copy()
    idx_self[0, 0, 0] = vs + 0 * h + 0
    f = pc.check_sharded_conv(_sharded(idx_self, send), vs, vs, s, "p")
    assert any("itself" in x.message and x.rule == "REPRO-P005" for x in f)
    # unsent slot: nobody populates shard 1's slot j=0 for shard 0
    idx_unsent = own.copy()
    idx_unsent[0, 0, 0] = vs + 1 * h + 0
    f = pc.check_sharded_conv(_sharded(idx_unsent, send), vs, vs, s, "p")
    assert any("never send" in x.message for x in f)
    # send rows must be local to the sender
    bad_send = send.copy()
    bad_send[1, 0, 1] = vs + 3
    f = pc.check_sharded_conv(_sharded(idx, bad_send), vs, vs, s, "p")
    assert any("send rows" in x.message.lower() for x in f)


# ---------------------------------------------------------------------------
# real built plan (integration) + cache keys (REPRO-P006)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built():
    from repro import engine
    from repro.data.scenes import N_CLASSES, make_scene
    from repro.models.scn import UNetConfig
    from repro.sparse.tensor import SparseVoxelTensor
    res, cap = 16, 512
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=res, capacity=cap,
                     n_classes=N_CLASSES)
    coords, feats, _, mask = make_scene(0, resolution=res, capacity=cap)
    t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                          jnp.asarray(mask))
    spec = engine.build_plan_spec([t], cfg, mem_budget=64 * 1024)
    plan = engine.build_scene_plan_host(t, cfg, spec=spec, plan_tiles=True)
    return t, cfg, plan


def test_real_plan_is_clean(built):
    _, _, plan = built
    assert pc.check_scene_plan(plan) == []


def test_real_plan_corrupted_tables_are_caught(built):
    _, _, plan = built
    lvl = next(l for l in plan.levels if l.sub.tiles is not None)
    v = int(np.asarray(lvl.mask).shape[0])
    orow = np.array(lvl.sub.tiles.out_rows, np.int32, copy=True)
    orow[0, 0] = v + 7  # beyond the trash row
    bad = NS(out_rows=orow,
             in_rows=np.asarray(lvl.sub.tiles.in_rows),
             local_idx=np.asarray(lvl.sub.tiles.local_idx),
             pair_counts=np.asarray(lvl.sub.tiles.pair_counts))
    f = pc.check_tiles(bad, lvl.sub.coir, np.asarray(lvl.mask), v, v,
                       None, "t")
    assert "REPRO-P003" in _rules(f)


def test_p006_cache_keys_rotate(built):
    from repro.engine.autotune import CostTable
    from repro.engine.plan import PlanCache
    t, cfg, _ = built
    cache = PlanCache(capacity=t.capacity)
    assert pc.check_cache_keys(cache, t, cfg, autotune=CostTable()) == []

    class Frozen:  # no generation counter at all
        def __repr__(self):
            return "Frozen()"

    f = pc.check_cache_keys(cache, t, cfg, autotune=Frozen())
    assert any("no generation" in x.message for x in f)

    class Hidden:  # has a counter but a repr that does not mix it
        generation = 0

        def __repr__(self):
            return "Hidden()"

    f = pc.check_cache_keys(cache, t, cfg, breakers=Hidden())
    assert any(x.rule == "REPRO-P006" and "rotate" in x.message for x in f)


def test_plan_cache_under_runtime_lock_check(monkeypatch, built):
    monkeypatch.setenv("REPRO_LOCK_CHECK", "1")
    from repro.engine.plan import PlanCache
    t, cfg, plan = built
    cache = PlanCache(capacity=t.capacity)
    assert isinstance(cache._lock, rt._CheckedLock)
    key = cache.key_for(t, cfg)
    assert cache.adopt(key, plan, device=False) is plan
    assert cache.adopt(key, plan, device=False) is plan  # hit path
    assert cache.invalidate() == 1


# ---------------------------------------------------------------------------
# hlo gates (REPRO-H001..H003)
# ---------------------------------------------------------------------------

def test_h001_flags_gather_and_scatter():
    def g(x, i):
        return jnp.take(x, i, axis=0)

    text = hg.compiled_text(g, jnp.ones((16, 4)), jnp.array([1, 3]))
    f = hg.forbidden_ops(text, where="g")
    assert any(x.rule == "REPRO-H001" and "gather" in x.message for x in f)

    # CPU XLA rewrites scatter into loops before final HLO, so seed the
    # scatter side with literal HLO text (forbidden_ops accepts text)
    text = textwrap.dedent("""\
        ENTRY %main (p0: f32[8], p1: s32[2], p2: f32[2]) -> f32[8] {
          %p0 = f32[8] parameter(0)
          %p1 = s32[2] parameter(1)
          %p2 = f32[2] parameter(2)
          ROOT %sc = f32[8] scatter(%p0, %p1, %p2), to_apply=%add
        }
    """)
    f = hg.forbidden_ops(text, where="s")
    assert any(x.rule == "REPRO-H001" and "scatter" in x.message for x in f)


def test_h001_clean_matmul():
    text = hg.compiled_text(lambda a, b: a @ b,
                            jnp.ones((8, 8)), jnp.ones((8, 8)))
    assert hg.forbidden_ops(text) == []


def test_h002_compile_budget():
    jf = jax.jit(lambda x: x * 2)
    jf(jnp.ones((4,)))
    jf(jnp.ones((8,)))
    assert hg.compile_count(jf) == 2
    assert _rules(hg.gate_compile_budget(jf, 1)) == ["REPRO-H002"]
    assert hg.gate_compile_budget(jf, 2) == []
    assert _rules(hg.gate_compile_budget(3, 2, where="engine")) == \
        ["REPRO-H002"]
    with pytest.raises(TypeError):
        hg.compile_count(lambda x: x)


def test_h003_vmem_budget():
    assert hg.gate_vmem_budget(NS(delta_o=16, delta_i=48, block_n=8), 8) \
        == []
    f = hg.gate_vmem_budget(
        NS(delta_o=4096, delta_i=65536, block_n=512), 256)
    assert _rules(f) == ["REPRO-H003"]
    # non-tile dispatch passes trivially
    assert hg.gate_vmem_budget(NS(delta_o=0, delta_i=0, block_n=None), 8) \
        == []
    got = hg.modeled_vmem_bytes(delta_o=2, delta_i=3, c_in=4, block_n=5,
                                k=6, itemsize=4)
    want = (2 * 3 * 4 + 2 * 5) * 4 + 2 * 2 * 6 * 4 + 2 * 6 * 4 * 5 * 4
    assert got == want


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_static_passes_clean(tmp_path, capsys):
    from repro.analysis.__main__ import main
    out = tmp_path / "findings.json"
    assert main(["--only", "lint", "--only", "locks",
                 "--json", str(out)]) == 0
    data = json.loads(out.read_text())
    assert data["n_findings"] == 0
    assert set(data["lock_graph"]["locks"]) == set(rt.LOCK_ORDER)


def test_cli_counts_findings(tmp_path):
    from repro.analysis.__main__ import main
    bad = tmp_path / "src" / "repro"
    bad.mkdir(parents=True)
    (bad / "mod.py").write_text(
        "import threading\nL = threading.Lock()\n"
        "t = threading.Thread(target=print)\n")
    rc = main(["--root", str(tmp_path), "--only", "lint", "--only", "locks"])
    assert rc == 3  # L003 name + L003 daemon + C003 raw lock
