"""SOAR / SPADE / CAROM / scheduler behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_shell_scene
from repro.core import carom, schedule, soar, spade
from repro.core.hashgrid import build_neighbor_table, kernel_offsets
from repro.core.sparse_conv import submanifold_coir
from repro.sparse.tensor import from_dense


@pytest.fixture(scope="module")
def shell():
    rng = np.random.default_rng(7)
    dense = make_shell_scene(rng, 28, 4)
    t = from_dense(dense)
    nbr = np.asarray(build_neighbor_table(
        t.coords, t.mask, jnp.asarray(kernel_offsets(3)), 28))
    coir = submanifold_coir(t, 28, 3)
    return t, nbr, np.asarray(coir.indices)


def test_soar_is_permutation(shell):
    t, nbr, idx = shell
    res = soar.soar_order(nbr, np.asarray(t.mask), 200)
    n = int(t.n_active())
    assert len(res.order) == n
    assert len(np.unique(res.order)) == n
    sizes = np.diff(res.chunk_starts)
    assert sizes.max() <= 200 and sizes.min() > 0


def test_soar_beats_raster(shell):
    t, nbr, idx = shell
    res = soar.soar_order(nbr, np.asarray(t.mask), 128)
    rast = soar.raster_order(np.asarray(t.coords), np.asarray(t.mask))
    a_soar = soar.tiled_unique_input_accesses(res.order, idx, 128)
    a_rast = soar.tiled_unique_input_accesses(rast, idx, 128)
    assert a_soar < a_rast  # Fig 23: SOAR saves input fetches


def test_soar_hierarchical(shell):
    t, nbr, idx = shell
    res = soar.soar_hierarchical(nbr, np.asarray(t.mask), [64, 512])
    n = int(t.n_active())
    assert len(np.unique(res.order)) == n


def test_sparsity_attributes_shape_and_trends(shell):
    t, nbr, idx = shell
    res = soar.soar_order(nbr, np.asarray(t.mask), 256)
    attrs = spade.extract_attributes(idx, np.asarray(t.mask), res.order)
    # SA_I falls with region size (surface/volume); ARF ~ constant (Fig 15)
    assert attrs.sa_minor_avg[0] >= attrs.sa_minor_avg[-1]
    assert np.ptp(attrs.arf_avg) < 0.5
    assert np.all(attrs.sa_minor_alloc_sst >= attrs.sa_minor_avg - 1e-9)
    assert np.all(attrs.sa_minor_alloc_rst <= attrs.sa_minor_alloc_sst + 1e-9)
    alpha, corr = spade.fit_surface_ratio(attrs)
    assert alpha > 0 and corr > 0.5


def test_spade_explore_respects_budget(shell):
    t, nbr, idx = shell
    res = soar.soar_order(nbr, np.asarray(t.mask), 256)
    attrs = spade.extract_attributes(idx, np.asarray(t.mask), res.order)
    v = int(t.n_active())
    layer = spade.LayerSpec("L", v, v, 27, 64, 96, 2)
    for budget in (32 * 1024, 64 * 1024, 256 * 1024):
        df = spade.explore(layer, {"CIRF": attrs, "CORF": attrs}, budget)
        assert df.tile_elems * layer.dtype_bytes <= budget * 1.001
    # larger memory -> no worse dataflow
    small = spade.explore(layer, {"CIRF": attrs}, 32 * 1024)
    big = spade.explore(layer, {"CIRF": attrs}, 1024 * 1024)
    assert big.da_elems <= small.da_elems * 1.001


def test_spade_walk_pattern_semantics(shell):
    t, nbr, idx = shell
    res = soar.soar_order(nbr, np.asarray(t.mask), 256)
    attrs = spade.extract_attributes(idx, np.asarray(t.mask), res.order)
    layer = spade.LayerSpec("L", 4096, 4096, 27, 64, 64, 2)
    # WS: weights fetched once; IS: inputs once; OS: outputs once (Eqn 5)
    for wp, idx_term in (("WS", 0), ("IS", 1), ("OS", 2)):
        da, br = spade.data_accesses(layer, attrs, 256, 32, 32, wp, "CIRF")
        base = {0: 64 * 64 * 27,
                1: attrs.at(256, "sa_minor_avg") * 4096 * 64,
                2: 4096 * 64 + attrs.at(256, "arf_avg") * 4096}[idx_term]
        assert abs(br[idx_term] - base) / base < 1e-6


def test_offline_table_near_optimal(shell):
    t, nbr, idx = shell
    res = soar.soar_order(nbr, np.asarray(t.mask), 256)
    attrs = spade.extract_attributes(idx, np.asarray(t.mask), res.order)
    v = int(t.n_active())
    layer = spade.LayerSpec("L", v, v, 27, 32, 32, 2)
    msa = spade.meta_attributes([attrs])
    table = spade.build_offline_table([layer], msa, 64 * 1024)
    arf = float(attrs.arf_avg[0])
    plan = spade.otf_lookup(table, layer, arf)
    direct = spade.explore(layer, {"CIRF": attrs, "CORF": attrs}, 64 * 1024)
    # offline plan within 2x of the input-specific optimum (paper: marginal loss)
    assert plan.da_elems <= 2.0 * direct.da_elems


def test_carom_constraint_and_value(shell):
    t, nbr, idx = shell
    res = soar.soar_order(nbr, np.asarray(t.mask), 256)
    attrs = spade.extract_attributes(idx, np.asarray(t.mask), res.order)
    v = int(t.n_active())
    layer = spade.LayerSpec("L", v, v, 27, 64, 64, 2)
    levels = [carom.MemLevel("L2", 2 << 20, 16, 1024),
              carom.MemLevel("L1", 64 << 10, 64, 1024)]
    plans = carom.carom_search(layer, {"CIRF": attrs, "CORF": attrs}, levels)
    assert len(plans) == 2
    greedy = carom.greedy_search(layer, {"CIRF": attrs, "CORF": attrs}, levels)
    # CAROM may pay more at the outer level, never more at both
    assert plans[0].da_elems >= greedy[0].da_elems * 0.999


def test_schedulers():
    rng = np.random.default_rng(3)
    work = rng.pareto(1.5, 100) * 100 + 10
    naive = schedule.schedule_naive(work, 8)
    paper = schedule.schedule_round_robin_sorted(work, 8)
    lpt = schedule.schedule_lpt(work, 8)
    ideal = work.sum() / 8
    assert lpt.makespan <= paper.makespan <= naive.makespan + 1e-9
    assert lpt.makespan >= ideal - 1e-9
    for a in (naive, paper, lpt):
        assert np.isclose(a.per_core_work.sum(), work.sum())
    # overlap model: sorted schedule no slower than naive under the bus model
    xfer = work * 0.1
    t_paper = schedule.phase_overlap_makespan(paper, work, xfer, 1.0, 10.0)
    t_naive = schedule.phase_overlap_makespan(naive, work, xfer, 1.0, 10.0)
    assert t_paper <= t_naive * 1.05


def test_soar_sa_alloc_no_worse_than_random(shell):
    """Integration: SOAR ordering gives SPADE an SA_I allocation no worse
    than a random permutation at every region size (locality -> smaller
    unique-input working sets, Fig 15/23)."""
    t, nbr, idx = shell
    mask = np.asarray(t.mask)
    res = soar.soar_order(nbr, mask, 256)
    rand = np.random.default_rng(11).permutation(np.flatnonzero(mask))
    a_soar = spade.extract_attributes(idx, mask, res.order)
    a_rand = spade.extract_attributes(idx, mask, rand)
    assert np.all(a_soar.sa_minor_alloc_sst <= a_rand.sa_minor_alloc_sst + 1e-9)
    assert np.all(a_soar.sa_minor_avg <= a_rand.sa_minor_avg + 1e-9)
