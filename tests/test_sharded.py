"""Mesh-sharded scenes: halo exchange, bitwise-vs-serial, sharded serving.

The acceptance bar: ``backend="sharded"`` ``apply_unet`` on a >=2-device
mesh is **bitwise identical** to the single-device reference path (the
same deterministic per-shard program under ``vmap(axis_name=...)``), with
per-shard plan builds observable in ``WaveScheduler`` stats — plus
fp-tolerance agreement with the unsharded ``"reference"`` einsum backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # image without hypothesis: deterministic local shim
    from _hypothesis_mini import given, settings, strategies as st

from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.dist.collectives import halo_exchange
from repro.dist.compat import make_mesh
from repro.models.scn import UNetConfig, init_unet
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.sparse.tensor import SparseVoxelTensor

RES, CAP = 24, 2048


def _scene(seed, res=RES, cap=CAP):
    coords, feats, labels, mask = make_scene(seed, resolution=res, capacity=cap)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


def _random_scene(rng, cap, res, n_active, channels=4):
    """Uniform random active voxels — receptive fields cross shard
    boundaries freely because the contiguous capacity split is unrelated
    to spatial position."""
    coords = np.full((cap, 3), -1, np.int32)
    feats = np.zeros((cap, channels), np.float32)
    mask = np.zeros((cap,), bool)
    if n_active:
        pts = np.unique(rng.integers(0, res, size=(n_active, 3)).astype(np.int32),
                        axis=0)
        coords[:len(pts)] = pts
        feats[:len(pts)] = rng.normal(size=(len(pts), channels))
        mask[:len(pts)] = True
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


def _mesh(n):
    return make_mesh((n,), ("shard",), devices=jax.devices()[:n])


@pytest.fixture(scope="module")
def setup():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    t = _scene(0)
    ref = engine.apply_unet(params, t.feats,
                            engine.build_scene_plan(t, cfg, plan_tiles=False),
                            backend="reference")
    return cfg, params, t, np.asarray(ref)


def test_halo_exchange_matches_numpy_oracle(rng):
    S, Vs, H, C = 4, 32, 6, 3
    feats = jnp.asarray(rng.normal(size=(S, Vs, C)).astype(np.float32))
    send = rng.integers(-1, Vs, size=(S, S, H)).astype(np.int32)
    got = np.asarray(halo_exchange(_mesh(S), feats, jnp.asarray(send)))
    want = np.zeros((S, S, H, C), np.float32)
    for d in range(S):
        for s in range(S):
            for j in range(H):
                if send[d, s, j] >= 0:
                    want[s, d, j] = np.asarray(feats)[d, send[d, s, j]]
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n_shards", [2, 4])
def test_sharded_unet_bitwise_vs_single_device(setup, n_shards):
    """Mesh execution == single-device reference path, bitwise; and the
    deterministic sharded numerics agree with the unsharded einsum
    reference to fp tolerance."""
    cfg, params, t, ref = setup
    splan = engine.build_sharded_scene_plan(
        t, cfg, layout=engine.ShardLayout(n_shards=n_shards))
    assert splan.halo_rows() > 0  # receptive fields really cross shards
    serial = jax.jit(
        lambda p, f, pl: engine.apply_unet(p, f, pl))(params, t.feats, splan)
    ctx = engine.ExecutionContext(mesh=_mesh(n_shards))
    meshed = jax.jit(
        lambda p, f, pl: engine.apply_unet(p, f, pl, ctx=ctx))(
            params, t.feats, splan)
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(meshed))
    m = np.asarray(t.mask)
    np.testing.assert_allclose(np.asarray(meshed)[m], ref[m],
                               rtol=1e-4, atol=1e-4)


def test_sharded_backend_is_scene_level(setup):
    cfg, params, t, ref = setup
    splan = engine.build_sharded_scene_plan(
        t, cfg, layout=engine.ShardLayout(n_shards=2))
    # a sharded plan cannot be forced onto a per-conv backend
    with pytest.raises(ValueError):
        engine.apply_unet(params, t.feats, splan, backend="reference")
    impl = engine.default_registry().get(engine.SHARDED)
    with pytest.raises(ValueError):
        impl.run(t.feats, params["stem"], splan, ctx=None)


# jitted once per shard count: every property-test example reuses the same
# signature (fixed capacity + pinned halo budget), so the sweep compiles
# 2x, not 2x-per-example
_PROP_FNS: dict = {}


def _prop_fns(n_shards):
    if n_shards not in _PROP_FNS:
        ctx = engine.ExecutionContext(mesh=_mesh(n_shards))
        _PROP_FNS[n_shards] = (
            jax.jit(lambda p, f, pl: engine.apply_unet(p, f, pl)),
            jax.jit(lambda p, f, pl: engine.apply_unet(p, f, pl, ctx=ctx)),
        )
    return _PROP_FNS[n_shards]


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 320))
def test_sharded_random_scenes_property(seed, n_active):
    """Random scenes — including empty shards and (at n_active=0) fully
    empty scenes — stay bitwise mesh-vs-serial over 2 and 4 virtual
    devices and allclose to the unsharded reference."""
    cap, res = 512, 16
    cfg = UNetConfig(widths=(4, 8), reps=1, resolution=res, capacity=cap,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(7), cfg)
    t = _random_scene(np.random.default_rng(seed), cap, res, n_active)
    ref = np.asarray(engine.apply_unet(
        params, t.feats, engine.build_scene_plan(t, cfg, plan_tiles=False),
        backend="reference"))
    for n_shards in (2, 4):
        # fixed halo budget -> one jit signature across examples
        layout = engine.ShardLayout(n_shards=n_shards, halo=cap // n_shards)
        splan = engine.build_sharded_scene_plan(t, cfg, layout=layout)
        serial_fn, mesh_fn = _prop_fns(n_shards)
        serial = serial_fn(params, t.feats, splan)
        meshed = mesh_fn(params, t.feats, splan)
        np.testing.assert_array_equal(np.asarray(serial), np.asarray(meshed))
        m = np.asarray(t.mask)
        np.testing.assert_allclose(np.asarray(meshed)[m], ref[m],
                                   rtol=1e-4, atol=1e-4)


def test_halo_budget_overflow_raises(setup):
    cfg, params, t, ref = setup
    with pytest.raises(ValueError, match="halo budget"):
        engine.build_sharded_scene_plan_host(
            t, cfg, layout=engine.ShardLayout(n_shards=4, halo=2))


def test_pin_halo_freezes_signature(setup):
    cfg, params, t, ref = setup
    layout = engine.pin_halo([_scene(0), _scene(1)], cfg,
                             engine.ShardLayout(n_shards=2))
    assert layout.halo > 0
    p0 = engine.build_sharded_scene_plan_host(t, cfg, layout=layout)
    p1 = engine.build_sharded_scene_plan_host(_scene(1), cfg, layout=layout)
    assert (jax.tree_util.tree_structure(p0)
            == jax.tree_util.tree_structure(p1))
    shapes = [tuple(x.shape) for x in jax.tree_util.tree_leaves(p0)]
    assert shapes == [tuple(x.shape) for x in jax.tree_util.tree_leaves(p1)]


def test_plan_cache_keys_mix_in_topology(setup):
    """Regression (PR-5 satellite): a plan built for one mesh topology or
    shard layout must never be served to another."""
    cfg, params, t, ref = setup
    cache = engine.PlanCache(capacity=8)
    ctx2 = engine.ExecutionContext(mesh=_mesh(2))
    ctx4 = engine.ExecutionContext(mesh=_mesh(4))
    k_host = cache.key_for(t, cfg, topology=None)
    k2 = cache.key_for(t, cfg, topology=ctx2.topology_key())
    k4 = cache.key_for(t, cfg, topology=ctx4.topology_key())
    assert len({k_host, k2, k4}) == 3
    # shard layout differences split keys too (it rides in build_kw)
    ka = cache.key_for(t, cfg, topology=ctx4.topology_key(),
                       layout=engine.ShardLayout(4, halo=64))
    kb = cache.key_for(t, cfg, topology=ctx4.topology_key(),
                       layout=engine.ShardLayout(4, halo=128))
    assert ka != kb
    # and a different shard axis on the same mesh is a different topology
    ctx4b = engine.ExecutionContext(mesh=_mesh(4), shard_axis="other")
    assert ctx4.topology_key() != ctx4b.topology_key()


def test_scene_engine_rejects_mismatched_mesh(setup):
    """A mesh lacking the layout's shard axis (or with the wrong size)
    must fail at construction, not inside the first wave's jit trace."""
    cfg, params, t, ref = setup
    layout = engine.ShardLayout(n_shards=4, halo=64)
    bad_axis = engine.ExecutionContext(
        mesh=make_mesh((4,), ("pod",), devices=jax.devices()[:4]))
    with pytest.raises(ValueError, match="mesh axis"):
        SceneEngine(cfg, params, batch=2, ctx=bad_axis, layout=layout)
    bad_size = engine.ExecutionContext(mesh=_mesh(2))
    with pytest.raises(ValueError, match="mesh axis"):
        SceneEngine(cfg, params, batch=2, ctx=bad_size, layout=layout)


def test_scene_engine_sharded_guards_signature_and_cache_args(setup):
    """A diverged plan signature (e.g. wrong scene capacity) raises and
    requeues instead of silently recompiling; plan_cache_size with an
    explicit ctx is rejected instead of silently ignored."""
    cfg, params, t, ref = setup
    layout = engine.ShardLayout(n_shards=4, halo=CAP // 4)
    ctx = engine.ExecutionContext(mesh=_mesh(4))
    eng = SceneEngine(cfg, params, batch=2, ctx=ctx, layout=layout)
    eng.submit([SceneRequest(0, t)])
    eng.serve()
    small = _scene(5, res=RES, cap=CAP // 2)  # divides 4 shards, wrong V
    eng.submit([SceneRequest(1, small)])
    with pytest.raises(RuntimeError, match="signature diverged"):
        eng.serve()
    assert eng.n_compilations == 1  # no silent second signature
    assert [r.rid for r in eng.queue] == [1]  # requeued, not dropped
    eng.close()
    with pytest.raises(ValueError, match="plan_cache_size"):
        SceneEngine(cfg, params, batch=2, ctx=ctx, plan_cache_size=4)


def test_scene_engine_serves_sharded_waves(setup):
    cfg, params, t, ref = setup
    n_shards = 4
    layout = engine.pin_halo([_scene(0), _scene(1)], cfg,
                             engine.ShardLayout(n_shards=n_shards))
    ctx = engine.ExecutionContext(mesh=_mesh(n_shards))
    eng = SceneEngine(cfg, params, batch=2, ctx=ctx, layout=layout)
    scenes = [_scene(200 + i) for i in range(5)]
    handles = eng.submit([SceneRequest(i, s) for i, s in enumerate(scenes)])
    eng.serve()
    assert all(h.done() for h in handles) and eng.n_compilations == 1
    # per-shard plan builds are observable in the scheduler stats
    for st_ in eng.wave_stats:
        assert st_.notes["plan_shards"] == n_shards
        assert st_.notes["plan_builds"] == len(st_.rids)
        assert st_.notes["halo_rows"] > 0
    # wave results == direct sharded apply off the same plan
    r0 = handles[0].result()
    plan0 = eng.cache.get_or_build(
        r0.scene, cfg, topology=ctx.topology_key(),
        builder=engine.build_sharded_scene_plan_host, layout=layout)
    direct = jax.jit(
        lambda p, f, pl: engine.apply_unet(p, f, pl, ctx=ctx))(
            params, r0.scene.feats, plan0)
    np.testing.assert_array_equal(r0.logits, np.asarray(direct))
    # resubmitting a known scene hits the plan cache
    eng.submit(SceneRequest(99, scenes[0])).result()
    assert eng.cache.hits >= 1 and eng.n_compilations == 1
    eng.close()
