"""Training loop, optimizers, gradient compression, checkpoint/elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.training import checkpoint, grad_compress
from repro.training.optimizer import OptHParams
from repro.training.train_loop import init_train_state, make_train_step


@pytest.fixture(scope="module")
def small_setup():
    cfg = get_config("stablelm-1.6b").reduced()
    hp = OptHParams(lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, hp)
    ds = TokenStream(cfg.vocab_size, batch=8, seq_len=64, seed=0)
    return cfg, hp, state, ds


def test_loss_decreases(small_setup):
    cfg, hp, state, ds = small_setup
    step = jax.jit(make_train_step(cfg, hp))
    losses = []
    for _ in range(12):
        state, m = step(state, {k: jnp.asarray(v) for k, v in next(ds).items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2
    assert np.isfinite(m["grad_norm"])


def test_microbatching_matches_full_batch():
    cfg = get_config("stablelm-1.6b").reduced()
    hp = OptHParams(lr=1e-3)
    s1 = init_train_state(jax.random.PRNGKey(0), cfg, hp)
    s2 = jax.tree.map(lambda x: x, s1)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 33)), jnp.int32)}
    f1 = jax.jit(make_train_step(cfg, hp, n_microbatches=1))
    f2 = jax.jit(make_train_step(cfg, hp, n_microbatches=4))
    s1, m1 = f1(s1, batch)
    s2, m2 = f2(s2, batch)
    # same data -> same mean loss and (approximately) same updated params
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)


def test_adafactor_trains_moe():
    cfg = get_config("llama4-maverick-400b-a17b").reduced()
    assert cfg.optimizer == "adafactor"
    hp = OptHParams(lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, hp)
    step = jax.jit(make_train_step(cfg, hp))
    ds = TokenStream(cfg.vocab_size, 4, 32, 1)
    losses = []
    for _ in range(8):
        state, m = step(state, {k: jnp.asarray(v) for k, v in next(ds).items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_grad_compression_error_feedback(rng):
    g = {"a": jnp.asarray(rng.normal(size=(1000,)), jnp.float32)}
    err = grad_compress.init_error_state(g)
    out, err = grad_compress.compress_decompress(g, err)
    # round-trip error is bounded by the block scale / 127
    scale = float(jnp.max(jnp.abs(g["a"]))) / 127
    assert float(jnp.max(jnp.abs(out["a"] - g["a"]))) < scale * 1.5
    # error feedback: repeated same gradient -> average converges
    acc = jnp.zeros_like(g["a"])
    e = grad_compress.init_error_state(g)
    for _ in range(20):
        o, e = grad_compress.compress_decompress(g, e)
        acc = acc + o["a"]
    np.testing.assert_allclose(np.asarray(acc / 20), np.asarray(g["a"]),
                               rtol=0, atol=scale * 1.2)
    assert grad_compress.compression_ratio(g, 4) > 3.5


def test_compressed_training_converges():
    cfg = get_config("stablelm-1.6b").reduced()
    hp = OptHParams(lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, hp)
    state["err"] = grad_compress.init_error_state(state["params"])
    step = jax.jit(make_train_step(cfg, hp, compress_grads=True))
    ds = TokenStream(cfg.vocab_size, 8, 48, 2)
    losses = []
    for _ in range(10):
        state, m = step(state, {k: jnp.asarray(v) for k, v in next(ds).items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1


def test_checkpoint_roundtrip_and_resume(small_setup, tmp_path):
    cfg, hp, state, _ = small_setup
    ds = TokenStream(cfg.vocab_size, 4, 16, 9)
    next(ds)
    checkpoint.save(state, str(tmp_path), 7, data_state=ds.state())
    assert checkpoint.latest_step(str(tmp_path)) == 7
    restored, man = checkpoint.restore(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # data pipeline resumes exactly
    ds2 = TokenStream.from_state(cfg.vocab_size, 4, 16, man["data_state"])
    np.testing.assert_array_equal(next(ds)["tokens"], next(ds2)["tokens"])


def test_checkpoint_async_and_atomic(small_setup, tmp_path):
    cfg, hp, state, _ = small_setup
    checkpoint.save_async(state, str(tmp_path), 3)
    checkpoint.wait_for_saves()
    assert checkpoint.latest_step(str(tmp_path)) == 3
    # no .tmp leftovers
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_elastic_restore_to_sharded(small_setup, tmp_path):
    """Restore under explicit shardings on the host mesh (elastic re-mesh)."""
    cfg, hp, state, _ = small_setup
    checkpoint.save(state, str(tmp_path), 1)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    sh = NamedSharding(mesh, P())
    restored, _ = checkpoint.restore(str(tmp_path), 1, state, shardings=sh)
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding == sh
