"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_shell_scene
from repro.core import soar
from repro.core.hashgrid import build_neighbor_table, kernel_offsets
from repro import engine
from repro.core.sparse_conv import (
    init_sparse_conv,
    reference_conv_cirf,
    submanifold_coir,
)
from repro.kernels.flash.flash import flash_attention
from repro.kernels.flash.ops import flash_attention_bshd
from repro.kernels.flash.ref import attention_ref
from repro.kernels.moe_gemm.moe_gemm import grouped_gemm
from repro.kernels.moe_gemm.ref import grouped_gemm_ref
from repro.kernels.sspnna.ref import sspnna_tile_ref
from repro.kernels.sspnna.sspnna import sspnna_tiles
from repro.sparse.tensor import from_dense


def _tol(dt):
    return (2e-2, 2e-2) if dt == jnp.bfloat16 else (1e-5, 1e-5)


@pytest.mark.parametrize("t,di,do,k,c,n,dt", [
    (3, 64, 32, 27, 16, 16, jnp.float32),
    (2, 96, 48, 27, 8, 24, jnp.float32),
    (4, 32, 32, 8, 32, 16, jnp.float32),
    (2, 64, 32, 27, 16, 16, jnp.bfloat16),
    (1, 16, 8, 27, 64, 64, jnp.float32),
])
def test_sspnna_kernel_vs_ref_sweep(rng, t, di, do, k, c, n, dt):
    feats = jnp.asarray(rng.normal(size=(t, di, c)), dt)
    idx = rng.integers(-1, di, (t, do, k)).astype(np.int32)
    w = jnp.asarray(rng.normal(size=(k, c, n)) * 0.1, dt)
    got = sspnna_tiles(feats, jnp.asarray(idx), w)
    ref = sspnna_tile_ref(feats, jnp.asarray(idx), w)
    rtol, atol = _tol(dt)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=rtol, atol=atol)


def test_sspnna_full_conv_path(rng):
    dense = make_shell_scene(rng, 18, 12)
    t = from_dense(dense)
    coir = submanifold_coir(t, 18, 3)
    params = init_sparse_conv(jax.random.PRNGKey(0), 27, 12, 16)
    nbr = np.asarray(build_neighbor_table(
        t.coords, t.mask, jnp.asarray(kernel_offsets(3)), 18))
    order = soar.soar_order(nbr, np.asarray(t.mask), 64).order
    cp = engine.conv_plan_for_layer(coir, order, 64, 192)
    out = engine.sparse_conv(t.feats, params, cp, backend="sspnna",
                             use_kernel=True)
    ref = reference_conv_cirf(t.feats, coir, params)
    mask = np.asarray(t.mask)
    np.testing.assert_allclose(np.asarray(out)[mask], np.asarray(ref)[mask],
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bh,sq,skv,d,causal,window,cap,dt", [
    (4, 256, 256, 64, True, None, None, jnp.float32),
    (2, 128, 256, 64, True, None, None, jnp.float32),
    (2, 256, 256, 64, True, 64, None, jnp.float32),
    (2, 256, 256, 64, True, None, 50.0, jnp.float32),
    (2, 256, 256, 128, False, None, None, jnp.float32),
    (2, 256, 256, 64, True, None, None, jnp.bfloat16),
    (1, 64, 512, 32, True, 128, 30.0, jnp.float32),
])
def test_flash_kernel_sweep(rng, bh, sq, skv, d, causal, window, cap, dt):
    q = jnp.asarray(rng.normal(size=(bh, sq, d)), dt)
    k = jnp.asarray(rng.normal(size=(bh, skv, d)), dt)
    v = jnp.asarray(rng.normal(size=(bh, skv, d)), dt)
    got = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=64, block_kv=64)
    ref = attention_ref(q[:, None], k[:, None], v[:, None], causal=causal,
                        window=window, softcap=cap)[:, 0]
    rtol, atol = _tol(dt)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=rtol, atol=atol)


def test_flash_gqa_wrapper_matches_model_attention(rng):
    from repro.models.attention import chunked_attention

    b, s, hq, hkv, d = 2, 256, 8, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    got = flash_attention_bshd(q, k, v, causal=True, block_q=64, block_kv=64)
    ref = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("e,c,d,f,bf,dt", [
    (4, 16, 32, 64, None, jnp.float32),
    (8, 8, 64, 128, 32, jnp.float32),
    (2, 32, 16, 48, 16, jnp.bfloat16),
])
def test_moe_grouped_gemm_sweep(rng, e, c, d, f, bf, dt):
    xin = jnp.asarray(rng.normal(size=(e, c, d)), dt)
    w = jnp.asarray(rng.normal(size=(e, d, f)) * 0.1, dt)
    valid = jnp.asarray(rng.random((e, c)) > 0.3)
    got = grouped_gemm(xin, w, valid, block_f=bf)
    ref = grouped_gemm_ref(xin, w, valid)
    rtol, atol = _tol(dt)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=rtol, atol=atol)


def test_resolve_interpret_gates_on_backend(monkeypatch):
    from repro.kernels import runtime

    # explicit override always wins
    assert runtime.resolve_interpret(True) is True
    assert runtime.resolve_interpret(False) is False
    # env override beats backend detection
    monkeypatch.setenv(runtime.ENV_INTERPRET, "0")
    assert runtime.resolve_interpret(None) is False
    monkeypatch.setenv(runtime.ENV_INTERPRET, "1")
    assert runtime.resolve_interpret(None) is True
    assert runtime.resolve_interpret(False) is False  # arg still wins
    # default: compiled on TPU, interpreted everywhere else
    monkeypatch.delenv(runtime.ENV_INTERPRET)
    expected = jax.default_backend() != "tpu"
    assert runtime.resolve_interpret(None) is expected
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert runtime.resolve_interpret(None) is False
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert runtime.resolve_interpret(None) is True


def test_interpret_resolves_per_call_not_at_first_trace(monkeypatch):
    """The env override must apply to later calls too: resolution happens in
    the unjitted wrapper, keying the jit cache on the concrete mode."""
    from repro.kernels import runtime
    from repro.kernels.sspnna import sspnna as mod

    seen = {}

    def fake(feats, idx, w, *, interpret, **kw):
        seen["interpret"] = interpret

    monkeypatch.setattr(mod, "_sspnna_tiles", fake)
    mod.sspnna_tiles(None, None, None)
    assert seen["interpret"] is (jax.default_backend() != "tpu")
    monkeypatch.setenv(runtime.ENV_INTERPRET, "0")
    mod.sspnna_tiles(None, None, None)
    assert seen["interpret"] is False
    mod.sspnna_tiles(None, None, None, interpret=True)  # explicit still wins
    assert seen["interpret"] is True
