"""SLO-aware admission + bucketed continuous batching + the handle API.

Scheduler-level tests run on stub stages (no jax) so the admission logic
is exercised fast and deterministically; the SceneEngine integration
tests serve real scenes through a ``SignatureFamily``.
"""
import threading

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # image without hypothesis: deterministic local shim
    from _hypothesis_mini import given, settings, strategies as st

from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.models.scn import UNetConfig, init_unet
from repro.serving import (
    COMPLETED,
    QUEUED,
    SHED,
    AdmissionPolicy,
    RequestHandle,
    RequestShedError,
    ServeRequest,
    WaveScheduler,
)
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.sparse.tensor import SparseVoxelTensor, compact_to_capacity

RES, CAP = 16, 1024


# ---------------------------------------------------------------------------
# scheduler-level admission (stub stages, no jax)
# ---------------------------------------------------------------------------

def _stub_sched(batch=2, policy=None, bucket_of=None, waves=None, **kw):
    """WaveScheduler over no-op stages; `waves` records admitted rids."""
    rec = waves if waves is not None else []

    def dispatch(reqs, payloads, stats):
        rec.append([r.rid for r in reqs])
        return payloads

    return WaveScheduler(batch=batch, plan=lambda r: r.rid,
                         dispatch=dispatch, drain=lambda rs, h: None,
                         policy=policy, bucket_of=bucket_of, **kw)


def test_priority_preempts_fifo_order():
    waves = []
    sched = _stub_sched(batch=2, policy=AdmissionPolicy(), waves=waves)
    reqs = [ServeRequest(0), ServeRequest(1),
            ServeRequest(2, priority=5), ServeRequest(3, priority=5)]
    sched.submit(reqs)
    sched.run()
    assert waves == [[2, 3], [0, 1]]
    assert all(r.status == COMPLETED for r in reqs)


def test_deadline_expired_requests_shed_not_dropped():
    waves = []
    sched = _stub_sched(batch=2, policy=AdmissionPolicy(), waves=waves)
    live = ServeRequest(0)
    dead = ServeRequest(1, deadline_ms=5.0)
    sched.submit([live, dead])
    dead.submit_ts -= 10_000.0  # long expired by the time admission runs
    sched.run()
    assert dead.status == SHED and dead.shed_reason == "deadline"
    assert dead in sched.shed and dead.done_ts is not None
    assert waves == [[0]] and live.status == COMPLETED
    # the shed is surfaced on the handle too, never silently swallowed
    with pytest.raises(RequestShedError, match="deadline"):
        RequestHandle(dead, sched).result()
    stats = sched.slo_stats()
    assert stats["n_shed"] == 1
    assert stats["shed_by_reason"] == {"deadline": 1}


def test_all_shed_wave_skipped_without_dispatch():
    waves = []
    sched = _stub_sched(batch=2, policy=AdmissionPolicy(), waves=waves)
    reqs = [ServeRequest(i, deadline_ms=5.0) for i in range(4)]
    sched.submit(reqs)
    for r in reqs:
        r.submit_ts -= 10_000.0
    sched.run()
    assert waves == [] and sched.stats == []  # no wave formed, no dispatch
    assert all(r.status == SHED for r in reqs) and len(sched.shed) == 4


def test_backpressure_sheds_overload_at_submit():
    sched = _stub_sched(batch=2, policy=AdmissionPolicy(max_queue=2))
    reqs = [ServeRequest(i) for i in range(3)]
    sched.submit(reqs)
    assert len(sched.queue) == 2
    assert reqs[2].status == SHED and reqs[2].shed_reason == "overload"
    sched.run()
    assert [r.status for r in reqs] == [COMPLETED, COMPLETED, SHED]


def test_waves_fill_from_a_single_bucket():
    waves = []
    sched = _stub_sched(batch=2, policy=AdmissionPolicy(),
                        bucket_of=lambda r: r.tenant, waves=waves)
    # interleaved buckets: FIFO would head-of-line block every wave
    reqs = [ServeRequest(i, tenant="ab"[i % 2]) for i in range(6)]
    sched.submit(reqs)
    sched.run()
    for w in waves:
        assert len({reqs[rid].tenant for rid in w}) == 1  # never mixed
    assert sorted(r for w in waves for r in w) == list(range(6))
    # a straggler bucket defers to later waves instead of blocking: the
    # first wave fills to batch from one bucket, FIFO would stop at rid 0
    assert len(waves[0]) == 2
    # admission records what it saw per wave
    for s, w in zip(sched.stats, waves):
        assert s.bucket == reqs[w[0]].tenant
        assert s.fill_frac == len(w) / sched.batch


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 30), st.integers(1, 8), st.integers(1, 4))
def test_weighted_fairness_never_starves_late_tenant(n_a, n_b, w_b):
    """Stride scheduling: a tenant-a flood submitted first cannot starve
    tenant b; b's admitted share tracks its weight."""
    waves = []
    pol = AdmissionPolicy(tenant_weights={"b": float(w_b)})
    sched = _stub_sched(batch=1, policy=pol, waves=waves)
    sched.submit([ServeRequest(i, tenant="a") for i in range(n_a)])
    sched.submit([ServeRequest(100 + i, tenant="b") for i in range(n_b)])
    sched.run()
    order = [w[0] for w in waves]
    assert len(order) == n_a + n_b  # everyone serves eventually
    a_seen = b_seen = 0
    for rid in order:
        if rid < 100:
            a_seen += 1
        else:
            b_seen += 1
        if b_seen < n_b:
            # while b has pending work, a's admissions are bounded by the
            # stride ratio (pass_a = a_seen*1 vs pass_b = b_seen/w_b)
            assert a_seen <= b_seen / w_b + 2


def test_sync_async_admit_identical_wave_order():
    def serve(sync):
        waves = []
        sched = _stub_sched(
            batch=2, policy=AdmissionPolicy(),
            bucket_of=lambda r: r.tenant, waves=waves, sync=sync)
        sched.submit([
            ServeRequest(i, tenant="ab"[i % 2], priority=i % 3)
            for i in range(8)])
        sched.run()
        return waves

    assert serve(True) == serve(False)


def test_run_rejects_reentry_and_max_waves_ticks():
    waves = []
    sched = _stub_sched(batch=2, policy=AdmissionPolicy(), waves=waves)
    sched.submit([ServeRequest(i) for i in range(6)])
    sched.run(max_waves=1)
    assert len(waves) == 1 and len(sched.queue) == 4
    sched.run(max_waves=2)
    assert len(waves) == 3 and not sched.queue
    # reentry guard: run() while running raises instead of corrupting state
    blocker = threading.Event()
    slow = WaveScheduler(batch=1, plan=lambda r: r,
                         dispatch=lambda rs, ps, st: blocker.wait(5),
                         drain=lambda rs, h: None)
    slow.submit([ServeRequest(0)])
    t = threading.Thread(target=slow.run)
    t.start()
    while not slow.running:
        pass
    with pytest.raises(RuntimeError, match="in progress"):
        slow.run()
    blocker.set()
    t.join()


# ---------------------------------------------------------------------------
# SignatureFamily / compact_to_capacity units
# ---------------------------------------------------------------------------

def test_choose_buckets_quantized_and_covering():
    caps = engine.choose_buckets([100, 120, 130, 700], max_buckets=2,
                                 quantum=64)
    assert caps == tuple(sorted(set(caps)))
    assert all(c % 64 == 0 for c in caps)
    assert caps[-1] >= 700  # top tier covers the largest observed scene
    assert len(caps) <= 2
    with pytest.raises(ValueError):
        engine.choose_buckets([])


def test_signature_family_bucket_assignment():
    fam = engine.SignatureFamily((256, 1024))
    assert fam.n_buckets == 2 and fam.max_capacity == 1024
    assert fam.bucket_for(1) == 256 and fam.bucket_for(256) == 256
    assert fam.bucket_for(257) == 1024
    assert fam.bucket_for(2048) is None  # too big for every bucket
    with pytest.raises(ValueError, match="ascending"):
        engine.SignatureFamily((1024, 256))
    with pytest.raises(ValueError):
        engine.SignatureFamily(())


def test_compact_to_capacity_roundtrip():
    coords, feats, _, mask = make_scene(3, resolution=RES, capacity=CAP)
    t = SparseVoxelTensor(coords, feats, mask)
    n = int(np.asarray(mask).sum())
    cap = int(np.ceil(n / 64) * 64)
    small, idx = compact_to_capacity(t, cap)
    assert small.capacity == cap and len(idx) == n
    assert int(small.mask.sum()) == n
    np.testing.assert_array_equal(small.coords[:n],
                                  np.asarray(t.coords)[idx])
    np.testing.assert_array_equal(small.feats[:n],
                                  np.asarray(t.feats)[idx])
    with pytest.raises(ValueError, match="larger bucket"):
        compact_to_capacity(t, max(n - 1, 0))


# ---------------------------------------------------------------------------
# SceneEngine integration: bucketed serving end to end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _scene_with(seed, n_active):
    """A CAP-capacity scene trimmed to exactly n_active active voxels."""
    coords, feats, _, mask = make_scene(seed, resolution=RES, capacity=CAP)
    mask = np.asarray(mask).copy()
    idx = np.flatnonzero(mask)
    assert len(idx) >= n_active, "raise RES or lower n_active"
    mask[idx[n_active:]] = False
    return SparseVoxelTensor(np.asarray(coords), np.asarray(feats), mask)


def test_bucketed_serving_matches_single_signature(setup):
    cfg, params = setup
    fam = engine.SignatureFamily((256, CAP))
    scenes = [_scene_with(10 + i, 120 + 10 * i) for i in range(3)]  # small
    scenes += [_scene_with(20 + i, 500 + 10 * i) for i in range(3)]  # big
    eng = SceneEngine(cfg, params, batch=2, family=fam,
                      policy=AdmissionPolicy())
    handles = eng.submit([SceneRequest(i, s) for i, s in enumerate(scenes)])
    eng.serve()
    # one compiled signature per bucket actually used, never more
    assert eng.n_compilations == 2
    for s in eng.wave_stats:
        assert s.bucket in (256, CAP)
    # results come back at the request's original capacity, equal (on
    # active rows) to plain single-signature serving
    ref = SceneEngine(cfg, params, batch=2)
    ref_handles = ref.submit(
        [SceneRequest(i, s) for i, s in enumerate(scenes)])
    ref.serve()
    for h, rh in zip(handles, ref_handles):
        r, rr = h.result(), rh.result()
        assert r.logits.shape == rr.logits.shape == (CAP, N_CLASSES)
        m = np.asarray(r.scene.mask)
        np.testing.assert_allclose(r.logits[m], rr.logits[m],
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(r.logits[~m], 0.0)  # padding rows
    eng.close(), ref.close()


def test_warm_single_size_traffic_compiles_once(setup):
    cfg, params = setup
    fam = engine.SignatureFamily((256, CAP))
    eng = SceneEngine(cfg, params, batch=2, family=fam)
    for i in range(4):  # same bucket every wave: exactly one signature
        eng.submit(SceneRequest(i, _scene_with(40 + i, 150)))
    eng.serve()
    assert eng.n_compilations == 1
    assert all(s.bucket == 256 for s in eng.wave_stats)
    eng.close()


def test_oversize_scene_shed_with_capacity_reason(setup):
    cfg, params = setup
    fam = engine.SignatureFamily((256,))
    eng = SceneEngine(cfg, params, batch=2, family=fam)
    ok = eng.submit(SceneRequest(0, _scene_with(50, 100)))
    big = eng.submit(SceneRequest(1, _scene_with(51, 500)))
    assert big.status == SHED and big.request.shed_reason == "capacity"
    eng.serve()
    assert ok.result().logits is not None
    with pytest.raises(RequestShedError, match="capacity"):
        big.result()
    assert eng.slo_stats()["shed_by_reason"] == {"capacity": 1}
    eng.close()


def test_bucketed_async_matches_sync_bitwise(setup):
    cfg, params = setup
    fam = engine.SignatureFamily((256, CAP))

    def serve(sync):
        eng = SceneEngine(cfg, params, batch=2, family=fam,
                          policy=AdmissionPolicy(), sync=sync, depth=2,
                          planner_threads=2)
        handles = eng.submit(
            [SceneRequest(i, _scene_with(60 + i, 100 + 90 * i))
             for i in range(5)])
        eng.serve()
        out = {h.request.rid: h.result().logits for h in handles}
        eng.close()
        return out

    by_sync, by_async = serve(True), serve(False)
    for rid in by_sync:
        np.testing.assert_array_equal(by_sync[rid], by_async[rid])


def test_build_signature_family_pins_specs(setup):
    cfg, _ = setup
    scenes = [_scene_with(70 + i, n) for i, n in
              enumerate([100, 120, 140, 560, 600])]
    fam = engine.build_signature_family(scenes, cfg, max_buckets=2,
                                        quantum=64, mem_budget=16 * 1024)
    assert 1 <= fam.n_buckets <= 2
    assert fam.max_capacity >= 600
    for cap in fam.capacities:
        assert fam.spec_for(cap) is not None  # pinned per-bucket spec


# ---------------------------------------------------------------------------
# handle API + deprecation shims
# ---------------------------------------------------------------------------

def test_handle_result_drives_engine_and_status_flows(setup):
    cfg, params = setup
    eng = SceneEngine(cfg, params, batch=2)
    h = eng.submit(SceneRequest(0, _scene_with(80, 200)))
    assert h.status == QUEUED and not h.done()
    r = h.result()  # no active run: result() pumps the queue itself
    assert r is h.request and h.done() and h.status == COMPLETED
    assert r.latency_ms is not None and r.latency_ms >= 0.0
    assert r.logits is not None
    eng.close()


def test_deprecated_run_and_completed_shims(setup):
    cfg, params = setup
    eng = SceneEngine(cfg, params, batch=2)
    eng.submit([SceneRequest(i, _scene_with(90 + i, 200)) for i in range(2)])
    with pytest.warns(DeprecationWarning, match="deprecated in repro.serving"):
        done = eng.run()
    assert len(done) == 2
    with pytest.warns(DeprecationWarning, match="deprecated in repro.serving"):
        assert eng.completed == done
    eng.close()
