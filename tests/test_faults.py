"""Fault-tolerant serving runtime, exercised through seeded injection.

The contract under test: with ``AdmissionPolicy.max_retries > 0`` the
serving stack *contains* every fault ``serving.faults`` can inject —
requests end in exactly one terminal state (completed / shed / failed),
nothing is lost or duplicated, wave-mates of a poisoned request are never
charged its retries (bisection isolates the poison first), dispatch
failures attributed to a backend trip its circuit breaker so new plans
reroute along the fallback chain, and a fault-free hardened engine is
bitwise identical to the legacy one. ``--chaos-seeds`` widens the random
fault-plan matrix (the CI chaos job runs seeds 0..4).
"""
import threading
import time
import types

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # image without hypothesis: deterministic local shim
    from _hypothesis_mini import given, settings, strategies as st

from repro.engine.backends import (
    CLOSED,
    HALF_OPEN,
    OPEN,
    Backend,
    BackendRegistry,
    BreakerBoard,
    CircuitBreaker,
    default_registry,
)
from repro.serving.api import (
    AdmissionPolicy,
    RequestFailedError,
    RequestShedError,
    ServeRequest,
    ServingBase,
)
from repro.serving.faults import (
    DeviceFaultError,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    PlanFaultError,
    WorkerDeath,
    active,
    inject_faults,
)
from repro.serving.scheduler import StageTimeout, WaveScheduler


# -- stub engine -------------------------------------------------------------


class _StubEngine(ServingBase):
    """Tiny ServingBase over trivial stages: plan returns the rid, drain
    writes ``r.out = rid * 10`` (so cross-wave contamination is visible).
    ``fail_rids`` poisons dispatch permanently; ``flaky`` maps rid -> how
    many dispatch attempts fail before succeeding."""

    def __init__(self, batch=2, *, policy=None, faults=None, sync=True,
                 fail_rids=(), flaky=None, dispatch_sleep=None, **kw):
        self.fail_rids = set(fail_rids)
        self.flaky = dict(flaky or {})
        self.dispatch_sleep = dispatch_sleep or {}
        self._attempts: dict[int, int] = {}
        self.scheduler = WaveScheduler(
            batch=batch, plan=self._plan, dispatch=self._dispatch,
            drain=self._drain, sync=sync, policy=policy, faults=faults, **kw)

    def _plan(self, r):
        return r.rid

    def _dispatch(self, reqs, payloads, stats):
        for r in reqs:
            n = self._attempts.get(r.rid, 0)
            self._attempts[r.rid] = n + 1
            sleep = self.dispatch_sleep.get(r.rid)
            if sleep is not None:
                time.sleep(sleep)
            if r.rid in self.fail_rids:
                raise RuntimeError(f"poisoned rid {r.rid}")
            if n < self.flaky.get(r.rid, 0):
                raise RuntimeError(f"transient rid {r.rid} attempt {n}")
        return payloads

    def _drain(self, reqs, payloads):
        for r, p in zip(reqs, payloads):
            r.out = p * 10


def _conserved(eng, rids):
    """Every submitted rid lands in exactly one terminal bucket."""
    sched = eng.scheduler
    done = [r.rid for r in sched.completed]
    failed = [r.rid for r in sched.failed]
    shed = [r.rid for r in sched.shed]
    everything = done + failed + shed
    assert sorted(everything) == sorted(rids)  # no loss, no duplication
    assert not sched.queue
    for r in sched.completed:
        assert r.out == r.rid * 10  # results match their request
    return set(done), set(failed), set(shed)


# -- injector ----------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("no_such_seam", rate=0.5)
    with pytest.raises(ValueError):
        FaultSpec("dispatch", rate=1.5)
    FaultSpec("dispatch", rate=0.0)  # bounds are inclusive


def test_injector_deterministic_and_order_independent():
    plan = FaultPlan(seed=11, specs=(FaultSpec("plan", rate=0.5),))

    def fires(keys):
        inj = FaultInjector(plan)
        out = []
        for k in keys:
            try:
                inj.maybe_fail("plan", rid=k)
                out.append((k, False))
            except PlanFaultError:
                out.append((k, True))
        return out

    keys = list(range(20))
    a = fires(keys)
    b = fires(keys)
    assert a == b and any(f for _, f in a) and not all(f for _, f in a)
    # rolls are keyed, not sequenced: visiting the keys in another order
    # gives each key the same outcome
    shuffled = fires(keys[::-1])
    assert dict(shuffled) == dict(a)
    # ...and the Nth attempt at one key re-rolls (retries aren't sticky)
    inj = FaultInjector(FaultPlan(seed=3, specs=(FaultSpec("plan", rate=0.5),)))
    outcomes = []
    for _ in range(32):
        try:
            inj.maybe_fail("plan", rid=7)
            outcomes.append(False)
        except PlanFaultError:
            outcomes.append(True)
    assert True in outcomes and False in outcomes


def test_injector_targeting_gates():
    # rids: only the targeted request can fire
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec("dispatch", rate=1.0, rids=(3,)),)))
    inj.maybe_fail("dispatch", rid=2)
    with pytest.raises(DeviceFaultError):
        inj.maybe_fail("dispatch", rid=3)
    # max_fires: bounded injections; after: skips early opportunities
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec("plan", rate=1.0, max_fires=2, after=1),)))
    hits = 0
    for k in range(6):
        try:
            inj.maybe_fail("plan", rid=k)
        except PlanFaultError:
            hits += 1
    assert hits == 2
    assert inj.stats()["fires"]["plan"] == 2
    assert inj.stats()["opportunities"]["plan"] == 6


def test_corrupt_coords_identity_when_cold():
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec("corrupt_frame", rate=1.0, rids=(1,)),)))
    coords = np.arange(24, dtype=np.int32).reshape(8, 3)
    same = inj.corrupt_coords(coords, rid=0)  # untargeted: same object back
    assert same is coords
    bad = inj.corrupt_coords(coords, rid=1)
    assert bad is not coords and bad.shape == coords.shape
    assert not np.array_equal(bad, coords)
    np.testing.assert_array_equal(coords,
                                  np.arange(24, dtype=np.int32).reshape(8, 3))


def test_ambient_injector_crosses_threads():
    assert active() is None
    inj = FaultInjector(FaultPlan())
    seen = []
    with inject_faults(inj):
        t = threading.Thread(target=lambda: seen.append(active()))
        t.start()
        t.join()
    assert seen == [inj]  # module global, visible from worker threads
    assert active() is None


def test_backend_resolve_seam_fires():
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec("backend_resolve", rate=1.0),)))
    plan = types.SimpleNamespace()  # reference has no plan requirements
    with inject_faults(inj):
        with pytest.raises(DeviceFaultError):
            default_registry().resolve(plan, "reference")
    assert default_registry().resolve(plan, "reference") == "reference"


# -- retry budgets / containment (stub scheduler) ----------------------------


@pytest.mark.parametrize("sync", [True, False])
def test_retry_budget_terminal_failure(sync):
    eng = _StubEngine(batch=2, sync=sync, fail_rids={3},
                      policy=AdmissionPolicy(max_retries=2,
                                             retry_backoff_ms=1.0))
    handles = eng.submit([ServeRequest(i) for i in range(6)])
    eng.serve()
    done, failed, shed = _conserved(eng, range(6))
    assert done == {0, 1, 2, 4, 5} and failed == {3} and not shed
    bad = eng.failed[0]
    assert bad.status == "failed" and bad.shed_reason == "error"
    assert bad.retries == 3  # charged to the budget, then one final strike
    assert isinstance(bad.error, RuntimeError)
    slo = eng.slo_stats()
    assert slo["shed_by_reason"] == {"error": 1}
    assert slo["n_failed"] == 1 and slo["n_retries"] == 3
    assert slo["wave_errors"] >= 3
    # the handle surfaces the terminal failure as a typed error that old
    # `except RequestShedError` call sites still catch
    h3 = next(h for h in handles if h.request.rid == 3)
    assert h3.done()
    with pytest.raises(RequestFailedError, match="failed after 3 retries"):
        h3.result()
    assert issubclass(RequestFailedError, RequestShedError)
    eng.close()


def test_bisection_spares_wave_mates():
    # batch 4: rid 2's poison first fails waves holding innocents — they
    # must complete with zero retries charged
    eng = _StubEngine(batch=4, fail_rids={2},
                      policy=AdmissionPolicy(max_retries=1,
                                             retry_backoff_ms=1.0))
    eng.submit([ServeRequest(i) for i in range(8)])
    eng.serve()
    done, failed, _ = _conserved(eng, range(8))
    assert failed == {2} and done == set(range(8)) - {2}
    for r in eng.scheduler.completed:
        assert r.retries == 0  # innocents never charged
    assert eng.failed[0].retries == 2
    eng.close()


def test_retry_backoff_is_exponential_waiting():
    eng = _StubEngine(batch=1, flaky={0: 2},
                      policy=AdmissionPolicy(max_retries=3,
                                             retry_backoff_ms=40.0))
    eng.submit(ServeRequest(0))
    t0 = time.perf_counter()
    eng.serve()
    elapsed = time.perf_counter() - t0
    done, failed, _ = _conserved(eng, [0])
    assert done == {0} and not failed
    assert eng.scheduler.completed[0].retries == 2
    assert elapsed >= 0.10  # 40ms + 80ms backoff actually waited out
    eng.close()


def test_legacy_mode_still_requeues_and_raises():
    # max_retries=0 (the default): the pre-hardening contract is intact
    eng = _StubEngine(batch=2, fail_rids={1})
    eng.submit([ServeRequest(i) for i in range(4)])
    with pytest.raises(RuntimeError, match="poisoned rid 1"):
        eng.serve()
    assert not eng.scheduler.failed
    queued = [r.rid for r in eng.scheduler.queue]
    done = [r.rid for r in eng.scheduler.completed]
    assert sorted(done + queued) == [0, 1, 2, 3]  # nothing dropped
    assert 1 in queued
    eng.close()


@pytest.mark.parametrize("sync", [True, False])
def test_worker_death_contained_only_with_budget(sync):
    faults = FaultPlan(specs=(FaultSpec("worker_death", rate=1.0,
                                        rids=(1,)),))
    # legacy: the BaseException escapes (except Exception won't catch it)
    eng = _StubEngine(batch=1, sync=sync, faults=FaultInjector(faults))
    eng.submit([ServeRequest(i) for i in range(3)])
    with pytest.raises(WorkerDeath):
        eng.serve()
    eng.close()
    # contained: the dead worker's request fails terminally, others serve
    eng = _StubEngine(batch=1, sync=sync, faults=FaultInjector(faults),
                      policy=AdmissionPolicy(max_retries=1,
                                             retry_backoff_ms=1.0))
    eng.submit([ServeRequest(i) for i in range(3)])
    eng.serve()
    done, failed, _ = _conserved(eng, range(3))
    assert failed == {1} and done == {0, 2}
    assert isinstance(eng.failed[0].error, WorkerDeath)
    eng.close()


def test_keyboard_interrupt_never_contained():
    class _Interrupting(_StubEngine):
        def _dispatch(self, reqs, payloads, stats):
            raise KeyboardInterrupt

    eng = _Interrupting(batch=2,
                        policy=AdmissionPolicy(max_retries=5,
                                               retry_backoff_ms=1.0))
    eng.submit([ServeRequest(i) for i in range(2)])
    with pytest.raises(KeyboardInterrupt):
        eng.serve()
    eng.close()


def test_stage_timeout_watchdog():
    eng = _StubEngine(batch=1, dispatch_sleep={0: 0.3},
                      policy=AdmissionPolicy(max_retries=1,
                                             retry_backoff_ms=1.0,
                                             stage_timeout_s=0.05))
    eng.submit([ServeRequest(i) for i in range(2)])
    eng.serve()
    done, failed, _ = _conserved(eng, range(2))
    assert failed == {0} and done == {1}
    assert isinstance(eng.failed[0].error, StageTimeout)
    eng.close()


def test_slow_wave_stall_injected():
    faults = FaultInjector(FaultPlan(specs=(
        FaultSpec("slow_wave", rate=1.0, delay_ms=30.0, max_fires=2),)))
    eng = _StubEngine(batch=2, faults=faults)
    eng.submit([ServeRequest(i) for i in range(4)])
    t0 = time.perf_counter()
    eng.serve()
    assert time.perf_counter() - t0 >= 0.05  # two 30ms stalls were real
    done, failed, _ = _conserved(eng, range(4))
    assert done == set(range(4)) and not failed
    assert faults.stats()["fires"]["slow_wave"] == 2
    eng.close()


# -- conservation property under random fault plans --------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_conservation_under_random_faults(seed):
    """Whatever a random FaultPlan throws at the contained runtime, every
    request ends in exactly one terminal state in both modes."""
    for sync in (True, False):
        eng = _StubEngine(batch=3, sync=sync,
                          faults=FaultInjector(FaultPlan.random(seed)),
                          policy=AdmissionPolicy(max_retries=2,
                                                 retry_backoff_ms=0.5))
        eng.submit([ServeRequest(i) for i in range(10)])
        eng.serve()
        done, failed, shed = _conserved(eng, range(10))
        assert not shed  # no deadlines/backpressure configured
        assert done | failed == set(range(10))
        eng.close()


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_sync_async_identical_for_request_keyed_faults(seed):
    """Faults rolled per-request (plan / worker_death seams, solo waves)
    give each rid the same terminal fate in sync and async modes."""
    rng = np.random.default_rng(seed)
    specs = tuple(
        FaultSpec(seam, rate=float(rng.uniform(0.1, 0.5)))
        for seam in ("plan", "worker_death") if rng.random() < 0.8) or (
        FaultSpec("plan", rate=0.3),)
    plan = FaultPlan(seed=seed, specs=specs)

    def terminal(sync):
        eng = _StubEngine(batch=1, sync=sync, faults=FaultInjector(plan),
                          policy=AdmissionPolicy(max_retries=2,
                                                 retry_backoff_ms=0.5))
        eng.submit([ServeRequest(i) for i in range(8)])
        eng.serve()
        _conserved(eng, range(8))
        out = {r.rid: (r.status, r.retries)
               for r in (eng.scheduler.completed + eng.scheduler.failed)}
        eng.close()
        return out

    assert terminal(True) == terminal(False)


def test_chaos_matrix(chaos_seed):
    """The CI chaos job's entry point: a resident stub engine survives a
    randomized fault plan end to end (``--chaos-seeds`` widens the
    matrix)."""
    eng = _StubEngine(batch=3,
                      faults=FaultInjector(FaultPlan.random(chaos_seed)),
                      policy=AdmissionPolicy(max_retries=2,
                                             retry_backoff_ms=0.5))
    eng.serve_forever()
    handles = []
    for burst in range(4):
        handles += eng.submit(
            [ServeRequest(burst * 10 + i) for i in range(10)])
        time.sleep(0.002)
    deadline = time.monotonic() + 30.0
    while not all(h.done() for h in handles):
        assert time.monotonic() < deadline, "chaos run wedged"
        time.sleep(0.005)
    h = eng.health()
    assert h["alive"] and h["ready"] and h["resident"]
    eng.close()
    rids = [h.request.rid for h in handles]
    done, failed, shed = _conserved(eng, rids)
    assert not shed and done | failed == set(rids)
    assert not eng.health()["alive"]


# -- serve_forever lifecycle (stub) ------------------------------------------


def test_serve_forever_lifecycle_and_health():
    eng = _StubEngine(batch=2, fail_rids={5},
                      policy=AdmissionPolicy(max_retries=1,
                                             retry_backoff_ms=1.0))
    t = eng.serve_forever()
    assert eng.serve_forever() is t  # idempotent while alive
    handles = eng.submit([ServeRequest(i) for i in range(8)])
    deadline = time.monotonic() + 15.0
    while not all(h.done() for h in handles):
        assert time.monotonic() < deadline
        time.sleep(0.005)
    h = eng.health()
    assert h["alive"] and h["resident"] and not h["draining"]
    assert h["n_completed"] == 7 and h["n_failed"] == 1
    assert h["queue_depth"] == 0 and h["last_wave_age_s"] is not None
    with pytest.raises(RequestFailedError):
        handles[5].result(timeout=1.0)
    eng.close()
    eng.close()  # idempotent
    assert not eng.health()["alive"] and not eng.health()["resident"]
    # the engine stays usable after close: caller-driven serving works
    h2 = eng.submit(ServeRequest(100))
    assert h2.result().out == 1000
    eng.close()


def test_close_drains_then_rejects_new_submits():
    class _SlowPlan(_StubEngine):
        def _plan(self, r):
            time.sleep(0.01)
            return r.rid

    eng = _SlowPlan(batch=1)
    eng.serve_forever()
    handles = eng.submit([ServeRequest(i) for i in range(5)])
    eng.close()  # graceful: the queued backlog is served, not dropped
    assert all(h.done() for h in handles)
    assert {h.request.rid for h in handles
            if h.request.status == "completed"} == set(range(5))
    # after close the resident thread is gone; _draining was reset, so a
    # plain submit serves caller-driven again
    assert eng.submit(ServeRequest(9)).result().out == 90
    eng.close()


# -- circuit breakers (fake clock) -------------------------------------------


def test_circuit_breaker_state_machine():
    now = [0.0]
    br = CircuitBreaker("x", failure_threshold=2, cooldown_s=5.0,
                        clock=lambda: now[0])
    assert br.state == CLOSED and br.allow()
    assert not br.record_failure()         # 1 strike: still closed
    assert br.record_failure()             # 2nd strike: trips
    assert br.state == OPEN and br.trips == 1
    assert not br.allow()                  # cooling
    now[0] = 5.1
    assert br.allow()                      # cooldown passed: one probe
    assert br.state == HALF_OPEN
    assert br.record_failure()             # probe failed: re-open
    assert br.state == OPEN and br.trips == 2
    now[0] = 10.3
    assert br.allow() and br.state == HALF_OPEN
    assert br.record_success()             # probe succeeded: closed
    assert br.state == CLOSED and br.consecutive_failures == 0
    assert br.snapshot() == {"state": CLOSED, "consecutive_failures": 0,
                             "trips": 2}
    with pytest.raises(ValueError):
        CircuitBreaker("x", failure_threshold=0)


class _NullBackend(Backend):
    def __init__(self, name, fallback=None):
        self.name, self.fallback = name, fallback

    def run(self, x, params, plan, *, ctx, **kw):
        return x


def test_breaker_board_routes_along_fallback_chain():
    reg = BackendRegistry()
    reg.register("a", _NullBackend("a", fallback="b"))
    reg.register("b", _NullBackend("b", fallback="c"))
    reg.register("c", _NullBackend("c"))
    now = [0.0]
    board = BreakerBoard(reg, failure_threshold=2, cooldown_s=5.0,
                         clock=lambda: now[0])
    assert board.route("a") == "a" and board.generation == 0
    board.record_failure("a")
    assert board.route("a") == "a"  # one strike: still closed
    changed = board.record_failure("a")
    assert changed and board.generation == 1
    assert board.route("a") == "b"          # tripped: next in chain
    for _ in range(2):
        board.record_failure("b")
    assert board.route("a") == "c"          # chain walks past b too
    assert board.allow("c") and not board.allow("a")
    assert "gen=" in repr(board)
    # recovery: cooldown -> half-open probe allowed -> success closes,
    # bumping the generation again (cached plans rotate)
    now[0] = 6.0
    assert board.route("a") == "a"
    gen = board.generation
    assert board.record_success("a")
    assert board.generation == gen + 1 and board.route("a") == "a"
    # unknown names route to themselves (no breaker is ever created)
    assert board.route("mystery") == "mystery"
    assert "mystery" not in board.states()


def test_breaker_board_fallback_cycle_is_safe():
    reg = BackendRegistry()
    reg.register("a", _NullBackend("a", fallback="b"))
    reg.register("b", _NullBackend("b", fallback="a"))
    board = BreakerBoard(reg, failure_threshold=1, cooldown_s=99.0)
    board.record_failure("a")
    board.record_failure("b")
    # both blocked and the chain is a cycle: something must still serve
    assert board.route("a") in ("a", "b")


def test_breaker_board_hooks_fire_on_state_change_only():
    reg = BackendRegistry()
    reg.register("a", _NullBackend("a"))
    board = BreakerBoard(reg, failure_threshold=2, cooldown_s=99.0)
    bumps = []
    board.add_hook(lambda: bumps.append(board.generation))
    board.record_failure("a")
    assert bumps == []          # no state change yet
    board.record_failure("a")
    assert bumps == [1]         # trip -> hook (cache invalidation) fires
    board.record_success("x")   # unknown backend: no-op
    assert bumps == [1]

    def boom():
        raise RuntimeError("observer bug")

    board2 = BreakerBoard(reg, failure_threshold=1, cooldown_s=99.0)
    board2.add_hook(boom)
    assert board2.record_failure("a")  # hook errors never break serving


# -- real engine: breakers, identity, resident serving, streams --------------

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import engine  # noqa: E402
from repro.data.scenes import N_CLASSES, make_scene  # noqa: E402
from repro.engine.context import ExecutionContext  # noqa: E402
from repro.engine.plan import PlanCache  # noqa: E402
from repro.models.scn import UNetConfig, init_unet  # noqa: E402
from repro.serving.scene_engine import SceneEngine, SceneRequest  # noqa: E402
from repro.sparse.tensor import SparseVoxelTensor  # noqa: E402

RES, CAP = 16, 1024


def _scene(seed, cap=CAP):
    coords, feats, _, mask = make_scene(seed, resolution=RES, capacity=cap)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


@pytest.fixture(scope="module")
def setup():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_plan_cache_waiter_sees_builder_error(setup):
    """Coalesced waiters of a failing build must raise the builder's
    error (not hang, not silently rebuild); the key is then released so a
    later caller builds fresh."""
    cfg, _ = setup
    cache = PlanCache(capacity=4)
    t = _scene(810)
    started, release = threading.Event(), threading.Event()
    calls: list = []
    errors: dict = {}

    def failing_builder(t, cfg, **kw):
        calls.append(1)
        started.set()
        assert release.wait(5.0)
        raise ValueError("injected build failure")

    def worker(i):
        try:
            cache.get_or_build(t, cfg, builder=failing_builder,
                               plan_tiles=False)
        except ValueError as e:
            errors[i] = e

    a = threading.Thread(target=worker, args=(0,))
    a.start()
    assert started.wait(5.0)          # A is inside the build
    b = threading.Thread(target=worker, args=(1,))
    b.start()                         # B coalesces onto A's in-flight build
    time.sleep(0.05)
    release.set()                     # ...and only now does the build fail
    a.join()
    b.join()
    # exactly one build ran; both the builder AND the waiter saw its error
    assert len(calls) == 1
    assert sorted(errors) == [0, 1]
    assert all("injected build failure" in str(e) for e in errors.values())
    assert len(cache) == 0
    # the key was released: a fresh call rebuilds successfully
    assert cache.get_or_build(t, cfg, plan_tiles=False) is not None
    assert len(cache) == 1


def test_breaker_trip_invalidates_context_plan_cache(setup):
    cfg, _ = setup
    ctx = ExecutionContext(plan_cache=PlanCache(capacity=8))
    ctx.registry.breakers.configure(failure_threshold=1, cooldown_s=99.0)
    ctx.plan_cache.get_or_build(_scene(820), cfg, plan_tiles=False)
    assert len(ctx.plan_cache) == 1
    ctx.registry.breakers.record_failure("sspnna")  # trips immediately
    assert len(ctx.plan_cache) == 0  # hook dropped stale-routing plans
    # breakers are context-scoped: the process default board is untouched
    assert "sspnna" not in default_registry().breakers.states()


def test_faults_disabled_hardened_engine_is_bitwise_identical(setup):
    """The robustness machinery must be invisible when nothing fails:
    a hardened engine (retry budget armed, no injector) produces bitwise
    the same logits as the legacy configuration."""
    cfg, params = setup
    scenes = [_scene(830 + i) for i in range(4)]

    def serve(policy):
        eng = SceneEngine(cfg, params, batch=2, sync=True, policy=policy)
        handles = eng.submit(
            [SceneRequest(i, s) for i, s in enumerate(scenes)])
        eng.serve()
        out = {h.request.rid: np.asarray(h.result().logits)
               for h in handles}
        eng.close()
        return out

    legacy = serve(None)
    hardened = serve(AdmissionPolicy(max_retries=2, retry_backoff_ms=1.0))
    assert legacy.keys() == hardened.keys()
    for rid in legacy:
        np.testing.assert_array_equal(legacy[rid], hardened[rid])


def test_dispatch_faults_trip_breaker_to_fallback(setup):
    """5%-style dispatch faults attributed to sspnna: the breaker trips
    OPEN, new plans reroute to the reference fallback, every request
    still completes, and the answers match a reference-only engine."""
    cfg, params = setup
    spec = engine.build_plan_spec([_scene(100), _scene(101)], cfg,
                                  mem_budget=16 * 1024)
    assert any(d.backend == engine.SSPNNA for d in spec.levels)
    ctx = ExecutionContext(plan_cache=PlanCache())
    ctx.registry.breakers.configure(failure_threshold=3, cooldown_s=60.0)
    inj = FaultInjector(FaultPlan(seed=0, specs=(
        FaultSpec("dispatch", rate=1.0, backend="sspnna", max_fires=3),)))
    eng = SceneEngine(cfg, params, batch=2, spec=spec, use_kernel=False,
                      sync=True, ctx=ctx, faults=inj,
                      policy=AdmissionPolicy(max_retries=4,
                                             retry_backoff_ms=1.0))
    scenes = [_scene(300 + i) for i in range(4)]
    handles = eng.submit([SceneRequest(i, s) for i, s in enumerate(scenes)])
    eng.serve()
    results = {h.request.rid: h.result() for h in handles}
    assert sorted(results) == [0, 1, 2, 3]  # nothing lost to the faults
    states = ctx.registry.breakers.states()
    assert states["sspnna"]["state"] == OPEN and states["sspnna"]["trips"] == 1
    assert eng.health()["breakers"]["sspnna"]["state"] == OPEN
    assert eng.scheduler.wave_errors == 3
    eng.close()
    ref = SceneEngine(cfg, params, batch=2, sync=True)
    rh = ref.submit([SceneRequest(i, s) for i, s in enumerate(scenes)])
    ref.serve()
    for i, h in enumerate(rh):
        np.testing.assert_allclose(np.asarray(results[i].logits),
                                   np.asarray(h.result().logits),
                                   rtol=1e-5, atol=1e-5)
    ref.close()


def test_serve_forever_survives_200_requests_with_faults(setup):
    """The acceptance bar: a resident real engine at a 5% dispatch fault
    rate survives 200 requests — conservation holds, the vast majority
    complete, and health stays coherent through close()."""
    cfg, params = setup
    inj = FaultInjector(FaultPlan(seed=3, specs=(
        FaultSpec("dispatch", rate=0.05),)))
    eng = SceneEngine(cfg, params, batch=2, sync=True, faults=inj,
                      policy=AdmissionPolicy(max_retries=3,
                                             retry_backoff_ms=1.0))
    eng.serve_forever()
    scenes = [_scene(840 + i) for i in range(6)]  # cycled: plan-cache hits
    handles = [eng.submit(SceneRequest(i, scenes[i % len(scenes)]))
               for i in range(200)]
    deadline = time.monotonic() + 300.0
    while not all(h.done() for h in handles):
        assert time.monotonic() < deadline, "resident serving wedged"
        time.sleep(0.01)
    assert eng.health()["alive"]
    eng.close()
    slo = eng.slo_stats()
    assert slo["n_completed"] + slo["n_failed"] == 200
    assert slo["n_completed"] >= 190  # non-cliff: faults cost retries,
    assert inj.stats()["fires"].get("dispatch", 0) > 0  # not completions
    for h in handles:
        try:
            r = h.result(timeout=1.0)
            assert r.logits is not None and not np.any(np.isnan(r.logits))
        except RequestFailedError:
            pass
    assert not eng.health()["alive"]


def test_corrupt_stream_frame_is_contained(setup):
    """A corrupted LiDAR frame (seeded garbage coords) must not wedge the
    stream: the frame is retried clean (or failed terminally) and later
    frames still serve."""
    cfg, params = setup
    from repro.data.scenes import make_lidar_sweep
    frames, shifts = make_lidar_sweep(9, 4, resolution=RES, capacity=256,
                                      step=4, churn=0.1)
    scenes = [SparseVoxelTensor(jnp.asarray(c), jnp.asarray(f),
                                jnp.asarray(m)) for c, f, _, m in frames]
    small = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=256,
                       n_classes=N_CLASSES)
    sp = init_unet(jax.random.PRNGKey(0), small)
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec("corrupt_frame", rate=1.0, rids=(1,), max_fires=1),)))
    eng = SceneEngine(small, sp, batch=2, sync=True, faults=inj,
                      policy=AdmissionPolicy(max_retries=2,
                                             retry_backoff_ms=1.0))
    reqs = eng.serve_stream(scenes, shifts)
    assert inj.stats()["fires"]["corrupt_frame"] == 1
    by_status = {r.rid: r.status for r in reqs}
    # nothing is lost and the corrupted frame never wedges its successors
    assert all(s in ("completed", "failed") for s in by_status.values())
    assert by_status[0] == by_status[2] == by_status[3] == "completed"
    for r in reqs:
        if r.status == "completed":
            assert not np.any(np.isnan(r.logits))
    eng.close()
