"""Async wave pipeline: sync/async equivalence, thread-safe plan cache,
poisoned-wave recovery, shared scheduler plumbing."""
import threading
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.engine.plan import PlanCache
from repro.models.scn import UNetConfig, init_unet
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.serving.scheduler import WaveScheduler
from repro.sparse.tensor import SparseVoxelTensor

RES, CAP = 16, 1024


def _scene(seed, cap=CAP):
    coords, feats, _, mask = make_scene(seed, resolution=RES, capacity=cap)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


@pytest.fixture(scope="module")
def setup():
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(eng, scenes):
    handles = eng.submit([SceneRequest(i, s) for i, s in enumerate(scenes)])
    eng.serve()
    return {h.request.rid: h.result() for h in handles}


def test_async_matches_sync_bitwise(setup):
    cfg, params = setup
    scenes = [_scene(200 + i) for i in range(5)]  # batch 2 -> short last wave
    by_sync = _serve(SceneEngine(cfg, params, batch=2, sync=True), scenes)
    by_async = _serve(SceneEngine(cfg, params, batch=2, sync=False, depth=2,
                                  planner_threads=2), scenes)
    assert by_sync.keys() == by_async.keys()
    for rid in by_sync:
        np.testing.assert_array_equal(by_sync[rid].logits,
                                      by_async[rid].logits)
        assert by_async[rid].done


def test_async_matches_sync_with_pinned_spec(setup):
    cfg, params = setup
    spec = engine.build_plan_spec([_scene(100), _scene(101)], cfg,
                                  mem_budget=16 * 1024)
    assert any(d.backend == engine.SSPNNA for d in spec.levels)
    scenes = [_scene(300 + i) for i in range(4)]
    by_sync = _serve(SceneEngine(cfg, params, batch=2, spec=spec,
                                 use_kernel=False, sync=True), scenes)
    eng = SceneEngine(cfg, params, batch=2, spec=spec, use_kernel=False,
                      sync=False)
    by_async = _serve(eng, scenes)
    for rid in by_sync:
        np.testing.assert_array_equal(by_sync[rid].logits,
                                      by_async[rid].logits)
    assert eng.n_compilations == 1  # pinned spec: one signature, async too


def test_async_wave_stats_and_timings(setup):
    cfg, params = setup
    eng = SceneEngine(cfg, params, batch=2, sync=False)
    _serve(eng, [_scene(400 + i) for i in range(4)])
    assert len(eng.wave_stats) == 2
    for st in eng.wave_stats:
        assert st.plan_ms > 0 and st.device_ms > 0
        assert 0.0 <= st.overlap_frac <= 1.0
        assert not st.sync
    tm = eng.timings()
    assert tm["waves"] == 2
    assert set(tm) >= {"plan_ms", "plan_wait_ms", "device_ms", "drain_ms",
                       "overlap_frac"}
    # sync mode reports zero overlap by construction
    es = SceneEngine(cfg, params, batch=2, sync=True)
    _serve(es, [_scene(500 + i) for i in range(2)])
    assert es.timings()["overlap_frac"] == 0.0


def test_plan_cache_concurrent_same_scene_builds_once(setup):
    cfg, _ = setup
    cache = PlanCache(capacity=8)
    t = _scene(600)
    n = 8
    results: list = [None] * n
    barrier = threading.Barrier(n)

    def worker(i):
        barrier.wait()
        results[i] = cache.get_or_build(t, cfg, plan_tiles=False)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert cache.misses == 1 and cache.hits == n - 1
    assert len(cache) == 1
    assert all(r is results[0] for r in results)  # one shared plan object


def test_plan_cache_concurrent_distinct_scenes(setup):
    cfg, _ = setup
    cache = PlanCache(capacity=8)
    scenes = [_scene(700 + i) for i in range(4)]
    out: dict = {}
    barrier = threading.Barrier(len(scenes))

    def worker(i):
        barrier.wait()
        out[i] = cache.get_or_build(scenes[i], cfg, plan_tiles=False)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(scenes))]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert cache.misses == len(scenes) and len(cache) == len(scenes)
    # host/device split: device=False returns numpy-leaf plans, device=True
    # the memoized uploaded twin
    host = cache.get_or_build(scenes[0], cfg, device=False, plan_tiles=False)
    assert isinstance(host.levels[0].sub.coir.indices, np.ndarray)
    dev = cache.get_or_build(scenes[0], cfg, device=True, plan_tiles=False)
    assert dev is cache.get_or_build(scenes[0], cfg, device=True,
                                     plan_tiles=False)
    np.testing.assert_array_equal(np.asarray(dev.levels[0].sub.coir.indices),
                                  host.levels[0].sub.coir.indices)


def test_plan_cache_failed_build_releases_key(setup):
    cfg, _ = setup
    cache = PlanCache(capacity=4)
    bad = _scene(800)
    bad_cfg = UNetConfig(widths=(8, 16, 32), reps=1, resolution=RES,
                         capacity=CAP, n_classes=N_CLASSES)
    spec = engine.build_plan_spec([_scene(801)], cfg, mem_budget=16 * 1024)
    with pytest.raises(ValueError):  # spec levels != cfg levels
        cache.get_or_build(bad, bad_cfg, spec=spec)
    # the key is released: a second attempt raises again (no deadlock) and
    # the cache still works for good builds
    with pytest.raises(ValueError):
        cache.get_or_build(bad, bad_cfg, spec=spec)
    assert cache.get_or_build(bad, cfg, plan_tiles=False) is not None


@pytest.mark.parametrize("sync", [True, False])
def test_poisoned_wave_requeues_without_losing_requests(setup, sync):
    cfg, params = setup
    eng = SceneEngine(cfg, params, batch=2, sync=sync, depth=2,
                      planner_threads=2)
    reqs = [SceneRequest(i, _scene(900 + i)) for i in range(6)]
    # rid 2 has a different capacity: its plan/feats can't stack with the
    # wave -> dispatch blows up after wave 0 is already in flight
    reqs[2] = SceneRequest(2, _scene(902, cap=CAP // 2))
    eng.submit(reqs)
    with pytest.raises(Exception):
        eng.serve()
    done = {r.rid for r in reqs if r.status == "completed"}
    queued = [r.rid for r in eng.queue]
    # nothing dropped, nothing duplicated, poisoned wave back at the front
    assert sorted(done) + queued == list(range(6))
    assert 2 in queued
    # drop the poison and the remaining requests serve to completion
    good = [r for r in eng.queue if r.rid != 2]
    eng.queue.clear()
    eng.submit(good)
    eng.serve()
    survivors = [r for r in reqs if r.rid != 2]
    assert {r.rid for r in survivors if r.status == "completed"} == \
        {0, 1, 3, 4, 5}
    for r in survivors:
        assert r.logits is not None and not np.any(np.isnan(r.logits))


def test_scheduler_validates_knobs():
    stages = dict(plan=lambda r: r, dispatch=lambda rs, ps, st: ps,
                  drain=lambda rs, h: None)
    with pytest.raises(ValueError):
        WaveScheduler(batch=0, **stages)
    with pytest.raises(ValueError):
        WaveScheduler(batch=1, depth=0, **stages)
    with pytest.raises(ValueError):
        WaveScheduler(batch=1, planner_threads=0, **stages)
    sched = WaveScheduler(batch=2, **stages)
    assert isinstance(sched.queue, deque)
    assert sched.run() == []  # empty queue is a no-op in both modes
    assert sched.run(sync=False) == []


def test_close_idempotent_and_drains_inflight_plans(setup):
    """close() racing an async run waits for the run — draining its
    planner-thread futures — instead of cancelling them; repeated closes
    are no-ops and the engine stays usable afterwards."""
    cfg, params = setup
    eng = SceneEngine(cfg, params, batch=2, sync=False, depth=2,
                      planner_threads=2)
    scenes = [_scene(1100 + i) for i in range(6)]
    handles = eng.submit([SceneRequest(i, s) for i, s in enumerate(scenes)])
    t = threading.Thread(target=eng.serve)
    t.start()
    eng.close()  # may land mid-run: must block until the run drains
    t.join()
    for h in handles:
        assert h.done() and h.result().logits is not None
    eng.close()  # idempotent
    eng.close()
    # a later serve lazily recreates the planner pool
    h2 = eng.submit(SceneRequest(99, _scene(1199)))
    eng.serve()
    assert h2.result().logits is not None
    eng.close()


def test_lm_engine_async_matches_sync(rng):
    from repro.configs import get_config
    from repro.models.transformer import init_lm
    from repro.serving.engine import Engine, Request

    cfg = get_config("stablelm-1.6b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(5)]

    def serve(sync, eos=None):
        eng = Engine(cfg, params, batch=2, prompt_len=16, max_new=4, eos=eos,
                     sync=sync)
        handles = eng.submit([Request(i, p) for i, p in enumerate(prompts)])
        eng.serve()
        return {h.request.rid: h.result().out for h in handles}

    outs_sync, outs_async = serve(True), serve(False)
    assert outs_sync == outs_async
    assert all(len(o) == 4 for o in outs_sync.values())
    # EOS truncation happens at drain time -> still mode-independent
    eos = outs_sync[0][0]
    assert serve(True, eos=eos) == serve(False, eos=eos)


def test_async_survives_plan_cache_eviction(setup):
    """LRU pressure between plan and dispatch must not rebuild or corrupt:
    dispatch adopts the plan-stage payload instead of re-building."""
    cfg, params = setup
    scenes = [_scene(1000 + i) for i in range(6)]
    by_sync = _serve(SceneEngine(cfg, params, batch=2, sync=True), scenes)
    eng = SceneEngine(cfg, params, batch=2, sync=False, depth=2,
                      planner_threads=2, plan_cache_size=1)
    by_async = _serve(eng, scenes)
    for rid in by_sync:
        np.testing.assert_array_equal(by_sync[rid].logits,
                                      by_async[rid].logits)
    # one counted miss per distinct scene at the plan stage; the dispatch
    # adoption path never counts and never rebuilds
    assert eng.cache.misses == len(scenes) and eng.cache.hits == 0
