"""Serving engine + pipeline parallelism + manual collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import init_lm
from repro.serving.engine import Engine, Request, make_prefill, make_serve_step


def test_engine_continuous_batching(rng):
    cfg = get_config("stablelm-1.6b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch=2, prompt_len=16, max_new=4)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 10).astype(np.int32),
                    max_new=4) for i in range(5)]
    handles = eng.submit(reqs)
    eng.serve()
    done = [h.result() for h in handles]
    assert len(done) == 5
    for r in done:
        assert len(r.out) >= 1
        assert all(0 <= t < cfg.vocab_size for t in r.out)


def test_serve_step_greedy_matches_prefill_logits(rng):
    cfg = get_config("granite-8b").reduced()
    params = init_lm(jax.random.PRNGKey(1), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    prefill = make_prefill(cfg, cache_pad=2)
    step = make_serve_step(cfg)
    last, cache = prefill(params, toks)
    nxt, logits, cache = step(params,
                              jnp.argmax(last[:, :cfg.vocab_size], -1)
                              .astype(jnp.int32)[:, None], cache)
    assert nxt.shape == (2,)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_pipeline_parallel_matches_serial():
    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 devices")
    from repro.dist.compat import make_mesh
    from repro.dist.pipeline import pipeline_apply, stack_stages

    n_stages = 2
    mesh = make_mesh((n_stages,), ("pipe",))
    rng = np.random.default_rng(1)
    stages = [{"w": jnp.asarray(rng.normal(size=(16, 16)) * 0.3, jnp.float32)}
              for _ in range(n_stages)]

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    x = jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)
    out = pipeline_apply(mesh, stage_fn, stack_stages(stages), x)
    ref = x
    for p in stages:
        ref = stage_fn(p, ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


def test_pipeline_parallel_single_device_mesh():
    """GPipe stage lib on a 1-wide pipe mesh == plain serial apply."""
    from repro.dist.compat import make_mesh
    from repro.dist.pipeline import pipeline_apply, stack_stages

    mesh = make_mesh((1,), ("pipe",))
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(16, 16)) * 0.3, jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    stacked = stack_stages([{"w": w}])
    x = jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)  # 4 microbatches
    out = pipeline_apply(mesh, stage_fn, stacked, x)
    ref = jnp.tanh(x @ w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


def test_compressed_psum_single_device():
    from repro.dist.collectives import compressed_psum
    from repro.dist.compat import make_mesh

    mesh = make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(300,)),
                          jnp.float32)}
    out = compressed_psum(mesh, g, axis="pod")
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) < 1.5 * scale
