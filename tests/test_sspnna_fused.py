"""Fused gather-GEMM-scatter SSpNNA kernel: bitwise oracle equivalence,
DMA-table layout, dead-tile skip, HLO traffic elimination, plan-key bump."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # image without hypothesis: deterministic local shim
    from _hypothesis_mini import given, settings, strategies as st

from conftest import make_shell_scene
from repro import engine
from repro.core.tiles import (
    build_tile_plan,
    dma_tile_tables,
    modeled_hbm_bytes,
    plan_dma_tables,
)
from repro.kernels.sspnna.ops import run_sspnna_conv
from repro.kernels.sspnna.ref import sspnna_tile_ref
from repro.kernels.sspnna.sspnna import sspnna_fused, sspnna_tiles
from repro.sparse.tensor import from_dense

K = 27


def _random_problem(rng, *, v=96, c=8, n=16, t=5, d_i=32, d_o=8,
                    hole_p=0.3, dead_p=0.3):
    """Random fused-kernel inputs honoring the planner contract: local_idx
    only references slots holding valid in_rows; alive tiles own disjoint
    output rows; dead tiles are all-pad."""
    feats = rng.normal(size=(v, c)).astype(np.float32)
    weights = (rng.normal(size=(K, c, n)) * 0.1).astype(np.float32)
    in_rows = np.full((t, d_i), -1, np.int32)
    out_rows = np.full((t, d_o), -1, np.int32)
    local_idx = np.full((t, d_o, K), -1, np.int32)
    out_pool = rng.permutation(v)
    taken = 0
    for ti in range(t):
        if rng.random() < dead_p:
            continue  # dead tile: all pads, pair_count 0
        n_valid = int(rng.integers(1, d_i + 1))
        in_rows[ti, :n_valid] = rng.choice(v, size=n_valid, replace=False)
        n_rows = int(rng.integers(1, d_o + 1))
        out_rows[ti, :n_rows] = out_pool[taken:taken + n_rows]
        taken += n_rows
        li = rng.integers(0, n_valid, (n_rows, K)).astype(np.int32)
        holes = rng.random((n_rows, K)) < hole_p
        local_idx[ti, :n_rows] = np.where(holes, -1, li)
    pair_counts = (local_idx >= 0).sum(axis=(1, 2)).astype(np.int32)
    return feats, weights, in_rows, out_rows, local_idx, pair_counts


def _oracle_conv(feats, weights, in_rows, out_rows, local_idx, pair_counts):
    """Compose the pinned tile oracle with a host-side gather/scatter."""
    tf = feats[np.maximum(in_rows, 0)]
    tile_out = np.asarray(sspnna_tile_ref(
        jnp.asarray(tf), jnp.asarray(local_idx), jnp.asarray(weights)))
    out = np.zeros((feats.shape[0], weights.shape[2]), np.float32)
    for ti in range(in_rows.shape[0]):
        if pair_counts[ti] == 0:
            continue  # dead tiles contribute nothing (rows stay zero)
        for o, row in enumerate(out_rows[ti]):
            if row >= 0:
                out[row] = tile_out[ti, o]
    return out


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 6), st.integers(4, 24), st.integers(1, 8),
       st.floats(0.0, 1.0), st.floats(0.0, 0.8))
def test_fused_bitwise_matches_oracle_over_random_plans(
        t, d_i, d_o, hole_p, dead_p):
    """Property: fused kernel == oracle bitwise over random tile plans with
    holes, empty/padded tiles, and dead tiles."""
    rng = np.random.default_rng(t * 1000 + d_i * 10 + d_o)
    feats, weights, in_rows, out_rows, local_idx, counts = _random_problem(
        rng, t=t, d_i=d_i, d_o=d_o, hole_p=hole_p, dead_p=dead_p)
    got = sspnna_fused(
        jnp.asarray(feats), jnp.asarray(weights), jnp.asarray(out_rows),
        jnp.asarray(in_rows), jnp.asarray(local_idx), jnp.asarray(counts),
        n_out=feats.shape[0], interpret=True)
    want = _oracle_conv(feats, weights, in_rows, out_rows, local_idx, counts)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("block_n,block_k,exact", [
    (None, None, True),   # pinned contraction order: bitwise vs oracle
    (8, None, True),      # N-blocking never touches the K*C reduction
    (None, 9, False),     # plane-blocked contraction: extra f32 accumulates
    (8, 9, False),
])
def test_fused_blocking_modes(rng, block_n, block_k, exact):
    feats, weights, in_rows, out_rows, local_idx, counts = _random_problem(
        rng, t=6, d_i=48, d_o=16, hole_p=0.4, dead_p=0.25)
    got = np.asarray(sspnna_fused(
        jnp.asarray(feats), jnp.asarray(weights), jnp.asarray(out_rows),
        jnp.asarray(in_rows), jnp.asarray(local_idx), jnp.asarray(counts),
        n_out=feats.shape[0], block_n=block_n, block_k=block_k,
        interpret=True))
    want = _oracle_conv(feats, weights, in_rows, out_rows, local_idx, counts)
    if exact:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_pregathered_kernel_bitwise_matches_oracle(rng):
    """The tile-stack kernel shares _tile_compute: bitwise too."""
    t, d_i, d_o, c, n = 4, 32, 8, 8, 16
    feats = jnp.asarray(rng.normal(size=(t, d_i, c)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, d_i, (t, d_o, K)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(K, c, n)) * 0.1, jnp.float32)
    got = sspnna_tiles(feats, idx, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(sspnna_tile_ref(feats, idx, w)))


def test_fused_under_vmap_matches_stacked(rng):
    """The serving engine vmaps apply_unet over scenes; the fused kernel must
    batch correctly (each scene sees its own plan tables)."""
    probs = [_random_problem(np.random.default_rng(s), t=4, d_i=24, d_o=8)
             for s in (1, 2)]
    stack = [jnp.asarray(np.stack([p[i] for p in probs])) for i in range(6)]
    # _random_problem yields (feats, w, in_rows, out_rows, idx, counts);
    # sspnna_fused takes out_rows before in_rows
    got = jax.vmap(
        lambda f, w, irow, orow, li, pc: sspnna_fused(
            f, w, orow, irow, li, pc, n_out=probs[0][0].shape[0],
            interpret=True)
    )(*stack)
    for b, p in enumerate(probs):
        want = _oracle_conv(*p)
        np.testing.assert_array_equal(np.asarray(got[b]), want)


def test_fused_all_dead_tiles_yield_zeros(rng):
    feats, weights, in_rows, out_rows, local_idx, _ = _random_problem(
        rng, t=3, d_i=16, d_o=4, dead_p=0.0)
    counts = jnp.zeros((3,), jnp.int32)  # force every tile dead
    got = np.asarray(sspnna_fused(
        jnp.asarray(feats), jnp.asarray(weights), jnp.asarray(out_rows),
        jnp.asarray(in_rows), jnp.asarray(local_idx), counts,
        n_out=feats.shape[0], interpret=True))
    np.testing.assert_array_equal(got, np.zeros_like(got))


def test_fused_full_conv_path_on_real_scene(rng):
    """End-to-end on a real shell scene + budgeted (padded) tile plan: fused
    == pre-gathered kernel == oracle path, all through run_sspnna_conv."""
    from repro.core import soar
    from repro.core.hashgrid import build_neighbor_table, kernel_offsets
    from repro.core.sparse_conv import submanifold_coir

    dense = make_shell_scene(rng, 18, 8)
    t = from_dense(dense)
    coir = submanifold_coir(t, 18, 3)
    nbr = np.asarray(build_neighbor_table(
        t.coords, t.mask, jnp.asarray(kernel_offsets(3)), 18))
    order = soar.soar_order(nbr, np.asarray(t.mask), 64).order
    realized = build_tile_plan(np.asarray(coir.indices), order, 32, 128)
    tp = build_tile_plan(np.asarray(coir.indices), order, 32, 128,
                         n_tiles=2 * realized.n_tiles + 2)  # dead-tile pad
    assert int((tp.pair_counts == 0).sum()) > 0
    dma = dma_tile_tables(tp, t.capacity)
    w = jnp.asarray(rng.normal(size=(K, 8, 16)) * 0.1, jnp.float32)

    def path(**kw):
        return np.asarray(run_sspnna_conv(
            t.feats, w, jnp.asarray(dma.out_rows), jnp.asarray(dma.in_rows),
            jnp.asarray(tp.local_idx), n_out=t.capacity, **kw))

    fused = path(pair_counts=jnp.asarray(dma.pair_counts), use_kernel=True)
    gathered = path(use_kernel=True, fused=False)
    oracle = path(use_kernel=False, fused=False)
    np.testing.assert_array_equal(fused, gathered)
    np.testing.assert_array_equal(fused, oracle)


def test_fused_hlo_eliminates_gather_and_scatter(rng):
    """Acceptance: the fused jitted graph holds no XLA gather, no scatter,
    and no (T, dI, C) working-set intermediate; the pre-gathered graph (the
    positive control) holds the gather and the intermediate."""
    v, c, n, t, d_i, d_o = 256, 8, 16, 6, 48, 16
    feats, weights, in_rows, out_rows, local_idx, counts = _random_problem(
        rng, v=v, c=c, n=n, t=t, d_i=d_i, d_o=d_o)
    args = (jnp.asarray(feats), jnp.asarray(weights))
    orow, irow = jnp.asarray(out_rows), jnp.asarray(in_rows)
    li, pc = jnp.asarray(local_idx), jnp.asarray(counts)

    def fused(f, w):
        return run_sspnna_conv(f, w, orow, irow, li, n_out=v,
                               pair_counts=pc, use_kernel=True)

    def pregathered(f, w):
        return run_sspnna_conv(f, w, orow, irow, li, n_out=v,
                               use_kernel=True, fused=False)

    inter = re.compile(rf"f32\[{t},{d_i},{c}\]")
    fused_hlo = jax.jit(fused).lower(*args).compile().as_text()
    assert not re.search(r"\bgather\(", fused_hlo)
    assert not re.search(r"\bscatter\(", fused_hlo)
    assert not inter.search(fused_hlo)
    pre_hlo = jax.jit(pregathered).lower(*args).compile().as_text()
    assert re.search(r"\bgather\(", pre_hlo)
    assert inter.search(pre_hlo)


# ---------------------------------------------------------------------------
# tile planner: DMA tables, overshoot handling, no silent pair drops
# ---------------------------------------------------------------------------

def test_dma_tile_tables_layout():
    cirf = np.array([[0, 1, -1], [1, 2, -1], [2, -1, -1]], np.int32)
    tp = build_tile_plan(cirf, np.arange(3), delta_o=2, delta_i=3)
    dma = dma_tile_tables(tp, n_out=3)
    assert dma.in_rows.min() >= 0
    assert set(np.unique(dma.out_rows[tp.out_rows < 0])) <= {3}
    assert dma.out_rows[tp.out_rows >= 0].min() >= 0
    assert dma.pair_counts.dtype == np.int32
    np.testing.assert_array_equal(dma.pair_counts, tp.pair_counts)


def test_single_row_overshoot_splits_unbudgeted_no_drops():
    """One row with 6 distinct partners, delta_i=2: the old planner silently
    truncated to 2 pairs; now it plane-splits with zero drops."""
    k = 6
    cirf = np.array([[10, 11, 12, 13, 14, 15]], np.int32)
    tp = build_tile_plan(cirf, np.array([0]), delta_o=4, delta_i=2)
    assert tp.n_row_splits == 2  # 6 partners / 2-slot working sets -> 3 tiles
    assert tp.dropped_pairs == 0
    assert int(tp.pair_counts.sum()) == k  # every pair survives
    # all split tiles target the same output row -> fused path must refuse
    rows = tp.out_rows[tp.out_rows >= 0]
    assert (rows == 0).all() and len(rows) == 3

    # numerics through the accumulating pre-gathered path == dense reference
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, 4, 8)) * 0.1, jnp.float32)
    got = np.asarray(run_sspnna_conv(
        feats, w, jnp.asarray(tp.out_rows), jnp.asarray(tp.in_rows),
        jnp.asarray(tp.local_idx), n_out=16, use_kernel=False, fused=False))
    want = np.zeros((16, 8), np.float32)
    want[0] = sum(np.asarray(feats)[cirf[0, p]] @ np.asarray(w)[p]
                  for p in range(k))
    np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-5)


def test_single_row_overshoot_raises_budgeted():
    cirf = np.array([[10, 11, 12, 13]], np.int32)
    with pytest.raises(ValueError, match="delta_i"):
        build_tile_plan(cirf, np.array([0]), delta_o=2, delta_i=2, n_tiles=4)


def test_conv_plan_for_layer_rejects_plane_splits():
    from repro.core.coir import COIR

    cirf = np.array([[1, 2, 3, 4], [2, 3, 4, 5]], np.int32)
    coir = COIR(jnp.asarray(cirf), jnp.zeros((2,), jnp.uint32),
                jnp.ones((8,), bool))
    with pytest.raises(ValueError, match="plane-split"):
        engine.conv_plan_for_layer(coir, np.arange(2), 2, 2)


def test_modeled_hbm_bytes_orders_paths():
    cirf = np.tile(np.arange(9, dtype=np.int32), (12, 3))[:, :27]
    tp = build_tile_plan(cirf, np.arange(12), delta_o=4, delta_i=32)
    d = plan_dma_tables(tp)
    m = modeled_hbm_bytes(tp, 16, 16)
    assert d["voxel_entries"] > 0 and d["block_entries"] == tp.n_tiles
    assert m["fused"] < m["pregathered"]  # the whole point of the PR


# ---------------------------------------------------------------------------
# plan-cache key versioning + block_n pinning
# ---------------------------------------------------------------------------

def _tiny_scene(seed=0):
    from repro.data.scenes import make_scene
    from repro.sparse.tensor import SparseVoxelTensor

    coords, feats, _, mask = make_scene(seed, resolution=16, capacity=512)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


def test_plan_cache_key_changes_across_table_layout_versions(monkeypatch):
    """Regression: a table-layout version bump must invalidate cached plans
    (same scene + config => different key)."""
    from repro.engine import plan as plan_mod
    from repro.models.scn import UNetConfig

    cfg = UNetConfig(widths=(8,), reps=1, resolution=16, capacity=512,
                     n_classes=4)
    t = _tiny_scene()
    cache = engine.PlanCache()
    k1 = cache.key_for(t, cfg, plan_tiles=False)
    assert k1 == cache.key_for(t, cfg, plan_tiles=False)  # stable in-version
    monkeypatch.setattr(plan_mod, "_PLAN_VERSION", plan_mod._PLAN_VERSION + 1)
    k2 = cache.key_for(t, cfg, plan_tiles=False)
    assert k1 != k2
    # and the current version is the v2 DMA-table layout
    assert plan_mod._PLAN_VERSION - 1 >= 2


def test_tile_arrays_carry_pair_counts_in_plans():
    from repro.models.scn import UNetConfig

    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=24, capacity=2048,
                     n_classes=4)
    from repro.data.scenes import make_scene
    from repro.sparse.tensor import SparseVoxelTensor
    coords, feats, _, mask = make_scene(0, resolution=24, capacity=2048)
    t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                          jnp.asarray(mask))
    plan = engine.build_scene_plan(t, cfg, mem_budget=16 * 1024)
    tiled = [lvl.sub for lvl in plan.levels if lvl.sub.tiles is not None]
    assert tiled, "expected at least one tiled level"
    for cp in tiled:
        tiles = cp.tiles
        assert tiles.pair_counts.shape == (tiles.out_rows.shape[0],)
        n_out = cp.coir.mask.shape[0]
        assert int(jnp.min(tiles.in_rows)) >= 0          # DMA layout
        assert int(jnp.max(tiles.out_rows)) <= n_out     # trash row bound


def test_block_n_autotune_pins_dispatch(rng):
    """A tuner hook's block_n lands in Dispatch and the tuned engine path
    stays numerically identical to the un-tuned one."""
    from repro.data.scenes import N_CLASSES, make_scene
    from repro.models.scn import UNetConfig, init_unet
    from repro.sparse.tensor import SparseVoxelTensor

    res, cap = 24, 2048
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=res, capacity=cap,
                     n_classes=N_CLASSES)

    def load(seed):
        coords, feats, _, mask = make_scene(seed, res, cap)
        return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                                 jnp.asarray(mask))

    seen = []

    def tuner(c_in, n_out, d_o, d_i):
        seen.append((c_in, n_out, d_o, d_i))
        return 8  # deterministic pin; widths are multiples of 8

    spec = engine.build_plan_spec([load(0)], cfg, mem_budget=16 * 1024,
                                  tune_block_n=tuner)
    tuned = [d for d in spec.levels if d.backend == engine.SSPNNA]
    assert tuned and seen
    assert all(d.block_n == 8 for d in tuned)

    params = init_unet(jax.random.PRNGKey(0), cfg)
    t = load(1)
    plan = engine.build_scene_plan(t, cfg, spec=spec)
    tuned_out = engine.apply_unet(params, t.feats, plan, backend="auto",
                                  use_kernel=True)
    # an un-tuned spec (block_n=0 -> full N) must give the same bits:
    # block_n only re-tiles the N axis, never the K*C contraction
    spec_plain = engine.build_plan_spec([load(0)], cfg, mem_budget=16 * 1024)
    assert all(d.block_n == 0 for d in spec_plain.levels)
    plan_plain = engine.build_scene_plan(t, cfg, spec=spec_plain)
    plain_out = engine.apply_unet(params, t.feats, plan_plain, backend="auto",
                                  use_kernel=True)
    np.testing.assert_array_equal(np.asarray(tuned_out), np.asarray(plain_out))


def test_autotune_block_n_returns_divisor():
    from repro.engine.autotune import autotune_block_n

    bn = autotune_block_n(8, 16, 8, 32, n_tiles=2, iters=1)
    assert 16 % bn == 0 and bn >= 8
    # memoized: second call is instant and identical
    assert autotune_block_n(8, 16, 8, 32, n_tiles=2, iters=1) == bn
