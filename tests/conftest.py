import os

# Give the suite a few virtual CPU devices so the dist layer (pipeline
# stages, mesh construction, compressed collectives) is exercised for real.
# Must be set before the first jax import anywhere in the test session.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 " + _flags).strip()

import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--chaos-seeds", default="0..1", metavar="SPEC",
        help="fault-injection seeds for the chaos matrix "
             "(tests/test_faults.py): 'a..b' inclusive range or a comma "
             "list, e.g. '0..4' or '3,7,11'")


def pytest_generate_tests(metafunc):
    if "chaos_seed" in metafunc.fixturenames:
        spec = metafunc.config.getoption("--chaos-seeds")
        if ".." in spec:
            lo, hi = spec.split("..", 1)
            seeds = list(range(int(lo), int(hi) + 1))
        else:
            seeds = [int(s) for s in spec.split(",") if s.strip()]
        metafunc.parametrize("chaos_seed", seeds)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_shell_scene(rng, resolution=24, channels=4):
    """Sphere-shell occupancy (surface-sparse, like real scans)."""
    r = resolution
    xx, yy, zz = np.meshgrid(*[np.arange(r)] * 3, indexing="ij")
    d = np.sqrt((xx - r / 2) ** 2 + (yy - r / 2) ** 2 + (zz - r / 2) ** 2)
    occ = np.abs(d - r / 3) < 0.9
    dense = np.zeros((r, r, r, channels), np.float32)
    dense[occ] = rng.normal(size=(occ.sum(), channels)).astype(np.float32)
    return dense
