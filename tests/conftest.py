import os

# Give the suite a few virtual CPU devices so the dist layer (pipeline
# stages, mesh construction, compressed collectives) is exercised for real.
# Must be set before the first jax import anywhere in the test session.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 " + _flags).strip()

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_shell_scene(rng, resolution=24, channels=4):
    """Sphere-shell occupancy (surface-sparse, like real scans)."""
    r = resolution
    xx, yy, zz = np.meshgrid(*[np.arange(r)] * 3, indexing="ij")
    d = np.sqrt((xx - r / 2) ** 2 + (yy - r / 2) ** 2 + (zz - r / 2) ** 2)
    occ = np.abs(d - r / 3) < 0.9
    dense = np.zeros((r, r, r, channels), np.float32)
    dense[occ] = rng.normal(size=(occ.sum(), channels)).astype(np.float32)
    return dense
