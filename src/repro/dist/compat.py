"""Version shims for the jax sharding / shard_map API surface.

The repo targets the baked-in toolchain (jax 0.4.x) but must also lower on
newer releases in CI. Three surfaces moved between versions:

* ``jax.sharding.AxisType`` (auto/explicit sharding modes) appeared in 0.5+;
  on older jax every mesh axis is implicitly "auto", so a stub enum suffices.
* ``jax.make_mesh`` grew an ``axis_types`` kwarg alongside ``AxisType``.
* ``shard_map`` graduated from ``jax.experimental`` and renamed its
  ``check_rep`` kwarg to ``check_vma``.
"""
from __future__ import annotations

import enum

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType

    _HAS_AXIS_TYPE = True
except ImportError:  # jax 0.4.x: every axis behaves as "auto"
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _HAS_AXIS_TYPE = False

try:  # jax >= 0.6 exposes it at top level
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with auto axis types on every jax version."""
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            axis_shapes, axis_names, devices=devices,
            axis_types=(AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, on every jax version."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:  # jax >= 0.6: check_rep renamed to check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
