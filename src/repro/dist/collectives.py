"""Compressed cross-pod collectives, expert a2a, and scene halo exchange.

``compressed_psum`` wires ``training.grad_compress``'s error-feedback int8
quantizer around the data-parallel gradient reduction: each device
quantizes its local (error-corrected) gradient to int8 blocks, the int8
payload + f32 block scales are what cross the pod links (an all-gather —
4x less wire traffic than f32), and every device dequantizes and sums the
gathered contributions. On a 1-device axis this degenerates to the pure
quantization round-trip, so single-host tests exercise exactly the
numerics that ship.

``expert_all_to_all`` is the MoE dispatch hillclimb option named by
``models.moe``: instead of the collective-free group-local gather (which
relies on activations being replicated over the model axis), tokens are
exchanged expert-major across the expert-parallel axis with
``lax.all_to_all``. Identity on a 1-device axis.

``halo_exchange`` moves the *halo rows* of a mesh-sharded sparse scene:
each shard owns a contiguous block of the capacity axis, and a sparse
conv's receptive fields reach into rows other shards own. The plan pass
(``core.host_meta.shard_halo_tables_np``) decides host-side exactly which
rows cross which link; at execution time only those rows ride a single
``all_to_all`` — the wire analogue of AccSS3D keeping the irregular gather
on-chip. ``halo_exchange_local`` is the inside-SPMD form
(``engine.shard`` calls it per conv, under ``shard_map`` or under
``vmap(axis_name=...)`` for the bitwise-identical single-device reference
path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map
from repro.training.grad_compress import _dequantize, _quantize_int8


def _replicated_specs(tree):
    return jax.tree.map(lambda _: P(), tree)


def compressed_psum(mesh, grads, axis: str = "pod", error_state=None):
    """EF-int8 psum of a gradient pytree over one mesh axis.

    Each device contributes its local leaf values; the wire format is int8
    blocks + f32 scales (see ``grad_compress.BLOCK``). Returns the summed
    pytree, or ``(summed, new_error_state)`` when ``error_state`` is given
    (the Seide-style residual to feed back next step).
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh axes {mesh.axis_names}")
    with_err = error_state is not None
    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def local(g_tree, e_tree):
        def one(g, e):
            x = g.astype(jnp.float32) + e
            q, s = _quantize_int8(x)
            # int8 payload + scales are the only cross-device traffic
            qg = jax.lax.all_gather(q, axis)
            sg = jax.lax.all_gather(s, axis)
            deq = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
            total = deq.reshape(-1)[: x.size].reshape(g.shape)
            err = x - _dequantize(q, s, g.shape)
            return total, err

        out = jax.tree.map(one, g_tree, e_tree)
        is_pair = lambda t: isinstance(t, tuple)  # noqa: E731
        summed = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        err = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return summed, err

    fn = shard_map(
        local, mesh,
        in_specs=(_replicated_specs(grads), _replicated_specs(grads)),
        out_specs=(_replicated_specs(grads), _replicated_specs(grads)))
    summed, new_err = fn(grads, error_state)
    return (summed, new_err) if with_err else summed


def halo_exchange_local(feats, send_rows, axis: str = "shard"):
    """Exchange halo feature rows across the shard axis (inside-SPMD form).

    ``feats`` is this shard's ``(Vs, C)`` block; ``send_rows`` its ``(S,
    H)`` send table — ``send_rows[d]`` lists the local rows shard ``d``
    needs (``-1`` pads, which arrive as zero rows; plan-built index blocks
    never reference pad slots). Returns ``(S, H, C)``: row block ``d`` is
    what shard ``d`` sent *us*, so a consumer's local buffer is
    ``concat([feats, recv.reshape(S*H, C)])`` — exactly the layout
    ``shard_halo_tables_np`` coded its local indices against.

    Pure data movement (one tiled ``all_to_all``): bitwise-exact, and
    valid under ``shard_map`` or ``vmap(axis_name=axis)`` alike.
    """
    payload = jnp.where((send_rows >= 0)[..., None],
                        jnp.take(feats, jnp.maximum(send_rows, 0), axis=0),
                        0)
    return jax.lax.all_to_all(payload, axis, split_axis=0, concat_axis=0,
                              tiled=True)


def halo_exchange(mesh, feats, send_rows, axis: str = "shard"):
    """Mesh-level halo exchange over stacked shard blocks.

    ``feats`` ``(S, Vs, C)`` and ``send_rows`` ``(S, S, H)`` are sharded
    over ``axis`` on dim 0; returns ``(S, S, H, C)`` where ``out[s, d]``
    holds the rows shard ``s`` received from shard ``d`` (zero rows at
    ``-1`` pads). Thin ``shard_map`` wrapper around
    :func:`halo_exchange_local` for tests and standalone use.
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh axes {mesh.axis_names}")

    def local(f, sr):
        return halo_exchange_local(f[0], sr[0], axis)[None]

    return shard_map(local, mesh, in_specs=(P(axis), P(axis)),
                     out_specs=P(axis))(feats, send_rows)


def expert_all_to_all(mesh, x, axis: str = "model",
                      split_axis: int = 1, concat_axis: int = 0):
    """All-to-all an (..., E, ...) dispatch tensor over the EP axis.

    ``x`` is group-major (G, E, cap, d) with experts sharded over ``axis``;
    the exchange returns it expert-major so each device holds the full token
    set for its local experts. Apply twice with swapped split/concat axes to
    invert. Identity when the axis has size 1.
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh axes {mesh.axis_names}")

    def local(t):
        return jax.lax.all_to_all(t, axis, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)

    # the concat dim arrives sharded (one group per device) and leaves whole;
    # the split dim arrives whole and leaves sharded (local experts only)
    in_specs = P(*(axis if d == concat_axis else None
                   for d in range(x.ndim)))
    out_specs = P(*(axis if d == split_axis else None
                    for d in range(x.ndim)))
    return shard_map(local, mesh, in_specs=in_specs, out_specs=out_specs)(x)
