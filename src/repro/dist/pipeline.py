"""GPipe-style stage-stacked pipeline execution over a "pipe" mesh axis.

``stack_stages`` stacks per-stage parameter pytrees along a new leading
axis; ``pipeline_apply`` shards that axis over the pipeline mesh axis and
runs the classic GPipe schedule with ``ppermute`` hand-offs: microbatch m
occupies stage s at step t = s + m, so n_micro microbatches drain through
n_stages stages in n_micro + n_stages - 1 steps.

On a 1-wide pipe axis the schedule collapses to a plain serial scan over
microbatches — no collectives, any output shape — which is what the serving
tests exercise on a single host. With 2+ stages the stage function must be
shape-preserving (activations hand off between identical stage bodies).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map


def stack_stages(stages):
    """Stack a list of per-stage param pytrees along a new leading axis."""
    if not stages:
        raise ValueError("stack_stages needs at least one stage")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *stages)


def _first_stage(stacked):
    return jax.tree.map(lambda a: a[0], stacked)


def pipeline_apply(mesh, stage_fn, stage_params, x, *, axis: str = "pipe"):
    """Run ``x`` (n_micro, micro_batch, ...) through the stacked stages.

    ``stage_fn(params, microbatch) -> microbatch`` is one stage body;
    ``stage_params`` comes from ``stack_stages`` and must have exactly
    ``mesh.shape[axis]`` stages. Returns the (n_micro, ...) outputs of the
    last stage, replicated over the pipe axis.
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh axes {mesh.axis_names}")
    n_stages = mesh.shape[axis]
    n_stacked = jax.tree.leaves(stage_params)[0].shape[0]
    if n_stacked != n_stages:
        raise ValueError(
            f"{n_stacked} stacked stages vs {n_stages}-wide {axis!r} axis")
    n_micro = x.shape[0]

    if n_stages == 1:
        params = _first_stage(stage_params)

        def body(_, mb):
            return None, stage_fn(params, mb)

        _, out = jax.lax.scan(body, None, x)
        return out

    out_struct = jax.eval_shape(
        stage_fn,
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                     stage_params),
        jax.ShapeDtypeStruct(x.shape[1:], x.dtype))
    if out_struct.shape != x.shape[1:] or out_struct.dtype != x.dtype:
        raise ValueError(
            f"multi-stage pipelines need shape/dtype-preserving stages; got "
            f"{x.shape[1:]}:{x.dtype} -> {out_struct.shape}:{out_struct.dtype}")

    def per_device(params, x_all):
        p = _first_stage(params)  # local (1, ...) slice -> this stage's tree
        stage = jax.lax.axis_index(axis)
        last = n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def step(carry, t):
            state, buf = carry
            # stage 0 pulls fresh microbatches; others consume the hand-off
            feed = x_all[jnp.clip(t, 0, n_micro - 1)]
            inp = jnp.where(stage == 0, feed, state)
            out = stage_fn(p, inp)
            m = t - last
            write = (stage == last) & (m >= 0)
            mc = jnp.clip(m, 0, n_micro - 1)
            buf = buf.at[mc].set(jnp.where(write, out, buf[mc]))
            state = jax.lax.ppermute(out, axis, perm)
            return (state, buf), None

        state0 = jnp.zeros(x_all.shape[1:], out_struct.dtype)
        buf0 = jnp.zeros((n_micro,) + out_struct.shape, out_struct.dtype)
        (_, buf), _ = jax.lax.scan(
            step, (state0, buf0), jnp.arange(n_micro + n_stages - 1))
        # only the last stage wrote real outputs; psum replicates them
        buf = jnp.where(stage == last, buf, jnp.zeros_like(buf))
        return jax.lax.psum(buf, axis)

    param_specs = jax.tree.map(lambda _: P(axis), stage_params)
    x_spec = P(*([None] * x.ndim))
    fn = shard_map(per_device, mesh,
                   in_specs=(param_specs, x_spec), out_specs=x_spec)
    return fn(stage_params, x)
