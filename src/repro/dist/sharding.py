"""ShardingRules: named in/out shardings for params, state, batches, caches.

One rules object per (config, mesh) pair. Mesh axes follow
``launch.mesh.make_production_mesh``: ``("data", "model")`` single pod or
``("pod", "data", "model")`` multi-pod. By default parameters are
tensor-parallel over ``"model"`` and replicated over the DP axes, while
batches shard their leading dimension over the DP axes (ZeRO-style optimizer
state rides the same per-leaf rule as the parameters it mirrors).

``full_dp=True`` is the dry-run's v4 variant: the model axis is folded into
data parallelism, so parameters are replicated and batches shard over every
mesh axis.

Every method is a divisibility-checked heuristic, never an error: a
dimension that no axis divides is simply left unsharded, which is what makes
the same rules valid on a 1-device host mesh and on 2x16x16 pods.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


class ShardingRules:
    def __init__(self, cfg, mesh, *, full_dp: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.full_dp = full_dp
        names = mesh.axis_names
        has_model = "model" in names
        self.model_axis = "model" if (has_model and not full_dp) else None
        dp = tuple(a for a in names if a != "model")
        if full_dp and has_model:
            dp = dp + ("model",)
        # axes of size 1 contribute nothing; dropping them keeps specs tidy
        self.dp_axes = tuple(a for a in dp if mesh.shape[a] > 1)
        self.model_size = (
            mesh.shape["model"] if self.model_axis
            and mesh.shape["model"] > 1 else 1)
        self.dp_size = math.prod(mesh.shape[a] for a in self.dp_axes) \
            if self.dp_axes else 1

    # -- helpers ------------------------------------------------------------

    def _named(self, *entries) -> NamedSharding:
        return NamedSharding(self.mesh, P(*entries))

    def replicated(self) -> NamedSharding:
        return self._named()

    def _dp_entry(self):
        if not self.dp_axes:
            return None
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def _divides(self, dim: int, size: int) -> bool:
        return size > 1 and dim >= size and dim % size == 0

    # -- parameters / optimizer state --------------------------------------

    def _param_spec(self, leaf) -> NamedSharding:
        """Tensor-parallel over "model" on the innermost divisible dim.

        Stacked per-cycle leaves (leading scan axis) never shard dim 0 —
        splitting layers across devices is the pipeline's job, not TP's.
        """
        shape = leaf.shape
        if self.model_size > 1 and shape:
            start = 0 if len(shape) == 1 else 1
            for d in range(len(shape) - 1, start - 1, -1):
                if self._divides(shape[d], self.model_size):
                    entries = [None] * len(shape)
                    entries[d] = "model"
                    return self._named(*entries)
        return self.replicated()

    def params_shardings(self, params):
        """Pytree of NamedShardings matching a params (or grads) pytree."""
        return jax.tree.map(self._param_spec, params)

    def state_shardings(self, state):
        """Train-state tree: params, optimizer moments, step, EF residual.

        Optimizer state mirrors the parameters (ZeRO-style, see
        ``training.optimizer``), so the per-leaf parameter rule applies to
        the whole tree; scalars (``step``) come out replicated.
        """
        return jax.tree.map(self._param_spec, state)

    # -- batches ------------------------------------------------------------

    def _batch_spec(self, leaf) -> NamedSharding:
        shape = leaf.shape
        entries = [None] * len(shape)
        if shape and self._divides(shape[0], self.dp_size):
            entries[0] = self._dp_entry()
        return self._named(*entries)

    def batch_shardings(self, batch):
        """Input batches shard dim 0 (global batch) over the DP axes."""
        return jax.tree.map(self._batch_spec, batch)

    # -- decode caches -------------------------------------------------------

    def _cache_spec(self, leaf) -> NamedSharding:
        """KV/state caches: heads over "model" when they divide, else the
        longest divisible dim (flash-decoding-style length sharding); batch
        over DP. Handles both per-layer leaves (batch leading) and stacked
        per-cycle leaves (n_cycles leading)."""
        shape = leaf.shape
        entries = [None] * len(shape)
        if not shape:
            return self.replicated()
        model_dim = None
        if self.model_size > 1:
            head_sizes = {self.cfg.n_kv_heads, self.cfg.n_heads}
            cands = [d for d in range(len(shape))
                     if self._divides(shape[d], self.model_size)]
            heads = [d for d in cands if shape[d] in head_sizes]
            pick = heads if heads else cands
            if pick:
                # rightmost on ties: heads/feature dims trail batch dims
                model_dim = max(pick, key=lambda d: (shape[d], d))
                entries[model_dim] = "model"
        if self.dp_size > 1:
            for d in range(len(shape)):
                if d != model_dim and self._divides(shape[d], self.dp_size):
                    entries[d] = self._dp_entry()
                    break
        return self._named(*entries)

    def cache_shardings(self, cache):
        return jax.tree.map(self._cache_spec, cache)
