"""Distributed-execution layer: sharding hints, rules, collectives, pipeline.

Four small modules, one contract: everything is an exact no-op (or a
single-device identity) when no mesh is active, so CPU tests and single-host
runs execute the same code path the 512-chip dry-run lowers.

* ``hints``       — ``DP`` / ``constrain`` / ``use_mesh``: PartitionSpec-style
  sharding hints that model code sprinkles on activations.
* ``sharding``    — ``ShardingRules``: named in/out shardings for params,
  optimizer state, batches and KV caches, consumed by ``launch.dryrun`` and
  ``training.train_loop``.
* ``collectives`` — ``compressed_psum`` (EF-int8 cross-pod DP reduction built
  on ``training.grad_compress``) and the expert-parallel all-to-all.
* ``pipeline``    — ``stack_stages`` / ``pipeline_apply``: GPipe-style
  stage-stacked pipeline execution over a ``"pipe"`` mesh axis.
"""
from repro.dist.collectives import (
    compressed_psum,
    expert_all_to_all,
    halo_exchange,
    halo_exchange_local,
)
from repro.dist.hints import DP, active_mesh, constrain, use_mesh
from repro.dist.pipeline import pipeline_apply, stack_stages
from repro.dist.sharding import ShardingRules

__all__ = [
    "DP",
    "ShardingRules",
    "active_mesh",
    "compressed_psum",
    "constrain",
    "expert_all_to_all",
    "halo_exchange",
    "halo_exchange_local",
    "pipeline_apply",
    "stack_stages",
    "use_mesh",
]
