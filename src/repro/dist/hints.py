"""PartitionSpec-style sharding hints for model code.

Model code annotates activations with ``constrain(x, DP, None, "model")``
style hints — one entry per array dimension. The hints only take effect
inside a ``use_mesh(mesh, dp=...)`` context (the dry-run wraps lowering in
one); with no active mesh ``constrain`` is an *exact* no-op that returns its
input unchanged, so single-device tests and CPU CI run the same code the
512-chip lowering sees.

Entry semantics per dimension:

* ``DP``        — shard over the active data-parallel axes (whatever tuple
  ``use_mesh`` declared, e.g. ``("pod", "data")`` or, for the full-mesh-DP
  variant, ``("pod", "data", "model")``).
* ``"name"``    — shard over that mesh axis. Silently dropped when the axis
  is absent, already consumed by DP (full-mesh DP folds "model" into the
  batch axes), or does not divide the dimension.
* ``None``      — leave the dimension unsharded.
"""
from __future__ import annotations

import contextlib
import contextvars
import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


class _DPSentinel:
    """Placeholder for 'the active data-parallel axes' in constrain()."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self):
        return "DP"


DP = _DPSentinel()

# (mesh, dp_axes) while a use_mesh() context is active, else None.
_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_dist_active_mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh, *, dp=("data",)):
    """Activate ``mesh`` for ``constrain`` hints; ``dp`` names the DP axes.

    DP axes absent from the mesh are dropped (call sites name the multi-pod
    superset, e.g. ``("pod", "data")`` on a single-pod mesh), but an entirely
    unknown dp set is a config error and raises.
    """
    dp = (dp,) if isinstance(dp, str) else tuple(dp)
    present = tuple(a for a in dp if a in mesh.axis_names)
    if dp and not present:
        raise ValueError(
            f"none of dp axes {dp} are in mesh axes {mesh.axis_names}")
    dp = present
    token = _ACTIVE.set((mesh, dp))
    try:
        yield mesh
    finally:
        _ACTIVE.reset(token)


def active_mesh():
    """Returns (mesh, dp_axes) inside use_mesh(), else None."""
    return _ACTIVE.get()


def _axis_sizes(mesh, axes):
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def constrain(x, *entries):
    """Apply a per-dimension sharding hint; identity when no mesh is active."""
    active = _ACTIVE.get()
    if active is None:
        return x
    mesh, dp = active
    if len(entries) != x.ndim:
        raise ValueError(
            f"constrain got {len(entries)} entries for rank-{x.ndim} array")
    used = set(dp)
    spec = []
    for dim, entry in zip(x.shape, entries):
        if entry is DP:
            axes = tuple(a for a in dp if mesh.shape[a] > 1)
            if axes and dim % _axis_sizes(mesh, axes) == 0 and dim > 0:
                spec.append(axes if len(axes) > 1 else axes[0])
            else:
                spec.append(None)
        elif entry is None:
            spec.append(None)
        else:
            cand = (entry,) if isinstance(entry, str) else tuple(entry)
            names, size = [], 1
            for a in cand:
                if (a in mesh.axis_names and a not in used
                        and mesh.shape[a] > 1
                        and dim % (size * mesh.shape[a]) == 0):
                    names.append(a)
                    size *= mesh.shape[a]
            used.update(names)
            if not names:
                spec.append(None)
            else:
                spec.append(tuple(names) if len(names) > 1 else names[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
