"""Mesh-sharded scenes: split one scene's capacity axis over a mesh axis.

One large scan does not fit one device arbitrarily far — the ROADMAP's top
open item is sharding a single scene's *capacity* axis over a mesh axis.
This module is that capability, built on the engine's new seams: the plan
is a first-class object (``ShardedScenePlan``), the execution path is a
registered backend (``"sharded"``), and the mesh rides in on the
``ExecutionContext``.

**Plan.** Shard ``s`` owns contiguous capacity rows ``[s*Vs, (s+1)*Vs)``
at every U-Net level (levels keep full capacity, so one split serves all).
The host pass (pure numpy — it slots into ``WaveScheduler``'s plan stage
and pipelines against device execution) builds, per conv site, the global
COIR block exactly as the unsharded planner would, then splits it with
``core.host_meta.shard_halo_tables_np``: per-shard local index blocks plus
*send tables* naming exactly which feature rows must cross which link —
the cross-shard receptive-field halo.

**Execution.** Each conv does one ``dist.collectives.halo_exchange_local``
(a single tiled ``all_to_all`` of only the halo rows), concatenates the
received rows after its own block, and runs the conv locally. Batch-norm
statistics are global: each shard contributes *chunked partial sums*
(fixed ``bn_chunk`` rows per partial), one tiny ``all_gather`` moves the
partials (``V/bn_chunk`` rows instead of ``V``), and a fixed-order scan
reduces them identically on every shard.

**Bitwise contract.** All cross-shard traffic is exact data movement, and
every floating-point reduction is *shape- and thread-configuration
stable*: the conv contraction accumulates per weight plane in fixed order
(each plane a short ``(Vo, C) @ (C, N)`` matmul XLA never re-tiles across
thread configs, unlike the fused ``(Vo, K*C)`` einsum), and BN totals come
from the fixed-order partial scan. Consequently executing a plan over a
2- or 4-device mesh (``shard_map``) is **bitwise identical** to the
single-device reference path (``vmap(axis_name=...)`` over the same local
function) — ``tests/test_sharded.py`` asserts this, plus fp-tolerance
agreement with the unsharded ``"reference"`` einsum backend.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.hashgrid import kernel_offsets
from repro.core.host_meta import (
    build_cirf_np,
    shard_halo_tables_np,
    transposed_coir_np,
)
from repro.dist.collectives import halo_exchange_local
from repro.dist.compat import shard_map
from repro.engine.backends import Backend, default_registry
from repro.engine.plan import level_geometry
from repro.sparse.tensor import SparseVoxelTensor

SHARDED = "sharded"


@dataclass(frozen=True)
class ShardLayout:
    """Static description of how a scene's capacity axis is sharded.

    ``halo`` is the per-(owner, consumer) halo row budget each conv's send
    tables are padded to; 0 sizes it per scene (adaptive — a new jit
    signature per scene), a positive value pins it (one signature, the
    serving mode; overflow raises at plan-build time, rows are never
    dropped). ``bn_chunk`` is the deterministic BN partial-sum chunk; it
    is snapped down to a divisor of the shard size at plan build.
    """

    n_shards: int
    axis: str = "shard"
    halo: int = 0
    bn_chunk: int = 256

    def shard_size(self, capacity: int) -> int:
        if self.n_shards < 1 or capacity % self.n_shards:
            raise ValueError(
                f"capacity {capacity} not divisible into {self.n_shards} "
                "equal shards")
        return capacity // self.n_shards


class ShardedConvPlan(NamedTuple):
    """Per-conv sharded metadata (leading dim = shard).

    ``indices`` ``(S, Vs, K)`` — COIR block in local coding: ``[0, Vs)``
    own rows, ``Vs + d*H + j`` halo slot ``j`` from shard ``d``, ``-1``
    holes. ``mask`` ``(S, Vs)`` — output-major active rows. ``send_rows``
    ``(S, S, H)`` — ``send_rows[d, s]``: rows shard ``d`` sends shard
    ``s``, local to ``d``, ``-1`` pads.
    """

    indices: jax.Array
    mask: jax.Array
    send_rows: jax.Array


class ShardedLevelPlan(NamedTuple):
    """One U-Net level, sharded: active mask + its three conv sites."""

    mask: jax.Array
    sub: ShardedConvPlan
    down: ShardedConvPlan | None
    up: ShardedConvPlan | None


@jax.tree_util.register_pytree_node_class
@dataclass
class ShardedScenePlan:
    """Per-scene sharded execution plan. ``stats`` is host-only (per-shard
    occupancy, halo rows/budgets per conv) and drops across jit."""

    levels: tuple[ShardedLevelPlan, ...]
    layout: ShardLayout
    stats: list[dict] | None = None

    #: engine.apply_unet routes plans carrying this attribute to the named
    #: scene-level backend's run_unet hook
    scene_backend = SHARDED

    @property
    def n_shards(self) -> int:
        return self.layout.n_shards

    def halo_rows(self) -> int:
        """Total real cross-shard rows one forward exchanges (from stats;
        0 if stats were dropped)."""
        if not self.stats:
            return 0
        return sum(sum(lvl["halo_rows"].values()) for lvl in self.stats)

    def device_upload(self) -> "ShardedScenePlan":
        """Device copy of a host-built plan (PlanCache memoizes this)."""
        return upload_sharded_scene_plan(self)

    def tree_flatten(self):
        return (tuple(self.levels),), self.layout

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux, None)


# ---------------------------------------------------------------------------
# Plan building (host, pure numpy)
# ---------------------------------------------------------------------------

def _shard_conv(indices, out_mask, n_shards: int, halo: int):
    local_idx, send_rows, n_halo = shard_halo_tables_np(
        indices, n_shards, halo)
    mask = np.asarray(out_mask).reshape(n_shards, -1)
    return ShardedConvPlan(local_idx, mask, send_rows), n_halo


def build_sharded_scene_plan_host(
    t: SparseVoxelTensor,
    cfg,
    *,
    layout: ShardLayout,
) -> ShardedScenePlan:
    """AdMAC metadata + halo split for one scene -> host (numpy) plan.

    The global per-level COIR blocks are built with the same numpy
    builders the unsharded planner uses (bit-identical metadata), then
    split into per-shard local blocks + send tables. Safe to call from
    planner threads; pair with :func:`upload_sharded_scene_plan`.
    """
    vs = layout.shard_size(t.capacity)
    chunk = math.gcd(max(int(layout.bn_chunk), 1), vs)
    layout = replace(layout, bn_chunk=chunk)
    offs2 = kernel_offsets(2, centered=False)
    offs3 = kernel_offsets(3)
    geometry = level_geometry(t, cfg)
    levels: list[ShardedLevelPlan] = []
    stats: list[dict] = []
    for li, (coords, mask, res) in enumerate(geometry):
        sub_coir = build_cirf_np(coords, mask, coords, mask, offs3, res)
        sub, halo_sub = _shard_conv(sub_coir.indices, mask,
                                    layout.n_shards, layout.halo)
        down = up = None
        halo_rows = {"sub": halo_sub}
        halo_budget = {"sub": int(sub.send_rows.shape[-1])}
        if li < len(cfg.widths) - 1:
            dn_coords, dn_mask, _ = geometry[li + 1]
            down_coir = build_cirf_np(
                dn_coords, dn_mask, coords, mask, offs2, res, stride=2)
            up_coir = transposed_coir_np(dn_coords, dn_mask, coords, mask,
                                         res, 2, 2)
            down, halo_rows["down"] = _shard_conv(
                down_coir.indices, dn_mask, layout.n_shards, layout.halo)
            up, halo_rows["up"] = _shard_conv(
                up_coir.indices, mask, layout.n_shards, layout.halo)
            halo_budget["down"] = int(down.send_rows.shape[-1])
            halo_budget["up"] = int(up.send_rows.shape[-1])
        shard_active = np.asarray(mask).reshape(layout.n_shards, -1).sum(1)
        stats.append({
            "level": li,
            "n_active": int(shard_active.sum()),
            "shard_active": [int(n) for n in shard_active],
            "halo_rows": halo_rows,
            "halo_budget": halo_budget,
        })
        levels.append(ShardedLevelPlan(
            np.asarray(mask).reshape(layout.n_shards, -1), sub, down, up))
    return ShardedScenePlan(tuple(levels), layout, stats)


def upload_sharded_scene_plan(plan: ShardedScenePlan) -> ShardedScenePlan:
    """Host (numpy) plan leaves -> jax arrays, preserving host-only stats."""
    out = jax.tree.map(jnp.asarray, plan)
    return ShardedScenePlan(out.levels, out.layout, plan.stats)


def build_sharded_scene_plan(
    t: SparseVoxelTensor,
    cfg,
    *,
    layout: ShardLayout,
) -> ShardedScenePlan:
    """Host build + device upload in one step (tests / direct use)."""
    return upload_sharded_scene_plan(
        build_sharded_scene_plan_host(t, cfg, layout=layout))


def pin_halo(scenes, cfg, layout: ShardLayout,
             margin: float = 1.5) -> ShardLayout:
    """Freeze the halo budget from representative scenes (serving mode).

    Sizes every conv's send tables to ``margin`` times the worst
    per-(owner, consumer) halo row count observed across ``scenes``, so
    every plan built from the returned layout shares one jit signature —
    the sharded analogue of ``build_plan_spec`` pinning tile counts.
    """
    worst = 0
    probe = replace(layout, halo=0)
    for t in scenes:
        plan = build_sharded_scene_plan_host(t, cfg, layout=probe)
        for lvl in plan.stats:
            worst = max(worst, *lvl["halo_budget"].values())
    return replace(layout, halo=int(np.ceil(margin * worst)) + 1)


# ---------------------------------------------------------------------------
# Execution (deterministic per-shard math + collectives)
# ---------------------------------------------------------------------------

def _plane_conv(buf, idx, weight):
    """Fixed-order plane-accumulated contraction -> (Vo, N) float32.

    Each weight plane is a ``(Vo, C) @ (C, N)`` matmul whose short
    per-row reduction XLA never re-tiles across thread configurations;
    accumulating planes in fixed k order keeps one shard's output rows
    bitwise independent of every other shard's — the property the
    fused ``(Vo, K*C)`` einsum does not have.
    """
    valid = idx >= 0
    g = jnp.where(valid[..., None],
                  jnp.take(buf, jnp.maximum(idx, 0), axis=0), 0)
    g = g.astype(jnp.float32)
    w = weight.astype(jnp.float32)
    out = g[:, 0, :] @ w[0]
    for k in range(1, w.shape[0]):
        out = out + g[:, k, :] @ w[k]
    return out


def _chunk_sums(x, chunk: int):
    """(rows, F) -> (rows // chunk, F) per-chunk column sums."""
    nc = x.shape[0] // chunk
    return jnp.sum(x.reshape(nc, chunk, x.shape[-1]), axis=1)


def _scan_sum(parts):
    """Fixed-order (sequential) total of stacked partial sums."""
    total, _ = jax.lax.scan(
        lambda c, p: (c + p, None),
        jnp.zeros(parts.shape[1:], parts.dtype), parts)
    return total


def _sharded_bn_relu(x, lvl_mask, scale, offset, axis: str, chunk: int,
                     eps: float = 1e-5):
    """Masked BN + ReLU with global statistics over the shard axis.

    Mirrors ``core.sparse_conv.masked_batchnorm_relu`` formula-for-formula;
    the only cross-shard traffic is the chunked partial sums
    (``V/chunk`` rows per gather instead of ``V``), reduced in fixed scan
    order so every shard computes bit-identical statistics.
    """
    mm = lvl_mask[:, None].astype(x.dtype)
    parts = _chunk_sums(jnp.concatenate([x * mm, mm], axis=1), chunk)
    tot = _scan_sum(jax.lax.all_gather(parts, axis, tiled=True))
    n = jnp.maximum(tot[-1], 1.0)
    mean = tot[:-1] / n
    vparts = _chunk_sums(jnp.square(x - mean) * mm, chunk)
    var = _scan_sum(jax.lax.all_gather(vparts, axis, tiled=True)) / n
    y = (x - mean) * jax.lax.rsqrt(var + eps) * scale + offset
    return jax.nn.relu(y) * mm


def _sharded_conv(x, cp: ShardedConvPlan, params, axis: str):
    """One conv site on this shard's rows: halo exchange + local conv."""
    recv = halo_exchange_local(x, cp.send_rows, axis)  # (S, H, C)
    buf = jnp.concatenate([x, recv.reshape(-1, x.shape[-1])], axis=0)
    out = _plane_conv(buf, cp.indices, params.weight)
    out = out.astype(x.dtype) + params.bias.astype(x.dtype)
    return out * cp.mask[:, None].astype(out.dtype)


def _local_apply_unet(params, x, levels, layout: ShardLayout):
    """Per-shard U-Net forward: (Vs, C_in) -> (Vs, n_classes).

    Valid under ``shard_map`` over ``layout.axis`` *or* under
    ``vmap(axis_name=layout.axis)`` — the latter is the single-device
    reference path the mesh execution is bitwise-matched against.
    """
    axis, chunk = layout.axis, layout.bn_chunk

    def block(x, lvl_mask, cp, bp):
        y = _sharded_conv(x, cp, bp["conv"], axis)
        return _sharded_bn_relu(y, lvl_mask, bp["bn_scale"],
                                bp["bn_offset"], axis, chunk)

    x = _sharded_conv(x, levels[0].sub, params["stem"], axis)
    skips = []
    for li, lvl in enumerate(levels):
        p = params["levels"][li]
        for blk in p["enc"]:
            x = block(x, lvl.mask, lvl.sub, blk)
        if lvl.down is not None:
            skips.append(x)
            x = _sharded_conv(x, lvl.down, p["down"], axis)
    for li in range(len(levels) - 2, -1, -1):
        lvl, p = levels[li], params["levels"][li]
        up = _sharded_conv(x, lvl.up, p["up"], axis)
        x = jnp.concatenate([skips[li], up], axis=-1)
        for blk in p["dec"]:
            x = block(x, lvl.mask, lvl.sub, blk)
    return x @ params["head"]["w"] + params["head"]["b"]


def apply_unet_sharded(
    params: dict,
    feats: jnp.ndarray,
    plan: ShardedScenePlan,
    *,
    mesh=None,
    axis: str | None = None,
) -> jnp.ndarray:
    """U-Net forward off a ShardedScenePlan -> (V, n_classes) logits.

    With ``mesh`` (carrying ``plan.layout.axis``), shards execute SPMD via
    ``shard_map`` with real collectives; without one, the same local
    function runs under ``vmap(axis_name=...)`` on one device — the
    reference path, bitwise identical to the mesh execution.
    """
    layout = plan.layout
    S = layout.n_shards
    vs = layout.shard_size(feats.shape[0])
    if plan.levels[0].mask.shape[-1] != vs:
        raise ValueError(
            f"plan shard size {plan.levels[0].mask.shape[-1]} != "
            f"feats shard size {vs}")
    blocks = feats.reshape(S, vs, feats.shape[-1])
    axis = axis or layout.axis
    if mesh is not None:
        if axis not in mesh.axis_names:
            raise ValueError(
                f"mesh axes {mesh.axis_names} lack shard axis {axis!r}")
        if int(mesh.shape[axis]) != S:
            raise ValueError(
                f"plan has {S} shards but mesh axis {axis!r} has size "
                f"{mesh.shape[axis]}")
        if axis != layout.axis:
            layout = replace(layout, axis=axis)

        def local(p, x, lvls):
            lvls1 = jax.tree.map(lambda a: a[0], lvls)
            return _local_apply_unet(p, x[0], lvls1, layout)[None]

        out = shard_map(
            local, mesh,
            in_specs=(jax.tree.map(lambda _: P(), params), P(axis),
                      jax.tree.map(lambda _: P(axis), plan.levels)),
            out_specs=P(axis))(params, blocks, plan.levels)
    else:
        out = jax.vmap(
            lambda x, lvls: _local_apply_unet(params, x, lvls, layout),
            axis_name=layout.axis)(blocks, plan.levels)
    return out.reshape(feats.shape[0], -1)


# ---------------------------------------------------------------------------
# Backend registration
# ---------------------------------------------------------------------------

class ShardedBackend(Backend):
    """Scene-level backend: mesh-sharded execution with halo exchange.

    Reached via ``engine.apply_unet`` on a ``ShardedScenePlan`` (the plan
    names it through ``scene_backend``); the mesh comes from the call's
    ``ExecutionContext``. Per-conv ``run`` is intentionally unsupported —
    a sharded conv only makes sense inside the scene's SPMD program.
    """

    name = SHARDED
    scene_level = True

    def supports(self, plan) -> bool:
        return isinstance(plan, ShardedScenePlan)

    def run(self, x, params, plan, *, ctx, **kw):
        raise ValueError(
            "the sharded backend executes whole scenes; call "
            "engine.apply_unet with a ShardedScenePlan")

    def run_unet(self, params, feats, plan, *, ctx, **kw):
        mesh = ctx.mesh if ctx is not None else None
        return apply_unet_sharded(params, feats, plan, mesh=mesh)


default_registry().register(SHARDED, ShardedBackend(), overwrite=True)
