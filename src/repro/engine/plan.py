"""Scene plans: the engine's unit of metadata building and caching.

A ``ScenePlan`` is everything the paper builds *before* running a layer,
bundled per input scene: per-level COIR metadata (the AdMAC pass), the SOAR
permutation, the SPADE-selected dataflow, and the tile metadata the SSpNNA
kernel consumes. It is a jax pytree — array leaves (COIR blocks, tile
tables) are traced, while the per-conv ``Dispatch`` decision rides in the
treedef as static aux data, so forcing a different backend or tile shape is
a (cached) recompile and everything else is a cache hit.

Two plan-building modes:

* **adaptive** (``spec=None``): full SPADE ``explore`` per level on this
  scene's own sparsity attributes — the paper's input-specific (JSA) flow.
  Tile counts match the scene, so plans for different scenes may differ in
  shape/static signature.
* **pinned** (``spec=build_plan_spec(...)``): dataflow decisions and tile
  counts are frozen from representative scenes (the offline/MSA flow,
  §V-C). Every plan built from one spec shares its jit signature — this is
  what ``serving.scene_engine`` batches through a single compilation.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spade
from repro.core.coir import COIR
from repro.core.hashgrid import kernel_offsets
from repro.core.host_meta import (
    StreamMetaState,
    build_cirf_np,
    downsample_coords_np,
    transposed_coir_np,
)
from repro.core.soar import raster_order, soar_order
from repro.analysis.runtime import ordered_condition, ordered_lock
from repro.core.tiles import build_tile_plan, dma_tile_tables, max_tiles
from repro.sparse.tensor import SparseVoxelTensor

REFERENCE = "reference"
SSPNNA = "sspnna"

_K_SUB = 27  # submanifold 3^3 kernel volume

# Layout version of the plan's array leaves; mixed into every PlanCache key
# so cached plans from an older table layout can never be served to a kernel
# expecting the new one. v2: TileArrays carries DMA-table-layout rows plus
# pair_counts for the fused kernel's dead-tile skip. v3: keys additionally
# carry the execution topology (mesh axes + shard layout), so a plan built
# for one mesh can never be served to another. v4: plan builds may consult
# circuit breakers (``breakers=`` build_kw, whose repr carries the board
# generation) and reroute dispatch away from tripped backends.
_PLAN_VERSION = 4


def _fault_injector():
    """The ambient serving-layer fault injector, if any (lazy import so
    the engine layer has no hard dependency on serving)."""
    try:
        from repro.serving import faults
    except ImportError:  # pragma: no cover - serving always ships
        return None
    return faults.active()


@dataclass(frozen=True)
class Dispatch:
    """Static per-conv execution decision (hashable -> jit aux data)."""

    backend: str = REFERENCE
    flavor: str = "CIRF"
    walk: str = "OS"
    delta_o: int = 0
    delta_i: int = 0
    n_tiles: int = 0
    block_n: int = 0  # pinned kernel N-block (0 = full N); see autotune_block_n


REFERENCE_DISPATCH = Dispatch()


class TileArrays(NamedTuple):
    """Device-side tile metadata in DMA-table layout
    (``core.tiles.dma_tile_tables``): ``in_rows`` pad slots are clamped to a
    safe source row, ``out_rows`` pad slots point at the trash row ``n_out``,
    and ``pair_counts`` is the fused kernel's dead-tile predicate."""

    out_rows: jax.Array     # (T, dO) int32, pads -> n_out (trash row)
    in_rows: jax.Array      # (T, dI) int32, pads clamped to 0
    local_idx: jax.Array    # (T, dO, K) int32, -1 holes
    pair_counts: jax.Array  # (T,) int32; 0 => dead tile


@jax.tree_util.register_pytree_node_class
@dataclass
class ConvPlan:
    """Plan for one conv site: COIR metadata + optional tile metadata."""

    coir: COIR
    tiles: TileArrays | None = None
    dispatch: Dispatch = REFERENCE_DISPATCH

    def tree_flatten(self):
        return (self.coir, self.tiles), self.dispatch

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)


class LevelPlan(NamedTuple):
    """One U-Net level: active set + its three conv sites."""

    coords: jax.Array
    mask: jax.Array
    sub: ConvPlan           # submanifold 3^3 metadata at this level
    down: ConvPlan | None   # strided 2^3 s2 conv to the next level
    up: ConvPlan | None     # transposed conv back to this level


@jax.tree_util.register_pytree_node_class
@dataclass
class ScenePlan:
    """Per-scene execution plan. ``stats`` is host-only diagnostics (ARF,
    chosen dataflows, tile fill) and is dropped across jit boundaries."""

    levels: tuple[LevelPlan, ...]
    stats: list[dict] | None = None

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def device_upload(self) -> "ScenePlan":
        """Device copy of a host-built plan (``PlanCache`` memoizes this;
        plan types with different leaves override it)."""
        return upload_scene_plan(self)

    def tree_flatten(self):
        return (tuple(self.levels),), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(children[0], None)


@dataclass(frozen=True)
class PlanSpec:
    """Pinned per-level dispatch decisions: every plan built from one spec
    has the same treedef and static shapes (one jit signature)."""

    levels: tuple[Dispatch, ...]


@dataclass(frozen=True)
class SignatureFamily:
    """A small family of pinned jit signatures: voxel-capacity buckets.

    Single-signature serving pads every scene to one capacity — great for
    compilation count, wasteful under heavy mixed-size traffic (a 300-voxel
    scan pays a 4096-voxel wave). A ``SignatureFamily`` is the middle
    ground: a handful of capacity tiers chosen from *observed* request
    sizes (the TorchSparse measured-over-modeled philosophy), each tier its
    own pinned ``PlanSpec``/jit signature. The serving engine compiles each
    bucket's signature on first use, so total compilations are bounded by
    ``n_buckets`` — and warm single-size traffic still compiles exactly 1.

    ``capacities`` must be ascending; ``specs`` pairs each capacity with a
    pinned :class:`PlanSpec` (or ``None`` for the always-single-signature
    reference plan at that capacity).
    """

    capacities: tuple[int, ...]
    specs: tuple[PlanSpec | None, ...] = ()

    def __post_init__(self):
        if not self.capacities:
            raise ValueError("SignatureFamily needs at least one capacity")
        if list(self.capacities) != sorted(set(self.capacities)):
            raise ValueError(
                f"capacities must be ascending+unique, got {self.capacities}")
        if not self.specs:
            object.__setattr__(
                self, "specs", (None,) * len(self.capacities))
        if len(self.specs) != len(self.capacities):
            raise ValueError(
                f"{len(self.specs)} specs for {len(self.capacities)} buckets")

    @property
    def n_buckets(self) -> int:
        return len(self.capacities)

    @property
    def max_capacity(self) -> int:
        return self.capacities[-1]

    def bucket_for(self, n_voxels: int) -> int | None:
        """Smallest bucket capacity fitting ``n_voxels`` active voxels;
        None when the scene exceeds every bucket (callers shed it)."""
        for cap in self.capacities:
            if n_voxels <= cap:
                return cap
        return None

    def spec_for(self, capacity: int) -> PlanSpec | None:
        return self.specs[self.capacities.index(capacity)]


def choose_buckets(sizes, max_buckets: int = 4, *,
                   quantum: int = 64) -> tuple[int, ...]:
    """Capacity tiers from observed request sizes (active-voxel counts).

    Quantile cuts over the observed distribution, rounded up to ``quantum``
    multiples and deduplicated — so dense regions of the size distribution
    get finer tiers and the top tier always covers the largest observed
    scene. Returns ascending capacities, at most ``max_buckets`` of them.
    """
    sizes = [int(s) for s in sizes]
    if not sizes:
        raise ValueError("choose_buckets needs at least one observed size")
    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    arr = np.sort(np.asarray(sizes))
    qs = np.linspace(0.0, 1.0, max_buckets + 1)[1:]
    caps = sorted({
        int(np.ceil(float(np.quantile(arr, q)) / quantum)) * quantum
        for q in qs})
    return tuple(caps)


def build_signature_family(
    scenes: list[SparseVoxelTensor],
    cfg,
    *,
    max_buckets: int = 4,
    quantum: int = 64,
    pin_specs: bool = True,
    **spec_kw,
) -> SignatureFamily:
    """Freeze a bucket family from representative scenes.

    Buckets come from the scenes' active-voxel counts (``choose_buckets``);
    with ``pin_specs=True`` each bucket gets its own offline-SPADE
    ``PlanSpec`` built from the representative scenes that fit it,
    compacted to the bucket capacity (``spec_kw`` forwards to
    ``build_plan_spec``). Buckets no representative scene fits keep
    ``spec=None`` (reference plans — still one signature per bucket).
    """
    from dataclasses import replace

    from repro.sparse.tensor import compact_to_capacity

    sizes = [int(np.asarray(t.mask).sum()) for t in scenes]
    caps = choose_buckets(sizes, max_buckets, quantum=quantum)
    specs: list[PlanSpec | None] = []
    for cap in caps:
        reps = [compact_to_capacity(t, cap)[0]
                for t, n in zip(scenes, sizes) if n <= cap]
        if pin_specs and reps:
            specs.append(build_plan_spec(reps, replace(cfg, capacity=cap),
                                         **spec_kw))
        else:
            specs.append(None)
    return SignatureFamily(caps, tuple(specs))


# ---------------------------------------------------------------------------
# Scene keys + plan cache
# ---------------------------------------------------------------------------

def scene_key(t: SparseVoxelTensor, tag: str = "") -> str:
    """Content hash of a scene's active geometry (features don't change the
    plan, so they are deliberately excluded)."""
    h = hashlib.sha1()
    h.update(np.asarray(t.coords).tobytes())
    h.update(np.asarray(t.mask).tobytes())
    h.update(tag.encode())
    return h.hexdigest()


class PlanCache:
    """Thread-safe LRU cache of ScenePlans keyed by scene content + config.

    Concurrent ``get_or_build`` calls for the same scene coalesce: the first
    caller builds (outside the lock), everyone else waits on a per-key event
    and returns the same plan object. Each entry holds the host-side plan
    (numpy leaves, what planner threads produce) and a lazily uploaded
    device copy — ``device=True`` (the default) returns the device plan,
    ``device=False`` the host plan, so an async pipeline can run the heavy
    numpy pass in a worker thread and defer the upload to dispatch time.

    If a build raises, the key is released and the failure propagates to
    every waiter coalesced on it (each raises the builder's exception
    instead of silently re-building); callers arriving *after* the
    failure start a fresh build — a poisoned scene never wedges the
    cache, and a transient failure never poisons the key.

    ``max_entries`` bounds the number of cached entries with LRU eviction
    (host *and* memoized device copies go together, so a long-running
    stream whose geometry drifts — every frame a fresh key — cannot leak
    plan entries without bound). It defaults to ``capacity`` so existing
    behavior is unchanged; pass a smaller value to tighten memory.
    """

    def __init__(self, capacity: int = 128, *,
                 max_entries: int | None = None):
        self.capacity = capacity
        self.max_entries = capacity if max_entries is None else int(max_entries)
        if self.max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self._plans: OrderedDict[str, dict] = OrderedDict()
        # key -> {"ev": Event, "error": BaseException | None}; the error
        # is set before the event so coalesced waiters see the failure
        self._building: dict[str, dict] = {}
        self._lock = ordered_lock("plan_cache")
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def invalidate(self) -> int:
        """Drop every cached entry (in-flight builds are unaffected: they
        insert fresh entries when they land). This is the autotune
        winner-flip hook — ``engine.autotune.CostTable`` fires it when a
        measured winner changes, so plans keyed under the old decision are
        rebuilt instead of served stale. Returns the number of entries
        dropped."""
        with self._lock:
            n = len(self._plans)
            self._plans.clear()
            self.invalidations += 1
        return n

    @staticmethod
    def _resolve(entry: dict, device: bool) -> ScenePlan:
        """Host plan, or the memoized device upload (done outside the global
        lock so planner threads never stall behind an upload)."""
        if not device:
            return entry["host"]
        if entry["device"] is None:
            with entry["dev_lock"]:
                if entry["device"] is None:
                    entry["device"] = entry["host"].device_upload()
        return entry["device"]

    def key_for(self, t: SparseVoxelTensor, cfg, *, topology: str | None = None,
                **build_kw) -> str:
        """Cache key for scene ``t`` under ``cfg`` + build mode: the same
        geometry under a different config/spec is a different plan. The key
        is an O(V) content hash — callers on a hot path should compute it
        once and pass it back via ``key=``. The table-layout version is
        mixed in so a layout bump invalidates every previously cached plan,
        and ``topology`` (``ExecutionContext.topology_key()``: mesh axes +
        shard axis) is mixed in so a plan built for one mesh topology is
        never served to another — sharded plans embed mesh-shaped halo
        tables that would silently misroute rows on a different mesh."""
        tag = (f"v{_PLAN_VERSION}|top={topology}|{cfg!r}|"
               f"{sorted(build_kw.items())!r}")
        return scene_key(t, tag)

    def get_or_build(self, t: SparseVoxelTensor, cfg, *, device: bool = True,
                     key: str | None = None, topology: str | None = None,
                     builder=None, **build_kw) -> ScenePlan:
        """Return the plan for scene ``t`` under ``cfg``, building at most
        once across threads (concurrent callers for the same key coalesce
        onto one build). ``key`` skips re-hashing when the caller already
        holds ``key_for(t, cfg, topology=..., **build_kw)``. ``builder``
        swaps the host plan builder (default ``build_scene_plan_host``;
        sharded serving passes ``engine.shard``'s) — callers must route
        distinguishing builder config through ``build_kw``/``topology`` so
        different builders never collide on a key."""
        if builder is None:
            builder = build_scene_plan_host
        if key is None:
            key = self.key_for(t, cfg, topology=topology, **build_kw)
        while True:
            with self._lock:
                entry = self._plans.get(key)
                if entry is not None:
                    self.hits += 1
                    self._plans.move_to_end(key)
                else:
                    rec = self._building.get(key)
                    if rec is None:  # this thread builds
                        rec = {"ev": threading.Event(), "error": None}
                        self._building[key] = rec
                        break
            if entry is not None:
                return self._resolve(entry, device)
            rec["ev"].wait()  # another thread is building this plan
            err = rec["error"]
            if err is not None:
                # the build we coalesced onto failed: every waiter gets
                # the builder's exception (a caller arriving after the
                # key was released starts a fresh build instead)
                raise err
            # build landed: loop re-checks the cache
        try:
            inj = _fault_injector()
            if inj is not None:
                inj.maybe_fail("plan_build", key=key)
            host = builder(t, cfg, **build_kw)
        except BaseException as e:
            with self._lock:
                self._building.pop(key, None)
            rec["error"] = e
            rec["ev"].set()
            raise
        entry = {"host": host, "device": None,
                 "dev_lock": ordered_lock("plan_cache.dev")}
        with self._lock:
            self.misses += 1
            self._plans[key] = entry
            while len(self._plans) > self.max_entries:
                self._plans.popitem(last=False)
            self._building.pop(key, None)
            rec["ev"].set()
        return self._resolve(entry, device)

    def adopt(self, key: str, host_plan: ScenePlan, *,
              device: bool = True) -> ScenePlan:
        """Fetch the cache entry at ``key`` (from ``key_for``) for an
        already-built host plan, re-inserting ``host_plan`` if the entry was
        evicted in the meantime — never rebuilds, never re-hashes, never
        counts. This is the dispatch-stage path: the plan stage built (and
        counted) the plan; dispatch just needs the memoized device copy even
        if LRU pressure evicted the entry between stages."""
        with self._lock:
            entry = self._plans.get(key)
            if entry is not None:
                self._plans.move_to_end(key)
            else:
                entry = {"host": host_plan, "device": None,
                         "dev_lock": ordered_lock("plan_cache.dev")}
                self._plans[key] = entry
                while len(self._plans) > self.max_entries:
                    self._plans.popitem(last=False)
        return self._resolve(entry, device)

    def __len__(self) -> int:
        return len(self._plans)


# ---------------------------------------------------------------------------
# Plan building
# ---------------------------------------------------------------------------

def level_geometry(t: SparseVoxelTensor, cfg) -> list[tuple]:
    """(coords, mask, resolution) of each U-Net pyramid level, as numpy.

    ``cfg`` is any UNet-like config exposing ``resolution`` and ``widths``
    (``models.scn.UNetConfig`` satisfies this; the engine takes the duck
    type to avoid depending on the model zoo). Runs entirely on the host —
    part of the plan pass an async pipeline keeps off the device."""
    out = []
    coords, mask, res = np.asarray(t.coords), np.asarray(t.mask), cfg.resolution
    for li in range(len(cfg.widths)):
        out.append((coords, mask, res))
        if li < len(cfg.widths) - 1:
            coords, mask = downsample_coords_np(coords, mask, res, 2)
            res //= 2
    return out


def _order_rows(sub_coir: COIR, coords, mask, how: str, chunk: int) -> np.ndarray:
    """Ordering of active rows for tiling: SOAR (paper), raster, or active
    (occupancy order, cheapest)."""
    mask_np = np.asarray(mask)
    if how == "soar":
        # the submanifold CIRF *is* the adjacency map (self at the center)
        return soar_order(np.asarray(sub_coir.indices), mask_np, chunk).order
    if how == "raster":
        return raster_order(np.asarray(coords), mask_np)
    return np.flatnonzero(mask_np)


def dispatch_from_dataflow(
    df: spade.Dataflow,
    attrs: spade.SparsityAttributes,
    n_majors: int,
    kernel_volume: int = _K_SUB,
    n_tiles: int | None = None,
) -> Dispatch:
    """Map a SPADE dataflow onto an engine backend decision.

    Rules: the tiled SSpNNA path serves out-major (CIRF) plans whose tile
    height is an actual tiling (``delta_o < n_majors``); everything else —
    CORF-flavored plans and whole-layer tiles — is the coarse single
    dispatch, i.e. the reference einsum. ``delta_i`` is sized from the SST
    allocation attribute so tiles fit without splitting in the common case.
    """
    if df.flavor != "CIRF" or df.delta_major >= n_majors:
        return REFERENCE_DISPATCH
    d_o = int(df.delta_major)
    d_i = min(
        n_majors,
        int(np.ceil(d_o * attrs.at(d_o, "sa_minor_alloc_sst"))) + kernel_volume,
    )
    return Dispatch(SSPNNA, df.flavor, df.walk, d_o, d_i,
                    n_tiles if n_tiles is not None else 0)


def _layer_spec(name: str, v: int, c: int) -> spade.LayerSpec:
    return spade.LayerSpec(name, v, v, _K_SUB, c, c, 2)


def build_plan_spec(
    scenes: list[SparseVoxelTensor],
    cfg,
    *,
    mem_budget: int = 64 * 1024,
    order: str = "soar",
    soar_chunk: int = 512,
    tile_margin: float = 2.0,
    tune_block_n=None,
    autotune=None,
) -> PlanSpec:
    """Freeze per-level dispatch decisions from representative scenes.

    The offline-SPADE flow (§V-C): extract sparsity attributes per scene and
    level, aggregate into meta-attributes (MSA), run the design-space sweep
    once, and pin the winning dataflow. Tile budgets take the analytic bound
    capped at ``tile_margin`` times the worst observed count, so per-scene
    plans keep their static shapes without drowning in padding tiles.

    ``tune_block_n`` is an optional ``(c_in, n_out, delta_o, delta_i) -> int``
    hook (e.g. ``repro.engine.autotune.autotune_block_n``) that picks the
    fused kernel's N-block per layer signature; the choice is pinned in each
    level's ``Dispatch.block_n`` so every plan built from this spec runs the
    tuned block instead of defaulting to full-N.

    ``autotune`` is an optional measured :class:`~repro.engine.autotune.
    CostTable`: each level's analytical decision is overridden by the
    cheapest *measured* backend at the level's shape signature when the
    table has one, and left untouched (miss recorded) when it doesn't — a
    cold table reproduces the analytical spec bitwise.
    """
    offs3 = kernel_offsets(3)
    n_levels = len(cfg.widths)
    per_level: list[list[spade.SparsityAttributes]] = [[] for _ in range(n_levels)]
    observed_tiles: list[int] = [0] * n_levels
    level_density: list[float] = [0.0] * n_levels
    geo_attrs = []
    for t in scenes:
        rows = []
        for li, (coords, mask, res) in enumerate(level_geometry(t, cfg)):
            coir = build_cirf_np(coords, mask, coords, mask, offs3, res)
            ordering = _order_rows(coir, coords, mask, order, soar_chunk)
            attrs = spade.extract_attributes(
                np.asarray(coir.indices), np.asarray(mask), ordering)
            per_level[li].append(attrs)
            level_density[li] += (float(np.asarray(mask).sum())
                                  / float(max(res, 1)) ** 3 / len(scenes))
            rows.append((coir, ordering))
        geo_attrs.append(rows)

    dispatches = []
    for li in range(n_levels):
        msa = spade.meta_attributes(per_level[li])
        layer = _layer_spec(f"level{li}", cfg.capacity, cfg.widths[li])
        df = spade.explore(layer, {"CIRF": msa, "CORF": msa}, mem_budget)
        d = dispatch_from_dataflow(df, msa, cfg.capacity)
        if autotune is not None:
            d = autotune.adjust_dispatch(
                d, n_in=cfg.capacity, n_out=cfg.capacity,
                c_in=cfg.widths[li], c_out=cfg.widths[li],
                density=level_density[li], kernel_volume=_K_SUB)
        if d.backend == SSPNNA:
            # worst observed budgeted tile count across the rep scenes
            for rows in geo_attrs:
                coir, ordering = rows[li]
                tp = build_tile_plan(
                    np.asarray(coir.indices), ordering, d.delta_o, d.delta_i)
                observed_tiles[li] = max(observed_tiles[li], tp.n_tiles)
            bound = max_tiles(cfg.capacity, d.delta_o, d.delta_i, _K_SUB)
            n_tiles = min(bound,
                          int(np.ceil(tile_margin * observed_tiles[li])) + 2)
            block_n = (int(tune_block_n(cfg.widths[li], cfg.widths[li],
                                        d.delta_o, d.delta_i))
                       if tune_block_n is not None else d.block_n)
            d = Dispatch(d.backend, d.flavor, d.walk, d.delta_o, d.delta_i,
                         n_tiles, block_n)
        dispatches.append(d)
    return PlanSpec(tuple(dispatches))


def _tile_arrays(cirf_indices, ordering, dispatch: Dispatch,
                 n_out: int) -> TileArrays | None:
    """Build fixed-shape tile metadata (DMA-table layout) for one conv;
    None on budget overflow or when the plan needs shared-output-row tiles
    the fused kernel can't serve (callers fall back to reference)."""
    try:
        tp = build_tile_plan(
            np.asarray(cirf_indices), ordering, dispatch.delta_o,
            dispatch.delta_i,
            n_tiles=dispatch.n_tiles if dispatch.n_tiles else None)
    except ValueError:
        return None
    if tp.n_row_splits:  # fused output DMA overwrites; can't share rows
        return None
    dma = dma_tile_tables(tp, n_out)
    return TileArrays(dma.out_rows, dma.in_rows,
                      np.asarray(tp.local_idx), dma.pair_counts)


def conv_plan_for_layer(
    coir: COIR,
    ordering: np.ndarray,
    delta_o: int,
    delta_i: int,
    *,
    walk: str = "OS",
    n_tiles: int | None = None,
) -> ConvPlan:
    """Tiled ConvPlan for a standalone conv site (benchmarks / tests).

    Plane-split plans (``delta_i`` < kernel volume forcing shared output
    rows) are rejected here — pick a working-set budget that fits one row.
    """
    tp = build_tile_plan(np.asarray(coir.indices), ordering, delta_o, delta_i,
                         n_tiles=n_tiles)
    if tp.n_row_splits:
        raise ValueError(
            f"delta_i={delta_i} forces {tp.n_row_splits} plane-split tiles; "
            "the fused kernel needs disjoint output rows — raise delta_i")
    dma = dma_tile_tables(tp, int(coir.mask.shape[0]))
    tiles = TileArrays(jnp.asarray(dma.out_rows), jnp.asarray(dma.in_rows),
                       jnp.asarray(tp.local_idx), jnp.asarray(dma.pair_counts))
    return ConvPlan(coir, tiles,
                    Dispatch(SSPNNA, "CIRF", walk, delta_o, delta_i,
                             tp.n_tiles))


def _map_leaves(plan: ScenePlan, convert) -> ScenePlan:
    """Apply ``convert`` to every array leaf, preserving host-only stats."""
    out = jax.tree.map(convert, plan)
    return ScenePlan(out.levels, plan.stats)


def upload_scene_plan(plan: ScenePlan) -> ScenePlan:
    """Device-upload step: host (numpy) plan leaves -> jax arrays.

    The only part of plan building that touches the device; everything
    upstream (``build_scene_plan_host``) is host work, so an async serving
    pipeline can build plans in worker threads and upload at dispatch time.
    """
    return _map_leaves(plan, jnp.asarray)


def build_scene_plan_host(
    t: SparseVoxelTensor,
    cfg,
    *,
    spec: PlanSpec | None = None,
    plan_tiles: bool = True,
    mem_budget: int = 64 * 1024,
    order: str = "soar",
    soar_chunk: int = 512,
    autotune=None,
    breakers=None,
) -> ScenePlan:
    """Host half of ``build_scene_plan``: all array leaves are numpy.

    This is the paper's offline pass (AdMAC metadata + SOAR reordering +
    SPADE selection + tile tables) with the device upload factored out —
    pair with ``upload_scene_plan``. Safe to call from planner threads.
    ``autotune`` (a measured ``engine.autotune.CostTable``) overrides
    adaptive-mode dispatch decisions with measured winners; see
    ``build_plan_spec``. ``breakers`` (a ``backends.BreakerBoard``)
    reroutes dispatch away from backends whose circuit breaker is open —
    its repr (carrying the board generation) participates in plan-cache
    keys, so routing changes rotate cached plans.
    """
    plan = _build_scene_plan(t, cfg, spec=spec, plan_tiles=plan_tiles,
                             mem_budget=mem_budget, order=order,
                             soar_chunk=soar_chunk, autotune=autotune,
                             breakers=breakers)
    return _map_leaves(plan, np.asarray)


def build_scene_plan(
    t: SparseVoxelTensor,
    cfg,
    *,
    spec: PlanSpec | None = None,
    plan_tiles: bool = True,
    mem_budget: int = 64 * 1024,
    order: str = "soar",
    soar_chunk: int = 512,
    autotune=None,
    breakers=None,
) -> ScenePlan:
    """One AdMAC + SOAR + SPADE pass -> a device-ready ScenePlan.

    ``plan_tiles=False`` skips ordering/attribute extraction entirely and
    produces an all-reference plan (metadata identical to the legacy
    ``models.scn.build_unet_metadata``, at the same cost). Composition of
    ``build_scene_plan_host`` (numpy) + ``upload_scene_plan`` (device).
    """
    return upload_scene_plan(build_scene_plan_host(
        t, cfg, spec=spec, plan_tiles=plan_tiles, mem_budget=mem_budget,
        order=order, soar_chunk=soar_chunk, autotune=autotune,
        breakers=breakers))


def _build_scene_plan(
    t: SparseVoxelTensor,
    cfg,
    *,
    spec: PlanSpec | None = None,
    plan_tiles: bool = True,
    mem_budget: int = 64 * 1024,
    order: str = "soar",
    soar_chunk: int = 512,
    autotune=None,
    breakers=None,
) -> ScenePlan:
    if spec is not None and len(spec.levels) != len(cfg.widths):
        raise ValueError(
            f"spec has {len(spec.levels)} levels but cfg has "
            f"{len(cfg.widths)} — was it built from another config?")
    offs2 = kernel_offsets(2, centered=False)
    offs3 = kernel_offsets(3)
    geometry = level_geometry(t, cfg)
    levels: list[LevelPlan] = []
    stats: list[dict] = []
    for li, (coords, mask, res) in enumerate(geometry):
        sub_coir = build_cirf_np(coords, mask, coords, mask, offs3, res)
        down = up = None
        if li < len(cfg.widths) - 1:
            dn_coords, dn_mask, _ = geometry[li + 1]
            down_coir = build_cirf_np(
                dn_coords, dn_mask, coords, mask, offs2, res, stride=2)
            up_coir = transposed_coir_np(dn_coords, dn_mask, coords, mask,
                                         res, 2, 2)
            # resolution-changing convs stay on the coarse single dispatch
            down = ConvPlan(down_coir)
            up = ConvPlan(up_coir)

        sub, info = _assemble_level(
            sub_coir, coords, mask, li, cfg, spec=spec, plan_tiles=plan_tiles,
            mem_budget=mem_budget, order=order, soar_chunk=soar_chunk,
            autotune=autotune, breakers=breakers)
        stats.append(info)
        levels.append(LevelPlan(coords, mask, sub, down, up))
    return ScenePlan(tuple(levels), stats)


def _assemble_level(
    sub_coir: COIR,
    coords,
    mask,
    li: int,
    cfg,
    *,
    spec: PlanSpec | None,
    plan_tiles: bool,
    mem_budget: int,
    order: str,
    soar_chunk: int,
    autotune=None,
    breakers=None,
) -> tuple[ConvPlan, dict]:
    """Dispatch/ordering/tile assembly for one level's submanifold conv.

    Deterministic in ``(sub_coir, coords, mask)`` for a fixed ``autotune``
    table state — the streaming planner relies on this: running it on a
    patched (bitwise-equal) COIR yields bitwise-equal orderings, tiles and
    dispatch decisions.
    """
    n_active = int(np.asarray(mask).sum())
    info: dict = {"level": li, "n_active": n_active}
    dispatch = REFERENCE_DISPATCH
    tiles = None
    if plan_tiles and n_active > 0:
        if spec is not None:
            dispatch = spec.levels[li]
        else:
            ordering = _order_rows(sub_coir, coords, mask, order, soar_chunk)
            attrs = spade.extract_attributes(
                np.asarray(sub_coir.indices), np.asarray(mask), ordering)
            layer = _layer_spec(f"level{li}", n_active, cfg.widths[li])
            df = spade.explore(layer, {"CIRF": attrs, "CORF": attrs},
                               mem_budget)
            dispatch = dispatch_from_dataflow(df, attrs, n_active)
            info["arf"] = float(attrs.arf_avg[0])
            info["da_elems"] = df.da_elems
            if autotune is not None:
                # measured-winner consult; a miss (recorded) keeps the
                # analytical decision bitwise-unchanged
                res3 = float(max(cfg.resolution >> li, 1)) ** 3
                dispatch = autotune.adjust_dispatch(
                    dispatch, n_in=n_active, n_out=n_active,
                    c_in=cfg.widths[li], c_out=cfg.widths[li],
                    density=n_active / res3, kernel_volume=_K_SUB)
                info["autotuned"] = dispatch.backend
        if breakers is not None and dispatch.backend != REFERENCE:
            # circuit-breaker consult: a tripped backend routes new plans
            # along its fallback chain. This happens at *build* time (not
            # resolve time) so the rerouted Dispatch lands in the plan's
            # treedef and the jitted call actually changes.
            routed = breakers.route(dispatch.backend)
            if routed != dispatch.backend:
                info["breaker_rerouted"] = (dispatch.backend, routed)
                dispatch = (REFERENCE_DISPATCH if routed == REFERENCE
                            else Dispatch(routed, dispatch.flavor,
                                          dispatch.walk, dispatch.delta_o,
                                          dispatch.delta_i, dispatch.n_tiles,
                                          dispatch.block_n))
        if dispatch.backend == SSPNNA:
            if spec is not None:
                ordering = _order_rows(sub_coir, coords, mask, order,
                                       soar_chunk)
            tiles = _tile_arrays(sub_coir.indices, ordering, dispatch,
                                 int(np.asarray(mask).shape[0]))
            if tiles is None:  # tile budget overflow: coarse dispatch
                info["tile_overflow"] = True
                dispatch = REFERENCE_DISPATCH
            elif not dispatch.n_tiles:
                # adaptive mode: record the realized tile count
                dispatch = Dispatch(
                    dispatch.backend, dispatch.flavor, dispatch.walk,
                    dispatch.delta_o, dispatch.delta_i,
                    int(tiles.out_rows.shape[0]), dispatch.block_n)
    info["dispatch"] = dispatch
    return ConvPlan(sub_coir, tiles, dispatch), info


# ---------------------------------------------------------------------------
# Streaming plans
# ---------------------------------------------------------------------------

class StreamPlanState:
    """Per-stream incremental planner: cached host plan + device buffers.

    One instance per LiDAR stream. ``plan_frame`` diffs each frame against
    the stream's cached previous frame (``core.host_meta.StreamMetaState``),
    patches the host plan's metadata tables instead of rebuilding them, and
    reuses the previous frame's ``ConvPlan`` objects outright for levels the
    delta did not touch (a pure ego shift leaves the whole row graph — and
    therefore SOAR orderings and tile tables — intact). Every frame's host
    plan is also registered in the shared :class:`PlanCache` under a
    version key (``stream|<id>|...|f<frame_no>``) so stream plans live under
    the same LRU budget as batch plans.

    Frames must be planned in order; ``plan_frame`` blocks until the
    previous frame of this stream has been planned. If the wait exceeds
    ``wait_s`` (a predecessor was shed or errored), the frame is planned as
    a full rebuild so a lost frame can never wedge the stream.

    ``device_plan`` memoizes uploads per leaf *identity*: unchanged tables
    keep their device buffers across frames, so a steady-state patched
    frame uploads only the arrays that actually changed. It is not
    thread-safe — call it from a single dispatch thread (as
    ``serving.scene_engine`` does).
    """

    def __init__(self, cfg, *, cache: PlanCache | None = None,
                 spec: PlanSpec | None = None,
                 plan_tiles: bool | None = None,
                 mem_budget: int = 64 * 1024, order: str = "soar",
                 soar_chunk: int = 512, min_overlap: float = 0.5,
                 stream_id: str | None = None, topology: str | None = None,
                 wait_s: float = 5.0):
        self.cfg = cfg
        self.cache = cache if cache is not None else PlanCache()
        self.spec = spec
        self.plan_tiles = (spec is not None) if plan_tiles is None \
            else bool(plan_tiles)
        self.mem_budget = mem_budget
        self.order = order
        self.soar_chunk = soar_chunk
        self.min_overlap = float(min_overlap)
        self.wait_s = float(wait_s)
        self.stream_id = stream_id if stream_id is not None \
            else f"s{id(self):x}"
        self._tag = (f"stream|{self.stream_id}|v{_PLAN_VERSION}"
                     f"|top={topology}|{cfg!r}|spec={spec is not None}"
                     f"|tiles={self.plan_tiles}|{order}|{soar_chunk}")
        self.meta = StreamMetaState(cfg.resolution, cfg.capacity,
                                    len(cfg.widths))
        self._cond = ordered_condition("stream.plan")
        self._next_frame = 0
        self._gap = False
        self._prev_plan: ScenePlan | None = None
        self._memo: dict = {}
        self.counts = {"reused": 0, "patched": 0, "rebuilt": 0}
        self._overlap_sum = 0.0
        self._plan_ms_sum = 0.0

    # -- planning ----------------------------------------------------------

    def plan_frame(self, t: SparseVoxelTensor, frame_no: int,
                   ego_shift=(0, 0, 0)) -> tuple[str, ScenePlan, np.ndarray,
                                                 dict]:
        """Plan one stream frame; returns ``(key, host_plan, frame_rows,
        info)``. ``frame_rows`` maps the caller's rows into the stream's
        canonical layout (feed it to ``pack_stream_frame_np`` for features
        and to scatter per-row results back out)."""
        with self._cond:
            deadline = time.monotonic() + self.wait_s
            while self._next_frame < frame_no:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(remaining)
            try:
                t0 = time.perf_counter()
                if self._next_frame != frame_no or self._gap:
                    # gap in the stream (shed/failed predecessor, or an
                    # out-of-order replay): the cached delta base is stale
                    self.meta.n = None
                self._gap = False
                meta = self.meta.step(np.asarray(t.coords),
                                      np.asarray(t.mask), ego_shift,
                                      min_overlap=self.min_overlap)
                plan = self._assemble(meta)
                plan_ms = (time.perf_counter() - t0) * 1e3
                self._prev_plan = plan
                self.counts[meta.mode] += 1
                self._overlap_sum += meta.overlap
                self._plan_ms_sum += plan_ms
                key = f"{self._tag}|f{frame_no}"
                self.cache.adopt(key, plan, device=False)
                info = {"mode": meta.mode, "overlap": meta.overlap,
                        "plan_ms": plan_ms,
                        "n_active": meta.info.get("n_active")}
                if "fallback" in meta.info:
                    info["fallback"] = meta.info["fallback"]
                return key, plan, meta.frame_rows, info
            finally:
                self._next_frame = max(self._next_frame, frame_no + 1)
                self._cond.notify_all()

    def skip_frame(self, frame_no: int) -> None:
        """Mark a shed/failed frame so its successors stop waiting for it.

        The serving layer calls this when admission sheds a stream frame
        (deadline/overload): the next planned frame rebuilds from scratch
        — its delta base, and the reference point of the caller's
        ``ego_shift``, is the frame that never arrived."""
        with self._cond:
            if frame_no >= self._next_frame:
                self._gap = True
                self._next_frame = frame_no + 1
                self._cond.notify_all()

    def _assemble(self, meta) -> ScenePlan:
        prev = self._prev_plan
        if meta.mode == "reused" and prev is not None:
            return prev
        n_levels = self.meta.n_levels
        levels: list[LevelPlan] = []
        stats: list[dict] = []
        for li in range(n_levels):
            coords, mask, sub_coir = meta.levels[li]
            if prev is not None and not meta.changed[li]:
                # untouched level: identical tables => identical ordering,
                # tiles and dispatch; reuse the ConvPlan object wholesale
                sub = prev.levels[li].sub
                info = dict(prev.stats[li]) if prev.stats else {"level": li}
            else:
                sub, info = _assemble_level(
                    sub_coir, coords, mask, li, self.cfg, spec=self.spec,
                    plan_tiles=self.plan_tiles, mem_budget=self.mem_budget,
                    order=self.order, soar_chunk=self.soar_chunk)
            down = up = None
            if li < n_levels - 1:
                if prev is not None and not meta.pair_changed[li]:
                    down = prev.levels[li].down
                    up = prev.levels[li].up
                else:
                    down_coir, up_coir = meta.pairs[li]
                    down = ConvPlan(down_coir)
                    up = ConvPlan(up_coir)
            levels.append(LevelPlan(coords, mask, sub, down, up))
            stats.append(info)
        return ScenePlan(tuple(levels), stats)

    # -- device upload with per-leaf memoization ---------------------------

    def device_plan(self, host_plan: ScenePlan) -> ScenePlan:
        """Upload a stream host plan, reusing device buffers for leaves
        that are the *same array object* as the previous frame's (patched
        frames share every untouched table). Single-threaded by contract."""
        new_memo: dict = {}
        old_memo = self._memo

        def convert(x):
            k = id(x)
            hit = old_memo.get(k)
            if hit is None or hit[0] is not x:
                hit = (x, jnp.asarray(x))
            new_memo[k] = hit
            return hit[1]

        out = jax.tree.map(convert, host_plan)
        self._memo = new_memo
        return ScenePlan(out.levels, host_plan.stats)

    # -- stats -------------------------------------------------------------

    def stats(self) -> dict:
        """Aggregate per-stream reuse counters (for ``WaveStats.notes``)."""
        frames = sum(self.counts.values())
        return {
            "frames": frames,
            **self.counts,
            "mean_overlap": self._overlap_sum / max(frames, 1),
            "mean_plan_ms": self._plan_ms_sum / max(frames, 1),
        }
