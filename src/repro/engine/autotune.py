"""Profile-guided SPADE: measured cost tables, autotune cache, re-profiling.

SPADE picks a dataflow per layer from the paper's analytical data-access
model (Eqn 5, ``core.spade``); our own benchmarks show the model can be
badly wrong on a real target — ``BENCH_sspnna.json`` has the fused kernel
at 0.18x of XLA's gather-einsum on CPU interpret even though the model
says it wins. TorchSparse attributes much of its speedup to replacing
exactly this kind of static modeling with *measured* adaptive tuning.
This module closes that loop:

* :func:`measure` — the shared warmup + median-of-k timing harness
  (``block_until_ready`` on every timed call). ``benchmarks.common.time_fn``
  is a thin wrapper over it, so the tuner and the bench suite agree on
  what a microsecond means.
* :class:`CostTable` — measured per-backend wall-clock keyed by a bucketed
  shape signature ``(n_in, n_out, C_in, C_out, K, density-bin, backend,
  block_n)``, with a persistent JSON cache (versioned with the plan-layout
  version plus a jax/device fingerprint; corrupt or stale files are
  ignored, writes are atomic), seedable from CI's ``BENCH_*.json``
  artifacts (:func:`seed_cost_table`).
* dispatch consult — ``engine.plan.build_plan_spec`` and adaptive plan
  builds call :meth:`CostTable.adjust_dispatch` first and fall back to the
  analytical decision on a miss (recording the miss); a cold table is
  bitwise identical to the unmeasured dispatcher.
* plan "recompilation" — when the measured winner for a signature flips,
  the table bumps its ``generation`` (part of its ``repr``, and therefore
  of every ``PlanCache`` key built with ``autotune=``) and fires its flip
  hooks (``ExecutionContext`` wires ``plan_cache.invalidate`` in).
* :func:`reprofile` — the budgeted idle-gap worker ``WaveScheduler`` runs
  between waves (``on_idle``): re-measures the hottest missed signatures,
  then the stalest still-consulted ones, on a synthetic workload at the
  signature's shape through *every* registered backend able to run it
  (:func:`measure_backends` walks the ``BackendRegistry``), so new
  backends are tuned without touching the tuner.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import tempfile
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.runtime import ordered_rlock

from repro.engine.plan import (
    _PLAN_VERSION,
    REFERENCE,
    REFERENCE_DISPATCH,
    SSPNNA,
    Dispatch,
    conv_plan_for_layer,
)

_SCHEMA = "repro-autotune/v1"
_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"

#: density-bin edges (log-spaced); scene sparsity only matters to dispatch
#: at order-of-magnitude granularity, and coarse bins are what make cached
#: measurements transfer across scenes
_DENSITY_EDGES = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1)


# ---------------------------------------------------------------------------
# Timing harness
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Measurement:
    """One timed signature: median and IQR spread of ``k`` samples (us)."""

    median_us: float
    spread_us: float
    k: int
    times_us: tuple = ()


def measure(fn, *args, warmup: int = 1, k: int = 5) -> Measurement:
    """Warmup + median-of-``k`` wall-clock of ``fn(*args)`` in us.

    Every call — warmup included — is ``jax.block_until_ready``'d, so
    async dispatch can't leak device time out of (or host time into) the
    sample. The median defeats one-off scheduler hiccups; ``spread_us``
    (interquartile range) is the noise floor callers can gate on.
    """
    for _ in range(max(int(warmup), 0)):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(int(k), 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    spread = float(np.percentile(times, 75) - np.percentile(times, 25))
    return Measurement(float(np.median(times)), spread, len(times),
                       tuple(times))


# ---------------------------------------------------------------------------
# Shape signatures
# ---------------------------------------------------------------------------

def _pow2(n: int) -> int:
    """Round up to the next power of two (0 stays 0): measured costs must
    transfer across scenes, so row counts are bucketed, never exact."""
    n = int(n)
    return 1 << (n - 1).bit_length() if n > 0 else 0


def density_bin(density: float) -> int:
    """Log-spaced sparsity bucket of an active-voxel density in [0, 1]."""
    return int(np.searchsorted(_DENSITY_EDGES, max(float(density), 0.0),
                               side="right"))


def _bin_density(b: int) -> float:
    """Representative density of a bin (geometric midpoint) — what the
    synthetic re-profiling workloads are generated at."""
    edges = (0.0,) + _DENSITY_EDGES + (1.0,)
    b = min(max(int(b), 0), len(edges) - 2)
    lo, hi = edges[b], edges[b + 1]
    return hi / 2.0 if lo == 0.0 else float(np.sqrt(lo * hi))


@dataclass(frozen=True)
class ShapeSig:
    """One cost-table key. ``n_in``/``n_out`` are power-of-two row-count
    buckets and ``density_bin`` a log-spaced sparsity bucket (exact values
    never repeat across scenes; buckets do). ``backend``/``block_n``
    distinguish measurements of the same shape; zeroing them
    (:meth:`group`) yields the lookup key dispatch consults."""

    n_in: int
    n_out: int
    c_in: int
    c_out: int
    k: int
    density_bin: int
    backend: str = ""
    block_n: int = 0

    def group(self) -> "ShapeSig":
        """The backend-free shape key measurements compete under."""
        if not self.backend and not self.block_n:
            return self
        return dataclasses.replace(self, backend="", block_n=0)

    def encode(self) -> str:
        return (f"{self.n_in}:{self.n_out}:{self.c_in}:{self.c_out}:"
                f"{self.k}:{self.density_bin}:{self.backend}:{self.block_n}")

    @classmethod
    def decode(cls, s: str) -> "ShapeSig":
        parts = s.split(":")
        if len(parts) != 8:
            raise ValueError(f"malformed ShapeSig {s!r}")
        nums = [int(p) for p in parts[:6]]
        return cls(*nums, backend=parts[6], block_n=int(parts[7]))


def signature(n_in: int, n_out: int, c_in: int, c_out: int, *,
              density: float, kernel_volume: int = 27, backend: str = "",
              block_n: int = 0) -> ShapeSig:
    """Bucketed signature of one conv site (the key everything agrees on:
    dispatch consults, profiling records, benches seed)."""
    return ShapeSig(_pow2(n_in), _pow2(n_out), int(c_in), int(c_out),
                    int(kernel_volume), density_bin(density), backend,
                    int(block_n))


# ---------------------------------------------------------------------------
# Cost table
# ---------------------------------------------------------------------------

@dataclass
class CostEntry:
    """One measured (signature, backend) cost. ``delta_o``/``delta_i`` are
    the tile shape the measurement ran at — what a reference->sspnna flip
    tiles the plan with; ``seq`` is the table-local recency stamp."""

    sig: ShapeSig
    median_us: float
    spread_us: float = 0.0
    k: int = 1
    delta_o: int = 0
    delta_i: int = 0
    seq: int = 0


def device_fingerprint() -> str:
    """jax version + platform + device kind: a cached measurement is only
    meaningful on the stack that produced it."""
    try:
        dev = jax.devices()[0]
        kind = getattr(dev, "device_kind", "") or dev.platform
    except Exception:  # no devices in exotic test rigs
        kind = "unknown"
    return f"jax={jax.__version__}|{jax.default_backend()}|{kind}"


def default_cache_path() -> str:
    """On-disk cache location; override with ``REPRO_AUTOTUNE_CACHE``."""
    env = os.environ.get(_ENV_CACHE)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


class CostTable:
    """Measured per-backend cost per shape signature, with flip tracking.

    Thread-safe (planner threads consult while an idle hook records).
    ``generation`` counts measured-winner flips; it is part of ``repr`` —
    and ``PlanCache.key_for`` reprs its build kwargs into every key — so
    passing ``autotune=table`` to a plan build makes cached plans
    self-invalidate on a flip, and :meth:`add_flip_hook` lets an
    ``ExecutionContext`` clear already-cached entries eagerly.

    A *miss* (consulted signature with no measurements) falls back to the
    analytical decision unchanged and is counted per signature; the idle
    re-profiler drains the hottest misses first.
    """

    def __init__(self, *, fingerprint: str | None = None):
        self.fingerprint = (device_fingerprint() if fingerprint is None
                            else fingerprint)
        self.generation = 0
        self.hits = 0
        #: how the table came to be: fresh | ok | missing | corrupt |
        #: version-mismatch | fingerprint-mismatch (see :meth:`load`)
        self.load_status = "fresh"
        self._groups: dict[ShapeSig, dict[ShapeSig, CostEntry]] = {}
        self._misses: dict[ShapeSig, dict] = {}
        self._group_hits: dict[ShapeSig, int] = {}
        self._seq = 0
        self._lock = ordered_rlock("autotune")
        self._flip_hooks: list = []

    def __repr__(self):
        # deliberately generation-only: plan-cache keys embed this repr and
        # must change exactly when the measured winner flips, not on every
        # recorded sample
        return f"CostTable(gen={self.generation})"

    def __len__(self) -> int:
        with self._lock:
            return sum(len(g) for g in self._groups.values())

    def entries(self) -> list[CostEntry]:
        with self._lock:
            return [e for g in self._groups.values() for e in g.values()]

    @property
    def miss_count(self) -> int:
        with self._lock:
            return sum(m["count"] for m in self._misses.values())

    def stats(self) -> dict:
        with self._lock:
            return {"entries": sum(len(g) for g in self._groups.values()),
                    "groups": len(self._groups), "hits": self.hits,
                    "misses": sum(m["count"] for m in self._misses.values()),
                    "generation": self.generation}

    # -- recording ---------------------------------------------------------

    def add_flip_hook(self, fn) -> None:
        """Call ``fn()`` whenever the measured winner of any signature
        flips (``ExecutionContext`` registers ``plan_cache.invalidate``)."""
        self._flip_hooks.append(fn)

    def _best_locked(self, gk: ShapeSig) -> CostEntry | None:
        g = self._groups.get(gk)
        if not g:
            return None
        return min(g.values(), key=lambda e: e.median_us)

    def record(self, sig: ShapeSig, median_us: float, *,
               spread_us: float = 0.0, k: int = 1, delta_o: int = 0,
               delta_i: int = 0) -> bool:
        """Record one measurement; returns True when it flipped the
        signature's winner (generation bumped, flip hooks fired). A first
        measurement of a signature that had recorded misses also counts as
        a flip — plans were built against the analytical fallback."""
        if not sig.backend:
            raise ValueError("record() needs sig.backend set")
        gk = sig.group()
        with self._lock:
            prev = self._best_locked(gk)
            prev_win = ((prev.sig.backend, prev.sig.block_n)
                        if prev is not None else None)
            had_miss = gk in self._misses
            self._seq += 1
            self._groups.setdefault(gk, {})[sig] = CostEntry(
                sig, float(median_us), float(spread_us), int(k),
                int(delta_o), int(delta_i), self._seq)
            self._misses.pop(gk, None)
            self._group_hits[gk] = 0
            best = self._best_locked(gk)
            win = (best.sig.backend, best.sig.block_n)
            flipped = (win != prev_win) if prev_win is not None else had_miss
            if flipped:
                self.generation += 1
            hooks = list(self._flip_hooks) if flipped else ()
        for fn in hooks:
            fn()
        return flipped

    # -- lookup ------------------------------------------------------------

    def best(self, sig: ShapeSig) -> CostEntry | None:
        """Cheapest measured entry for ``sig``'s shape group (any backend);
        None on a cold group. Counts as consultation interest for the
        staleness-driven re-profiler."""
        gk = sig.group()
        with self._lock:
            e = self._best_locked(gk)
            if e is not None:
                self._group_hits[gk] = self._group_hits.get(gk, 0) + 1
            return e

    def note_miss(self, sig: ShapeSig, *, delta_o: int = 0,
                  delta_i: int = 0, backend: str = "") -> None:
        """Count a consulted-but-unmeasured signature, remembering the
        analytical dispatch parameters so re-profiling can tile with them."""
        gk = sig.group()
        with self._lock:
            m = self._misses.setdefault(
                gk, {"count": 0, "delta_o": 0, "delta_i": 0, "backend": ""})
            m["count"] += 1
            if delta_o:
                m["delta_o"], m["delta_i"] = int(delta_o), int(delta_i)
            if backend:
                m["backend"] = backend

    def clear_miss(self, sig: ShapeSig) -> None:
        with self._lock:
            self._misses.pop(sig.group(), None)

    def hottest_misses(self, n: int | None = None) -> list[tuple[ShapeSig,
                                                                 dict]]:
        """Missed signatures by consult count, hottest first."""
        with self._lock:
            items = sorted(self._misses.items(),
                           key=lambda kv: -kv[1]["count"])
        return items if n is None else items[:n]

    def stalest_groups(self, n: int | None = None) -> list[ShapeSig]:
        """Measured groups consulted since their last measurement, oldest
        measurement first — the re-profiler's second-priority queue."""
        with self._lock:
            cands = [(gk, max(e.seq for e in g.values()))
                     for gk, g in self._groups.items()
                     if self._group_hits.get(gk, 0) > 0]
        cands.sort(key=lambda kv: kv[1])
        out = [gk for gk, _ in cands]
        return out if n is None else out[:n]

    # -- dispatch consult --------------------------------------------------

    def adjust_dispatch(self, dispatch: Dispatch, *, n_in: int, n_out: int,
                        c_in: int, c_out: int, density: float,
                        kernel_volume: int = 27) -> Dispatch:
        """Measured-winner override of one analytical ``Dispatch``.

        Cold group: the analytical decision is returned *unchanged* (and
        the miss recorded) — a cold table is bitwise-identical to the
        unmeasured dispatcher. On a hit, the cheapest measured backend
        wins: flips to reference drop the tile parameters; flips to sspnna
        tile with the winning measurement's ``delta_o``/``delta_i`` (from
        the analytical decision when the measurement carries none) and
        adopt its measured ``block_n``.
        """
        gk = signature(n_in, n_out, c_in, c_out, density=density,
                       kernel_volume=kernel_volume)
        best = self.best(gk)
        if best is None:
            self.note_miss(gk, delta_o=dispatch.delta_o,
                           delta_i=dispatch.delta_i,
                           backend=dispatch.backend)
            return dispatch
        with self._lock:
            self.hits += 1
        win = best.sig.backend
        if win == dispatch.backend:
            if win == SSPNNA and best.sig.block_n and not dispatch.block_n:
                return dataclasses.replace(dispatch,
                                           block_n=best.sig.block_n)
            return dispatch
        if win == REFERENCE:
            return REFERENCE_DISPATCH
        if win == SSPNNA:
            d_o = best.delta_o or dispatch.delta_o
            d_i = best.delta_i or dispatch.delta_i
            if not (d_o and d_i):  # nothing to tile with; keep analytical
                return dispatch
            return Dispatch(SSPNNA, "CIRF", dispatch.walk or "OS",
                            int(d_o), int(d_i), 0, best.sig.block_n)
        return dataclasses.replace(dispatch, backend=win)

    # -- persistence -------------------------------------------------------

    def to_payload(self) -> dict:
        with self._lock:
            entries = [{"sig": e.sig.encode(), "median_us": e.median_us,
                        "spread_us": e.spread_us, "k": e.k,
                        "delta_o": e.delta_o, "delta_i": e.delta_i}
                       for g in self._groups.values() for e in g.values()]
            return {"schema": _SCHEMA, "plan_version": _PLAN_VERSION,
                    "fingerprint": self.fingerprint,
                    "generation": self.generation, "entries": entries}

    def save(self, path: str | None = None) -> str:
        """Atomic write (tmp file + rename) so a crashed writer can never
        leave a truncated cache for the next process to trip on."""
        path = path or default_cache_path()
        payload = self.to_payload()
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".autotune-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    @classmethod
    def load(cls, path: str | None = None, *,
             fingerprint: str | None = None) -> "CostTable":
        """Load a cached table; *any* problem — missing file, corrupt or
        truncated JSON, plan-version or device-fingerprint mismatch —
        yields an empty table (``load_status`` says why) rather than an
        error or a stale measurement."""
        path = path or default_cache_path()
        table = cls(fingerprint=fingerprint)
        try:
            with open(path) as f:
                payload = json.load(f)
        except FileNotFoundError:
            table.load_status = "missing"
            return table
        except (OSError, ValueError, UnicodeDecodeError):
            table.load_status = "corrupt"
            return table
        try:
            if (not isinstance(payload, dict)
                    or payload.get("schema") != _SCHEMA
                    or int(payload.get("plan_version", -1)) != _PLAN_VERSION):
                table.load_status = "version-mismatch"
                return table
            if payload.get("fingerprint") != table.fingerprint:
                table.load_status = "fingerprint-mismatch"
                return table
            for row in payload.get("entries", []):
                table.record(ShapeSig.decode(row["sig"]),
                             float(row["median_us"]),
                             spread_us=float(row.get("spread_us", 0.0)),
                             k=int(row.get("k", 1)),
                             delta_o=int(row.get("delta_o", 0)),
                             delta_i=int(row.get("delta_i", 0)))
            table.generation = int(payload.get("generation", 0))
        except (KeyError, TypeError, ValueError, AttributeError):
            fresh = cls(fingerprint=fingerprint)
            fresh.load_status = "corrupt"
            return fresh
        table.load_status = "ok"
        return table


# ---------------------------------------------------------------------------
# Seeding from bench artifacts
# ---------------------------------------------------------------------------

def _derived_tokens(derived: str) -> dict:
    out = {}
    for tok in derived.split():
        if "=" in tok:
            key, val = tok.split("=", 1)
            out[key] = val
    return out


_SSPNNA_ROW = re.compile(r"sspnna/r(\d+)_.*_(fused|xla)$")


def _seed_row(table: CostTable, name: str, us: float, derived: str,
              kernel_volume: int) -> bool:
    if us <= 0:
        return False
    toks = _derived_tokens(derived)
    if "sig" in toks:  # canonical form (bench_dispatch emits these)
        try:
            sig = ShapeSig.decode(toks["sig"])
        except ValueError:
            return False
        if not sig.backend:
            return False
        table.record(sig, us,
                     delta_o=int(toks.get("delta_o", 0) or 0),
                     delta_i=int(toks.get("delta_i", 0) or 0))
        return True
    m = _SSPNNA_ROW.match(name)  # bench_sspnna arms: fused / xla einsum
    if m is None:
        return False
    res, arm = int(m.group(1)), m.group(2)
    try:
        density = float(toks["density"])
        c_in, c_out = int(toks["C"]), int(toks["N"])
        d_o, d_i = int(toks.get("dO", 0)), int(toks.get("dI", 0))
    except (KeyError, ValueError):
        return False
    n_active = max(int(round(density * res ** 3)), 1)
    backend = SSPNNA if arm == "fused" else REFERENCE
    sig = signature(n_active, n_active, c_in, c_out, density=density,
                    kernel_volume=kernel_volume, backend=backend)
    table.record(sig, us,
                 delta_o=d_o if backend == SSPNNA else 0,
                 delta_i=d_i if backend == SSPNNA else 0)
    return True


def seed_cost_table(table: CostTable, paths, *,
                    kernel_volume: int = 27) -> int:
    """Seed measurements from ``bench-rows/v1`` JSON artifacts.

    Two row shapes are understood: rows whose ``derived`` carries an
    explicit ``sig=<encoded>`` token (what ``bench_dispatch`` emits), and
    ``bench_sspnna`` sweep rows (``sspnna/r<res>_*_{fused,xla}`` — fused
    maps to the ``sspnna`` backend, the xla gather-einsum to ``reference``;
    the pre-gathered arm matches no engine backend and is skipped), whose
    signature is reconstructed from the derived ``density/dO/dI/C/N``
    tokens. Unreadable files and unrecognized rows are skipped. Returns
    the number of entries recorded.
    """
    n = 0
    for path in paths:
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            continue
        for row in payload.get("rows", []) if isinstance(payload, dict) \
                else []:
            try:
                if _seed_row(table, str(row.get("name", "")),
                             float(row.get("us_per_call", 0.0)),
                             str(row.get("derived", "")), kernel_volume):
                    n += 1
            except (TypeError, ValueError):
                continue
    return n


# ---------------------------------------------------------------------------
# Backend profiling
# ---------------------------------------------------------------------------

def measure_backends(plan, feats, params, *, registry=None, ctx=None,
                     warmup: int = 1, k: int = 3,
                     **run_kw) -> dict[str, Measurement]:
    """Measured cost of every registered backend able to run ``plan``.

    Walks the ``BackendRegistry`` (scene-level backends and those whose
    ``supports(plan)`` says no are skipped), so a newly registered backend
    is profiled — and therefore eligible to win dispatch — without any
    tuner changes. Returns ``{backend_name: Measurement}``.
    """
    if registry is None:
        from repro.engine.backends import default_registry
        registry = default_registry()
    if ctx is None:
        from repro.engine.context import current_context
        ctx = current_context()
    out: dict[str, Measurement] = {}
    for name in registry.names():
        impl = registry.get(name)
        if impl.scene_level or not impl.supports(plan):
            continue
        try:
            out[name] = measure(
                lambda impl=impl: impl.run(feats, params, plan, ctx=ctx,
                                           **run_kw),
                warmup=warmup, k=k)
        except NotImplementedError:
            continue
    return out


def _synth_workload(gk: ShapeSig, *, delta_o: int = 0, delta_i: int = 0,
                    seed: int = 0):
    """A genuine tiled conv workload at a signature's bucketed shape:
    unique random voxels at the bin's representative density, real CIRF
    metadata and tile tables. None when the signature can't be realized
    (non-3^3 kernels, zero rows, un-tileable deltas)."""
    from repro.core.hashgrid import kernel_offsets
    from repro.core.host_meta import build_cirf_np
    from repro.core.sparse_conv import SparseConvParams

    if gk.k != 27 or gk.n_out <= 0 or gk.c_in <= 0 or gk.c_out <= 0:
        return None
    n = max(int(gk.n_out), 8)
    density = _bin_density(gk.density_bin)
    res = int(np.ceil((n / density) ** (1.0 / 3.0)))
    res = min(max(res, 2), 512)
    while res ** 3 <= n:
        res += 1
    total = res ** 3
    rng = np.random.default_rng(seed)
    cells = np.unique(rng.integers(0, total, size=2 * n + 16))
    while cells.size < n:
        cells = np.unique(np.concatenate(
            [cells, rng.integers(0, total, size=n)]))
    cells = rng.permutation(cells)[:n]
    coords = np.stack(np.unravel_index(cells, (res, res, res)),
                      axis=1).astype(np.int32)
    mask = np.ones(n, bool)
    coir = build_cirf_np(coords, mask, coords, mask, kernel_offsets(3), res)
    ordering = np.flatnonzero(mask)
    d_o = min(int(delta_o) or min(64, max(8, n // 8)), n)
    d_i = int(delta_i) or (3 * d_o + gk.k)
    plan = None
    while plan is None:
        try:
            plan = conv_plan_for_layer(coir, ordering, d_o, d_i)
        except ValueError:  # plane-split tiles: widen the working set
            if d_i >= n + gk.k:
                return None
            d_i = min(2 * d_i, n + gk.k)
    feats = jnp.asarray(rng.normal(size=(n, gk.c_in)), jnp.float32)
    params = SparseConvParams(
        jnp.asarray(rng.normal(size=(gk.k, gk.c_in, gk.c_out)) * 0.1,
                    jnp.float32),
        jnp.zeros((gk.c_out,), jnp.float32))
    return plan, feats, params


def profile_group(table: CostTable, sig: ShapeSig, *, delta_o: int = 0,
                  delta_i: int = 0, registry=None, ctx=None, k: int = 3,
                  seed: int = 0, **run_kw) -> dict[str, Measurement]:
    """Measure every runnable backend at one signature group and record
    the results (clearing the group's miss). Empty when the signature
    can't be synthesized — the miss is dropped so the re-profiler never
    spins on it."""
    gk = sig.group()
    work = _synth_workload(gk, delta_o=delta_o, delta_i=delta_i, seed=seed)
    if work is None:
        table.clear_miss(gk)
        return {}
    plan, feats, params = work
    results = measure_backends(plan, feats, params, registry=registry,
                               ctx=ctx, k=k, **run_kw)
    d = plan.dispatch
    for name, m in results.items():
        table.record(dataclasses.replace(gk, backend=name), m.median_us,
                     spread_us=m.spread_us, k=m.k,
                     delta_o=d.delta_o, delta_i=d.delta_i)
    if not results:
        table.clear_miss(gk)
    return results


def reprofile(table: CostTable, *, registry=None, ctx=None,
              budget_ms: float = 50.0, max_sigs: int | None = None,
              k: int = 2, seed: int = 0, **run_kw) -> int:
    """Budgeted re-profiling pass: hottest missed signatures first, then
    the stalest still-consulted measured ones.

    This is what a ``WaveScheduler`` idle-gap hook runs between waves —
    strictly off the serving hot path, and off entirely at
    ``budget_ms <= 0`` (the default everywhere tests don't opt in). The
    wall-clock budget is checked before each signature, so one pass costs
    at most ``budget_ms`` plus a single signature's profiling time.
    Returns the number of signature groups profiled.
    """
    if budget_ms <= 0:
        return 0
    t0 = time.perf_counter()
    done = 0
    while max_sigs is None or done < max_sigs:
        if (time.perf_counter() - t0) * 1e3 >= budget_ms:
            break
        target, d_o, d_i = None, 0, 0
        misses = table.hottest_misses(1)
        if misses:
            target, m = misses[0]
            d_o, d_i = m["delta_o"], m["delta_i"]
        else:
            stale = table.stalest_groups(1)
            if stale:
                target = stale[0]
        if target is None:
            break
        profile_group(table, target, delta_o=d_o, delta_i=d_i,
                      registry=registry, ctx=ctx, k=k, seed=seed + done,
                      **run_kw)
        done += 1
    return done


# ---------------------------------------------------------------------------
# Fused-kernel block_n sweep (moved from benchmarks.common)
# ---------------------------------------------------------------------------

# per-parameter-set memo so a plan-spec build sweeps each layer shape once
_BLOCK_N_CACHE: dict[tuple, int] = {}


def _block_n_candidates(n: int) -> list[int]:
    """Divisors of ``n`` worth sweeping: full-N down to 8-wide blocks."""
    cands = [b for b in (n, n // 2, n // 4) if b >= 8 and n % b == 0]
    return cands or [n]


def autotune_block_n(c_in: int, n_out: int, delta_o: int, delta_i: int,
                     *, kernel_volume: int = 27, n_tiles: int = 8,
                     iters: int = 3, seed: int = 0) -> int:
    """Pick the fused kernel's N-block for one ``(C, N, dO, dI)`` signature.

    Times ``kernels.sspnna.sspnna_fused`` on synthetic tiles at the layer's
    shape for each candidate divisor of ``n_out`` and returns the fastest.
    Memoized per full parameter set; pass as
    ``build_plan_spec(tune_block_n=...)`` so SPADE plans pin the choice in
    ``Dispatch.block_n`` instead of defaulting to full-N.
    """
    key = (c_in, n_out, delta_o, delta_i, kernel_volume, n_tiles, iters, seed)
    if key in _BLOCK_N_CACHE:
        return _BLOCK_N_CACHE[key]
    from repro.kernels.sspnna.sspnna import sspnna_fused

    rng = np.random.default_rng(seed)
    # big enough for the working sets AND the n_tiles*delta_o disjoint
    # output rows drawn below
    v = max(4 * delta_i, n_tiles * delta_o, 256)
    feats = jnp.asarray(rng.normal(size=(v, c_in)), jnp.float32)
    weights = jnp.asarray(
        rng.normal(size=(kernel_volume, c_in, n_out)) * 0.1, jnp.float32)
    in_rows = jnp.asarray(
        rng.integers(0, v, (n_tiles, delta_i)).astype(np.int32))
    out_rows = jnp.asarray(
        rng.permutation(v)[: n_tiles * delta_o]
        .reshape(n_tiles, delta_o).astype(np.int32))
    local_idx = jnp.asarray(
        rng.integers(-1, delta_i, (n_tiles, delta_o, kernel_volume))
        .astype(np.int32))
    counts = jnp.ones((n_tiles,), jnp.int32)

    best_bn, best_us = 0, float("inf")
    for bn in _block_n_candidates(n_out):
        us = measure(
            lambda bn=bn: sspnna_fused(
                feats, weights, out_rows, in_rows, local_idx, counts,
                n_out=v, block_n=bn),
            warmup=1, k=iters).median_us
        if us < best_us:
            best_bn, best_us = bn, us
    _BLOCK_N_CACHE[key] = best_bn
    return best_bn
