"""repro.engine: plan-driven sparse-conv execution.

Build a ``ScenePlan`` once per input scene (COIR + SOAR + SPADE + tiles),
then run every conv through ``sparse_conv`` / every U-Net through
``apply_unet`` — the engine dispatches each layer to the reference einsum
or the tiled SSpNNA Pallas path per the plan.
"""
from repro.engine.api import (
    BACKENDS,
    apply_unet,
    conv_block,
    reference_plan,
    resolve_backend,
    sparse_conv,
)
from repro.engine.plan import (
    REFERENCE,
    SSPNNA,
    ConvPlan,
    Dispatch,
    LevelPlan,
    PlanCache,
    PlanSpec,
    ScenePlan,
    TileArrays,
    build_plan_spec,
    build_scene_plan,
    build_scene_plan_host,
    conv_plan_for_layer,
    dispatch_from_dataflow,
    level_geometry,
    scene_key,
    upload_scene_plan,
)

__all__ = [
    "BACKENDS",
    "REFERENCE",
    "SSPNNA",
    "ConvPlan",
    "Dispatch",
    "LevelPlan",
    "PlanCache",
    "PlanSpec",
    "ScenePlan",
    "TileArrays",
    "apply_unet",
    "build_plan_spec",
    "build_scene_plan",
    "build_scene_plan_host",
    "conv_block",
    "conv_plan_for_layer",
    "dispatch_from_dataflow",
    "level_geometry",
    "reference_plan",
    "resolve_backend",
    "scene_key",
    "sparse_conv",
    "upload_scene_plan",
]
