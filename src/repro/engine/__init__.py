"""repro.engine: plan-driven sparse-conv execution.

Build a ``ScenePlan`` once per input scene (COIR + SOAR + SPADE + tiles),
then run every conv through ``sparse_conv`` / every U-Net through
``apply_unet``. Dispatch goes through the backend registry
(``engine.backends``) under an ``ExecutionContext`` (``engine.context``)
that owns the mesh, registry view and plan cache; mesh-sharded scenes
(``engine.shard``) execute as the registered ``"sharded"`` backend with
halo exchange for cross-shard receptive fields.
"""
from repro.engine.autotune import (
    CostTable,
    Measurement,
    ShapeSig,
    autotune_block_n,
    default_cache_path,
    device_fingerprint,
    measure,
    measure_backends,
    profile_group,
    reprofile,
    seed_cost_table,
    signature,
)
from repro.engine.api import (
    apply_unet,
    available_backends,
    conv_block,
    reference_plan,
    resolve_backend,
    sparse_conv,
)
from repro.engine.backends import (
    AUTO,
    Backend,
    BackendRegistry,
    default_registry,
    register_backend,
)
from repro.engine.context import (
    ExecutionContext,
    current_context,
    default_context,
    set_default_context,
    use_context,
)
from repro.engine.plan import (
    REFERENCE,
    SSPNNA,
    ConvPlan,
    Dispatch,
    LevelPlan,
    PlanCache,
    PlanSpec,
    ScenePlan,
    SignatureFamily,
    StreamPlanState,
    TileArrays,
    build_plan_spec,
    build_signature_family,
    choose_buckets,
    build_scene_plan,
    build_scene_plan_host,
    conv_plan_for_layer,
    dispatch_from_dataflow,
    level_geometry,
    scene_key,
    upload_scene_plan,
)
from repro.engine.shard import (  # noqa: F401  (registers the backend too)
    SHARDED,
    ShardLayout,
    ShardedScenePlan,
    apply_unet_sharded,
    build_sharded_scene_plan,
    build_sharded_scene_plan_host,
    pin_halo,
    upload_sharded_scene_plan,
)


def __getattr__(name: str):
    # legacy closed-enum alias; api owns the (single) definition
    if name == "BACKENDS":
        from repro.engine import api
        return api.BACKENDS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "AUTO",
    "BACKENDS",
    "REFERENCE",
    "SHARDED",
    "SSPNNA",
    "Backend",
    "BackendRegistry",
    "ConvPlan",
    "CostTable",
    "Measurement",
    "Dispatch",
    "ExecutionContext",
    "LevelPlan",
    "PlanCache",
    "PlanSpec",
    "ScenePlan",
    "ShapeSig",
    "ShardLayout",
    "ShardedScenePlan",
    "SignatureFamily",
    "StreamPlanState",
    "TileArrays",
    "apply_unet",
    "apply_unet_sharded",
    "autotune_block_n",
    "available_backends",
    "build_plan_spec",
    "build_scene_plan",
    "build_scene_plan_host",
    "build_sharded_scene_plan",
    "build_sharded_scene_plan_host",
    "build_signature_family",
    "choose_buckets",
    "conv_block",
    "conv_plan_for_layer",
    "current_context",
    "default_cache_path",
    "default_context",
    "default_registry",
    "device_fingerprint",
    "dispatch_from_dataflow",
    "level_geometry",
    "measure",
    "measure_backends",
    "pin_halo",
    "profile_group",
    "reference_plan",
    "register_backend",
    "reprofile",
    "resolve_backend",
    "scene_key",
    "seed_cost_table",
    "set_default_context",
    "signature",
    "sparse_conv",
    "upload_scene_plan",
    "upload_sharded_scene_plan",
    "use_context",
]
