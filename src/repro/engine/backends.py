"""Backend registry: pluggable execution paths behind one dispatcher.

AccSS3D's co-design premise is that metadata and execution are decided
together — SPADE emits a *dataflow decision*, and the engine maps it onto an
execution path. Pre-registry, that mapping was a closed string enum and an
if/elif chain in ``engine.api``; every new path (sharded scenes, future
TPU-tuned kernels) meant editing the dispatcher. Now the seam is explicit:

* a ``Backend`` implements ``supports(plan)`` / ``run(x, params, plan,
  ctx=...)`` (and optionally ``run_unet`` for scene-level paths that own the
  whole forward, e.g. mesh-sharded execution);
* a ``BackendRegistry`` resolves the *name* recorded in a plan's
  ``Dispatch`` to an implementation, following each backend's declared
  ``fallback`` when a plan lacks what the backend needs (the classic case:
  an SSpNNA decision whose tile budget overflowed falls back to the
  reference einsum);
* registries chain: ``registry.view()`` makes a scoped child, so an
  ``ExecutionContext`` can overlay experimental backends without mutating
  the process-wide default registry.

``Dispatch``/SPADE emit backend *names*; nothing in the planner or the
dispatcher enumerates implementations, so a new backend registers from
anywhere (``engine.register_backend``) and is immediately routable.
"""
from __future__ import annotations

from repro.core.sparse_conv import reference_conv_cirf
from repro.engine.plan import REFERENCE, SSPNNA, ConvPlan
from repro.kernels.sspnna.ops import run_sspnna_conv

AUTO = "auto"


class Backend:
    """One execution path for plan-driven sparse convolution.

    Subclasses set ``name`` (the registry key ``Dispatch.backend`` refers
    to), optionally ``plan_requirements`` (plan attributes that must be
    non-None for ``run`` to serve the plan) and ``fallback`` (the registry
    name resolution degrades to when ``supports`` says no).

    ``run`` executes one conv site. Scene-level backends (which own the
    whole U-Net forward, e.g. mesh-sharded execution) additionally
    implement ``run_unet``; ``engine.apply_unet`` routes plans that carry a
    ``scene_backend`` attribute there instead of walking levels itself.
    """

    name: str = ""
    #: plan attributes that must be present (non-None) for run() to work
    plan_requirements: tuple[str, ...] = ()
    #: registry name to resolve to instead when supports() is False
    fallback: str | None = None
    #: True for backends that execute whole scenes via run_unet
    scene_level: bool = False

    def supports(self, plan) -> bool:
        return all(getattr(plan, req, None) is not None
                   for req in self.plan_requirements)

    def run(self, x, params, plan: ConvPlan, *, ctx, **kw):
        raise NotImplementedError(
            f"backend {self.name!r} does not implement per-conv run()")

    def run_unet(self, params, feats, plan, *, ctx, **kw):
        raise NotImplementedError(
            f"backend {self.name!r} does not implement scene-level run_unet()")

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"


class BackendRegistry:
    """Name -> Backend mapping with parent chaining and fallback resolution.

    Lookup walks ``self`` then ``parent``; registration always writes to
    ``self``, so a ``view()`` child can shadow or extend the process
    default without mutating it (an ``ExecutionContext`` holds such a
    view).
    """

    def __init__(self, parent: "BackendRegistry | None" = None):
        self._impls: dict[str, Backend] = {}
        self._parent = parent

    def register(self, name: str, impl: Backend, *,
                 overwrite: bool = False) -> Backend:
        if not name or name == AUTO:
            raise ValueError(f"invalid backend name {name!r}")
        if not overwrite and name in self:
            raise ValueError(
                f"backend {name!r} already registered; pass overwrite=True "
                "to replace it")
        if not callable(getattr(impl, "run", None)):
            raise TypeError(f"backend impl {impl!r} has no run() hook")
        self._impls[name] = impl
        return impl

    def unregister(self, name: str) -> None:
        """Remove a registration made on *this* registry (not the parent)."""
        self._impls.pop(name, None)

    def get(self, name: str) -> Backend:
        reg: BackendRegistry | None = self
        while reg is not None:
            impl = reg._impls.get(name)
            if impl is not None:
                return impl
            reg = reg._parent
        raise ValueError(
            f"backend {name!r} not one of {(AUTO,) + self.names()}")

    def names(self) -> tuple[str, ...]:
        seen: dict[str, None] = {}
        reg: BackendRegistry | None = self
        while reg is not None:
            for n in reg._impls:
                seen.setdefault(n)
            reg = reg._parent
        return tuple(sorted(seen))

    def __contains__(self, name: str) -> bool:
        reg: BackendRegistry | None = self
        while reg is not None:
            if name in reg._impls:
                return True
            reg = reg._parent
        return False

    def view(self) -> "BackendRegistry":
        """A scoped child registry: reads chain to this one, writes stay
        local. This is what a fresh ``ExecutionContext`` holds."""
        return BackendRegistry(parent=self)

    def resolve(self, plan, backend: str = AUTO) -> str:
        """The backend name a call will actually run.

        ``"auto"`` reads the name the planner recorded in
        ``plan.dispatch``; a backend that can't serve the plan degrades
        along its declared ``fallback`` chain (e.g. SSpNNA without tile
        metadata -> reference).
        """
        if backend == AUTO:
            backend = plan.dispatch.backend
        impl = self.get(backend)  # raises ValueError on unknown names
        seen = {backend}
        while not impl.supports(plan):
            if impl.fallback is None or impl.fallback in seen:
                raise ValueError(
                    f"backend {backend!r} cannot serve this plan and "
                    "declares no (acyclic) fallback")
            backend = impl.fallback
            seen.add(backend)
            impl = self.get(backend)
        return backend


class ReferenceBackend(Backend):
    """Gather + one fused einsum over all weight planes — the coarse M-V
    dispatch and the numerical oracle (``core.sparse_conv``)."""

    name = REFERENCE

    def run(self, x, params, plan: ConvPlan, *, ctx, **kw):
        del ctx, kw  # kernel knobs don't apply to the einsum path
        return reference_conv_cirf(x, plan.coir, params)


class SSpNNABackend(Backend):
    """The fused gather-GEMM-scatter Pallas path driven by the plan's
    ``TileArrays`` (see ``kernels.sspnna``); plans without tile metadata
    (resolution-changing convs, tile-budget overflows) fall back to
    reference."""

    name = SSPNNA
    plan_requirements = ("tiles",)
    fallback = REFERENCE

    def run(self, x, params, plan: ConvPlan, *, ctx,
            use_kernel: bool = True, interpret: bool | None = None,
            block_n: int | None = None, **kw):
        del ctx, kw
        raw = run_sspnna_conv(
            x, params.weight, plan.tiles.out_rows, plan.tiles.in_rows,
            plan.tiles.local_idx, n_out=plan.coir.mask.shape[0],
            pair_counts=plan.tiles.pair_counts,
            use_kernel=use_kernel, interpret=interpret,
            block_n=block_n or (plan.dispatch.block_n or None))
        out = raw.astype(x.dtype) + params.bias.astype(x.dtype)
        return out * plan.coir.mask[:, None].astype(out.dtype)


_DEFAULT_REGISTRY: BackendRegistry | None = None


def default_registry() -> BackendRegistry:
    """The process-wide registry ``reference``/``sspnna`` (and ``sharded``,
    registered by ``engine.shard`` on import) live on."""
    global _DEFAULT_REGISTRY
    if _DEFAULT_REGISTRY is None:
        _DEFAULT_REGISTRY = BackendRegistry()
        _DEFAULT_REGISTRY.register(REFERENCE, ReferenceBackend())
        _DEFAULT_REGISTRY.register(SSPNNA, SSpNNABackend())
    return _DEFAULT_REGISTRY


def register_backend(name: str, impl: Backend, *,
                     overwrite: bool = False) -> Backend:
    """Register an execution backend process-wide.

    After this, any plan whose ``Dispatch.backend`` names ``name`` (or any
    explicit ``backend=name`` call) routes to ``impl`` — no engine code
    changes needed. Scoped alternative: register on
    ``ExecutionContext.registry`` to confine the backend to one context.
    """
    return default_registry().register(name, impl, overwrite=overwrite)
