"""Backend registry: pluggable execution paths behind one dispatcher.

AccSS3D's co-design premise is that metadata and execution are decided
together — SPADE emits a *dataflow decision*, and the engine maps it onto an
execution path. Pre-registry, that mapping was a closed string enum and an
if/elif chain in ``engine.api``; every new path (sharded scenes, future
TPU-tuned kernels) meant editing the dispatcher. Now the seam is explicit:

* a ``Backend`` implements ``supports(plan)`` / ``run(x, params, plan,
  ctx=...)`` (and optionally ``run_unet`` for scene-level paths that own the
  whole forward, e.g. mesh-sharded execution);
* a ``BackendRegistry`` resolves the *name* recorded in a plan's
  ``Dispatch`` to an implementation, following each backend's declared
  ``fallback`` when a plan lacks what the backend needs (the classic case:
  an SSpNNA decision whose tile budget overflowed falls back to the
  reference einsum);
* registries chain: ``registry.view()`` makes a scoped child, so an
  ``ExecutionContext`` can overlay experimental backends without mutating
  the process-wide default registry.

``Dispatch``/SPADE emit backend *names*; nothing in the planner or the
dispatcher enumerates implementations, so a new backend registers from
anywhere (``engine.register_backend``) and is immediately routable.

**Circuit breakers.** Every registry carries a :class:`BreakerBoard`
(``registry.breakers``): per-backend :class:`CircuitBreaker` state
machines fed by the serving layer (``N`` consecutive dispatch failures
attributed to a backend trip it OPEN). A tripped breaker makes the
*planner* reroute new plans along the backend's declared ``fallback``
chain (``BreakerBoard.route``) — rerouting must happen at plan-build
time, not at ``resolve()`` time, because ``resolve`` runs inside jit
traces and its answer is baked into the compiled call. Each state change
bumps the board's ``generation``, which the plan-cache key mixes in (via
the board's ``repr``), so cached plans built for the old routing rotate
out; a hook (wired by ``ExecutionContext``) also invalidates the cache
eagerly. After ``cooldown_s`` the breaker goes HALF_OPEN and lets one
probe plan through; a success closes it, a failure re-opens it.
"""
from __future__ import annotations

import time

from repro.analysis.runtime import ordered_rlock
from repro.core.sparse_conv import reference_conv_cirf
from repro.engine.plan import REFERENCE, SSPNNA, ConvPlan
from repro.kernels.sspnna.ops import run_sspnna_conv

AUTO = "auto"


def _fault_injector():
    """The ambient serving-layer fault injector, if any (lazy import so
    the engine layer has no hard dependency on serving)."""
    try:
        from repro.serving import faults
    except ImportError:  # pragma: no cover - serving always ships
        return None
    return faults.active()


# breaker states
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """Per-backend consecutive-failure circuit breaker.

    CLOSED counts consecutive failures; at ``failure_threshold`` it trips
    OPEN (the board stops routing plans to the backend). After
    ``cooldown_s`` the next ``allow()`` moves it HALF_OPEN, admitting one
    probe: ``record_success`` closes it again, ``record_failure``
    re-opens it (and restarts the cooldown). ``clock`` is injectable for
    tests. Not thread-safe on its own — :class:`BreakerBoard` serializes
    access.
    """

    def __init__(self, name: str, *, failure_threshold: int = 5,
                 cooldown_s: float = 1.0, clock=time.monotonic):
        if failure_threshold < 1:
            raise ValueError(
                f"failure_threshold must be >= 1, got {failure_threshold}")
        self.name = name
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self.state = CLOSED
        self.consecutive_failures = 0
        self.trips = 0           # total CLOSED/HALF_OPEN -> OPEN transitions
        self._opened_at: float | None = None

    def allow(self) -> bool:
        """May a *new plan* route to this backend right now? OPEN flips
        to HALF_OPEN (one probe allowed) once the cooldown has passed."""
        if self.state == OPEN:
            if (self._opened_at is not None
                    and self._clock() - self._opened_at >= self.cooldown_s):
                self.state = HALF_OPEN
                return True
            return False
        return True

    def record_failure(self) -> bool:
        """Count one attributed failure; returns True when the breaker
        state changed (tripped or re-opened)."""
        self.consecutive_failures += 1
        if self.state == HALF_OPEN or (
                self.state == CLOSED
                and self.consecutive_failures >= self.failure_threshold):
            self.state = OPEN
            self.trips += 1
            self._opened_at = self._clock()
            return True
        return False

    def record_success(self) -> bool:
        """Count one success; returns True when the state changed (a
        HALF_OPEN probe succeeded and the breaker closed)."""
        self.consecutive_failures = 0
        if self.state == HALF_OPEN:
            self.state = CLOSED
            self._opened_at = None
            return True
        return False

    def snapshot(self) -> dict:
        return {"state": self.state,
                "consecutive_failures": self.consecutive_failures,
                "trips": self.trips}

    def __repr__(self):
        return (f"<CircuitBreaker {self.name!r} {self.state} "
                f"fails={self.consecutive_failures}>")


class BreakerBoard:
    """All circuit breakers of one registry, plus the routing logic.

    ``record_failure``/``record_success`` are fed by the serving layer
    with backend *names* (lazily creating breakers on first failure).
    ``route(name)`` is consulted by the planner: it follows the
    registry's fallback chain past backends whose breaker is not
    ``allow()``-ing traffic. Every state change bumps ``generation`` —
    mixed into plan-cache keys through ``repr(board)`` — and fires the
    registered hooks (``ExecutionContext`` wires
    ``plan_cache.invalidate`` here).
    """

    def __init__(self, registry: "BackendRegistry", *,
                 failure_threshold: int = 5, cooldown_s: float = 1.0,
                 clock=time.monotonic):
        self._registry = registry
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self.generation = 0
        self._breakers: dict[str, CircuitBreaker] = {}
        self._hooks: list = []
        self._lock = ordered_rlock("breakers")

    def configure(self, *, failure_threshold: int | None = None,
                  cooldown_s: float | None = None) -> "BreakerBoard":
        """Adjust defaults for breakers created after this call."""
        with self._lock:
            if failure_threshold is not None:
                self.failure_threshold = failure_threshold
            if cooldown_s is not None:
                self.cooldown_s = cooldown_s
        return self

    def add_hook(self, hook) -> None:
        """``hook()`` fires (outside the lock) on every generation bump."""
        self._hooks.append(hook)

    def breaker(self, name: str) -> CircuitBreaker:
        with self._lock:
            br = self._breakers.get(name)
            if br is None:
                br = CircuitBreaker(
                    name, failure_threshold=self.failure_threshold,
                    cooldown_s=self.cooldown_s, clock=self._clock)
                self._breakers[name] = br
            return br

    def _bump(self) -> None:
        for hook in list(self._hooks):
            try:
                hook()
            except Exception:
                pass  # observers must not take down serving

    def record_failure(self, name: str) -> bool:
        """Attribute one failure to ``name``; True if its breaker state
        changed (hooks fire and the generation bumps)."""
        with self._lock:
            changed = self.breaker(name).record_failure()
            if changed:
                self.generation += 1
        if changed:
            self._bump()
        return changed

    def record_success(self, name: str) -> bool:
        with self._lock:
            br = self._breakers.get(name)
            changed = br.record_success() if br is not None else False
            if changed:
                self.generation += 1
        if changed:
            self._bump()
        return changed

    def allow(self, name: str) -> bool:
        """True unless ``name`` has a tripped (still-cooling) breaker.
        Doesn't create breakers: unknown names are allowed."""
        with self._lock:
            br = self._breakers.get(name)
            return True if br is None else br.allow()

    def route(self, name: str) -> str:
        """The backend new plans should target: ``name`` itself when its
        breaker admits traffic, else the first allowed backend along the
        registry's fallback chain (cycle-safe; the chain's last resort is
        returned even when itself blocked — something must serve)."""
        with self._lock:
            seen = set()
            current = name
            while current not in seen:
                seen.add(current)
                br = self._breakers.get(current)
                if br is None or br.allow():
                    return current
                try:
                    impl = self._registry.get(current)
                except ValueError:
                    return current
                if impl.fallback is None:
                    return current
                current = impl.fallback
            return current

    def states(self) -> dict:
        """Snapshot for ``health()``: name -> breaker state dict."""
        with self._lock:
            return {n: b.snapshot() for n, b in self._breakers.items()}

    def __repr__(self):
        # repr participates in plan-cache keys: the generation is the
        # only state that must rotate them
        return f"<BreakerBoard gen={self.generation}>"


class Backend:
    """One execution path for plan-driven sparse convolution.

    Subclasses set ``name`` (the registry key ``Dispatch.backend`` refers
    to), optionally ``plan_requirements`` (plan attributes that must be
    non-None for ``run`` to serve the plan) and ``fallback`` (the registry
    name resolution degrades to when ``supports`` says no).

    ``run`` executes one conv site. Scene-level backends (which own the
    whole U-Net forward, e.g. mesh-sharded execution) additionally
    implement ``run_unet``; ``engine.apply_unet`` routes plans that carry a
    ``scene_backend`` attribute there instead of walking levels itself.
    """

    name: str = ""
    #: plan attributes that must be present (non-None) for run() to work
    plan_requirements: tuple[str, ...] = ()
    #: registry name to resolve to instead when supports() is False
    fallback: str | None = None
    #: True for backends that execute whole scenes via run_unet
    scene_level: bool = False

    def supports(self, plan) -> bool:
        return all(getattr(plan, req, None) is not None
                   for req in self.plan_requirements)

    def run(self, x, params, plan: ConvPlan, *, ctx, **kw):
        raise NotImplementedError(
            f"backend {self.name!r} does not implement per-conv run()")

    def run_unet(self, params, feats, plan, *, ctx, **kw):
        raise NotImplementedError(
            f"backend {self.name!r} does not implement scene-level run_unet()")

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"


class BackendRegistry:
    """Name -> Backend mapping with parent chaining and fallback resolution.

    Lookup walks ``self`` then ``parent``; registration always writes to
    ``self``, so a ``view()`` child can shadow or extend the process
    default without mutating it (an ``ExecutionContext`` holds such a
    view).
    """

    def __init__(self, parent: "BackendRegistry | None" = None):
        self._impls: dict[str, Backend] = {}
        self._parent = parent
        #: per-registry circuit breakers (views get their own board, so
        #: a context's breaker trips stay scoped to that context)
        self.breakers = BreakerBoard(self)

    def register(self, name: str, impl: Backend, *,
                 overwrite: bool = False) -> Backend:
        if not name or name == AUTO:
            raise ValueError(f"invalid backend name {name!r}")
        if not overwrite and name in self:
            raise ValueError(
                f"backend {name!r} already registered; pass overwrite=True "
                "to replace it")
        if not callable(getattr(impl, "run", None)):
            raise TypeError(f"backend impl {impl!r} has no run() hook")
        self._impls[name] = impl
        return impl

    def unregister(self, name: str) -> None:
        """Remove a registration made on *this* registry (not the parent)."""
        self._impls.pop(name, None)

    def get(self, name: str) -> Backend:
        reg: BackendRegistry | None = self
        while reg is not None:
            impl = reg._impls.get(name)
            if impl is not None:
                return impl
            reg = reg._parent
        raise ValueError(
            f"backend {name!r} not one of {(AUTO,) + self.names()}")

    def names(self) -> tuple[str, ...]:
        seen: dict[str, None] = {}
        reg: BackendRegistry | None = self
        while reg is not None:
            for n in reg._impls:
                seen.setdefault(n)
            reg = reg._parent
        return tuple(sorted(seen))

    def __contains__(self, name: str) -> bool:
        reg: BackendRegistry | None = self
        while reg is not None:
            if name in reg._impls:
                return True
            reg = reg._parent
        return False

    def view(self) -> "BackendRegistry":
        """A scoped child registry: reads chain to this one, writes stay
        local. This is what a fresh ``ExecutionContext`` holds."""
        return BackendRegistry(parent=self)

    def resolve(self, plan, backend: str = AUTO) -> str:
        """The backend name a call will actually run.

        ``"auto"`` reads the name the planner recorded in
        ``plan.dispatch``; a backend that can't serve the plan degrades
        along its declared ``fallback`` chain (e.g. SSpNNA without tile
        metadata -> reference).
        """
        if backend == AUTO:
            backend = plan.dispatch.backend
        inj = _fault_injector()
        if inj is not None:
            inj.maybe_fail("backend_resolve", key=backend)
        impl = self.get(backend)  # raises ValueError on unknown names
        seen = {backend}
        while not impl.supports(plan):
            if impl.fallback is None or impl.fallback in seen:
                raise ValueError(
                    f"backend {backend!r} cannot serve this plan and "
                    "declares no (acyclic) fallback")
            backend = impl.fallback
            seen.add(backend)
            impl = self.get(backend)
        return backend


class ReferenceBackend(Backend):
    """Gather + one fused einsum over all weight planes — the coarse M-V
    dispatch and the numerical oracle (``core.sparse_conv``)."""

    name = REFERENCE

    def run(self, x, params, plan: ConvPlan, *, ctx, **kw):
        del ctx, kw  # kernel knobs don't apply to the einsum path
        return reference_conv_cirf(x, plan.coir, params)


class SSpNNABackend(Backend):
    """The fused gather-GEMM-scatter Pallas path driven by the plan's
    ``TileArrays`` (see ``kernels.sspnna``); plans without tile metadata
    (resolution-changing convs, tile-budget overflows) fall back to
    reference."""

    name = SSPNNA
    plan_requirements = ("tiles",)
    fallback = REFERENCE

    def run(self, x, params, plan: ConvPlan, *, ctx,
            use_kernel: bool = True, interpret: bool | None = None,
            block_n: int | None = None, **kw):
        del ctx, kw
        raw = run_sspnna_conv(
            x, params.weight, plan.tiles.out_rows, plan.tiles.in_rows,
            plan.tiles.local_idx, n_out=plan.coir.mask.shape[0],
            pair_counts=plan.tiles.pair_counts,
            use_kernel=use_kernel, interpret=interpret,
            block_n=block_n or (plan.dispatch.block_n or None))
        out = raw.astype(x.dtype) + params.bias.astype(x.dtype)
        return out * plan.coir.mask[:, None].astype(out.dtype)


_DEFAULT_REGISTRY: BackendRegistry | None = None


def default_registry() -> BackendRegistry:
    """The process-wide registry ``reference``/``sspnna`` (and ``sharded``,
    registered by ``engine.shard`` on import) live on."""
    global _DEFAULT_REGISTRY
    if _DEFAULT_REGISTRY is None:
        _DEFAULT_REGISTRY = BackendRegistry()
        _DEFAULT_REGISTRY.register(REFERENCE, ReferenceBackend())
        _DEFAULT_REGISTRY.register(SSPNNA, SSpNNABackend())
    return _DEFAULT_REGISTRY


def register_backend(name: str, impl: Backend, *,
                     overwrite: bool = False) -> Backend:
    """Register an execution backend process-wide.

    After this, any plan whose ``Dispatch.backend`` names ``name`` (or any
    explicit ``backend=name`` call) routes to ``impl`` — no engine code
    changes needed. Scoped alternative: register on
    ``ExecutionContext.registry`` to confine the backend to one context.
    """
    return default_registry().register(name, impl, overwrite=overwrite)
