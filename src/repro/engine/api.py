"""One plan-driven entry point for sparse convolution.

``sparse_conv(x, params, plan, backend=..., ctx=...)`` is the execution API
the rest of the repo programs against; the COIR metadata, SOAR ordering,
SPADE dataflow decision and SSpNNA tile tables all arrive pre-packaged in
the ``ConvPlan`` (see ``repro.engine.plan``), so call sites never re-derive
them — the paper's co-design, surfaced as one function.

Dispatch goes through the backend registry (``repro.engine.backends``):
``Dispatch``/SPADE emit a backend *name*, the context's registry resolves
it to an implementation (following declared fallbacks — e.g. an SSpNNA
decision whose plan lost its tile metadata degrades to ``reference``), and
new paths plug in via ``engine.register_backend`` without touching this
module. The built-ins:

* ``"reference"`` — gather + one fused einsum over all weight planes
  (``core.sparse_conv.reference_conv_cirf``), the coarse M-V dispatch and
  the numerical oracle.
* ``"sspnna"`` — the fused gather-GEMM-scatter Pallas path
  (``kernels.sspnna``) driven by the plan's ``TileArrays``.
* ``"sharded"`` — mesh-sharded scene execution with halo exchange
  (``engine.shard``); scene-level, reached via ``apply_unet`` on a
  ``ShardedScenePlan``.
* ``"auto"`` — follow the decision recorded in ``plan.dispatch``.

``ctx=`` names the :class:`~repro.engine.context.ExecutionContext` (mesh,
registry view, plan cache) the call runs under; omitted, the ambient
context applies, so pre-context call sites keep working.

``apply_unet`` runs the whole SCN U-Net off a ``ScenePlan``; it is pure in
(params, feats, plan) and vmap/jit-friendly — the serving engine batches it
with a leading scene axis. Plans that carry a ``scene_backend`` attribute
(``ShardedScenePlan``) are handed whole to that backend's ``run_unet``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.coir import COIR
from repro.core.sparse_conv import SparseConvParams, masked_batchnorm_relu
from repro.engine.backends import AUTO, default_registry
from repro.engine.context import ExecutionContext, current_context
from repro.engine.plan import REFERENCE_DISPATCH, ConvPlan, ScenePlan


def __getattr__(name: str):
    # legacy alias for the closed enum this module used to hard-code;
    # computed on access so late registrations show up
    if name == "BACKENDS":
        return (AUTO,) + default_registry().names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def available_backends(ctx: ExecutionContext | None = None) -> tuple[str, ...]:
    """Backend names resolvable under ``ctx`` (ambient context if None)."""
    ctx = ctx if ctx is not None else current_context()
    return (AUTO,) + ctx.registry.names()


def reference_plan(coir: COIR) -> ConvPlan:
    """Wrap bare COIR metadata as an einsum-only plan."""
    return ConvPlan(coir, None, REFERENCE_DISPATCH)


def resolve_backend(plan: ConvPlan, backend: str = AUTO,
                    ctx: ExecutionContext | None = None) -> str:
    """The backend a call will actually run, after plan-driven dispatch
    and fallback resolution through the context's registry."""
    ctx = ctx if ctx is not None else current_context()
    return ctx.registry.resolve(plan, backend)


def sparse_conv(
    x: jnp.ndarray,
    params: SparseConvParams,
    plan: ConvPlan,
    *,
    backend: str = AUTO,
    ctx: ExecutionContext | None = None,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_n: int | None = None,
) -> jnp.ndarray:
    """Run one sparse conv according to its plan -> (V_out, N) features."""
    ctx = ctx if ctx is not None else current_context()
    name = ctx.registry.resolve(plan, backend)
    return ctx.registry.get(name).run(
        x, params, plan, ctx=ctx, use_kernel=use_kernel, interpret=interpret,
        block_n=block_n)


def conv_block(x, mask, plan: ConvPlan, p, **conv_kw):
    """Conv + masked BN + ReLU, the SCN building block."""
    y = sparse_conv(x, p["conv"], plan, **conv_kw)
    return masked_batchnorm_relu(y, mask, p["bn_scale"], p["bn_offset"])


def apply_unet(
    params: dict,
    feats: jnp.ndarray,
    plan: "ScenePlan",
    *,
    backend: str = AUTO,
    ctx: ExecutionContext | None = None,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """U-Net forward off a ScenePlan -> (V, n_classes) level-0 logits.

    Plans carrying a ``scene_backend`` attribute (e.g. ``ShardedScenePlan``)
    are executed whole by that backend's ``run_unet`` hook — the level walk
    below only serves per-conv plans.
    """
    ctx = ctx if ctx is not None else current_context()
    scene_backend = getattr(plan, "scene_backend", None)
    if scene_backend is not None:
        if backend not in (AUTO, scene_backend):
            raise ValueError(
                f"plan is bound to scene-level backend {scene_backend!r}; "
                f"backend={backend!r} cannot serve it")
        impl = ctx.registry.get(scene_backend)
        return impl.run_unet(params, feats, plan, ctx=ctx,
                             use_kernel=use_kernel, interpret=interpret)

    kw = dict(backend=backend, ctx=ctx, use_kernel=use_kernel,
              interpret=interpret)
    x = sparse_conv(feats, params["stem"], plan.levels[0].sub, **kw)
    skips = []
    for li, lvl in enumerate(plan.levels):
        p = params["levels"][li]
        for blk in p["enc"]:
            x = conv_block(x, lvl.mask, lvl.sub, blk, **kw)
        if lvl.down is not None:
            skips.append(x)
            x = sparse_conv(x, p["down"], lvl.down, **kw)
    for li in range(len(plan.levels) - 2, -1, -1):
        lvl, p = plan.levels[li], params["levels"][li]
        up = sparse_conv(x, p["up"], lvl.up, **kw)
        x = jnp.concatenate([skips[li], up], axis=-1)
        for blk in p["dec"]:
            x = conv_block(x, lvl.mask, lvl.sub, blk, **kw)
    return x @ params["head"]["w"] + params["head"]["b"]
