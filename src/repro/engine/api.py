"""One plan-driven entry point for sparse convolution.

``sparse_conv(x, params, plan, backend=...)`` is the execution API the rest
of the repo programs against; the COIR metadata, SOAR ordering, SPADE
dataflow decision and SSpNNA tile tables all arrive pre-packaged in the
``ConvPlan`` (see ``repro.engine.plan``), so call sites never re-derive
them — the paper's co-design, surfaced as one function.

Backend dispatch rules:

* ``"reference"`` — gather + one fused einsum over all weight planes
  (``core.sparse_conv.reference_conv_cirf``), the coarse M-V dispatch and
  the numerical oracle.
* ``"sspnna"`` — the fused gather-GEMM-scatter Pallas path
  (``kernels.sspnna``) driven by the plan's ``TileArrays``: global features
  go straight into the kernel, whose scalar-prefetched DMA tables stream
  tile working sets on-chip and write output rows in place — no gathered
  HBM intermediate, no post-kernel scatter. ``Dispatch.block_n`` (pinned by
  ``build_plan_spec(tune_block_n=...)``) selects the kernel's N-block.
  Plans without tile metadata (resolution-changing convs, tile-budget
  overflows) fall back to reference.
* ``"auto"`` — follow the SPADE decision recorded in ``plan.dispatch``.

``apply_unet`` runs the whole SCN U-Net off a ``ScenePlan``; it is pure in
(params, feats, plan) and vmap/jit-friendly — the serving engine batches it
with a leading scene axis.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.coir import COIR
from repro.core.sparse_conv import (
    SparseConvParams,
    masked_batchnorm_relu,
    reference_conv_cirf,
)
from repro.engine.plan import (
    REFERENCE,
    REFERENCE_DISPATCH,
    SSPNNA,
    ConvPlan,
    ScenePlan,
)
from repro.kernels.sspnna.ops import run_sspnna_conv

BACKENDS = ("auto", REFERENCE, SSPNNA)


def reference_plan(coir: COIR) -> ConvPlan:
    """Wrap bare COIR metadata as an einsum-only plan."""
    return ConvPlan(coir, None, REFERENCE_DISPATCH)


def resolve_backend(plan: ConvPlan, backend: str = "auto") -> str:
    """The backend a call will actually run, after plan-driven dispatch."""
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r} not one of {BACKENDS}")
    if backend == "auto":
        backend = plan.dispatch.backend
    if backend == SSPNNA and plan.tiles is None:
        return REFERENCE
    return backend


def sparse_conv(
    x: jnp.ndarray,
    params: SparseConvParams,
    plan: ConvPlan,
    *,
    backend: str = "auto",
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_n: int | None = None,
) -> jnp.ndarray:
    """Run one sparse conv according to its plan -> (V_out, N) features."""
    if resolve_backend(plan, backend) == REFERENCE:
        return reference_conv_cirf(x, plan.coir, params)
    raw = run_sspnna_conv(
        x, params.weight, plan.tiles.out_rows, plan.tiles.in_rows,
        plan.tiles.local_idx, n_out=plan.coir.mask.shape[0],
        pair_counts=plan.tiles.pair_counts,
        use_kernel=use_kernel, interpret=interpret,
        block_n=block_n or (plan.dispatch.block_n or None))
    out = raw.astype(x.dtype) + params.bias.astype(x.dtype)
    return out * plan.coir.mask[:, None].astype(out.dtype)


def conv_block(x, mask, plan: ConvPlan, p, **conv_kw):
    """Conv + masked BN + ReLU, the SCN building block."""
    y = sparse_conv(x, p["conv"], plan, **conv_kw)
    return masked_batchnorm_relu(y, mask, p["bn_scale"], p["bn_offset"])


def apply_unet(
    params: dict,
    feats: jnp.ndarray,
    plan: ScenePlan,
    *,
    backend: str = "auto",
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """U-Net forward off a ScenePlan -> (V, n_classes) level-0 logits."""
    kw = dict(backend=backend, use_kernel=use_kernel, interpret=interpret)
    x = sparse_conv(feats, params["stem"], plan.levels[0].sub, **kw)
    skips = []
    for li, lvl in enumerate(plan.levels):
        p = params["levels"][li]
        for blk in p["enc"]:
            x = conv_block(x, lvl.mask, lvl.sub, blk, **kw)
        if lvl.down is not None:
            skips.append(x)
            x = sparse_conv(x, p["down"], lvl.down, **kw)
    for li in range(len(plan.levels) - 2, -1, -1):
        lvl, p = plan.levels[li], params["levels"][li]
        up = sparse_conv(x, p["up"], lvl.up, **kw)
        x = jnp.concatenate([skips[li], up], axis=-1)
        for blk in p["dec"]:
            x = conv_block(x, lvl.mask, lvl.sub, blk, **kw)
    return x @ params["head"]["w"] + params["head"]["b"]
