"""ExecutionContext: the object that owns mesh + backends + caches.

Pre-context, the engine's moving parts were loose globals — the backend
choice a string enum, the plan cache and scheduler knobs constructor
arguments scattered over call sites, and *no* home at all for a device
mesh. ``ExecutionContext`` bundles them:

* ``mesh`` / ``shard_axis`` — where sharded scene plans execute. ``None``
  (the default) means single-device: sharded plans still run, on the
  serial single-device reference path (``engine.shard``).
* ``registry`` — a scoped :class:`~repro.engine.backends.BackendRegistry`
  view chained to the process default, so per-context backend overlays
  never leak.
* ``plan_cache`` — the content-keyed :class:`~repro.engine.plan.PlanCache`
  serving layers share. Cache keys mix in :meth:`topology_key`, so a plan
  built for one mesh can never be served to another.
* scheduler wiring defaults (``sync`` / ``depth`` / ``planner_threads``)
  that ``serving`` engines pick up when built from a context.

Call sites pass ``ctx=`` to ``engine.sparse_conv`` / ``engine.apply_unet``
/ ``SceneEngine``; omitting it resolves the ambient context — either the
innermost ``use_context(...)`` block or the module-level default — so
pre-context call sites (and the deprecation shims) keep working unchanged.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field

from repro.engine.backends import AUTO, Backend, BackendRegistry, default_registry
from repro.engine.plan import PlanCache


@dataclass
class ExecutionContext:
    """Mesh + backend registry + plan cache + scheduler defaults."""

    #: device mesh sharded scene plans execute on (None = single device)
    mesh: object | None = None
    #: mesh axis the scene capacity axis is sharded over
    shard_axis: str = "shard"
    #: scoped backend registry (chains to the process default)
    registry: BackendRegistry = field(
        default_factory=lambda: default_registry().view())
    #: content-keyed scene-plan cache (topology mixed into every key)
    plan_cache: PlanCache = field(default_factory=PlanCache)
    #: serving defaults picked up by engines built from this context
    sync: bool = True
    depth: int = 2
    planner_threads: int = 2
    #: default ``serving.AdmissionPolicy`` for engines built from this
    #: context (typed loosely so the engine layer doesn't import serving);
    #: None = FIFO admission
    admission: object | None = None
    #: measured-dispatch cost table (``engine.autotune.CostTable``; typed
    #: loosely so the dataclass stays import-light). When set, plan builds
    #: under this context consult measured winners before the analytical
    #: model, and a winner flip invalidates ``plan_cache`` (wired below).
    autotune: object | None = None
    #: idle-gap re-profiling budget per scheduler tick, in ms. 0 (the
    #: default — notably in tests) disables online re-profiling entirely;
    #: serving engines only install the ``WaveScheduler`` idle hook when
    #: this is positive *and* ``autotune`` is set.
    autotune_reprofile_ms: float = 0.0

    def __post_init__(self):
        # plans cached under a measured decision must not outlive it: when
        # the table's winner flips, every cached plan is dropped (keys also
        # rotate — the table's generation is repr'd into them)
        hook = getattr(self.autotune, "add_flip_hook", None)
        if hook is not None:
            hook(self.plan_cache.invalidate)
        # same invariant for circuit breakers: a breaker trip reroutes new
        # plan builds (engine.plan consults registry.breakers), so plans
        # cached under the old routing must rotate out — the board's
        # generation is repr'd into keys AND the cache is invalidated
        # eagerly on every breaker state change
        board = getattr(self.registry, "breakers", None)
        if board is not None:
            board.add_hook(self.plan_cache.invalidate)

    @property
    def n_shards(self) -> int:
        """Size of the shard axis (1 when no mesh / axis is absent)."""
        if self.mesh is None:
            return 1
        if self.shard_axis not in getattr(self.mesh, "axis_names", ()):
            return 1
        return int(self.mesh.shape[self.shard_axis])

    def topology_key(self) -> str:
        """Hashable description of the execution topology, mixed into plan
        cache keys: a plan built for one mesh/shard layout must never be
        served to another."""
        if self.mesh is None:
            return "host"
        axes = ",".join(
            f"{a}={self.mesh.shape[a]}" for a in self.mesh.axis_names)
        return f"mesh({axes})|shard_axis={self.shard_axis}"

    def resolve_backend(self, plan, backend: str = AUTO) -> str:
        """The backend name a call under this context will actually run."""
        return self.registry.resolve(plan, backend)

    def backend(self, name: str) -> Backend:
        return self.registry.get(name)


_DEFAULT: ExecutionContext | None = None
#: innermost use_context() override, if any
_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_engine_active_ctx", default=None)


def default_context() -> ExecutionContext:
    """The module-level default context legacy call sites resolve to."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = ExecutionContext()
    return _DEFAULT


def set_default_context(ctx: ExecutionContext) -> ExecutionContext | None:
    """Replace the module-level default; returns the previous one."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, ctx
    return prev


def current_context() -> ExecutionContext:
    """The ambient context: innermost ``use_context`` block, else the
    module default."""
    active = _ACTIVE.get()
    return active if active is not None else default_context()


@contextlib.contextmanager
def use_context(ctx: ExecutionContext):
    """Make ``ctx`` the ambient context for the dynamic extent of the
    block (thread/task-local, like ``dist.hints.use_mesh``)."""
    token = _ACTIVE.set(ctx)
    try:
        yield ctx
    finally:
        _ACTIVE.reset(token)
