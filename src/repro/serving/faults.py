"""Deterministic, seeded fault injection for the serving runtime.

The serving stack (scheduler waves, plan builds, backend dispatch,
stream frames) has a handful of *seams* where production failures show
up: a plan build raises, a device kernel errors, a wave stalls, a LiDAR
frame arrives corrupted, a worker thread dies. ``FaultPlan`` describes
*what* to inject (per-seam rates, optional backend/rid targeting) and
``FaultInjector`` decides *when* — with hash-based rolls keyed on
``(seed, spec, seam, key, attempt)`` so outcomes are reproducible and
independent of thread interleaving: the Nth attempt at a given key
always rolls the same number, no matter which worker gets there first.

Usage::

    plan = FaultPlan(seed=7, specs=(FaultSpec("dispatch", rate=0.05),))
    inj = FaultInjector(plan)
    eng = SceneEngine(cfg, faults=inj)        # explicit wiring, or:
    with inject_faults(inj):                  # ambient (reaches plan.py)
        ...

The ambient injector is a plain module global (NOT a contextvar): plan
builds run on scheduler worker threads, and contextvars don't cross
thread boundaries.

Everything here is a no-op at zero cost when no injector is installed —
the hardened runtime paths check ``faults is None`` / ``active() is
None`` first.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib

import numpy as np

from repro.analysis.runtime import ordered_lock

#: Named injection points. Handlers exist for each (see README
#: "Fault tolerance"): scheduler retry budget, PlanCache error
#: propagation, circuit breakers, watchdogs, stream gap recovery.
SEAMS = (
    "plan",             # scheduler plan stage (worker thread)
    "plan_build",       # PlanCache.get_or_build builder call
    "dispatch",         # scheduler dispatch stage / device error
    "backend_resolve",  # BackendRegistry.resolve
    "slow_wave",        # dispatch stall (delay_ms), exercises watchdogs
    "corrupt_frame",    # stream frame coords garbage
    "worker_death",     # BaseException from the plan stage
)


class FaultError(RuntimeError):
    """Base class for injected faults (carries seam + optional rid)."""

    def __init__(self, msg, *, seam=None, rid=None):
        super().__init__(msg)
        self.seam = seam
        self.rid = rid


class PlanFaultError(FaultError):
    """Injected plan-build failure."""


class DeviceFaultError(FaultError):
    """Injected dispatch/device failure; ``backend`` names the culprit."""

    def __init__(self, msg, *, seam=None, rid=None, backend=None):
        super().__init__(msg, seam=seam, rid=rid)
        self.backend = backend


class WorkerDeath(BaseException):
    """Simulates a worker thread dying: deliberately NOT an Exception,
    so naive ``except Exception`` handlers don't contain it — only the
    scheduler's explicit containment path does."""

    def __init__(self, msg, *, seam=None, rid=None):
        super().__init__(msg)
        self.seam = seam
        self.rid = rid


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault source: a seam, a probability, and optional targeting.

    ``rate`` is the per-opportunity probability in [0, 1]. ``backend``
    attributes dispatch faults to a named backend (for breaker tests).
    ``delay_ms`` is the stall for ``slow_wave`` specs. ``max_fires``
    bounds total injections from this spec; ``after`` skips the first N
    opportunities; ``rids`` restricts to specific request ids.
    """

    seam: str
    rate: float = 0.0
    backend: str | None = None
    delay_ms: float = 0.0
    max_fires: int | None = None
    after: int = 0
    rids: tuple | None = None

    def __post_init__(self):
        if self.seam not in SEAMS:
            raise ValueError(f"unknown seam {self.seam!r}; known: {SEAMS}")
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seed plus a tuple of :class:`FaultSpec`. Hashable, printable,
    and fully determines injector behaviour (given the same sequence of
    opportunities per key)."""

    seed: int = 0
    specs: tuple = ()

    @staticmethod
    def random(seed: int, *, max_specs: int = 3, max_rate: float = 0.3):
        """A small random plan for chaos/property tests: ``seed`` picks
        1..max_specs specs over the error-injecting seams with rates in
        (0, max_rate]."""
        rng = np.random.default_rng(seed)
        pool = ["plan", "plan_build", "dispatch", "slow_wave",
                "worker_death"]
        n = int(rng.integers(1, max_specs + 1))
        specs = []
        for _ in range(n):
            seam = pool[int(rng.integers(0, len(pool)))]
            rate = float(rng.uniform(0.02, max_rate))
            delay = float(rng.uniform(1.0, 5.0)) if seam == "slow_wave" else 0.0
            specs.append(FaultSpec(seam, rate=rate, delay_ms=delay))
        return FaultPlan(seed=seed, specs=tuple(specs))


class FaultInjector:
    """Deterministic executor for a :class:`FaultPlan`.

    Thread-safe. Tracks per-seam opportunity and fire counts in
    ``self.fires`` / ``self.opportunities`` so tests can assert that a
    seam was actually exercised.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = ordered_lock("faults.injector")
        # per-spec-index counters
        self._opps = [0] * len(plan.specs)
        self._fired = [0] * len(plan.specs)
        # per (spec_idx, key) attempt counters: the Nth attempt at a key
        # rolls deterministically regardless of global ordering.
        self._attempts: dict = {}
        self.fires: dict[str, int] = {}
        self.opportunities: dict[str, int] = {}

    # -- deterministic rolls -------------------------------------------------

    def _roll(self, spec_idx: int, seam: str, key, attempt: int) -> float:
        h = hashlib.sha256(
            repr((self.plan.seed, spec_idx, seam, key, attempt)).encode()
        ).digest()
        return int.from_bytes(h[:8], "big") / float(2 ** 64)

    def _should_fire(self, spec_idx: int, spec: FaultSpec, key, rid) -> bool:
        with self._lock:
            self._opps[spec_idx] += 1
            self.opportunities[spec.seam] = (
                self.opportunities.get(spec.seam, 0) + 1)
            if spec.rids is not None and rid not in spec.rids:
                return False
            if self._opps[spec_idx] <= spec.after:
                return False
            if (spec.max_fires is not None
                    and self._fired[spec_idx] >= spec.max_fires):
                return False
            akey = (spec_idx, key)
            attempt = self._attempts.get(akey, 0)
            self._attempts[akey] = attempt + 1
            if self._roll(spec_idx, spec.seam, key, attempt) >= spec.rate:
                return False
            self._fired[spec_idx] += 1
            self.fires[spec.seam] = self.fires.get(spec.seam, 0) + 1
            return True

    # -- seam entry points ---------------------------------------------------

    def maybe_fail(self, seam: str, *, rid=None, key=None):
        """Raise an injected error at ``seam`` if a spec fires.

        ``key`` scopes the deterministic roll (e.g. ``("wave", n)`` or a
        plan-cache key); defaults to ``rid``.
        """
        if key is None:
            key = rid
        for i, spec in enumerate(self.plan.specs):
            if spec.seam != seam:
                continue
            if not self._should_fire(i, spec, key, rid):
                continue
            if seam == "worker_death":
                raise WorkerDeath(
                    f"injected worker death (rid={rid})", seam=seam, rid=rid)
            if seam in ("dispatch", "backend_resolve"):
                raise DeviceFaultError(
                    f"injected device fault (rid={rid}, "
                    f"backend={spec.backend})",
                    seam=seam, rid=rid, backend=spec.backend)
            raise PlanFaultError(
                f"injected {seam} fault (rid={rid})", seam=seam, rid=rid)

    def stall_ms(self, *, key=None) -> float:
        """Total injected stall (ms) for ``slow_wave`` specs at this
        opportunity; the caller sleeps."""
        total = 0.0
        for i, spec in enumerate(self.plan.specs):
            if spec.seam != "slow_wave":
                continue
            if self._should_fire(i, spec, key, None):
                total += spec.delay_ms
        return total

    def corrupt_coords(self, coords, *, rid=None):
        """Return a corrupted copy of ``coords`` if a ``corrupt_frame``
        spec fires, else ``coords`` unchanged. Corruption scribbles
        seeded garbage (including negatives) over ~1/8 of the rows."""
        for i, spec in enumerate(self.plan.specs):
            if spec.seam != "corrupt_frame":
                continue
            if not self._should_fire(i, spec, rid, rid):
                continue
            c = np.array(coords, copy=True)
            if c.shape[0] == 0:
                return c
            rng = np.random.default_rng(
                (self.plan.seed * 1000003 + i) & 0xFFFFFFFF)
            n = max(1, c.shape[0] // 8)
            rows = rng.choice(c.shape[0], size=n, replace=False)
            garbage = rng.integers(-64, 4096, size=(n,) + c.shape[1:])
            c[rows] = garbage.astype(c.dtype)
            return c
        return coords

    def stats(self) -> dict:
        with self._lock:
            return {
                "seed": self.plan.seed,
                "fires": dict(self.fires),
                "opportunities": dict(self.opportunities),
            }


# -- ambient injector --------------------------------------------------------
#
# A module global, not a contextvar: plan builds run on scheduler worker
# threads and must see the injector installed by the test's main thread.

_ACTIVE: FaultInjector | None = None
_ACTIVE_LOCK = ordered_lock("faults.install")


def active() -> FaultInjector | None:
    """The ambient injector, or None (the common, zero-cost case)."""
    return _ACTIVE


def install(inj: FaultInjector | None) -> FaultInjector | None:
    """Set the ambient injector; returns the previous one."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        prev = _ACTIVE
        _ACTIVE = inj
        return prev


@contextlib.contextmanager
def inject_faults(inj: FaultInjector):
    """Install ``inj`` as the ambient injector for the block."""
    prev = install(inj)
    try:
        yield inj
    finally:
        install(prev)
