"""Serving engine: prefill + batched decode with continuous batching.

``make_prefill``/``make_serve_step`` are the jit-able pure steps the
dry-run lowers (decode_* / long_* cells lower ``serve_step``). ``Engine``
is a small host-side driver used by the examples: it packs requests into a
fixed batch, prefills, decodes until EOS/max-tokens, and refills slots —
continuous batching at fixed shapes (slot reuse, no recompilation).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import (
    decode_step,
    forward,
)


def make_prefill(cfg: ModelConfig, cache_pad: int = 0):
    def prefill(params, tokens, frontend_embeds=None, enc_frames=None):
        kw = {}
        if cfg.frontend == "vision" and frontend_embeds is not None:
            kw["frontend_embeds"] = frontend_embeds
        if cfg.is_encdec:
            kw["enc_frames"] = enc_frames
        logits, cache, _ = forward(params, cfg, tokens, mode="prefill",
                                   cache_pad=cache_pad, **kw)
        return logits[:, -1], cache

    return prefill


def make_serve_step(cfg: ModelConfig, moe_groups: int | None = None):
    def serve_step(params, token, cache):
        logits, cache = decode_step(params, cfg, token, cache,
                                    moe_groups=moe_groups)
        next_tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), logits[:, -1], cache

    return serve_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class Engine:
    """Host-side continuous-batching driver (fixed shapes)."""

    def __init__(self, cfg: ModelConfig, params, batch: int, prompt_len: int,
                 max_new: int, eos: int | None = None):
        self.cfg, self.params = cfg, params
        self.batch, self.prompt_len, self.max_new = batch, prompt_len, max_new
        self.eos = eos
        self.prefill = jax.jit(make_prefill(cfg, cache_pad=max_new))
        self.step = jax.jit(make_serve_step(cfg))
        self.queue: list[Request] = []
        self.completed: list[Request] = []

    def submit(self, reqs: list[Request]):
        self.queue.extend(reqs)

    def run(self):
        while self.queue:
            active = [self.queue.pop(0) for _ in
                      range(min(self.batch, len(self.queue)))]
            toks = np.zeros((self.batch, self.prompt_len), np.int32)
            for i, r in enumerate(active):
                toks[i, -len(r.prompt):] = r.prompt[: self.prompt_len]
            last_logits, cache = self.prefill(self.params, jnp.asarray(toks))
            tok = jnp.argmax(last_logits[:, : self.cfg.vocab_size], -1)
            tok = tok.astype(jnp.int32)[:, None]
            for _ in range(self.max_new):
                for i, r in enumerate(active):
                    if not r.done:
                        t = int(tok[i, 0])
                        r.out.append(t)
                        if self.eos is not None and t == self.eos:
                            r.done = True
                nxt, _, cache = self.step(self.params, tok, cache)
                tok = nxt[:, None]
                if all(r.done for r in active):
                    break
            for r in active:
                r.done = True
                self.completed.append(r)
        return self.completed
