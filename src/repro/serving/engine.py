"""Serving engine: prefill + batched decode with continuous batching.

``make_prefill``/``make_serve_step`` are the jit-able pure steps the
dry-run lowers (decode_* / long_* cells lower ``serve_step``). ``Engine``
is the host-side driver used by the examples, built on the same
``serving.scheduler.WaveScheduler`` as the 3D scene engine — one shared
queueing/batching/pipelining core for both modalities:

* **plan** — pack each prompt into its fixed-length slot row (host numpy,
  planner threads);
* **dispatch** — prefill + ``max_new`` greedy decode steps, all enqueued
  without host syncs (the emitted tokens stay on device);
* **drain** — one readback of the wave's token block, then per-request EOS
  truncation on the host.

``sync=False`` pipelines the stages (wave *k+1* packs while wave *k*
decodes); results are identical in both modes because EOS handling happens
entirely at drain time.

The driver API (``submit() -> RequestHandle``, ``serve()``, ``timings()``,
``slo_stats()``) comes from :class:`repro.serving.api.ServingBase` — the
same surface as the 3D ``SceneEngine``, so SLO-aware admission
(``policy=AdmissionPolicy(...)``: priority/deadline ordering, weighted
tenant fairness, backpressure shedding) applies to LM traffic for free.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import (
    decode_step,
    forward,
)
from repro.serving.api import AdmissionPolicy, ServeRequest, ServingBase
from repro.serving.scheduler import WaveScheduler


def make_prefill(cfg: ModelConfig, cache_pad: int = 0):
    def prefill(params, tokens, frontend_embeds=None, enc_frames=None):
        kw = {}
        if cfg.frontend == "vision" and frontend_embeds is not None:
            kw["frontend_embeds"] = frontend_embeds
        if cfg.is_encdec:
            kw["enc_frames"] = enc_frames
        logits, cache, _ = forward(params, cfg, tokens, mode="prefill",
                                   cache_pad=cache_pad, **kw)
        return logits[:, -1], cache

    return prefill


def make_serve_step(cfg: ModelConfig, moe_groups: int | None = None):
    def serve_step(params, token, cache):
        logits, cache = decode_step(params, cfg, token, cache,
                                    moe_groups=moe_groups)
        next_tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), logits[:, -1], cache

    return serve_step


@dataclass
class Request(ServeRequest):
    """One prompt to serve; SLO fields (tenant/priority/deadline_ms) come
    from :class:`~repro.serving.api.ServeRequest` as keyword-only args."""

    prompt: np.ndarray = None
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class Engine(ServingBase):
    """Host-side continuous-batching driver (fixed shapes)."""

    def __init__(self, cfg: ModelConfig, params, batch: int, prompt_len: int,
                 max_new: int, eos: int | None = None, *,
                 sync: bool = True, depth: int = 2,
                 planner_threads: int = 2,
                 policy: AdmissionPolicy | None = None,
                 faults=None):
        self.cfg, self.params = cfg, params
        self.batch, self.prompt_len, self.max_new = batch, prompt_len, max_new
        self.eos = eos
        self.prefill = jax.jit(make_prefill(cfg, cache_pad=max_new))
        self.step = jax.jit(make_serve_step(cfg))
        self.scheduler = WaveScheduler(
            batch=batch, plan=self._plan_stage, dispatch=self._dispatch_stage,
            drain=self._drain_stage, sync=sync, depth=depth,
            planner_threads=planner_threads, policy=policy, faults=faults)

    # -- pipeline stages -----------------------------------------------------

    def _plan_stage(self, req: Request) -> np.ndarray:
        """Pack one prompt into its fixed-length slot row (host work)."""
        row = np.zeros((self.prompt_len,), np.int32)
        prompt = np.asarray(req.prompt)[: self.prompt_len]
        if len(prompt):
            row[-len(prompt):] = prompt
        return row

    def _dispatch_stage(self, reqs: list[Request], rows, stats) -> jax.Array:
        del stats  # the LM engine records nothing beyond the shared timings
        if self.max_new < 1:
            return jnp.zeros((self.batch, 0), jnp.int32)
        toks = np.zeros((self.batch, self.prompt_len), np.int32)
        for i, row in enumerate(rows):
            toks[i] = row
        last_logits, cache = self.prefill(self.params, jnp.asarray(toks))
        tok = jnp.argmax(last_logits[:, : self.cfg.vocab_size], -1)
        tok = tok.astype(jnp.int32)[:, None]
        # early EOS exit needs a host sync per step, which would stall the
        # async pipeline — only the blocking mode pays for it (and wins the
        # old run()'s short-circuit back)
        check_eos = self.eos is not None and self.scheduler.running_sync
        done = [False] * len(reqs)
        emitted = [tok]
        for _ in range(self.max_new - 1):
            if check_eos:
                for i in range(len(reqs)):
                    done[i] = done[i] or int(tok[i, 0]) == self.eos
                if all(done):
                    break
            nxt, _, cache = self.step(self.params, tok, cache)
            tok = nxt[:, None]
            emitted.append(tok)
        return jnp.concatenate(emitted, axis=1)  # (batch, <=max_new), device

    def _drain_stage(self, reqs: list[Request], emitted) -> None:
        emitted = np.asarray(emitted)
        for i, r in enumerate(reqs):
            for t in emitted[i]:
                r.out.append(int(t))
                if self.eos is not None and int(t) == self.eos:
                    break
            r.done = True
