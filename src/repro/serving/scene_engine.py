"""Batched 3D-scene serving: fixed-capacity slots, cached plans, one jit.

The 3D face of the shared ``serving.scheduler.WaveScheduler``: the host
packs up to ``batch`` scene requests per wave, builds (or cache-hits) each
scene's ``ScenePlan``, stacks the plans along a leading scene axis and runs
one jitted vmapped U-Net forward. All shapes are static — scene capacity is
fixed, and a pinned ``PlanSpec`` freezes the SPADE dispatch decisions and
tile counts — so every wave after the first is a jit cache hit
(``n_compilations`` stays 1).

Stage split (the paper's offline-pass/execution overlap, served):

* **plan** — ``PlanCache.get_or_build(device=False)``: the AdMAC + SOAR +
  SPADE numpy pass, run on planner threads up to ``depth`` waves ahead;
* **dispatch** — fetch the (memoized) device upload of each plan, stack the
  wave, enqueue the jitted forward without blocking;
* **drain** — block on the previous wave's logits and fill the requests.

``sync=True`` (default) runs the same stages back-to-back — bitwise
identical results, no overlap; ``sync=False`` pipelines them and reports
``plan_ms`` / ``device_ms`` / ``overlap_frac`` per wave via ``wave_stats``
/ ``timings()``.

Short waves are padded with a copy of the first scene's plan and zero
features; padding slots are dropped before results are handed back.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import api as engine_api
from repro.engine.plan import PlanCache, PlanSpec, ScenePlan
from repro.serving.scheduler import WaveScheduler, WaveStats
from repro.sparse.tensor import SparseVoxelTensor


@dataclass
class SceneRequest:
    rid: int
    scene: SparseVoxelTensor
    logits: np.ndarray | None = None   # (capacity, n_classes)
    pred: np.ndarray | None = None     # (capacity,) argmax classes
    done: bool = False


class SceneEngine:
    """Host-side batched scene driver (fixed shapes, plan-cached).

    ``spec=None`` serves every scene on the reference backend (always a
    single jit signature); pass ``spec=build_plan_spec(rep_scenes, cfg)`` to
    serve the SPADE-planned reference/SSpNNA mix at pinned tile shapes.
    ``sync=False`` turns on the asynchronous wave pipeline: plan building
    for wave *k+1* overlaps device execution of wave *k* and readback of
    wave *k−1* (``depth`` device waves in flight, ``planner_threads`` host
    builders).
    """

    def __init__(self, cfg, params, batch: int,
                 spec: PlanSpec | None = None, *,
                 backend: str = "auto", use_kernel: bool = False,
                 interpret: bool | None = None, plan_cache_size: int = 128,
                 order: str = "soar", soar_chunk: int = 512,
                 sync: bool = True, depth: int = 2,
                 planner_threads: int = 2):
        self.cfg, self.params, self.batch, self.spec = cfg, params, batch, spec
        self._plan_kw = dict(spec=spec, plan_tiles=spec is not None,
                             order=order, soar_chunk=soar_chunk)
        self.cache = PlanCache(plan_cache_size)
        self.scheduler = WaveScheduler(
            batch=batch, plan=self._plan_stage, dispatch=self._dispatch_stage,
            drain=self._drain_stage, sync=sync, depth=depth,
            planner_threads=planner_threads)

        def batched_apply(params, feats, plans):
            # feats/plans arrive as length-`batch` lists; stacking inside the
            # jit keeps dispatch a single async enqueue (no eager per-leaf
            # stack ops racing the in-flight wave on the device queue)
            batch_feats = jnp.stack(feats)
            batch_plan = jax.tree.map(lambda *xs: jnp.stack(xs), *plans)
            return jax.vmap(
                lambda f, p: engine_api.apply_unet(
                    params, f, p, backend=backend, use_kernel=use_kernel,
                    interpret=interpret)
            )(batch_feats, batch_plan)

        self._apply = jax.jit(batched_apply)

    # -- introspection -------------------------------------------------------

    @property
    def n_compilations(self) -> int:
        """Distinct jit signatures compiled so far; -1 if the running jax
        version doesn't expose the cache-size probe."""
        cache_size = getattr(self._apply, "_cache_size", None)
        return int(cache_size()) if cache_size is not None else -1

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def completed(self) -> list[SceneRequest]:
        return self.scheduler.completed

    @property
    def wave_stats(self) -> list[WaveStats]:
        return self.scheduler.stats

    def timings(self) -> dict:
        return self.scheduler.timings()

    # -- pipeline stages -----------------------------------------------------

    def _plan_stage(self, req: SceneRequest) -> tuple[str, ScenePlan]:
        """Host-side plan build (numpy leaves); runs on planner threads.

        The payload carries the cache key so the dispatch thread never
        re-hashes the scene on the critical path."""
        key = self.cache.key_for(req.scene, self.cfg, **self._plan_kw)
        plan = self.cache.get_or_build(req.scene, self.cfg, device=False,
                                       key=key, **self._plan_kw)
        return key, plan

    def _dispatch_stage(self, reqs: list[SceneRequest], payloads):
        # the plan stage built (and counted) these host plans; adopt fetches
        # the memoized device upload without rebuilding (even if LRU
        # pressure evicted the entry) and without skewing hits/misses
        plans = [self.cache.adopt(key, hp, device=True)
                 for key, hp in payloads]
        t0 = jax.tree_util.tree_structure(plans[0])
        for r, p in zip(reqs, plans):
            if jax.tree_util.tree_structure(p) != t0:
                raise RuntimeError(
                    f"scene {r.rid}: plan signature diverged from "
                    "the wave (tile-budget overflow?); raise "
                    "tile_margin in build_plan_spec")
        feats = [r.scene.feats for r in reqs]
        while len(plans) < self.batch:  # pad the wave to fixed batch
            plans.append(plans[0])
            feats.append(jnp.zeros_like(feats[0]))
        return self._apply(self.params, feats, plans)

    def _drain_stage(self, reqs: list[SceneRequest], logits) -> None:
        logits = np.asarray(logits)
        for i, r in enumerate(reqs):
            r.logits = logits[i]
            r.pred = logits[i].argmax(-1)
            r.done = True

    # -- driver API ----------------------------------------------------------

    def submit(self, reqs: list[SceneRequest]) -> None:
        self.scheduler.submit(reqs)

    def run(self, sync: bool | None = None) -> list[SceneRequest]:
        """Serve the queue to empty (``sync=None`` keeps the constructor
        mode); a stage failure re-queues the affected waves and re-raises."""
        return self.scheduler.run(sync=sync)

    def close(self) -> None:
        """Release the planner thread pool (engine stays usable)."""
        self.scheduler.close()
