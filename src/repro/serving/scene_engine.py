"""Batched 3D-scene serving: fixed-capacity slots, cached plans, one jit.

The 3D analogue of ``serving.engine``'s continuous-batching LM driver: the
host packs up to ``batch`` scene requests per wave, builds (or cache-hits)
each scene's ``ScenePlan``, stacks the plans along a leading scene axis and
runs one jitted vmapped U-Net forward. All shapes are static — scene
capacity is fixed, and a pinned ``PlanSpec`` freezes the SPADE dispatch
decisions and tile counts — so every wave after the first is a jit cache
hit (``n_compilations`` stays 1).

Short waves are padded with a copy of the first scene's plan and zero
features; padding slots are dropped before results are handed back.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import api as engine_api
from repro.engine.plan import PlanCache, PlanSpec
from repro.sparse.tensor import SparseVoxelTensor


@dataclass
class SceneRequest:
    rid: int
    scene: SparseVoxelTensor
    logits: np.ndarray | None = None   # (capacity, n_classes)
    pred: np.ndarray | None = None     # (capacity,) argmax classes
    done: bool = False


class SceneEngine:
    """Host-side batched scene driver (fixed shapes, plan-cached).

    ``spec=None`` serves every scene on the reference backend (always a
    single jit signature); pass ``spec=build_plan_spec(rep_scenes, cfg)`` to
    serve the SPADE-planned reference/SSpNNA mix at pinned tile shapes.
    """

    def __init__(self, cfg, params, batch: int,
                 spec: PlanSpec | None = None, *,
                 backend: str = "auto", use_kernel: bool = False,
                 interpret: bool = True, plan_cache_size: int = 128,
                 order: str = "soar", soar_chunk: int = 512):
        self.cfg, self.params, self.batch, self.spec = cfg, params, batch, spec
        self._plan_kw = dict(spec=spec, plan_tiles=spec is not None,
                             order=order, soar_chunk=soar_chunk)
        self.cache = PlanCache(plan_cache_size)
        self.queue: list[SceneRequest] = []
        self.completed: list[SceneRequest] = []

        def batched_apply(params, feats, plans):
            return jax.vmap(
                lambda f, p: engine_api.apply_unet(
                    params, f, p, backend=backend, use_kernel=use_kernel,
                    interpret=interpret)
            )(feats, plans)

        self._apply = jax.jit(batched_apply)

    @property
    def n_compilations(self) -> int:
        """Distinct jit signatures compiled so far; -1 if the running jax
        version doesn't expose the cache-size probe."""
        cache_size = getattr(self._apply, "_cache_size", None)
        return int(cache_size()) if cache_size is not None else -1

    def submit(self, reqs: list[SceneRequest]) -> None:
        self.queue.extend(reqs)

    def run(self) -> list[SceneRequest]:
        while self.queue:
            active = [self.queue.pop(0)
                      for _ in range(min(self.batch, len(self.queue)))]
            try:
                plans = [self.cache.get_or_build(r.scene, self.cfg,
                                                 **self._plan_kw)
                         for r in active]
                t0 = jax.tree_util.tree_structure(plans[0])
                for r, p in zip(active, plans):
                    if jax.tree_util.tree_structure(p) != t0:
                        raise RuntimeError(
                            f"scene {r.rid}: plan signature diverged from "
                            "the wave (tile-budget overflow?); raise "
                            "tile_margin in build_plan_spec")
            except Exception:
                self.queue = active + self.queue  # don't drop the wave
                raise
            feats = [r.scene.feats for r in active]
            while len(plans) < self.batch:  # pad the wave to fixed batch
                plans.append(plans[0])
                feats.append(jnp.zeros_like(feats[0]))
            batch_plan = jax.tree.map(lambda *xs: jnp.stack(xs), *plans)
            logits = self._apply(self.params, jnp.stack(feats), batch_plan)
            logits = np.asarray(logits)
            for i, r in enumerate(active):
                r.logits = logits[i]
                r.pred = logits[i].argmax(-1)
                r.done = True
                self.completed.append(r)
        return self.completed
