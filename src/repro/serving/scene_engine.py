"""Batched 3D-scene serving: fixed-capacity slots, cached plans, few jits.

The 3D face of the shared ``serving.scheduler.WaveScheduler``: the host
packs up to ``batch`` scene requests per wave, builds (or cache-hits) each
scene's plan, and runs the wave through one jitted forward. All shapes are
static — scene capacity is fixed per signature, and a pinned ``PlanSpec``
(or, sharded, a pinned halo budget) freezes the plan signature — so every
wave after the first is a jit cache hit.

The engine executes under an :class:`~repro.engine.context.ExecutionContext`
(``ctx=``): the context owns the plan cache (topology mixed into every
key), the backend registry the jitted forward dispatches through, the
default admission policy, and — for sharded serving — the device mesh.
Three serving modes:

* **batched** (default): plans stack along a leading scene axis and one
  vmapped U-Net forward serves the wave at a single pinned capacity
  (``n_compilations`` stays 1).
* **bucketed** (``family=SignatureFamily(...)``): continuous batching over
  a small family of capacity tiers. Each request is assigned the smallest
  bucket its *active* voxels fit at submit time; the plan stage re-packs
  the scene to the bucket capacity (active rows first — so a client can
  over-pad its upload and still serve from a small bucket) and admission
  fills each wave from same-bucket requests. One jit signature per bucket,
  compiled on first use — mixed traffic compiles at most
  ``family.n_buckets`` signatures, warm single-size traffic exactly 1.
  Pair with a :class:`~repro.serving.scheduler.AdmissionPolicy`
  (``policy=`` or ``ctx.admission``) for priority/deadline admission,
  weighted tenant fairness, and backpressure shedding.
* **sharded** (``layout=ShardLayout(...)`` with a pinned ``halo`` budget):
  each scene's capacity axis is split over ``ctx.mesh``'s shard axis; the
  plan stage builds per-shard metadata + halo send tables (pure numpy, on
  planner threads), and dispatch enqueues one sharded forward per scene.
  Each wave's ``WaveStats.notes`` records the per-shard plan builds and
  halo rows.

Stage split (the paper's offline-pass/execution overlap, served):

* **plan** — ``PlanCache.get_or_build(device=False)``: the AdMAC + SOAR +
  SPADE (+ bucket re-pack / halo split) numpy pass, run on planner threads
  up to ``depth`` waves ahead;
* **dispatch** — fetch the (memoized) device upload of each plan and
  enqueue the jitted forward without blocking;
* **drain** — block on the previous wave's logits and fill the requests
  (bucketed scenes scatter back to their original row positions).

``sync=True`` (default) runs the same stages back-to-back — bitwise
identical results given the same admitted wave order, no overlap;
``sync=False`` pipelines them and reports ``plan_ms`` / ``device_ms`` /
``overlap_frac`` per wave via ``wave_stats`` / ``timings()``.

Short waves are padded with a copy of the first scene's plan and zero
features; padding slots are dropped before results are handed back.

The driver API (``submit() -> RequestHandle``, ``serve()``, ``timings()``,
``slo_stats()``) comes from :class:`repro.serving.api.ServingBase`; the
pre-handle ``run()`` / ``.completed`` surface survives as deprecation
shims there.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import api as engine_api
from repro.engine.context import ExecutionContext
from repro.engine.plan import PlanCache, PlanSpec, SignatureFamily
from repro.engine.shard import ShardLayout, build_sharded_scene_plan_host
from repro.serving.api import AdmissionPolicy, ServeRequest, ServingBase
from repro.serving.scheduler import WaveScheduler
from repro.sparse.tensor import SparseVoxelTensor, compact_to_capacity


@dataclass
class SceneRequest(ServeRequest):
    """One scene to segment; SLO fields (tenant/priority/deadline_ms) come
    from :class:`~repro.serving.api.ServeRequest` as keyword-only args."""

    scene: SparseVoxelTensor = None
    logits: np.ndarray | None = None   # (capacity, n_classes)
    pred: np.ndarray | None = None     # (capacity,) argmax classes
    done: bool = False


class SceneEngine(ServingBase):
    """Host-side batched scene driver (fixed shapes, plan-cached).

    ``spec=None`` serves every scene on the reference backend (always a
    single jit signature); pass ``spec=build_plan_spec(rep_scenes, cfg)``
    to serve the SPADE-planned reference/SSpNNA mix at pinned tile shapes,
    ``family=build_signature_family(rep_scenes, cfg)`` for bucketed
    continuous batching over a family of capacity tiers, or
    ``layout=pin_halo(rep_scenes, cfg, ShardLayout(...))`` (with a
    mesh-carrying ``ctx``) to serve mesh-sharded scenes. ``sync=False``
    turns on the asynchronous wave pipeline: plan building for wave *k+1*
    overlaps device execution of wave *k* and readback of wave *k−1*
    (``depth`` device waves in flight, ``planner_threads`` host builders).
    ``sync`` / ``depth`` / ``planner_threads`` / ``policy`` default to the
    context's scheduler wiring when left ``None``.
    """

    def __init__(self, cfg, params, batch: int,
                 spec: PlanSpec | None = None, *,
                 ctx: ExecutionContext | None = None,
                 layout: ShardLayout | None = None,
                 family: SignatureFamily | None = None,
                 policy: AdmissionPolicy | None = None,
                 backend: str = "auto", use_kernel: bool = False,
                 interpret: bool | None = None,
                 plan_cache_size: int | None = None,
                 order: str = "soar", soar_chunk: int = 512,
                 sync: bool | None = None, depth: int | None = None,
                 planner_threads: int | None = None):
        if ctx is None:
            ctx = ExecutionContext(
                plan_cache=PlanCache(plan_cache_size or 128))
        elif plan_cache_size is not None:
            raise ValueError(
                "plan_cache_size only applies when the engine builds its "
                "own context; size ctx.plan_cache when passing ctx=")
        self.cfg, self.params, self.batch, self.spec = cfg, params, batch, spec
        self.ctx, self.layout, self.family = ctx, layout, family
        self.cache = ctx.plan_cache
        self._topology = ctx.topology_key()
        self._plan_sig = None  # sharded mode: pinned wave plan signature
        if policy is None:
            policy = ctx.admission
        if family is not None:
            if spec is not None:
                raise ValueError(
                    "spec= and family= are mutually exclusive: the family "
                    "carries a pinned spec per capacity bucket")
            if layout is not None:
                raise ValueError(
                    "family= and layout= are mutually exclusive: sharded "
                    "serving pins a single halo-budget signature")
            # per-bucket configs share params; only the capacity tier (and
            # with it the plan/jit signature) differs
            self._bucket_cfgs = {
                cap: dataclasses.replace(cfg, capacity=cap)
                for cap in family.capacities}
            self._bucket_kw = {
                cap: dict(spec=family.spec_for(cap),
                          plan_tiles=family.spec_for(cap) is not None,
                          order=order, soar_chunk=soar_chunk)
                for cap in family.capacities}
            self._builder = None
        elif layout is not None:
            if spec is not None:
                raise ValueError(
                    "spec= and layout= are mutually exclusive: sharded "
                    "serving plans its own per-shard metadata")
            if layout.halo < 1:
                raise ValueError(
                    "sharded serving needs a pinned halo budget for a "
                    "single jit signature; pin one with engine.pin_halo")
            if ctx.mesh is not None:
                axes = getattr(ctx.mesh, "axis_names", ())
                if (layout.axis not in axes
                        or int(ctx.mesh.shape[layout.axis]) != layout.n_shards):
                    raise ValueError(
                        f"layout needs mesh axis {layout.axis!r} of size "
                        f"{layout.n_shards}; ctx mesh has axes "
                        f"{dict(getattr(ctx.mesh, 'shape', {}))}")
            self._plan_kw = dict(layout=layout)
            self._builder = build_sharded_scene_plan_host
        else:
            self._plan_kw = dict(spec=spec, plan_tiles=spec is not None,
                                 order=order, soar_chunk=soar_chunk)
            self._builder = None  # PlanCache default (build_scene_plan_host)
        self.scheduler = WaveScheduler(
            batch=batch, plan=self._plan_stage, dispatch=self._dispatch_stage,
            drain=self._drain_stage,
            sync=ctx.sync if sync is None else sync,
            depth=ctx.depth if depth is None else depth,
            planner_threads=(ctx.planner_threads if planner_threads is None
                             else planner_threads),
            policy=policy,
            bucket_of=((lambda r: getattr(r, "_bucket", None))
                       if family is not None else None))

        if layout is not None:
            def sharded_apply(params, feats, plan):
                return engine_api.apply_unet(
                    params, feats, plan, backend=backend, ctx=ctx,
                    use_kernel=use_kernel, interpret=interpret)

            self._apply = jax.jit(sharded_apply)
        else:
            def batched_apply(params, feats, plans):
                # feats/plans arrive as length-`batch` lists; stacking
                # inside the jit keeps dispatch a single async enqueue (no
                # eager per-leaf stack ops racing the in-flight wave on the
                # device queue)
                batch_feats = jnp.stack(feats)
                batch_plan = jax.tree.map(lambda *xs: jnp.stack(xs), *plans)
                return jax.vmap(
                    lambda f, p: engine_api.apply_unet(
                        params, f, p, backend=backend, ctx=ctx,
                        use_kernel=use_kernel, interpret=interpret)
                )(batch_feats, batch_plan)

            self._apply = jax.jit(batched_apply)

    # -- introspection -------------------------------------------------------

    @property
    def n_compilations(self) -> int:
        """Distinct jit signatures compiled so far (bucketed serving pays
        one per bucket actually used); -1 if the running jax version
        doesn't expose the cache-size probe."""
        cache_size = getattr(self._apply, "_cache_size", None)
        return int(cache_size()) if cache_size is not None else -1

    # -- admission -----------------------------------------------------------

    def _prepare(self, req: SceneRequest) -> str | None:
        """Bucket assignment at submit time (bucketed mode): the smallest
        family capacity the scene's active voxels fit; a scene exceeding
        every bucket is shed with reason ``"capacity"``."""
        if self.family is None:
            return None
        n_active = int(np.asarray(req.scene.mask).sum())
        cap = self.family.bucket_for(n_active)
        if cap is None:
            return "capacity"
        req._bucket = cap
        req._n_active = n_active
        return None

    # -- pipeline stages -----------------------------------------------------

    def _plan_stage(self, req: SceneRequest):
        """Host-side plan build (numpy leaves); runs on planner threads.

        The payload carries the cache key so the dispatch thread never
        re-hashes the scene on the critical path. Bucketed mode re-packs
        the scene to its bucket capacity first (active rows in original
        order) and remembers the row mapping for the drain scatter."""
        if self.family is not None:
            cap = req._bucket
            scene, active_idx = compact_to_capacity(req.scene, cap)
            req._active_idx = active_idx
            cfg, plan_kw = self._bucket_cfgs[cap], self._bucket_kw[cap]
        else:
            scene, cfg, plan_kw = req.scene, self.cfg, self._plan_kw
        key = self.cache.key_for(scene, cfg,
                                 topology=self._topology, **plan_kw)
        plan = self.cache.get_or_build(scene, cfg, device=False,
                                       key=key, builder=self._builder,
                                       **plan_kw)
        if self.family is not None:
            return key, plan, scene.feats  # re-packed feats (numpy)
        return key, plan

    def _dispatch_stage(self, reqs: list[SceneRequest], payloads, stats):
        # the plan stage built (and counted) these host plans; adopt fetches
        # the memoized device upload without rebuilding (even if LRU
        # pressure evicted the entry) and without skewing hits/misses
        plans = [self.cache.adopt(p[0], p[1], device=True) for p in payloads]
        if self.layout is not None:
            # the pinned halo budget promises one jit signature across
            # every wave; a diverging plan (wrong capacity, re-pinned
            # layout) must fail loudly, not silently recompile
            for r, p in zip(reqs, plans):
                leaves, td = jax.tree_util.tree_flatten(p)
                sig = (td, tuple(x.shape for x in leaves))
                if self._plan_sig is None:
                    self._plan_sig = sig
                elif sig != self._plan_sig:
                    raise RuntimeError(
                        f"scene {r.rid}: sharded plan signature diverged "
                        "from the pinned layout (capacity mismatch or a "
                        "re-pinned halo budget?); re-pin with "
                        "engine.pin_halo")
            stats.notes["plan_shards"] = self.layout.n_shards
            stats.notes["plan_builds"] = len(payloads)
            stats.notes["halo_rows"] = sum(
                p[1].halo_rows() for p in payloads)
            # per-scene sharded forwards; jax async dispatch keeps the
            # loop non-blocking, so the wave still pipelines as one unit
            return [self._apply(self.params, r.scene.feats, p)
                    for r, p in zip(reqs, plans)]
        t0 = jax.tree_util.tree_structure(plans[0])
        for r, p in zip(reqs, plans):
            if jax.tree_util.tree_structure(p) != t0:
                raise RuntimeError(
                    f"scene {r.rid}: plan signature diverged from "
                    "the wave (tile-budget overflow?); raise "
                    "tile_margin in build_plan_spec")
        if self.family is not None:
            # admission guarantees a single-bucket wave; a mixed wave here
            # means the bucket hook was bypassed — fail before compiling a
            # stray signature
            caps = {r._bucket for r in reqs}
            if len(caps) != 1:
                raise RuntimeError(
                    f"wave mixes capacity buckets {sorted(caps)}; bucketed "
                    "serving admits one bucket per wave")
            feats = [jnp.asarray(p[2]) for p in payloads]
        else:
            feats = [r.scene.feats for r in reqs]
        while len(plans) < self.batch:  # pad the wave to fixed batch
            plans.append(plans[0])
            feats.append(jnp.zeros_like(feats[0]))
        return self._apply(self.params, feats, plans)

    def _drain_stage(self, reqs: list[SceneRequest], logits) -> None:
        if isinstance(logits, list):  # sharded mode: per-scene handles
            logits = np.stack([np.asarray(h) for h in logits])
        else:
            logits = np.asarray(logits)
        for i, r in enumerate(reqs):
            if self.family is not None:
                # scatter compacted-bucket rows back to the request's
                # original row positions (padding rows stay zero-logit)
                idx = r._active_idx
                out = np.zeros((r.scene.capacity, logits.shape[-1]),
                               logits.dtype)
                out[idx] = logits[i][: len(idx)]
                r.logits = out
            else:
                r.logits = logits[i]
            r.pred = r.logits.argmax(-1)
            r.done = True
