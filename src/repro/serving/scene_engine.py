"""Batched 3D-scene serving: fixed-capacity slots, cached plans, few jits.

The 3D face of the shared ``serving.scheduler.WaveScheduler``: the host
packs up to ``batch`` scene requests per wave, builds (or cache-hits) each
scene's plan, and runs the wave through one jitted forward. All shapes are
static — scene capacity is fixed per signature, and a pinned ``PlanSpec``
(or, sharded, a pinned halo budget) freezes the plan signature — so every
wave after the first is a jit cache hit.

The engine executes under an :class:`~repro.engine.context.ExecutionContext`
(``ctx=``): the context owns the plan cache (topology mixed into every
key), the backend registry the jitted forward dispatches through, the
default admission policy, and — for sharded serving — the device mesh.
Three serving modes:

* **batched** (default): plans stack along a leading scene axis and one
  vmapped U-Net forward serves the wave at a single pinned capacity
  (``n_compilations`` stays 1).
* **bucketed** (``family=SignatureFamily(...)``): continuous batching over
  a small family of capacity tiers. Each request is assigned the smallest
  bucket its *active* voxels fit at submit time; the plan stage re-packs
  the scene to the bucket capacity (active rows first — so a client can
  over-pad its upload and still serve from a small bucket) and admission
  fills each wave from same-bucket requests. One jit signature per bucket,
  compiled on first use — mixed traffic compiles at most
  ``family.n_buckets`` signatures, warm single-size traffic exactly 1.
  Pair with a :class:`~repro.serving.scheduler.AdmissionPolicy`
  (``policy=`` or ``ctx.admission``) for priority/deadline admission,
  weighted tenant fairness, and backpressure shedding.
* **sharded** (``layout=ShardLayout(...)`` with a pinned ``halo`` budget):
  each scene's capacity axis is split over ``ctx.mesh``'s shard axis; the
  plan stage builds per-shard metadata + halo send tables (pure numpy, on
  planner threads), and dispatch enqueues one sharded forward per scene.
  Each wave's ``WaveStats.notes`` records the per-shard plan builds and
  halo rows.

On top of the batched mode, ``open_stream()`` / ``serve_stream()`` add a
**streaming** path for LiDAR sweeps: frames submitted through a
:class:`StreamHandle` are planned *incrementally* — each frame diffs
against the stream's previous frame (after ego-motion re-basing) and
patches the cached host plan's metadata tables instead of rebuilding
them, with a full-rebuild fallback under heavy churn. Admission keeps
frames FIFO within a stream (they are order-dependent) while the policy
still arbitrates between streams and one-shot requests; each wave's
``WaveStats.notes`` reports ``stream_reused`` / ``stream_patched`` /
``stream_rebuilt`` counts, mean ``stream_overlap`` and summed
``stream_plan_ms``.

Stage split (the paper's offline-pass/execution overlap, served):

* **plan** — ``PlanCache.get_or_build(device=False)``: the AdMAC + SOAR +
  SPADE (+ bucket re-pack / halo split) numpy pass, run on planner threads
  up to ``depth`` waves ahead;
* **dispatch** — fetch the (memoized) device upload of each plan and
  enqueue the jitted forward without blocking;
* **drain** — block on the previous wave's logits and fill the requests
  (bucketed scenes scatter back to their original row positions).

``sync=True`` (default) runs the same stages back-to-back — bitwise
identical results given the same admitted wave order, no overlap;
``sync=False`` pipelines them and reports ``plan_ms`` / ``device_ms`` /
``overlap_frac`` per wave via ``wave_stats`` / ``timings()``.

Short waves are padded with a copy of the first scene's plan and zero
features; padding slots are dropped before results are handed back.

The driver API (``submit() -> RequestHandle``, ``serve()``, ``timings()``,
``slo_stats()``) comes from :class:`repro.serving.api.ServingBase`; the
pre-handle ``run()`` / ``.completed`` surface survives as deprecation
shims there.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.runtime import ordered_lock
from repro.core.host_meta import pack_stream_frame_np
from repro.engine import api as engine_api
from repro.engine.context import ExecutionContext
from repro.engine.plan import (
    REFERENCE,
    PlanCache,
    PlanSpec,
    SignatureFamily,
    StreamPlanState,
)
from repro.engine.shard import ShardLayout, build_sharded_scene_plan_host
from repro.serving.api import AdmissionPolicy, ServeRequest, ServingBase
from repro.serving.scheduler import WaveScheduler
from repro.sparse.tensor import SparseVoxelTensor, compact_to_capacity


@dataclass
class SceneRequest(ServeRequest):
    """One scene to segment; SLO fields (tenant/priority/deadline_ms) come
    from :class:`~repro.serving.api.ServeRequest` as keyword-only args."""

    scene: SparseVoxelTensor = None
    logits: np.ndarray | None = None   # (capacity, n_classes)
    pred: np.ndarray | None = None     # (capacity,) argmax classes
    done: bool = False


@dataclass
class StreamFrameRequest(SceneRequest):
    """One frame of an open LiDAR stream (made by ``StreamHandle.submit``).

    Carries the stream handle, its monotonically assigned ``frame_no`` and
    the ``ego_shift`` from the previous frame. After serving, ``logits`` /
    ``pred`` are in the *caller's* row layout (the drain stage scatters the
    stream's canonical rows back through ``frame_rows``), and
    ``plan_info`` records how the frame was planned: ``mode`` in
    {``reused``, ``patched``, ``rebuilt``}, voxel ``overlap`` fraction with
    the previous frame, host ``plan_ms``."""

    stream: "StreamHandle | None" = None
    frame_no: int = -1
    ego_shift: tuple = (0, 0, 0)
    plan_info: dict | None = None

    # scheduler hooks: per-stream FIFO admission keys
    @property
    def _stream_key(self):
        return None if self.stream is None else self.stream.stream_id

    @property
    def _stream_frame(self) -> int:
        return self.frame_no


class StreamHandle:
    """Client view of one open stream on a :class:`SceneEngine`.

    ``submit(scene, ego_shift)`` queues the stream's next frame (frame
    numbers are assigned monotonically; admission keeps them FIFO within
    the stream even under an urgency policy) and returns the usual
    :class:`~repro.serving.api.RequestHandle`. ``stats()`` reports the
    stream's plan-reuse counters."""

    def __init__(self, engine: "SceneEngine", state: StreamPlanState):
        self.engine = engine
        self.state = state
        self._next_frame = 0
        self._lock = ordered_lock("stream.handle")

    @property
    def stream_id(self) -> str:
        return self.state.stream_id

    def submit(self, scene: SparseVoxelTensor, ego_shift=(0, 0, 0), *,
               rid: int | None = None, **slo):
        """Queue the next frame of this stream; ``ego_shift`` is the ego
        translation (in voxels) since the *previous* submitted frame.
        SLO kwargs (tenant/priority/deadline_ms) pass through."""
        with self._lock:
            frame_no = self._next_frame
            self._next_frame += 1
        req = StreamFrameRequest(
            rid=frame_no if rid is None else rid, scene=scene,
            stream=self, frame_no=frame_no, ego_shift=tuple(ego_shift),
            **slo)
        return self.engine.submit(req)

    def stats(self) -> dict:
        """Aggregate plan-reuse stats: frames, reused/patched/rebuilt
        counts, mean overlap, mean host plan ms."""
        return self.state.stats()


class SceneEngine(ServingBase):
    """Host-side batched scene driver (fixed shapes, plan-cached).

    ``spec=None`` serves every scene on the reference backend (always a
    single jit signature); pass ``spec=build_plan_spec(rep_scenes, cfg)``
    to serve the SPADE-planned reference/SSpNNA mix at pinned tile shapes,
    ``family=build_signature_family(rep_scenes, cfg)`` for bucketed
    continuous batching over a family of capacity tiers, or
    ``layout=pin_halo(rep_scenes, cfg, ShardLayout(...))`` (with a
    mesh-carrying ``ctx``) to serve mesh-sharded scenes. ``sync=False``
    turns on the asynchronous wave pipeline: plan building for wave *k+1*
    overlaps device execution of wave *k* and readback of wave *k−1*
    (``depth`` device waves in flight, ``planner_threads`` host builders).
    ``sync`` / ``depth`` / ``planner_threads`` / ``policy`` default to the
    context's scheduler wiring when left ``None``.
    """

    def __init__(self, cfg, params, batch: int,
                 spec: PlanSpec | None = None, *,
                 ctx: ExecutionContext | None = None,
                 layout: ShardLayout | None = None,
                 family: SignatureFamily | None = None,
                 policy: AdmissionPolicy | None = None,
                 backend: str = "auto", use_kernel: bool = False,
                 interpret: bool | None = None,
                 plan_cache_size: int | None = None,
                 order: str = "soar", soar_chunk: int = 512,
                 sync: bool | None = None, depth: int | None = None,
                 planner_threads: int | None = None,
                 faults=None):
        if ctx is None:
            ctx = ExecutionContext(
                plan_cache=PlanCache(plan_cache_size or 128))
        elif plan_cache_size is not None:
            raise ValueError(
                "plan_cache_size only applies when the engine builds its "
                "own context; size ctx.plan_cache when passing ctx=")
        self.cfg, self.params, self.batch, self.spec = cfg, params, batch, spec
        self.ctx, self.layout, self.family = ctx, layout, family
        self.cache = ctx.plan_cache
        self._topology = ctx.topology_key()
        self._plan_sig = None  # sharded mode: pinned wave plan signature
        #: the context registry's circuit breakers; dispatch failures feed
        #: them (via the scheduler's on_wave_error) and plan builds consult
        #: them, so a failing backend reroutes to its fallback
        self._breakers = getattr(ctx.registry, "breakers", None)
        if policy is None:
            policy = ctx.admission
        if family is not None:
            if spec is not None:
                raise ValueError(
                    "spec= and family= are mutually exclusive: the family "
                    "carries a pinned spec per capacity bucket")
            if layout is not None:
                raise ValueError(
                    "family= and layout= are mutually exclusive: sharded "
                    "serving pins a single halo-budget signature")
            # per-bucket configs share params; only the capacity tier (and
            # with it the plan/jit signature) differs
            self._bucket_cfgs = {
                cap: dataclasses.replace(cfg, capacity=cap)
                for cap in family.capacities}
            self._bucket_kw = {
                cap: dict(spec=family.spec_for(cap),
                          plan_tiles=family.spec_for(cap) is not None,
                          order=order, soar_chunk=soar_chunk)
                for cap in family.capacities}
            if getattr(ctx, "autotune", None) is not None:
                for kw in self._bucket_kw.values():
                    kw["autotune"] = ctx.autotune
            if self._breakers is not None:
                for kw in self._bucket_kw.values():
                    kw["breakers"] = self._breakers
            self._builder = None
        elif layout is not None:
            if spec is not None:
                raise ValueError(
                    "spec= and layout= are mutually exclusive: sharded "
                    "serving plans its own per-shard metadata")
            if layout.halo < 1:
                raise ValueError(
                    "sharded serving needs a pinned halo budget for a "
                    "single jit signature; pin one with engine.pin_halo")
            if ctx.mesh is not None:
                axes = getattr(ctx.mesh, "axis_names", ())
                if (layout.axis not in axes
                        or int(ctx.mesh.shape[layout.axis]) != layout.n_shards):
                    raise ValueError(
                        f"layout needs mesh axis {layout.axis!r} of size "
                        f"{layout.n_shards}; ctx mesh has axes "
                        f"{dict(getattr(ctx.mesh, 'shape', {}))}")
            self._plan_kw = dict(layout=layout)
            self._builder = build_sharded_scene_plan_host
        else:
            self._plan_kw = dict(spec=spec, plan_tiles=spec is not None,
                                 order=order, soar_chunk=soar_chunk)
            if getattr(ctx, "autotune", None) is not None:
                # the table's generation is repr'd into every cache key, so
                # a measured-winner flip rotates keys (and the flip hook
                # clears entries) — cached plans never outlive the decision
                self._plan_kw["autotune"] = ctx.autotune
            if self._breakers is not None:
                # same invariant for breaker routing: the board's repr
                # carries its generation, so a trip/close rotates keys
                self._plan_kw["breakers"] = self._breakers
            self._builder = None  # PlanCache default (build_scene_plan_host)
        self._streams: dict[str, StreamHandle] = {}
        self.scheduler = WaveScheduler(
            batch=batch, plan=self._plan_stage, dispatch=self._dispatch_stage,
            drain=self._drain_stage,
            sync=ctx.sync if sync is None else sync,
            depth=ctx.depth if depth is None else depth,
            planner_threads=(ctx.planner_threads if planner_threads is None
                             else planner_threads),
            policy=policy,
            bucket_of=((lambda r: getattr(r, "_bucket", None))
                       if family is not None else None),
            on_shed=self._on_shed,
            on_idle=self._make_idle_hook(ctx),
            faults=faults,
            on_wave_error=self._on_wave_error)

        if layout is not None:
            def sharded_apply(params, feats, plan):
                return engine_api.apply_unet(
                    params, feats, plan, backend=backend, ctx=ctx,
                    use_kernel=use_kernel, interpret=interpret)

            self._apply = jax.jit(sharded_apply)
        else:
            def batched_apply(params, feats, plans):
                # feats/plans arrive as length-`batch` lists; stacking
                # inside the jit keeps dispatch a single async enqueue (no
                # eager per-leaf stack ops racing the in-flight wave on the
                # device queue)
                batch_feats = jnp.stack(feats)
                batch_plan = jax.tree.map(lambda *xs: jnp.stack(xs), *plans)
                return jax.vmap(
                    lambda f, p: engine_api.apply_unet(
                        params, f, p, backend=backend, ctx=ctx,
                        use_kernel=use_kernel, interpret=interpret)
                )(batch_feats, batch_plan)

            self._apply = jax.jit(batched_apply)

    # -- introspection -------------------------------------------------------

    @property
    def n_compilations(self) -> int:
        """Distinct jit signatures compiled so far (bucketed serving pays
        one per bucket actually used); -1 if the running jax version
        doesn't expose the cache-size probe."""
        cache_size = getattr(self._apply, "_cache_size", None)
        return int(cache_size()) if cache_size is not None else -1

    # -- streaming -----------------------------------------------------------

    def open_stream(self, stream_id: str | None = None, *,
                    min_overlap: float = 0.5,
                    wait_s: float = 5.0) -> StreamHandle:
        """Open a LiDAR stream: subsequent frames submitted through the
        returned :class:`StreamHandle` are planned *incrementally* — each
        frame diffs against the previous one (after ``ego_shift``
        re-basing) and patches the cached host plan instead of rebuilding
        it, falling back to a full rebuild when voxel overlap drops below
        ``min_overlap``. Streams need the fixed-capacity batched mode
        (``family=`` re-packs rows per bucket and ``layout=`` pins a
        sharded signature; both are incompatible with a per-stream
        canonical row layout)."""
        if self.family is not None or self.layout is not None:
            raise ValueError(
                "open_stream needs the fixed-capacity batched mode; "
                "family= and layout= engines cannot serve streams")
        if stream_id is not None and stream_id in self._streams:
            raise ValueError(f"stream {stream_id!r} is already open")
        state = StreamPlanState(
            self.cfg, cache=self.cache, spec=self.spec,
            plan_tiles=self._plan_kw["plan_tiles"],
            order=self._plan_kw["order"],
            soar_chunk=self._plan_kw["soar_chunk"],
            min_overlap=min_overlap, stream_id=stream_id,
            topology=self._topology, wait_s=wait_s)
        handle = StreamHandle(self, state)
        self._streams[state.stream_id] = handle
        return handle

    def serve_stream(self, frames, ego_shifts=None, *,
                     stream: StreamHandle | None = None,
                     min_overlap: float = 0.5,
                     **slo) -> list[StreamFrameRequest]:
        """Serve a whole sweep through one stream: submit every frame in
        order (``ego_shifts[i]`` is frame *i*'s ego translation since
        frame *i−1*), pump the queue, and return the fulfilled requests.
        Pass ``stream=`` to continue an already-open stream; otherwise a
        fresh one is opened with ``min_overlap``."""
        frames = list(frames)
        if ego_shifts is None:
            ego_shifts = [(0, 0, 0)] * len(frames)
        ego_shifts = [tuple(s) for s in ego_shifts]
        if len(ego_shifts) != len(frames):
            raise ValueError(
                f"{len(frames)} frames but {len(ego_shifts)} ego_shifts")
        if stream is None:
            stream = self.open_stream(min_overlap=min_overlap)
        handles = [stream.submit(t, shift, **slo)
                   for t, shift in zip(frames, ego_shifts)]
        self.serve()
        return [h.result() for h in handles]

    def _on_shed(self, req) -> None:
        # a shed stream frame must not wedge its successors: advance the
        # stream's frame gate (the next planned frame rebuilds)
        if isinstance(req, StreamFrameRequest) and req.stream is not None:
            req.stream.state.skip_frame(req.frame_no)

    def _make_idle_hook(self, ctx):
        """Idle-gap re-profiling hook for the wave scheduler, or ``None``.

        Only installed when the context carries a cost table *and* a
        positive ``autotune_reprofile_ms`` budget — profiling never rides
        the serving hot path, and tests (budget 0, the default) see no
        hook at all.
        """
        table = getattr(ctx, "autotune", None)
        budget_ms = float(getattr(ctx, "autotune_reprofile_ms", 0.0) or 0.0)
        if table is None or budget_ms <= 0.0:
            return None

        def _idle(scheduler) -> None:
            from repro.engine.autotune import reprofile

            reprofile(table, registry=ctx.registry, ctx=ctx,
                      budget_ms=budget_ms)

        return _idle

    # -- admission -----------------------------------------------------------

    def _prepare(self, req: SceneRequest) -> str | None:
        """Bucket assignment at submit time (bucketed mode): the smallest
        family capacity the scene's active voxels fit; a scene exceeding
        every bucket is shed with reason ``"capacity"``."""
        if self.family is None:
            return None
        n_active = int(np.asarray(req.scene.mask).sum())
        cap = self.family.bucket_for(n_active)
        if cap is None:
            return "capacity"
        req._bucket = cap
        req._n_active = n_active
        return None

    # -- pipeline stages -----------------------------------------------------

    def _plan_stage(self, req: SceneRequest):
        """Host-side plan build (numpy leaves); runs on planner threads.

        The payload carries the cache key so the dispatch thread never
        re-hashes the scene on the critical path. Bucketed mode re-packs
        the scene to its bucket capacity first (active rows in original
        order) and remembers the row mapping for the drain scatter.

        Stream frames take the incremental path: ``StreamPlanState``
        blocks until the stream's previous frame has been planned, diffs
        against it, and patches (or reuses) the cached host plan; features
        are re-packed into the stream's canonical row layout here so
        dispatch stays a plain upload."""
        if isinstance(req, StreamFrameRequest):
            scene = req.scene
            inj = self.scheduler.faults
            if inj is not None:
                # corrupt-frame seam: scribble garbage over the frame's
                # coords before planning — exercises the stream's
                # gap/rebuild recovery (and plan-stage containment when
                # the corruption makes the build raise)
                coords = np.asarray(scene.coords)
                corrupted = inj.corrupt_coords(coords, rid=req.rid)
                if corrupted is not coords:
                    scene = SparseVoxelTensor(
                        jnp.asarray(corrupted), scene.feats, scene.mask)
            state = req.stream.state
            key, plan, frame_rows, info = state.plan_frame(
                scene, req.frame_no, req.ego_shift)
            req.plan_info = info
            req._frame_rows = frame_rows
            req._backends = self._plan_backends(plan)
            feats = pack_stream_frame_np(frame_rows,
                                         np.asarray(scene.feats))
            return "stream", key, plan, feats, state
        if self.family is not None:
            cap = req._bucket
            scene, active_idx = compact_to_capacity(req.scene, cap)
            req._active_idx = active_idx
            cfg, plan_kw = self._bucket_cfgs[cap], self._bucket_kw[cap]
        else:
            scene, cfg, plan_kw = req.scene, self.cfg, self._plan_kw
        key = self.cache.key_for(scene, cfg,
                                 topology=self._topology, **plan_kw)
        plan = self.cache.get_or_build(scene, cfg, device=False,
                                       key=key, builder=self._builder,
                                       **plan_kw)
        req._backends = self._plan_backends(plan)
        if self.family is not None:
            return key, plan, scene.feats  # re-packed feats (numpy)
        return key, plan

    @staticmethod
    def _plan_backends(plan) -> tuple:
        """Non-reference backends this plan dispatches to — the circuit
        breakers a failure of the request's wave is attributed to (when
        the exception itself doesn't name one)."""
        names = set()
        for info in getattr(plan, "stats", None) or ():
            d = info.get("dispatch") if isinstance(info, dict) else None
            name = getattr(d, "backend", None)
            if name is not None and name != REFERENCE:
                names.add(name)
        return tuple(sorted(names))

    def _on_wave_error(self, exc, reqs, stage: str) -> None:
        """Contained-wave-failure observer (scheduler ``on_wave_error``):
        attribute dispatch/drain failures to backend circuit breakers —
        the exception's ``backend`` attribute when it names one (e.g. an
        injected ``DeviceFaultError``), else every non-reference backend
        the wave's plans dispatch to."""
        board = self._breakers
        if board is None or stage not in ("dispatch", "drain"):
            return
        name = getattr(exc, "backend", None)
        names = ((name,) if name else
                 sorted({b for r in reqs
                         for b in getattr(r, "_backends", ())}))
        for n in names:
            board.record_failure(n)

    def _dispatch_stage(self, reqs: list[SceneRequest], payloads, stats):
        # the plan stage built (and counted) these host plans; adopt fetches
        # the memoized device upload without rebuilding (even if LRU
        # pressure evicted the entry) and without skewing hits/misses.
        # Stream frames upload through their StreamPlanState's per-leaf
        # identity memo instead, so a patched frame re-uploads only the
        # tables the delta actually touched.
        plans = [p[4].device_plan(p[2]) if p[0] == "stream"
                 else self.cache.adopt(p[0], p[1], device=True)
                 for p in payloads]
        if self.layout is not None:
            # the pinned halo budget promises one jit signature across
            # every wave; a diverging plan (wrong capacity, re-pinned
            # layout) must fail loudly, not silently recompile
            for r, p in zip(reqs, plans):
                leaves, td = jax.tree_util.tree_flatten(p)
                sig = (td, tuple(x.shape for x in leaves))
                if self._plan_sig is None:
                    self._plan_sig = sig
                elif sig != self._plan_sig:
                    raise RuntimeError(
                        f"scene {r.rid}: sharded plan signature diverged "
                        "from the pinned layout (capacity mismatch or a "
                        "re-pinned halo budget?); re-pin with "
                        "engine.pin_halo")
            stats.notes["plan_shards"] = self.layout.n_shards
            stats.notes["plan_builds"] = len(payloads)
            stats.notes["halo_rows"] = sum(
                p[1].halo_rows() for p in payloads)
            # per-scene sharded forwards; jax async dispatch keeps the
            # loop non-blocking, so the wave still pipelines as one unit
            return [self._apply(self.params, r.scene.feats, p)
                    for r, p in zip(reqs, plans)]
        t0 = jax.tree_util.tree_structure(plans[0])
        for r, p in zip(reqs, plans):
            if jax.tree_util.tree_structure(p) != t0:
                raise RuntimeError(
                    f"scene {r.rid}: plan signature diverged from "
                    "the wave (tile-budget overflow?); raise "
                    "tile_margin in build_plan_spec")
        if self.family is not None:
            # admission guarantees a single-bucket wave; a mixed wave here
            # means the bucket hook was bypassed — fail before compiling a
            # stray signature
            caps = {r._bucket for r in reqs}
            if len(caps) != 1:
                raise RuntimeError(
                    f"wave mixes capacity buckets {sorted(caps)}; bucketed "
                    "serving admits one bucket per wave")
            feats = [jnp.asarray(p[2]) for p in payloads]
        else:
            feats = [jnp.asarray(p[3]) if p[0] == "stream"
                     else r.scene.feats
                     for r, p in zip(reqs, payloads)]
        s_infos = [r.plan_info for r in reqs
                   if isinstance(r, StreamFrameRequest)]
        if s_infos:
            for mode in ("reused", "patched", "rebuilt"):
                stats.notes[f"stream_{mode}"] = sum(
                    1 for i in s_infos if i["mode"] == mode)
            stats.notes["stream_overlap"] = float(
                sum(i["overlap"] for i in s_infos) / len(s_infos))
            stats.notes["stream_plan_ms"] = float(
                sum(i["plan_ms"] for i in s_infos))
        while len(plans) < self.batch:  # pad the wave to fixed batch
            plans.append(plans[0])
            feats.append(jnp.zeros_like(feats[0]))
        return self._apply(self.params, feats, plans)

    def _drain_stage(self, reqs: list[SceneRequest], logits) -> None:
        if isinstance(logits, list):  # sharded mode: per-scene handles
            logits = np.stack([np.asarray(h) for h in logits])
        else:
            logits = np.asarray(logits)
        for i, r in enumerate(reqs):
            if isinstance(r, StreamFrameRequest):
                # scatter the stream's canonical rows back to the
                # caller's row positions (inactive rows stay zero-logit)
                fr = r._frame_rows
                out = np.zeros((r.scene.capacity, logits.shape[-1]),
                               logits.dtype)
                act = fr >= 0
                out[act] = logits[i][fr[act]]
                r.logits = out
            elif self.family is not None:
                # scatter compacted-bucket rows back to the request's
                # original row positions (padding rows stay zero-logit)
                idx = r._active_idx
                out = np.zeros((r.scene.capacity, logits.shape[-1]),
                               logits.dtype)
                out[idx] = logits[i][: len(idx)]
                r.logits = out
            else:
                r.logits = logits[i]
            r.pred = r.logits.argmax(-1)
            r.done = True
        if self._breakers is not None:
            # a drained wave is a success for every backend it exercised:
            # closes HALF_OPEN probes and resets consecutive-failure counts
            for n in sorted({b for r in reqs
                             for b in getattr(r, "_backends", ())}):
                self._breakers.record_success(n)

    def _health_extra(self) -> dict:
        board = self._breakers
        return {"breakers": board.states() if board is not None else {}}
