"""Shared wave scheduler: the queueing/batching core of both engines.

AccSS3D's headline move is overlapping the offline pass (AdMAC metadata +
SOAR reordering + SPADE selection) with accelerator execution. Serving-side
that means a three-stage pipeline over request *waves* of up to ``batch``:

* **plan** — per-request host work (plan-cache builds, prompt packing) runs
  on a small thread pool, up to ``depth`` waves ahead of the device;
* **dispatch** — one non-blocking jitted call per wave (jax async dispatch:
  the host gets device handles back before the compute finishes);
* **drain** — result readback, which only blocks for wave *k−depth* while
  wave *k* is planning and wave *k−1* is executing.

``WaveScheduler`` owns the request deque, admission, completion plumbing
and per-wave timing; the engines plug in the three stage callbacks:

    plan(request) -> payload               # host-only, thread-safe
    dispatch(requests, payloads, stats) -> h  # enqueue device work, no block
    drain(requests, h) -> None             # block on h, fill request results

``stats`` is the wave's ``WaveStats``; dispatch may record engine-specific
observations in ``stats.notes`` (e.g. the sharded scene engine records the
per-shard plan builds and halo rows of each wave) — they ride along with
the timing rows in ``scheduler.stats``.

Admission is FIFO by default. Passing an :class:`AdmissionPolicy` (and/or a
``bucket_of`` compatibility hook) turns on *continuous batching with
SLO-aware admission* — the vLLM-style idea transplanted onto scene waves:

* each wave is filled greedily from the most urgent **compatible** queued
  requests (same ``bucket_of`` key — e.g. the scene engine's capacity
  bucket), so a straggler at the head of the queue is preempted to a later
  wave instead of head-of-line blocking everything behind it;
* urgency is strict ``priority`` first, then weighted per-tenant fairness
  (stride scheduling over ``tenant_weights`` — a one-tenant flood cannot
  starve the others), then earliest deadline, then arrival order;
* requests whose ``deadline_ms`` has already expired are **shed** at
  admission time — surfaced on ``scheduler.shed`` with ``status="shed"``
  and a ``shed_reason``, never silently dropped — and ``max_queue``
  bounds the queue with explicit overload shedding at submit time
  (backpressure instead of unbounded buffering).

``sync=True`` degenerates to the classic blocking wave loop (same stages,
run back-to-back on the caller's thread) — numerics are identical in both
modes because the stages are *and* admission is: both modes admit from the
same queue state with the same policy, so the same admitted wave order
produces bitwise-identical results. Any stage exception re-queues every
admitted but uncompleted request at the front of the queue (in-flight
device waves are drained first), so a poisoned wave neither deadlocks the
pipeline nor drops requests.

**Failure containment.** With ``AdmissionPolicy.max_retries > 0`` the
scheduler *contains* stage failures instead of propagating them:

* a failed multi-request wave is **bisected** — every member's wave cap is
  halved and the wave re-queued, so within ``log2(batch)`` rounds a single
  poisoned request is isolated into a solo wave without charging its
  innocent wave-mates a retry;
* a failed **solo** wave charges the request one retry; past the budget it
  lands terminally on ``scheduler.failed`` with ``status="failed"`` /
  ``shed_reason="error"`` (counted by ``slo_stats()`` under
  ``shed_by_reason["error"]``), otherwise it backs off exponentially
  (``retry_backoff_ms * 2**(n-1)``) before re-admission;
* ``stage_timeout_s`` arms a watchdog on the plan and dispatch stages —
  a hung stage raises :class:`StageTimeout`, which is contained like any
  other stage error;
* injected :class:`~repro.serving.faults.WorkerDeath` (a BaseException,
  simulating a dying worker thread) is contained too; real
  ``KeyboardInterrupt``/``SystemExit`` still propagate.

With ``max_retries == 0`` (the default) the legacy requeue-and-raise
behavior is preserved exactly.

Per-wave ``WaveStats`` make the overlap *and* the admission measurable:
``plan_ms`` is the host plan work (summed over requests), ``plan_span_ms``
its wall-clock span, ``plan_wait_ms`` the span remainder the dispatcher
actually had to wait for, ``overlap_frac = 1 - wait/span`` the fraction
hidden behind device execution (0 in sync mode by construction);
``queue_depth`` / ``bucket`` / ``fill_frac`` / ``n_shed`` describe what
admission saw and decided. ``slo_stats()`` aggregates the per-request
view: p50/p99 latency, deadline goodput, shed counts.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from collections.abc import Callable, Mapping, Sequence
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from dataclasses import dataclass, field

from repro.analysis.runtime import ordered_lock
from repro.serving.faults import WorkerDeath

# request lifecycle states (mirrored by serving.api.ServeRequest.status)
QUEUED = "queued"
RUNNING = "running"
COMPLETED = "completed"
SHED = "shed"
FAILED = "failed"


class StageTimeout(RuntimeError):
    """A plan/dispatch stage exceeded ``AdmissionPolicy.stage_timeout_s``."""


def overlap_fraction(plan_span_ms: float, plan_wait_ms: float) -> float:
    """Fraction of the plan stage's wall-clock span hidden behind device
    execution. The span (first build start -> last build end), not the sum
    of per-thread build times, is the denominator, so planner-thread
    parallelism within a wave doesn't masquerade as pipeline overlap."""
    if plan_span_ms <= 0.0:
        return 0.0
    return max(0.0, min(1.0, 1.0 - plan_wait_ms / plan_span_ms))


@dataclass(frozen=True)
class AdmissionPolicy:
    """SLO-aware admission knobs for :class:`WaveScheduler`.

    ``max_queue`` is the backpressure bound: a submit beyond it is shed
    immediately with ``shed_reason="overload"`` (the caller gets the
    request back with ``status="shed"``, never a silent drop).
    ``shed_expired`` sheds requests whose ``submit_ts + deadline_ms`` has
    passed at admission time with ``shed_reason="deadline"``.
    ``tenant_weights`` drive stride-scheduled weighted fairness between
    tenants (missing tenants get ``default_weight``); a tenant with twice
    the weight gets twice the admitted share under contention.

    ``max_retries`` caps how many times a *solo* failed wave is retried
    before the request fails terminally (``status="failed"``,
    ``shed_reason="error"``); 0 (the default) preserves the legacy
    requeue-and-raise behavior. ``retry_backoff_ms`` is the base of the
    exponential backoff between retries. ``stage_timeout_s`` arms a
    watchdog on the plan and dispatch stages (None disables it).
    """

    max_queue: int | None = None
    shed_expired: bool = True
    tenant_weights: Mapping[str, float] | None = None
    default_weight: float = 1.0
    max_retries: int = 0
    retry_backoff_ms: float = 10.0
    stage_timeout_s: float | None = None

    def weight(self, tenant: str) -> float:
        w = (self.tenant_weights or {}).get(tenant, self.default_weight)
        return max(float(w), 1e-9)


@dataclass
class WaveStats:
    """Timing of one wave through the plan/dispatch/drain stages (ms),
    plus what admission saw when it formed the wave."""

    wave: int
    rids: tuple
    sync: bool
    plan_ms: float = 0.0       # host plan-stage work, summed over requests
    plan_span_ms: float = 0.0  # wall-clock span of this wave's plan builds
    plan_wait_ms: float = 0.0  # span remainder the dispatcher waited on
    dispatch_ms: float = 0.0   # host time enqueueing the jitted call
    device_ms: float = 0.0     # dispatch call -> results drained
    drain_ms: float = 0.0      # time blocked in readback
    queue_depth: int = 0       # queue length when admission ran
    n_shed: int = 0            # requests shed by this admission pass
    bucket: object = None      # bucket_of key the wave was filled from
    fill_frac: float = 1.0     # admitted / batch (padding slots are waste)
    #: engine-specific observations the dispatch stage records (e.g. the
    #: sharded scene engine's per-shard plan builds / halo rows)
    notes: dict = field(default_factory=dict)

    @property
    def overlap_frac(self) -> float:
        """Fraction of plan wall-clock hidden behind device execution."""
        return overlap_fraction(self.plan_span_ms, self.plan_wait_ms)


def _now_ms() -> float:
    return time.perf_counter() * 1e3


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Linear-interpolated percentile of an ascending list (numpy-free so
    the scheduler core stays dependency-light)."""
    if not sorted_vals:
        return 0.0
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = (len(sorted_vals) - 1) * q
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


class WaveScheduler:
    """Wave admission + async pipeline shared by the LM and 3D engines."""

    def __init__(
        self,
        *,
        batch: int,
        plan: Callable,
        dispatch: Callable,
        drain: Callable,
        sync: bool = True,
        depth: int = 2,
        planner_threads: int = 2,
        policy: AdmissionPolicy | None = None,
        bucket_of: Callable | None = None,
        on_shed: Callable | None = None,
        on_idle: Callable | None = None,
        faults=None,
        on_wave_error: Callable | None = None,
    ):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if planner_threads < 1:
            raise ValueError(
                f"planner_threads must be >= 1, got {planner_threads}")
        self.batch = batch
        self.sync = sync
        self.depth = depth
        self.planner_threads = planner_threads
        self.policy = policy
        self.bucket_of = bucket_of
        #: optional observer called on every shed request (e.g. the scene
        #: engine unblocks a stream whose frame was shed mid-sequence)
        self.on_shed = on_shed
        #: optional idle-gap worker, called as ``on_idle(self)`` after a
        #: ``run()`` drains the queue — strictly between ticks, never on
        #: the serving hot path (the scene engine wires the autotune
        #: re-profiler here when the context opts in with a budget)
        self.on_idle = on_idle
        self.idle_ticks = 0
        #: optional FaultInjector (serving.faults) exercising the plan /
        #: dispatch / slow-wave / worker-death seams; None = zero cost
        self.faults = faults
        #: optional observer called as ``on_wave_error(exc, reqs, stage)``
        #: whenever a wave fails in contained mode (the scene engine feeds
        #: backend circuit breakers from here)
        self.on_wave_error = on_wave_error
        self._plan, self._dispatch, self._drain = plan, dispatch, drain
        self.queue: deque = deque()
        self.completed: list = []
        self.shed: list = []
        self.failed: list = []
        self.stats: list[WaveStats] = []
        self.retries_charged = 0   # total solo-wave retries granted
        self.wave_errors = 0       # total contained wave failures
        self.last_wave_ts: float | None = None  # monotonic, last _finish
        #: set by ServingBase.serve_forever: a resident thread owns run(),
        #: so RequestHandle.result() must wait instead of driving
        self.resident = False
        #: signals the resident serving thread that work arrived
        self._work = threading.Event()
        #: mode of the run in progress (stages may consult it to trade
        #: host syncs for pipelining); None outside ``run``
        self.running_sync: bool | None = None
        self._wave = 0
        self._seq = 0
        self._pool: ThreadPoolExecutor | None = None  # lazy, persists runs
        self._pool_lock = ordered_lock("scheduler.pool")
        self._idle = threading.Event()  # cleared while run() is on a thread
        self._idle.set()
        # stride-scheduling state: per-tenant virtual pass + global floor
        self._tenant_pass: dict[str, float] = {}
        self._vt = 0.0
        self._admit_info: dict = {}

    # -- queue plumbing ------------------------------------------------------

    @property
    def running(self) -> bool:
        """True while a ``run()`` is in progress on some thread."""
        return not self._idle.is_set()

    def enqueue(self, r, *, shed: str | None = None):
        """Admit one request into the queue: stamps ``submit_ts`` / ``seq``
        / ``status`` (on requests that carry them), applies the policy's
        backpressure bound, and returns the request. ``shed=`` lets a
        caller surface a request it already knows cannot be served (e.g.
        no capacity bucket fits) through the same shed plumbing."""
        self._stamp(r)
        if shed is not None:
            self.shed_request(r, shed)
            return r
        pol = self.policy
        if (pol is not None and pol.max_queue is not None
                and len(self.queue) >= pol.max_queue):
            self.shed_request(r, "overload")
            return r
        self.queue.append(r)
        self._work.set()
        return r

    def submit(self, reqs: Sequence) -> None:
        for r in reqs:
            self.enqueue(r)

    def __len__(self) -> int:
        return len(self.queue)

    def _stamp(self, r) -> None:
        """Give a request its arrival metadata; tolerate bare objects that
        don't carry the ServeRequest fields (legacy scheduler users)."""
        try:
            if getattr(r, "submit_ts", None) is None:
                r.submit_ts = _now_ms()
            if getattr(r, "seq", -1) < 0:
                r.seq = self._seq
                self._seq += 1
            if getattr(r, "_event", None) is None:
                r._event = threading.Event()
            r.status = QUEUED
        except (AttributeError, TypeError):
            pass

    def _set_status(self, r, status: str) -> None:
        try:
            r.status = status
        except (AttributeError, TypeError):
            return
        if status in (COMPLETED, SHED, FAILED):
            try:
                r.done_ts = _now_ms()
            except (AttributeError, TypeError):
                pass
            ev = getattr(r, "_event", None)
            if ev is not None:
                ev.set()

    def shed_request(self, r, reason: str) -> None:
        """Shed ``r`` with ``shed_reason=reason``: the request is surfaced
        on ``self.shed`` (and its completion event fires) — load shedding
        is explicit, never a silent drop."""
        try:
            r.shed_reason = reason
        except (AttributeError, TypeError):
            pass
        self._set_status(r, SHED)
        self.shed.append(r)
        if self.on_shed is not None:
            self.on_shed(r)

    def fail_request(self, r, exc) -> None:
        """Terminally fail ``r`` (retry budget exhausted): surfaced on
        ``self.failed`` with ``status="failed"`` / ``shed_reason="error"``
        and the causing exception on ``r.error``; the completion event
        fires so waiters wake (``RequestHandle.result()`` raises
        ``RequestFailedError``)."""
        try:
            r.error = exc
            r.shed_reason = "error"
        except (AttributeError, TypeError):
            pass
        self._set_status(r, FAILED)
        self.failed.append(r)
        if self.on_shed is not None:
            self.on_shed(r)

    @staticmethod
    def _expired(r, now: float) -> bool:
        deadline = getattr(r, "deadline_ms", None)
        submit_ts = getattr(r, "submit_ts", None)
        return (deadline is not None and submit_ts is not None
                and now > submit_ts + deadline)

    def _admit_key(self, r):
        """Urgency ordering: strict priority, then weighted tenant
        fairness, then earliest deadline, then arrival order."""
        deadline = getattr(r, "deadline_ms", None)
        submit_ts = getattr(r, "submit_ts", None) or 0.0
        expires = (submit_ts + deadline) if deadline is not None \
            else float("inf")
        tenant = getattr(r, "tenant", "default")
        return (-getattr(r, "priority", 0),
                self._tenant_pass.get(tenant, self._vt),
                expires, getattr(r, "seq", 0))

    def _charge_tenant(self, r) -> None:
        pol = self.policy
        if pol is None:
            return
        tenant = getattr(r, "tenant", "default")
        p = self._tenant_pass.get(tenant, self._vt)
        self._tenant_pass[tenant] = p + 1.0 / pol.weight(tenant)
        self._vt = max(self._vt, p)

    @staticmethod
    def _stream_heads(avail: list) -> list:
        """Restrict candidates to each stream's earliest queued frame.

        Stream requests (carrying ``_stream_key`` / ``_stream_frame``) are
        order-dependent: frame *t+1*'s incremental plan patches frame
        *t*'s, so admitting frames out of order would stall the plan stage
        on a frame that hasn't been planned yet. Non-stream requests pass
        through untouched, and the policy's urgency ordering still picks
        *between* streams — this only pins the order *within* one."""
        heads: dict = {}
        for r in avail:
            k = getattr(r, "_stream_key", None)
            if k is None:
                continue
            f = getattr(r, "_stream_frame", 0)
            if k not in heads or f < heads[k]:
                heads[k] = f
        if not heads:
            return avail
        return [r for r in avail
                if getattr(r, "_stream_key", None) is None
                or getattr(r, "_stream_frame", 0) == heads[r._stream_key]]

    def _admit(self) -> list:
        """Form the next wave. FIFO without a policy/bucket hook; with one,
        greedy continuous batching: shed expired requests, then fill from
        the most urgent compatible (same-bucket) candidates, preempting
        stragglers to later waves (stream requests are additionally held
        to per-stream FIFO frame order). May return ``[]`` when shedding
        emptied the queue — the caller skips the wave without a
        dispatch."""
        depth0 = len(self.queue)
        if self.policy is None and self.bucket_of is None:
            reqs = [self.queue.popleft()
                    for _ in range(min(self.batch, len(self.queue)))]
            for r in reqs:
                self._set_status(r, RUNNING)
            self._admit_info = dict(queue_depth=depth0, n_shed=0,
                                    bucket=None, n_admitted=len(reqs))
            return reqs
        now = _now_ms()
        n_shed = 0
        keep: list = []     # survivors, original queue order
        pending: list = []  # survivors that are also ready (not backing off)
        next_ready: float | None = None
        for r in self.queue:
            if (self.policy is not None and self.policy.shed_expired
                    and self._expired(r, now)):
                self.shed_request(r, "deadline")
                n_shed += 1
                continue
            keep.append(r)
            nb = getattr(r, "_not_before", None)
            if nb is not None and nb > now:
                # retry backoff: stays queued but is not a candidate yet
                next_ready = nb if next_ready is None else min(next_ready, nb)
            else:
                pending.append(r)
        admitted: list = []
        bucket = None
        limit = self.batch
        avail = list(pending)
        while avail and len(admitted) < limit:
            # bisection wave caps: a request whose cap is already filled
            # waits for a later (smaller) wave
            cands = [r for r in self._stream_heads(avail)
                     if (getattr(r, "_wave_cap", None) or self.batch)
                     > len(admitted)]
            if not cands:
                break
            best = min(cands, key=self._admit_key)
            if not admitted and self.bucket_of is not None:
                # first pick fixes the wave's signature bucket; everything
                # incompatible waits for a later wave instead of blocking
                bucket = self.bucket_of(best)
                avail = [r for r in avail
                         if self.bucket_of(r) == bucket]
            limit = min(limit, getattr(best, "_wave_cap", None) or self.batch)
            admitted.append(best)
            avail.remove(best)
            self._charge_tenant(best)
            self._set_status(best, RUNNING)
        admitted_ids = {id(r) for r in admitted}
        self.queue.clear()
        self.queue.extend(r for r in keep if id(r) not in admitted_ids)
        self._admit_info = dict(queue_depth=depth0, n_shed=n_shed,
                                bucket=bucket, n_admitted=len(admitted),
                                next_ready_ms=next_ready)
        return admitted

    def _requeue(self, waves: list[list]) -> None:
        """Put admitted-but-uncompleted waves back at the queue front."""
        pending = [r for wave in waves for r in wave]
        for r in pending:
            self._set_status(r, QUEUED)
        self.queue.extendleft(reversed(pending))
        if pending:
            self._work.set()

    # -- failure containment -------------------------------------------------

    @property
    def _contained(self) -> bool:
        """True when stage failures are handled in-loop (retry budgets,
        bisection) instead of the legacy requeue-and-raise."""
        pol = self.policy
        return pol is not None and pol.max_retries > 0

    @staticmethod
    def _containable(exc) -> bool:
        """Which exceptions containment may swallow: every ``Exception``
        plus the injected ``WorkerDeath`` BaseException — but never a real
        ``KeyboardInterrupt`` / ``SystemExit``."""
        return isinstance(exc, (Exception, WorkerDeath))

    def _handle_wave_failure(self, reqs: list, exc, stage: str) -> None:
        """Contained-mode response to a failed wave: bisect multi-request
        waves (halve every member's wave cap, requeue), charge solo waves
        a retry with exponential backoff, and fail terminally past the
        budget. Innocent wave-mates are never charged a retry — only a
        solo failure is attributable to its request."""
        self.wave_errors += 1
        if self.on_wave_error is not None:
            try:
                self.on_wave_error(exc, reqs, stage)
            except Exception:
                pass  # observers must not take down containment
        if len(reqs) > 1:
            for r in reqs:
                cap = getattr(r, "_wave_cap", None) or self.batch
                try:
                    r._wave_cap = max(1, cap // 2)
                except (AttributeError, TypeError):
                    pass
            self._requeue([reqs])
            return
        r = reqs[0]
        n = getattr(r, "retries", 0) + 1
        try:
            r.retries = n
            r.error = exc
        except (AttributeError, TypeError):
            pass
        self.retries_charged += 1
        pol = self.policy
        if n > pol.max_retries:
            self.fail_request(r, exc)
            return
        backoff = pol.retry_backoff_ms * (2.0 ** (n - 1))
        try:
            r._not_before = _now_ms() + backoff
        except (AttributeError, TypeError):
            pass
        self._requeue([reqs])

    def _idle_wait(self) -> None:
        """Sleep briefly when the queue holds only backing-off requests,
        so the run loop doesn't spin while waiting out a retry backoff."""
        ready = self._admit_info.get("next_ready_ms")
        delay_s = 0.001 if ready is None \
            else max(0.0, (ready - _now_ms()) / 1e3)
        time.sleep(min(delay_s, 0.05) + 1e-4)

    def _with_timeout(self, fn, args, budget_s, stage: str):
        """Watchdog: run ``fn(*args)`` bounded by ``budget_s``. The stage
        runs on a daemon thread so a genuine hang is abandoned (the thread
        leaks until it returns — the price of a watchdog in-process) and
        :class:`StageTimeout` is raised for containment to handle."""
        if budget_s is None:
            return fn(*args)
        box: dict = {}

        def _target():
            try:
                box["result"] = fn(*args)
            except BaseException as e:  # noqa: BLE001 - re-raised below
                box["error"] = e

        t = threading.Thread(target=_target, daemon=True,
                             name=f"wave-watchdog-{stage}")
        t.start()
        t.join(budget_s)
        if t.is_alive():
            raise StageTimeout(
                f"{stage} stage exceeded {budget_s:.3f}s watchdog")
        if "error" in box:
            raise box["error"]
        return box["result"]

    def _new_stats(self, reqs: list, sync: bool) -> WaveStats:
        info = self._admit_info
        st = WaveStats(self._wave, tuple(getattr(r, "rid", None)
                                         for r in reqs), sync,
                       queue_depth=info.get("queue_depth", len(reqs)),
                       n_shed=info.get("n_shed", 0),
                       bucket=info.get("bucket"),
                       fill_frac=len(reqs) / self.batch)
        self._wave += 1
        return st

    def _finish(self, reqs: list, st: WaveStats) -> None:
        self.stats.append(st)
        self.last_wave_ts = time.monotonic()
        for r in reqs:
            self._set_status(r, COMPLETED)
        self.completed.extend(reqs)

    def timings(self) -> dict:
        """Aggregate pipeline timings over every wave served so far."""
        span = sum(s.plan_span_ms for s in self.stats)
        wait = sum(s.plan_wait_ms for s in self.stats)
        return {
            "waves": len(self.stats),
            "plan_ms": sum(s.plan_ms for s in self.stats),
            "plan_span_ms": span,
            "plan_wait_ms": wait,
            "device_ms": sum(s.device_ms for s in self.stats),
            "drain_ms": sum(s.drain_ms for s in self.stats),
            "overlap_frac": overlap_fraction(span, wait),
        }

    def slo_stats(self) -> dict:
        """Per-request SLO view over everything served (or shed) so far:
        p50/p99 end-to-end latency (submit -> drain, ms), deadline goodput
        (completions that met their deadline, as a fraction of everything
        submitted and as completions/s), and shed counts by reason."""
        lats = []
        met = 0
        for r in self.completed:
            t0 = getattr(r, "submit_ts", None)
            t1 = getattr(r, "done_ts", None)
            if t0 is None or t1 is None:
                continue
            lats.append(t1 - t0)
            deadline = getattr(r, "deadline_ms", None)
            if deadline is None or (t1 - t0) <= deadline:
                met += 1
        lats.sort()
        shed_by_reason: dict[str, int] = {}
        for r in list(self.shed) + list(self.failed):
            reason = getattr(r, "shed_reason", None) or "unknown"
            shed_by_reason[reason] = shed_by_reason.get(reason, 0) + 1
        n_total = len(self.completed) + len(self.shed) + len(self.failed)
        ts = [getattr(r, "submit_ts", None) for r in self.completed]
        te = [getattr(r, "done_ts", None) for r in self.completed]
        ts = [t for t in ts if t is not None]
        te = [t for t in te if t is not None]
        wall_s = (max(te) - min(ts)) / 1e3 if ts and te else 0.0
        return {
            "n_completed": len(self.completed),
            "n_shed": len(self.shed),
            "n_failed": len(self.failed),
            "n_retries": self.retries_charged,
            "wave_errors": self.wave_errors,
            "shed_by_reason": shed_by_reason,
            "p50_ms": _percentile(lats, 0.50),
            "p99_ms": _percentile(lats, 0.99),
            "goodput_frac": met / n_total if n_total else 0.0,
            "goodput_rps": met / wall_s if wall_s > 0 else 0.0,
        }

    # -- execution -----------------------------------------------------------

    def run(self, sync: bool | None = None,
            max_waves: int | None = None) -> list:
        """Serve the queue (to empty, or at most ``max_waves`` admitted
        waves — the tick-driven mode arrival simulators use); returns the
        completed-request list. Only one ``run`` may be active at a time.

        When the queue drains completely, ``on_idle(self)`` (if set) runs
        *after* the pipeline is done — the idle gap between ticks, where
        background work (autotune re-profiling) can spend its budget
        without touching a serving wave."""
        if not self._idle.is_set():
            raise RuntimeError("run() already in progress on another thread")
        self._idle.clear()
        self.running_sync = self.sync if sync is None else sync
        try:
            if self.running_sync:
                self._run_sync(max_waves)
            else:
                self._run_async(max_waves)
        finally:
            self.running_sync = None
            self._idle.set()
        if self.on_idle is not None and not self.queue:
            self.idle_ticks += 1
            self.on_idle(self)
        return self.completed

    def _timed_plan(self, req):
        t0 = _now_ms()
        inj = self.faults
        if inj is not None:
            rid = getattr(req, "rid", None)
            inj.maybe_fail("worker_death", rid=rid)
            inj.maybe_fail("plan", rid=rid)
        payload = self._plan(req)
        return payload, t0, _now_ms()

    def _dispatch_with_faults(self, reqs, payloads, st):
        inj = self.faults
        if inj is not None:
            stall = inj.stall_ms(key=("wave", st.wave))
            if stall > 0:
                time.sleep(stall / 1e3)
            inj.maybe_fail("dispatch", key=("wave", st.wave))
        return self._dispatch(reqs, payloads, st)

    def _run_sync(self, max_waves: int | None = None) -> None:
        waves_left = max_waves if max_waves is not None else float("inf")
        budget = self.policy.stage_timeout_s if self.policy is not None \
            else None
        while self.queue and waves_left > 0:
            reqs = self._admit()
            if not reqs:  # everything shed, or every request backing off
                if self.queue:
                    self._idle_wait()
                continue
            waves_left -= 1
            st = self._new_stats(reqs, sync=True)
            stage = "plan"
            try:
                payloads = []
                for r in reqs:
                    payload, t0, t1 = self._with_timeout(
                        self._timed_plan, (r,), budget, "plan")
                    payloads.append(payload)
                    st.plan_ms += t1 - t0
                st.plan_span_ms = st.plan_ms   # serial builds
                st.plan_wait_ms = st.plan_span_ms  # nothing hidden in sync
                stage = "dispatch"
                t_disp = _now_ms()
                handle = self._with_timeout(
                    self._dispatch_with_faults, (reqs, payloads, st),
                    budget, "dispatch")
                st.dispatch_ms = _now_ms() - t_disp
                stage = "drain"
                t_drain = _now_ms()
                self._drain(reqs, handle)
                st.drain_ms = _now_ms() - t_drain
                st.device_ms = _now_ms() - t_disp
            except BaseException as e:
                if self._contained and self._containable(e):
                    self._handle_wave_failure(reqs, e, stage)
                    continue
                self._requeue([reqs])
                raise
            self._finish(reqs, st)

    def _pool_or_start(self) -> ThreadPoolExecutor:
        # lazy and persistent: paced workloads call run() per arrival group
        # and should not pay thread churn every time
        with self._pool_lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.planner_threads,
                    thread_name_prefix="wave-planner")
            return self._pool

    def close(self) -> None:
        """Shut down the planner thread pool (idempotent; a later run()
        lazily recreates it). Waits for any in-flight ``run`` — and with
        it every planner-thread future — to drain first, so a close racing
        an async run can neither cancel its plan builds nor leave the pool
        half-down."""
        self._idle.wait()
        with self._pool_lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    @staticmethod
    def _settle(futs) -> None:
        """Cancel-or-wait every future so no planner thread is still
        mutating a request we are about to requeue; stage errors of an
        already-failed wave are deliberately swallowed here."""
        for f in futs:
            if f.cancel():
                continue
            try:
                f.result()
            except BaseException:  # noqa: BLE001 - wave already handled
                pass

    def _run_async(self, max_waves: int | None = None) -> None:
        pool = self._pool_or_start()
        waves_left = max_waves if max_waves is not None else float("inf")
        contained = self._contained
        budget = self.policy.stage_timeout_s if self.policy is not None \
            else None
        planned: deque = deque()   # (reqs, stats, [plan futures])
        inflight: deque = deque()  # (reqs, stats, handle, t_dispatched)
        failed: list = []          # requests of the wave that blew up
        futs: list = []            # plan futures of the wave being gathered
        try:
            while (self.queue and waves_left > 0) or planned or inflight:
                progressed = False
                # keep up to `depth` waves in the plan stage
                while (self.queue and waves_left > 0
                       and len(planned) < self.depth):
                    reqs = self._admit()
                    if not reqs:
                        # shedding emptied the queue, or every queued
                        # request is backing off — don't spin the fill loop
                        break
                    progressed = True
                    waves_left -= 1
                    failed = reqs  # cover the gap until safely planned
                    st = self._new_stats(reqs, sync=False)
                    wave_futs = [pool.submit(self._timed_plan, r)
                                 for r in reqs]
                    planned.append((reqs, st, wave_futs))
                    failed = []
                # dispatch the oldest planned wave (waits only for the
                # *remaining* plan time — the hidden part ran while the
                # previous wave was executing on the device)
                if planned:
                    progressed = True
                    reqs, st, futs = planned.popleft()
                    failed = reqs
                    stage = "plan"
                    try:
                        t_gather = _now_ms()
                        payloads, starts, ends = [], [], []
                        for f in futs:
                            try:
                                payload, t0, t1 = f.result(timeout=budget)
                            except (_FutureTimeout, TimeoutError) as te:
                                raise StageTimeout(
                                    f"plan stage exceeded {budget:.3f}s "
                                    f"watchdog") from te
                            payloads.append(payload)
                            st.plan_ms += t1 - t0
                            starts.append(t0)
                            ends.append(t1)
                        if ends:
                            st.plan_span_ms = max(ends) - min(starts)
                        st.plan_wait_ms = _now_ms() - t_gather
                        stage = "dispatch"
                        t_disp = _now_ms()
                        handle = self._with_timeout(
                            self._dispatch_with_faults, (reqs, payloads, st),
                            budget, "dispatch")
                        st.dispatch_ms = _now_ms() - t_disp
                        inflight.append((reqs, st, handle, t_disp))
                    except BaseException as e:
                        if not (contained and self._containable(e)):
                            raise
                        self._settle(futs)
                        self._handle_wave_failure(reqs, e, stage)
                    failed = []
                    futs = []
                # drain once the device pipeline is `depth` deep, or
                # unconditionally when there is nothing left to feed it
                while inflight and (
                        len(inflight) >= self.depth
                        or not ((self.queue and waves_left > 0) or planned)):
                    progressed = True
                    item = inflight.popleft()
                    failed = item[0]
                    try:
                        self._drain_one(item)
                    except BaseException as e:
                        if not (contained and self._containable(e)):
                            raise
                        self._handle_wave_failure(item[0], e, "drain")
                    failed = []
                if not progressed:
                    # queue holds only backing-off requests: wait out the
                    # shortest backoff instead of spinning
                    self._idle_wait()
        except BaseException:
            # salvage device work already in flight, then put every
            # unfinished request back so nothing is dropped; cancel queued
            # plan builds (of the failed wave and the lookahead waves) so
            # the exception isn't stalled behind them
            for f in futs:
                f.cancel()
            leftovers = []
            for item in inflight:
                try:
                    self._drain_one(item)
                except BaseException:
                    leftovers.append(item[0])
            leftovers.append(failed)
            for reqs, _, wave_futs in planned:
                for f in wave_futs:
                    f.cancel()
                leftovers.append(reqs)
            self._requeue(leftovers)
            raise

    def _drain_one(self, item) -> None:
        reqs, st, handle, t_disp = item
        t0 = _now_ms()
        self._drain(reqs, handle)
        t1 = _now_ms()
        st.drain_ms = t1 - t0
        st.device_ms = t1 - t_disp
        self._finish(reqs, st)
