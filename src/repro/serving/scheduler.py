"""Shared wave scheduler: the queueing/batching core of both engines.

AccSS3D's headline move is overlapping the offline pass (AdMAC metadata +
SOAR reordering + SPADE selection) with accelerator execution. Serving-side
that means a three-stage pipeline over request *waves* of up to ``batch``:

* **plan** — per-request host work (plan-cache builds, prompt packing) runs
  on a small thread pool, up to ``depth`` waves ahead of the device;
* **dispatch** — one non-blocking jitted call per wave (jax async dispatch:
  the host gets device handles back before the compute finishes);
* **drain** — result readback, which only blocks for wave *k−depth* while
  wave *k* is planning and wave *k−1* is executing.

``WaveScheduler`` owns the request deque, admission, completion plumbing
and per-wave timing; the engines plug in the three stage callbacks:

    plan(request) -> payload               # host-only, thread-safe
    dispatch(requests, payloads, stats) -> h  # enqueue device work, no block
    drain(requests, h) -> None             # block on h, fill request results

``stats`` is the wave's ``WaveStats``; dispatch may record engine-specific
observations in ``stats.notes`` (e.g. the sharded scene engine records the
per-shard plan builds and halo rows of each wave) — they ride along with
the timing rows in ``scheduler.stats``.

``sync=True`` degenerates to the classic blocking wave loop (same stages,
run back-to-back on the caller's thread) — numerics are identical in both
modes because the stages are. Any stage exception re-queues every admitted
but uncompleted request at the front of the queue (in-flight device waves
are drained first), so a poisoned wave neither deadlocks the pipeline nor
drops requests.

Per-wave ``WaveStats`` make the overlap measurable: ``plan_ms`` is the host
plan work (summed over requests), ``plan_span_ms`` its wall-clock span,
``plan_wait_ms`` the span remainder the dispatcher actually had to wait
for, and ``overlap_frac = 1 - wait/span`` the fraction hidden behind
device execution (0 in sync mode by construction).
"""
from __future__ import annotations

import time
from collections import deque
from collections.abc import Callable, Sequence
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field


def overlap_fraction(plan_span_ms: float, plan_wait_ms: float) -> float:
    """Fraction of the plan stage's wall-clock span hidden behind device
    execution. The span (first build start -> last build end), not the sum
    of per-thread build times, is the denominator, so planner-thread
    parallelism within a wave doesn't masquerade as pipeline overlap."""
    if plan_span_ms <= 0.0:
        return 0.0
    return max(0.0, min(1.0, 1.0 - plan_wait_ms / plan_span_ms))


@dataclass
class WaveStats:
    """Timing of one wave through the plan/dispatch/drain stages (ms)."""

    wave: int
    rids: tuple
    sync: bool
    plan_ms: float = 0.0       # host plan-stage work, summed over requests
    plan_span_ms: float = 0.0  # wall-clock span of this wave's plan builds
    plan_wait_ms: float = 0.0  # span remainder the dispatcher waited on
    dispatch_ms: float = 0.0   # host time enqueueing the jitted call
    device_ms: float = 0.0     # dispatch call -> results drained
    drain_ms: float = 0.0      # time blocked in readback
    #: engine-specific observations the dispatch stage records (e.g. the
    #: sharded scene engine's per-shard plan builds / halo rows)
    notes: dict = field(default_factory=dict)

    @property
    def overlap_frac(self) -> float:
        """Fraction of plan wall-clock hidden behind device execution."""
        return overlap_fraction(self.plan_span_ms, self.plan_wait_ms)


def _now_ms() -> float:
    return time.perf_counter() * 1e3


class WaveScheduler:
    """Wave admission + async pipeline shared by the LM and 3D engines."""

    def __init__(
        self,
        *,
        batch: int,
        plan: Callable,
        dispatch: Callable,
        drain: Callable,
        sync: bool = True,
        depth: int = 2,
        planner_threads: int = 2,
    ):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if planner_threads < 1:
            raise ValueError(
                f"planner_threads must be >= 1, got {planner_threads}")
        self.batch = batch
        self.sync = sync
        self.depth = depth
        self.planner_threads = planner_threads
        self._plan, self._dispatch, self._drain = plan, dispatch, drain
        self.queue: deque = deque()
        self.completed: list = []
        self.stats: list[WaveStats] = []
        #: mode of the run in progress (stages may consult it to trade
        #: host syncs for pipelining); None outside ``run``
        self.running_sync: bool | None = None
        self._wave = 0
        self._pool: ThreadPoolExecutor | None = None  # lazy, persists runs

    # -- queue plumbing ------------------------------------------------------

    def submit(self, reqs: Sequence) -> None:
        self.queue.extend(reqs)

    def __len__(self) -> int:
        return len(self.queue)

    def _admit(self) -> list:
        return [self.queue.popleft()
                for _ in range(min(self.batch, len(self.queue)))]

    def _requeue(self, waves: list[list]) -> None:
        """Put admitted-but-uncompleted waves back at the queue front."""
        pending = [r for wave in waves for r in wave]
        self.queue.extendleft(reversed(pending))

    def _new_stats(self, reqs: list, sync: bool) -> WaveStats:
        st = WaveStats(self._wave, tuple(getattr(r, "rid", None)
                                         for r in reqs), sync)
        self._wave += 1
        return st

    def _finish(self, reqs: list, st: WaveStats) -> None:
        self.stats.append(st)
        self.completed.extend(reqs)

    def timings(self) -> dict:
        """Aggregate pipeline timings over every wave served so far."""
        span = sum(s.plan_span_ms for s in self.stats)
        wait = sum(s.plan_wait_ms for s in self.stats)
        return {
            "waves": len(self.stats),
            "plan_ms": sum(s.plan_ms for s in self.stats),
            "plan_span_ms": span,
            "plan_wait_ms": wait,
            "device_ms": sum(s.device_ms for s in self.stats),
            "drain_ms": sum(s.drain_ms for s in self.stats),
            "overlap_frac": overlap_fraction(span, wait),
        }

    # -- execution -----------------------------------------------------------

    def run(self, sync: bool | None = None) -> list:
        """Serve the queue to empty; returns the completed-request list."""
        self.running_sync = self.sync if sync is None else sync
        try:
            if self.running_sync:
                self._run_sync()
            else:
                self._run_async()
        finally:
            self.running_sync = None
        return self.completed

    def _timed_plan(self, req):
        t0 = _now_ms()
        payload = self._plan(req)
        return payload, t0, _now_ms()

    def _run_sync(self) -> None:
        while self.queue:
            reqs = self._admit()
            st = self._new_stats(reqs, sync=True)
            try:
                payloads = []
                for r in reqs:
                    payload, t0, t1 = self._timed_plan(r)
                    payloads.append(payload)
                    st.plan_ms += t1 - t0
                st.plan_span_ms = st.plan_ms   # serial builds
                st.plan_wait_ms = st.plan_span_ms  # nothing hidden in sync
                t_disp = _now_ms()
                handle = self._dispatch(reqs, payloads, st)
                st.dispatch_ms = _now_ms() - t_disp
                t_drain = _now_ms()
                self._drain(reqs, handle)
                st.drain_ms = _now_ms() - t_drain
                st.device_ms = _now_ms() - t_disp
            except BaseException:
                self._requeue([reqs])
                raise
            self._finish(reqs, st)

    def _pool_or_start(self) -> ThreadPoolExecutor:
        # lazy and persistent: paced workloads call run() per arrival group
        # and should not pay thread churn every time
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.planner_threads,
                thread_name_prefix="wave-planner")
        return self._pool

    def close(self) -> None:
        """Shut down the planner thread pool (idempotent; a later run()
        lazily recreates it)."""
        if self._pool is not None:
            self._pool.shutdown(wait=True, cancel_futures=True)
            self._pool = None

    def _run_async(self) -> None:
        pool = self._pool_or_start()
        planned: deque = deque()   # (reqs, stats, [plan futures])
        inflight: deque = deque()  # (reqs, stats, handle, t_dispatched)
        failed: list = []          # requests of the wave that blew up
        futs: list = []            # plan futures of the wave being gathered
        try:
            while self.queue or planned or inflight:
                # keep up to `depth` waves in the plan stage
                while self.queue and len(planned) < self.depth:
                    reqs = self._admit()
                    failed = reqs  # cover the gap until safely planned
                    st = self._new_stats(reqs, sync=False)
                    wave_futs = [pool.submit(self._timed_plan, r)
                                 for r in reqs]
                    planned.append((reqs, st, wave_futs))
                    failed = []
                # dispatch the oldest planned wave (waits only for the
                # *remaining* plan time — the hidden part ran while the
                # previous wave was executing on the device)
                if planned:
                    reqs, st, futs = planned.popleft()
                    failed = reqs
                    t_gather = _now_ms()
                    payloads, starts, ends = [], [], []
                    for f in futs:
                        payload, t0, t1 = f.result()
                        payloads.append(payload)
                        st.plan_ms += t1 - t0
                        starts.append(t0)
                        ends.append(t1)
                    if ends:
                        st.plan_span_ms = max(ends) - min(starts)
                    st.plan_wait_ms = _now_ms() - t_gather
                    t_disp = _now_ms()
                    handle = self._dispatch(reqs, payloads, st)
                    st.dispatch_ms = _now_ms() - t_disp
                    inflight.append((reqs, st, handle, t_disp))
                    failed = []
                    futs = []
                # drain once the device pipeline is `depth` deep, or
                # unconditionally when there is nothing left to feed it
                while inflight and (len(inflight) >= self.depth
                                    or not (self.queue or planned)):
                    item = inflight.popleft()
                    failed = item[0]
                    self._drain_one(item)
                    failed = []
        except BaseException:
            # salvage device work already in flight, then put every
            # unfinished request back so nothing is dropped; cancel queued
            # plan builds (of the failed wave and the lookahead waves) so
            # the exception isn't stalled behind them
            for f in futs:
                f.cancel()
            leftovers = []
            for item in inflight:
                try:
                    self._drain_one(item)
                except BaseException:
                    leftovers.append(item[0])
            leftovers.append(failed)
            for reqs, _, wave_futs in planned:
                for f in wave_futs:
                    f.cancel()
                leftovers.append(reqs)
            self._requeue(leftovers)
            raise

    def _drain_one(self, item) -> None:
        reqs, st, handle, t_disp = item
        t0 = _now_ms()
        self._drain(reqs, handle)
        t1 = _now_ms()
        st.drain_ms = t1 - t0
        st.device_ms = t1 - t_disp
        self._finish(reqs, st)
