"""Serving stack: the shared wave scheduler + the LM and 3D scene engines.

``serving.api`` is the one driver surface both engines share:
``ServeRequest`` (id/tenant/priority/deadline SLO envelope),
``ServingBase.submit() -> RequestHandle`` and ``serve()``, with
``AdmissionPolicy`` controlling priority/deadline ordering, weighted
tenant fairness and backpressure shedding.  ``serving.scheduler``'s
``WaveScheduler`` owns queueing, wave admission, the async
plan/dispatch/drain pipeline and per-wave timing; ``serving.engine``
(LM prefill+decode) and ``serving.scene_engine`` (batched sparse-conv
U-Net) plug their stage callbacks into it. The engine submodules are
imported lazily by callers to keep ``import repro.serving`` light.

``serving.faults`` is the deterministic fault-injection layer
(``FaultPlan``/``FaultInjector``); the scheduler, plan cache and backend
registry expose named seams it can fire, and the hardened runtime
(retry budgets, circuit breakers, ``serve_forever()``) contains
everything it can inject.
"""
from repro.serving.api import (
    COMPLETED,
    FAILED,
    QUEUED,
    RUNNING,
    SHED,
    AdmissionPolicy,
    RequestFailedError,
    RequestHandle,
    RequestShedError,
    ServeRequest,
    ServingBase,
)
from repro.serving.faults import (
    FaultError,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    inject_faults,
)
from repro.serving.scheduler import StageTimeout, WaveScheduler, WaveStats

# scene-engine surface (incl. the streaming API) is re-exported lazily so
# `import repro.serving` stays light (no jax import on the fast path)
_SCENE_ENGINE_NAMES = (
    "SceneEngine", "SceneRequest", "StreamFrameRequest", "StreamHandle")


def __getattr__(name: str):
    if name in _SCENE_ENGINE_NAMES:
        from repro.serving import scene_engine
        return getattr(scene_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "COMPLETED",
    "FAILED",
    "QUEUED",
    "RUNNING",
    "SHED",
    "AdmissionPolicy",
    "FaultError",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "RequestFailedError",
    "RequestHandle",
    "RequestShedError",
    "SceneEngine",
    "SceneRequest",
    "ServeRequest",
    "ServingBase",
    "StageTimeout",
    "StreamFrameRequest",
    "StreamHandle",
    "WaveScheduler",
    "WaveStats",
    "inject_faults",
]
