"""Serving stack: the shared wave scheduler + the LM and 3D scene engines.

``serving.scheduler.WaveScheduler`` owns queueing, wave admission, the
async plan/dispatch/drain pipeline and per-wave timing; ``serving.engine``
(LM prefill+decode) and ``serving.scene_engine`` (batched sparse-conv
U-Net) plug their stage callbacks into it. The engine submodules are
imported lazily by callers to keep ``import repro.serving`` light.
"""
from repro.serving.scheduler import WaveScheduler, WaveStats

__all__ = ["WaveScheduler", "WaveStats"]
