"""One serving surface for both engines: requests, handles, engine base.

Pre-redesign, ``serving.scene_engine.SceneRequest`` and
``serving.engine.Request`` were parallel dataclasses with duplicated
``submit/run/queue/completed/wave_stats/timings/close`` surfaces on their
engines, and no way to express priority, deadline or tenant. This module
is the single surface both engines now share:

* :class:`ServeRequest` — the request base every payload subclass extends
  (``SceneRequest`` adds a scene, the LM ``Request`` a prompt). Carries
  the SLO fields admission schedules on: ``tenant``, ``priority``,
  ``deadline_ms``, plus the lifecycle ``status`` ∈ {``queued``,
  ``running``, ``completed``, ``shed``} and timestamps the scheduler
  stamps (``submit_ts`` at submit, ``done_ts`` at drain/shed).
* :class:`RequestHandle` — what ``submit()`` returns: a future-like view
  (``.done()``, ``.result(timeout=)``, ``.status``) instead of callers
  polling ``engine.completed``. ``result()`` drives the engine on the
  calling thread when nothing else is, or waits for the active run; a
  shed request raises :class:`RequestShedError` (shedding is surfaced,
  never silent).
* :class:`ServingBase` — the engine mixin owning the driver API: typed
  ``submit() -> RequestHandle``, ``serve()`` (pump the queue), a resident
  ``serve_forever()`` front door (background serving thread with graceful
  drain on ``close()`` and a ``health()`` liveness snapshot), stats
  plumbing, and the deprecated list-returning ``run()`` / ``.completed``
  shims the pre-handle call sites keep working through.

A request that exhausts its retry budget (``AdmissionPolicy.max_retries``)
ends in the terminal ``status="failed"`` / ``shed_reason="error"`` —
``result()`` raises :class:`RequestFailedError`, a subclass of
:class:`RequestShedError` so existing ``except RequestShedError`` callers
keep working unchanged.

Migration (the PR 2/5 playbook — old entry points warn, tests error on
uncaptured deprecations):

    completed = eng.run()          ->  handles = eng.submit(reqs)
    for r in eng.completed: ...        eng.serve()
                                       for h in handles: r = h.result()
"""
from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass, field

from repro.analysis.runtime import ordered_lock
from repro.serving.faults import WorkerDeath

from repro.serving.scheduler import (
    COMPLETED,
    FAILED,
    QUEUED,
    RUNNING,
    SHED,
    AdmissionPolicy,
    WaveScheduler,
    WaveStats,
)

__all__ = [
    "COMPLETED", "FAILED", "QUEUED", "RUNNING", "SHED",
    "AdmissionPolicy", "RequestFailedError", "RequestHandle",
    "RequestShedError", "ServeRequest", "ServingBase", "WaveScheduler",
    "WaveStats",
]


@dataclass
class ServeRequest:
    """Base serving request: identity + SLO fields + lifecycle state.

    Engines subclass this with their payload (scene, prompt, ...). The
    SLO fields are keyword-only so payload subclasses keep their natural
    positional signatures (``SceneRequest(rid, scene)``).

    ``priority`` is strict (higher = more urgent); ``deadline_ms`` is
    relative to ``submit_ts``; ``tenant`` feeds weighted fairness. All
    three are only acted on when the scheduler runs an
    :class:`~repro.serving.scheduler.AdmissionPolicy`.
    """

    rid: int
    tenant: str = field(default="default", kw_only=True)
    priority: int = field(default=0, kw_only=True)
    deadline_ms: float | None = field(default=None, kw_only=True)
    status: str = field(default=QUEUED, kw_only=True)
    shed_reason: str | None = field(default=None, kw_only=True)
    submit_ts: float | None = field(default=None, kw_only=True)
    done_ts: float | None = field(default=None, kw_only=True)
    seq: int = field(default=-1, kw_only=True)
    #: retries charged so far (solo-wave failures only; see scheduler)
    retries: int = field(default=0, kw_only=True)
    #: the exception that failed the request terminally (status="failed")
    #: or caused its most recent retry
    error: BaseException | None = field(
        default=None, kw_only=True, repr=False, compare=False)
    _event: threading.Event | None = field(
        default=None, kw_only=True, repr=False, compare=False)

    @property
    def latency_ms(self) -> float | None:
        """End-to-end submit -> drain latency, once completed/shed."""
        if self.submit_ts is None or self.done_ts is None:
            return None
        return self.done_ts - self.submit_ts


class RequestShedError(RuntimeError):
    """Raised by ``RequestHandle.result()`` for a shed request; carries
    the request (``.request``) with its ``shed_reason``."""

    def __init__(self, request: ServeRequest):
        self.request = request
        super().__init__(
            f"request {request.rid} was shed "
            f"({request.shed_reason or 'unknown'})")


class RequestFailedError(RequestShedError):
    """Raised by ``RequestHandle.result()`` for a terminally failed
    request (retry budget exhausted); subclasses
    :class:`RequestShedError` so pre-existing ``except RequestShedError``
    callers keep working. ``.request.error`` carries the last cause."""

    def __init__(self, request: ServeRequest):
        self.request = request
        cause = request.error
        RuntimeError.__init__(
            self,
            f"request {request.rid} failed after {request.retries} "
            f"retries ({type(cause).__name__ if cause else 'unknown'}: "
            f"{cause})")


class RequestHandle:
    """Future-like view of one submitted request."""

    __slots__ = ("request", "_scheduler")

    def __init__(self, request: ServeRequest, scheduler: WaveScheduler):
        self.request = request
        self._scheduler = scheduler

    @property
    def status(self) -> str:
        return self.request.status

    def done(self) -> bool:
        """True once the request reached a terminal state (completed,
        shed, or failed)."""
        return self.request.status in (COMPLETED, SHED, FAILED)

    def result(self, timeout: float | None = None) -> ServeRequest:
        """The fulfilled request (results filled in by the engine's drain
        stage). Drives the scheduler on the calling thread if no run (and
        no resident serving thread) is active; otherwise waits up to
        ``timeout`` seconds for the active run to complete it. Raises
        :class:`RequestShedError` if the request was shed,
        :class:`RequestFailedError` if it failed terminally,
        ``TimeoutError`` on timeout."""
        r = self.request
        if not self.done():
            if self._scheduler.running or self._scheduler.resident:
                ev = r._event
                if ev is None or not ev.wait(timeout):
                    raise TimeoutError(
                        f"request {r.rid} still {r.status} after "
                        f"{timeout}s")
            else:
                self._scheduler.run()
        if r.status == FAILED:
            raise RequestFailedError(r)
        if r.status == SHED:
            raise RequestShedError(r)
        if r.status != COMPLETED:
            raise TimeoutError(f"request {r.rid} still {r.status}")
        return r

    def __repr__(self) -> str:
        return (f"RequestHandle(rid={self.request.rid}, "
                f"status={self.request.status!r})")


#: guards resident-thread creation (ServingBase is a mixin with no
#: __init__, so per-instance state starts as class-attribute defaults)
_SERVE_LOCK = ordered_lock("serving.serve")


class ServingBase:
    """Driver surface shared by ``SceneEngine`` and the LM ``Engine``.

    Subclasses build ``self.scheduler`` (a :class:`WaveScheduler` wired
    with their plan/dispatch/drain stages) in ``__init__`` and may
    override :meth:`_prepare` to classify a request before admission
    (e.g. the scene engine's capacity-bucket assignment; returning a
    string sheds the request with that reason)."""

    scheduler: WaveScheduler
    # resident-serving state (class-attr defaults: ServingBase is a mixin
    # without an __init__; instances shadow these once serve_forever runs)
    _serve_thread: threading.Thread | None = None
    _serve_stop: threading.Event | None = None
    _draining: bool = False

    # -- submission ----------------------------------------------------------

    def _prepare(self, req: ServeRequest) -> str | None:
        """Pre-admission hook; return a shed reason to reject ``req``."""
        return None

    def submit(self, reqs):
        """Submit one request (or a sequence) for serving; returns a
        :class:`RequestHandle` per request (a single handle for a single
        request). Requests the policy sheds at submit time (backpressure,
        no compatible bucket) come back with ``status="shed"``."""
        single = isinstance(reqs, ServeRequest)
        rlist = [reqs] if single else list(reqs)
        handles = []
        for r in rlist:
            shed = "shutdown" if self._draining else self._prepare(r)
            self.scheduler.enqueue(r, shed=shed)
            handles.append(RequestHandle(r, self.scheduler))
        return handles[0] if single else handles

    # -- driving -------------------------------------------------------------

    def serve(self, sync: bool | None = None,
              max_waves: int | None = None) -> None:
        """Pump the queue (to empty, or ``max_waves`` waves) on the
        calling thread; results land on the submitted requests/handles.
        ``sync=None`` keeps the constructor mode; a stage failure
        re-queues the affected waves and re-raises."""
        self.scheduler.run(sync=sync, max_waves=max_waves)

    def run(self, sync: bool | None = None) -> list:
        """Deprecated list-returning driver; use ``submit()`` +
        ``serve()`` and read results off the handles."""
        warnings.warn(
            "list-returning run() is deprecated in repro.serving; use "
            "submit() -> RequestHandle + serve(), and read results via "
            "handle.result()", DeprecationWarning, stacklevel=2)
        self.scheduler.run(sync=sync)
        return self.scheduler.completed

    def serve_forever(self, *, sync: bool | None = None,
                      poll_s: float = 0.02) -> threading.Thread:
        """Start (or return) the resident serving thread: a background
        daemon that pumps the queue whenever work arrives, so ``submit``
        alone is enough to get served. Idempotent — a second call while
        the thread is alive returns it unchanged. ``close()`` performs
        the graceful drain: in-queue requests are served (or, if the
        backlog cannot make progress, shed with
        ``shed_reason="shutdown"`` — explicitly, never silently) before
        the thread exits. Serving-loop exceptions are recorded on
        ``self.serve_errors`` and surfaced by :meth:`health`."""
        with _SERVE_LOCK:
            t = self._serve_thread
            if t is not None and t.is_alive():
                return t
            stop = threading.Event()
            self._serve_stop = stop
            if not hasattr(self, "serve_errors"):
                self.serve_errors: list = []
            sched = self.scheduler
            sched.resident = True

            def _loop():
                while True:
                    sched._work.clear()
                    if sched.queue:
                        try:
                            sched.run(sync=sync)
                        except (Exception, WorkerDeath) as e:
                            self.serve_errors.append(e)
                            del self.serve_errors[:-100]
                            if stop.is_set():
                                # drain cannot make progress (legacy
                                # max_retries=0 with a poisoned backlog):
                                # shed what's left, explicitly
                                while sched.queue:
                                    sched.shed_request(
                                        sched.queue.popleft(), "shutdown")
                                break
                            stop.wait(poll_s)
                    elif stop.is_set():
                        break
                    else:
                        sched._work.wait(poll_s)

            t = threading.Thread(target=_loop, daemon=True,
                                 name=f"{type(self).__name__}-serve")
            self._serve_thread = t
            t.start()
            return t

    def close(self) -> None:
        """Graceful shutdown: if a resident serving thread is running,
        reject new submits (``shed_reason="shutdown"``), drain the queue,
        and join the thread; then release the planner thread pool. The
        engine stays usable afterwards (a later ``serve``/
        ``serve_forever`` restarts cleanly). Idempotent."""
        t = self._serve_thread
        if t is not None:
            self._draining = True
            stop = self._serve_stop
            if stop is not None:
                stop.set()
            self.scheduler._work.set()  # wake an idle serving loop
            t.join()
            self._serve_thread = None
            self._serve_stop = None
            self.scheduler.resident = False
            self._draining = False
        self.scheduler.close()

    # -- introspection -------------------------------------------------------

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def completed(self) -> list:
        """Deprecated: poll ``RequestHandle.done()`` / ``.result()``."""
        warnings.warn(
            ".completed is deprecated in repro.serving; submit() returns "
            "RequestHandles — use handle.done() / handle.result()",
            DeprecationWarning, stacklevel=2)
        return self.scheduler.completed

    @property
    def shed(self) -> list:
        """Requests shed by admission/backpressure (surfaced, not
        dropped)."""
        return self.scheduler.shed

    @property
    def wave_stats(self) -> list[WaveStats]:
        return self.scheduler.stats

    @property
    def failed(self) -> list:
        """Requests that exhausted their retry budget (terminal
        ``status="failed"``)."""
        return self.scheduler.failed

    def timings(self) -> dict:
        return self.scheduler.timings()

    def slo_stats(self) -> dict:
        return self.scheduler.slo_stats()

    def health(self) -> dict:
        """Liveness/readiness snapshot for external monitors: resident
        thread state, queue depth, terminal-state counts, retry/error
        counters, and the age of the last completed wave. Engines add
        their own signals (e.g. circuit-breaker states) via
        :meth:`_health_extra`."""
        sched = self.scheduler
        t = self._serve_thread
        last = sched.last_wave_ts
        h = {
            "alive": bool(t is not None and t.is_alive()),
            "ready": not self._draining,
            "resident": sched.resident,
            "draining": self._draining,
            "queue_depth": len(sched.queue),
            "n_completed": len(sched.completed),
            "n_shed": len(sched.shed),
            "n_failed": len(sched.failed),
            "n_retries": sched.retries_charged,
            "wave_errors": sched.wave_errors,
            "serve_errors": len(getattr(self, "serve_errors", ())),
            "last_wave_age_s": (None if last is None
                                else time.monotonic() - last),
        }
        h.update(self._health_extra())
        return h

    def _health_extra(self) -> dict:
        """Engine-specific health signals merged into :meth:`health`."""
        return {}
