"""One serving surface for both engines: requests, handles, engine base.

Pre-redesign, ``serving.scene_engine.SceneRequest`` and
``serving.engine.Request`` were parallel dataclasses with duplicated
``submit/run/queue/completed/wave_stats/timings/close`` surfaces on their
engines, and no way to express priority, deadline or tenant. This module
is the single surface both engines now share:

* :class:`ServeRequest` — the request base every payload subclass extends
  (``SceneRequest`` adds a scene, the LM ``Request`` a prompt). Carries
  the SLO fields admission schedules on: ``tenant``, ``priority``,
  ``deadline_ms``, plus the lifecycle ``status`` ∈ {``queued``,
  ``running``, ``completed``, ``shed``} and timestamps the scheduler
  stamps (``submit_ts`` at submit, ``done_ts`` at drain/shed).
* :class:`RequestHandle` — what ``submit()`` returns: a future-like view
  (``.done()``, ``.result(timeout=)``, ``.status``) instead of callers
  polling ``engine.completed``. ``result()`` drives the engine on the
  calling thread when nothing else is, or waits for the active run; a
  shed request raises :class:`RequestShedError` (shedding is surfaced,
  never silent).
* :class:`ServingBase` — the engine mixin owning the driver API: typed
  ``submit() -> RequestHandle``, ``serve()`` (pump the queue), stats
  plumbing, and the deprecated list-returning ``run()`` / ``.completed``
  shims the pre-handle call sites keep working through.

Migration (the PR 2/5 playbook — old entry points warn, tests error on
uncaptured deprecations):

    completed = eng.run()          ->  handles = eng.submit(reqs)
    for r in eng.completed: ...        eng.serve()
                                       for h in handles: r = h.result()
"""
from __future__ import annotations

import threading
import warnings
from dataclasses import dataclass, field

from repro.serving.scheduler import (
    COMPLETED,
    QUEUED,
    RUNNING,
    SHED,
    AdmissionPolicy,
    WaveScheduler,
    WaveStats,
)

__all__ = [
    "COMPLETED", "QUEUED", "RUNNING", "SHED",
    "AdmissionPolicy", "RequestHandle", "RequestShedError", "ServeRequest",
    "ServingBase", "WaveScheduler", "WaveStats",
]


@dataclass
class ServeRequest:
    """Base serving request: identity + SLO fields + lifecycle state.

    Engines subclass this with their payload (scene, prompt, ...). The
    SLO fields are keyword-only so payload subclasses keep their natural
    positional signatures (``SceneRequest(rid, scene)``).

    ``priority`` is strict (higher = more urgent); ``deadline_ms`` is
    relative to ``submit_ts``; ``tenant`` feeds weighted fairness. All
    three are only acted on when the scheduler runs an
    :class:`~repro.serving.scheduler.AdmissionPolicy`.
    """

    rid: int
    tenant: str = field(default="default", kw_only=True)
    priority: int = field(default=0, kw_only=True)
    deadline_ms: float | None = field(default=None, kw_only=True)
    status: str = field(default=QUEUED, kw_only=True)
    shed_reason: str | None = field(default=None, kw_only=True)
    submit_ts: float | None = field(default=None, kw_only=True)
    done_ts: float | None = field(default=None, kw_only=True)
    seq: int = field(default=-1, kw_only=True)
    _event: threading.Event | None = field(
        default=None, kw_only=True, repr=False, compare=False)

    @property
    def latency_ms(self) -> float | None:
        """End-to-end submit -> drain latency, once completed/shed."""
        if self.submit_ts is None or self.done_ts is None:
            return None
        return self.done_ts - self.submit_ts


class RequestShedError(RuntimeError):
    """Raised by ``RequestHandle.result()`` for a shed request; carries
    the request (``.request``) with its ``shed_reason``."""

    def __init__(self, request: ServeRequest):
        self.request = request
        super().__init__(
            f"request {request.rid} was shed "
            f"({request.shed_reason or 'unknown'})")


class RequestHandle:
    """Future-like view of one submitted request."""

    __slots__ = ("request", "_scheduler")

    def __init__(self, request: ServeRequest, scheduler: WaveScheduler):
        self.request = request
        self._scheduler = scheduler

    @property
    def status(self) -> str:
        return self.request.status

    def done(self) -> bool:
        """True once the request completed or was shed."""
        return self.request.status in (COMPLETED, SHED)

    def result(self, timeout: float | None = None) -> ServeRequest:
        """The fulfilled request (results filled in by the engine's drain
        stage). Drives the scheduler on the calling thread if no run is
        active; otherwise waits up to ``timeout`` seconds for the active
        run to complete it. Raises :class:`RequestShedError` if the
        request was shed, ``TimeoutError`` on timeout."""
        r = self.request
        if not self.done():
            if self._scheduler.running:
                ev = r._event
                if ev is None or not ev.wait(timeout):
                    raise TimeoutError(
                        f"request {r.rid} still {r.status} after "
                        f"{timeout}s")
            else:
                self._scheduler.run()
        if r.status == SHED:
            raise RequestShedError(r)
        if r.status != COMPLETED:
            raise TimeoutError(f"request {r.rid} still {r.status}")
        return r

    def __repr__(self) -> str:
        return (f"RequestHandle(rid={self.request.rid}, "
                f"status={self.request.status!r})")


class ServingBase:
    """Driver surface shared by ``SceneEngine`` and the LM ``Engine``.

    Subclasses build ``self.scheduler`` (a :class:`WaveScheduler` wired
    with their plan/dispatch/drain stages) in ``__init__`` and may
    override :meth:`_prepare` to classify a request before admission
    (e.g. the scene engine's capacity-bucket assignment; returning a
    string sheds the request with that reason)."""

    scheduler: WaveScheduler

    # -- submission ----------------------------------------------------------

    def _prepare(self, req: ServeRequest) -> str | None:
        """Pre-admission hook; return a shed reason to reject ``req``."""
        return None

    def submit(self, reqs):
        """Submit one request (or a sequence) for serving; returns a
        :class:`RequestHandle` per request (a single handle for a single
        request). Requests the policy sheds at submit time (backpressure,
        no compatible bucket) come back with ``status="shed"``."""
        single = isinstance(reqs, ServeRequest)
        rlist = [reqs] if single else list(reqs)
        handles = []
        for r in rlist:
            self.scheduler.enqueue(r, shed=self._prepare(r))
            handles.append(RequestHandle(r, self.scheduler))
        return handles[0] if single else handles

    # -- driving -------------------------------------------------------------

    def serve(self, sync: bool | None = None,
              max_waves: int | None = None) -> None:
        """Pump the queue (to empty, or ``max_waves`` waves) on the
        calling thread; results land on the submitted requests/handles.
        ``sync=None`` keeps the constructor mode; a stage failure
        re-queues the affected waves and re-raises."""
        self.scheduler.run(sync=sync, max_waves=max_waves)

    def run(self, sync: bool | None = None) -> list:
        """Deprecated list-returning driver; use ``submit()`` +
        ``serve()`` and read results off the handles."""
        warnings.warn(
            "list-returning run() is deprecated in repro.serving; use "
            "submit() -> RequestHandle + serve(), and read results via "
            "handle.result()", DeprecationWarning, stacklevel=2)
        self.scheduler.run(sync=sync)
        return self.scheduler.completed

    def close(self) -> None:
        """Release the planner thread pool (engine stays usable); waits
        for any in-flight run to drain first."""
        self.scheduler.close()

    # -- introspection -------------------------------------------------------

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def completed(self) -> list:
        """Deprecated: poll ``RequestHandle.done()`` / ``.result()``."""
        warnings.warn(
            ".completed is deprecated in repro.serving; submit() returns "
            "RequestHandles — use handle.done() / handle.result()",
            DeprecationWarning, stacklevel=2)
        return self.scheduler.completed

    @property
    def shed(self) -> list:
        """Requests shed by admission/backpressure (surfaced, not
        dropped)."""
        return self.scheduler.shed

    @property
    def wave_stats(self) -> list[WaveStats]:
        return self.scheduler.stats

    def timings(self) -> dict:
        return self.scheduler.timings()

    def slo_stats(self) -> dict:
        return self.scheduler.slo_stats()
