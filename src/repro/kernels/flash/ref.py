"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  scale=None):
    """q: (B, H, Sq, D); k/v: (B, H, Skv, D) -> (B, H, Sq, D).

    Sq positions are right-aligned on Skv (q token i sits at absolute
    position Skv - Sq + i), matching decode/prefill continuation semantics.
    """
    b, h, sq, d = q.shape
    skv = k.shape[2]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = skv - sq + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
