"""Flash attention Pallas kernel (TPU): online softmax, VMEM-resident tiles.

The baseline XLA attention (repro.models.attention.chunked_attention)
round-trips the (Cq, Ckv) score tiles through HBM — the dominant memory
term in the train_4k/prefill_32k rooflines. This kernel keeps scores, the
running (m, l) statistics and the output accumulator in VMEM across the kv
grid dimension, so HBM traffic drops to Q/K/V/O only.

Grid: (batch*kv_heads*groups, n_q_blocks, n_kv_blocks) — the kv dimension
iterates fastest; scratch (acc, m, l) persists across it and the output
block is written on the last kv step (standard TPU flash pattern).

Supports: causal masking (right-aligned q), sliding window, logit softcap —
everything the assigned archs need (gemma-2 softcap, danube/rg window).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int | None,
            softcap: float | None, sq: int, skv: int,
            block_q: int, block_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                       # (bq, d)
    k = k_ref[0]                       # (bkv, d)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                           # (bq, bkv)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    # absolute positions: q right-aligned on skv
    q_pos = (skv - sq) + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,   # (BH, Sq, D)
    k: jax.Array,   # (BH, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    # resolve before the jit boundary: the cache keys on the concrete mode
    return _flash_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, block_q=block_q,
                            block_kv=block_kv,
                            interpret=resolve_interpret(interpret))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv",
                     "interpret"),
)
def _flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int | None,
    softcap: float | None,
    block_q: int,
    block_kv: int,
    interpret: bool,
) -> jax.Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    grid = (bh, sq // block_q, skv // block_kv)
    scale = float(1.0 / (d ** 0.5))
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        sq=sq, skv=skv, block_q=block_q, block_kv=block_kv,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        # f32 accumulators persist in VMEM across the kv grid dimension
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
