"""jit'd wrapper: model-layout flash attention (GQA folding)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash.flash import flash_attention


def flash_attention_bshd(q, k, v, *, causal=True, window=None, softcap=None,
                         block_q=128, block_kv=128, interpret=None):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D).

    GQA: q heads are grouped per kv head; k/v are repeated group-wise by
    folding (B, Hkv, G) into the kernel's leading grid dimension.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * hkv, g, sq, d)
    qf = qf.reshape(b * hkv * g, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d), g, axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d), g, axis=0)
    o = flash_attention(qf, kf, vf, causal=causal, window=window,
                        softcap=softcap, block_q=block_q, block_kv=block_kv,
                        interpret=interpret)
    o = o.reshape(b, hkv * g, sq, d).transpose(0, 2, 1, 3)
    return o
