"""Grouped expert GEMM Pallas kernel (the MoE face of SSpNNA's SyMAC).

The MoE dispatch produces (E, cap, d) expert inputs with a validity mask —
token-level spatial sparsity in exactly the paper's sense: each (expert,
slot) pair is a matrix-vector unit of work, grouped per expert the way
WAVES groups active voxels per weight plane. The kernel runs one MXU GEMM
per (expert, f-block) grid cell with the f32 accumulator VMEM-resident, and
skips nothing (capacity padding is zeroed — RST's overshoot rule bounds the
waste, see core/moe_spade).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(x_ref, w_ref, valid_ref, o_ref):
    x = x_ref[0]                    # (C, d)
    w = w_ref[0]                    # (d, bf)
    valid = valid_ref[0]            # (C,)
    x = jnp.where(valid[:, None], x, 0)
    o_ref[0] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def grouped_gemm(
    xin: jax.Array,    # (E, C, d)
    w: jax.Array,      # (E, d, f)
    valid: jax.Array,  # (E, C) bool
    *,
    block_f: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    # resolve before the jit boundary: the cache keys on the concrete mode
    return _grouped_gemm(xin, w, valid, block_f=block_f,
                         interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def _grouped_gemm(
    xin: jax.Array,
    w: jax.Array,
    valid: jax.Array,
    *,
    block_f: int | None,
    interpret: bool,
) -> jax.Array:
    e, c, d = xin.shape
    f = w.shape[2]
    bf = block_f or f
    assert f % bf == 0
    grid = (e, f // bf)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, d, bf), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, c), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, bf), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), xin.dtype),
        interpret=interpret,
    )(xin, w, valid)
