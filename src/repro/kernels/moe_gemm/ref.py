"""Pure-jnp oracle for the grouped expert GEMM."""
from __future__ import annotations

import jax.numpy as jnp


def grouped_gemm_ref(xin, w, valid):
    """xin: (E, C, d); w: (E, d, f); valid: (E, C) bool -> (E, C, f)."""
    x = jnp.where(valid[..., None], xin, 0)
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(xin.dtype)
