"""jit'd wrapper for the grouped expert GEMM."""
from __future__ import annotations

from repro.kernels.moe_gemm.moe_gemm import grouped_gemm
from repro.kernels.moe_gemm.ref import grouped_gemm_ref

__all__ = ["grouped_gemm", "grouped_gemm_ref"]
