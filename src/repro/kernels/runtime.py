"""Shared Pallas execution-mode policy for every kernel wrapper.

The kernels in this package are written for the TPU Pallas lowering; on any
other backend they must run in interpret mode. Every wrapper used to
hardcode ``interpret=True``, which silently pinned the interpreter even on
real TPUs (ROADMAP "SSpNNA compiled path"). ``resolve_interpret`` is the
single gate: an explicit ``True``/``False`` always wins, then the
``REPRO_PALLAS_INTERPRET`` environment override, and the default
(``None``) compiles on TPU and interprets everywhere else.

The public kernel wrappers resolve *before* their jit boundary, so a
per-call env change retraces with the new mode. Long-lived jitted closures
above them (``SceneEngine._apply``, the LM engine's prefill/step) capture
the resolved mode at their own first trace — to change the mode of a
running engine, pass ``interpret=`` explicitly when constructing it rather
than flipping the env var afterwards.
"""
from __future__ import annotations

import os

import jax

ENV_INTERPRET = "REPRO_PALLAS_INTERPRET"


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Resolve an ``interpret=`` knob to a concrete bool.

    ``interpret`` of ``True``/``False`` is an explicit per-call override and
    is returned as-is. ``None`` defers to the ``REPRO_PALLAS_INTERPRET``
    env var (``0``/``false``/``off`` force compiled, anything truthy forces
    interpret) and finally to backend presence: compiled on TPU, interpreted
    on every other backend.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(ENV_INTERPRET)
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off", "")
    return jax.default_backend() != "tpu"
