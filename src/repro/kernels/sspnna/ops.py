"""jit'd public wrapper: sparse conv through the SSpNNA kernel + tile plan.

Implements the full §V-A execution flow on one chip. The default (fused)
path hands the *global* feature array to the Pallas kernel, whose
scalar-prefetched DMA tables stream each tile's working set HBM→VMEM and
write tile outputs straight to their global rows — the jitted graph holds
no ``(T, dI, C)`` gathered intermediate and no post-kernel scatter
(``tests/test_sspnna_fused.py`` pins this via HLO inspection).

The legacy pre-gathered path (``fused=False`` / ``use_kernel=False``)
materializes the working-set copy with XLA dynamic-gather, runs the
tile-stack kernel or the jnp oracle, and scatters tile outputs back with an
accumulating ``.at[].add`` — the accumulate (not overwrite) is what makes
plane-split tiles (``TilePlan.n_row_splits > 0``) correct, and is a bitwise
no-op for ordinary disjoint-row plans.

``run_sspnna_conv`` is the execution primitive the engine dispatcher
(``repro.engine.sparse_conv``) drives; ``sspnna_conv`` and
``sspnna_conv_from_plan`` are the old direct entry points, kept as
deprecation shims.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core.tiles import TilePlan
from repro.kernels.runtime import resolve_interpret
from repro.kernels.sspnna.ref import sspnna_tile_ref
from repro.kernels.sspnna.sspnna import sspnna_fused, sspnna_tiles


def run_sspnna_conv(
    feats: jax.Array,         # (V_in, C) global input features
    weights: jax.Array,       # (K, C, N)
    out_rows: jax.Array,      # (T, dO) from TilePlan / dma_tile_tables
    in_rows: jax.Array,       # (T, dI)
    local_idx: jax.Array,     # (T, dO, K)
    *,
    n_out: int,
    pair_counts: jax.Array | None = None,  # (T,) enables the fused path
    use_kernel: bool = True,
    fused: bool | None = None,
    interpret: bool | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    """Tiled sparse convolution -> (n_out, N) features (no bias/mask).

    ``fused=None`` resolves to the fused gather-GEMM-scatter kernel whenever
    the kernel path is on and ``pair_counts`` is available (the engine
    always threads it from the plan's ``TileArrays``); passing
    ``fused=True`` without counts derives them from ``local_idx`` on
    device. Plans whose tiles share output rows (``n_row_splits > 0``)
    must pass ``fused=False`` — the fused output DMA overwrites, the
    pre-gathered scatter accumulates.

    ``interpret`` resolves *before* the jit boundary (see
    ``kernels.runtime.resolve_interpret``) so direct calls honor late
    backend/env changes by retracing. Callers that wrap this in their own
    long-lived jit (e.g. the serving engines) capture the mode at their
    first trace — pass ``interpret=`` explicitly there instead."""
    if fused is None:
        fused = use_kernel and pair_counts is not None
    if fused and not use_kernel:
        raise ValueError("fused=True requires use_kernel=True "
                         "(the fused path is the Pallas kernel)")
    return _run_sspnna_conv(
        feats, weights, out_rows, in_rows, local_idx, pair_counts,
        n_out=n_out, use_kernel=use_kernel, fused=fused,
        interpret=resolve_interpret(interpret), block_n=block_n,
        block_k=block_k)


@functools.partial(
    jax.jit, static_argnames=("n_out", "use_kernel", "fused", "interpret",
                              "block_n", "block_k"))
def _run_sspnna_conv(
    feats: jax.Array,
    weights: jax.Array,
    out_rows: jax.Array,
    in_rows: jax.Array,
    local_idx: jax.Array,
    pair_counts: jax.Array | None,
    *,
    n_out: int,
    use_kernel: bool,
    fused: bool,
    interpret: bool,
    block_n: int | None,
    block_k: int | None,
) -> jax.Array:
    n = weights.shape[2]
    if fused:
        counts = (pair_counts if pair_counts is not None
                  else (local_idx >= 0).sum(axis=(1, 2)).astype(jnp.int32))
        return sspnna_fused(
            feats, weights, out_rows, in_rows, local_idx, counts,
            n_out=n_out, block_n=block_n, block_k=block_k,
            interpret=interpret)
    in_ok = in_rows >= 0
    tile_feats = jnp.take(feats, jnp.maximum(in_rows, 0), axis=0)
    tile_feats = jnp.where(in_ok[..., None], tile_feats, 0)
    if use_kernel:
        tile_out = sspnna_tiles(
            tile_feats, local_idx, weights, block_n=block_n,
            block_k=block_k, interpret=interpret
        )
    else:
        tile_out = sspnna_tile_ref(tile_feats, local_idx, weights)
    rows = jnp.where(out_rows >= 0, out_rows, n_out)
    out = jnp.zeros((n_out + 1, n), tile_out.dtype)
    # accumulate (not overwrite): plane-split tiles may share an output row;
    # for disjoint-row plans adding into zeros is the same result
    out = out.at[rows.reshape(-1)].add(tile_out.reshape(-1, n), mode="drop")
    return out[:n_out]


def sspnna_conv(
    feats: jax.Array,
    weights: jax.Array,
    out_rows: jax.Array,
    in_rows: jax.Array,
    local_idx: jax.Array,
    *,
    n_out: int,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Deprecated: call ``repro.engine.sparse_conv(backend='sspnna')``."""
    warnings.warn(
        "sspnna_conv is deprecated; route through repro.engine.sparse_conv "
        "with a tiled ConvPlan instead", DeprecationWarning, stacklevel=2)
    return run_sspnna_conv(
        feats, weights, out_rows, in_rows, local_idx, n_out=n_out,
        use_kernel=use_kernel, interpret=interpret, block_n=block_n)


def sspnna_conv_from_plan(
    feats: jax.Array,
    weights: jax.Array,
    plan: TilePlan,
    *,
    n_out: int,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Deprecated: call ``repro.engine.sparse_conv(backend='sspnna')``."""
    warnings.warn(
        "sspnna_conv_from_plan is deprecated; route through "
        "repro.engine.sparse_conv with a tiled ConvPlan instead",
        DeprecationWarning, stacklevel=2)
    return run_sspnna_conv(
        feats,
        weights,
        jnp.asarray(plan.out_rows),
        jnp.asarray(plan.in_rows),
        jnp.asarray(plan.local_idx),
        n_out=n_out,
        # shared-row (plane-split) plans need the accumulating scatter
        pair_counts=(jnp.asarray(plan.pair_counts)
                     if use_kernel and plan.n_row_splits == 0 else None),
        use_kernel=use_kernel,
        interpret=interpret,
        block_n=block_n,
    )
