"""jit'd public wrapper: sparse conv through the SSpNNA kernel + tile plan.

Implements the full §V-A execution flow on one chip:
  global feats --(DMA: per-voxel entries)--> tile working sets
  tile metadata + weights --> SSpNNA kernel --> tile outputs
  tile outputs --(DMA: block entries, ordered)--> global output rows

The gather/scatter here are the DMA engines' job in the paper (tables built
by ``repro.core.tiles.plan_dma_tables``); XLA dynamic-gather performs them,
and only the compute-dense inner tile runs in Pallas.

``run_sspnna_conv`` is the execution primitive the engine dispatcher
(``repro.engine.sparse_conv``) drives; ``sspnna_conv`` and
``sspnna_conv_from_plan`` are the old direct entry points, kept as
deprecation shims.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core.tiles import TilePlan
from repro.kernels.runtime import resolve_interpret
from repro.kernels.sspnna.ref import sspnna_tile_ref
from repro.kernels.sspnna.sspnna import sspnna_tiles


def run_sspnna_conv(
    feats: jax.Array,         # (V_in, C) global input features
    weights: jax.Array,       # (K, C, N)
    out_rows: jax.Array,      # (T, dO) from TilePlan
    in_rows: jax.Array,       # (T, dI)
    local_idx: jax.Array,     # (T, dO, K)
    *,
    n_out: int,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Tiled sparse convolution -> (n_out, N) features (no bias/mask).

    ``interpret`` resolves *before* the jit boundary (see
    ``kernels.runtime.resolve_interpret``) so direct calls honor late
    backend/env changes by retracing. Callers that wrap this in their own
    long-lived jit (e.g. the serving engines) capture the mode at their
    first trace — pass ``interpret=`` explicitly there instead."""
    return _run_sspnna_conv(
        feats, weights, out_rows, in_rows, local_idx, n_out=n_out,
        use_kernel=use_kernel, interpret=resolve_interpret(interpret),
        block_n=block_n)


@functools.partial(
    jax.jit, static_argnames=("n_out", "use_kernel", "interpret", "block_n"))
def _run_sspnna_conv(
    feats: jax.Array,
    weights: jax.Array,
    out_rows: jax.Array,
    in_rows: jax.Array,
    local_idx: jax.Array,
    *,
    n_out: int,
    use_kernel: bool,
    interpret: bool,
    block_n: int | None,
) -> jax.Array:
    in_ok = in_rows >= 0
    tile_feats = jnp.take(feats, jnp.maximum(in_rows, 0), axis=0)
    tile_feats = jnp.where(in_ok[..., None], tile_feats, 0)
    if use_kernel:
        tile_out = sspnna_tiles(
            tile_feats, local_idx, weights, block_n=block_n, interpret=interpret
        )
    else:
        tile_out = sspnna_tile_ref(tile_feats, local_idx, weights)
    n = weights.shape[2]
    out_ok = out_rows >= 0
    rows = jnp.where(out_ok, out_rows, n_out)
    out = jnp.zeros((n_out, n), tile_out.dtype)
    # tiles own disjoint output runs -> plain set, no accumulation race
    out = out.at[rows.reshape(-1)].set(
        tile_out.reshape(-1, n), mode="drop"
    )
    return out


def sspnna_conv(
    feats: jax.Array,
    weights: jax.Array,
    out_rows: jax.Array,
    in_rows: jax.Array,
    local_idx: jax.Array,
    *,
    n_out: int,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Deprecated: call ``repro.engine.sparse_conv(backend='sspnna')``."""
    warnings.warn(
        "sspnna_conv is deprecated; route through repro.engine.sparse_conv "
        "with a tiled ConvPlan instead", DeprecationWarning, stacklevel=2)
    return run_sspnna_conv(
        feats, weights, out_rows, in_rows, local_idx, n_out=n_out,
        use_kernel=use_kernel, interpret=interpret, block_n=block_n)


def sspnna_conv_from_plan(
    feats: jax.Array,
    weights: jax.Array,
    plan: TilePlan,
    *,
    n_out: int,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Deprecated: call ``repro.engine.sparse_conv(backend='sspnna')``."""
    warnings.warn(
        "sspnna_conv_from_plan is deprecated; route through "
        "repro.engine.sparse_conv with a tiled ConvPlan instead",
        DeprecationWarning, stacklevel=2)
    return run_sspnna_conv(
        feats,
        weights,
        jnp.asarray(plan.out_rows),
        jnp.asarray(plan.in_rows),
        jnp.asarray(plan.local_idx),
        n_out=n_out,
        use_kernel=use_kernel,
        interpret=interpret,
        block_n=block_n,
    )
