"""Pure-jnp oracle for the SSpNNA tile kernel.

Semantics: for tile t, output slot o, weight plane k, the partner feature is
``feats[t, local_idx[t, o, k]]`` (zeros when the index is -1); the output is
the contraction of the gathered ``(dO, K, C)`` block with the ``(K, C, N)``
weights, accumulated in f32.

The contraction is written as a single flattened ``(dO, K*C) @ (K*C, N)``
``dot_general`` — the same reduction the kernels perform after their
``(dO*K, dI)`` partial-permutation gather matmul — so the Pallas paths are
**bitwise identical** to this oracle on CPU (the fused-kernel property
tests assert exact equality, not allclose). An einsum over ``(k, c)``
jointly is the same math but XLA may reduce it in a different order, which
is why the flattened form is the pinned spec.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sspnna_tile_ref(feats, local_idx, weights):
    """feats: (T, dI, C); local_idx: (T, dO, K) -1 holes; weights: (K, C, N)
    -> (T, dO, N) in feats.dtype."""
    valid = local_idx >= 0
    idx = jnp.maximum(local_idx, 0)
    # (T, 1, dI, C) gathered along dI by (T, dO, K, 1) -> (T, dO, K, C)
    gathered = jnp.take_along_axis(feats[:, None, :, :], idx[..., None], axis=2)
    gathered = jnp.where(valid[..., None], gathered, 0)
    t, d_o, k, c = gathered.shape
    n = weights.shape[2]
    out = jax.lax.dot_general(
        gathered.reshape(t, d_o, k * c),
        weights.reshape(k * c, n),
        dimension_numbers=(((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return out.astype(feats.dtype)
