"""Pure-jnp oracle for the SSpNNA tile kernel.

Semantics: for tile t, output slot o, weight plane k, the partner feature is
``feats[t, local_idx[t, o, k]]`` (zeros when the index is -1); the output is
the sum over planes of partner @ weight[k], accumulated in f32.
"""
from __future__ import annotations

import jax.numpy as jnp


def sspnna_tile_ref(feats, local_idx, weights):
    """feats: (T, dI, C); local_idx: (T, dO, K); weights: (K, C, N)
    -> (T, dO, N) in feats.dtype."""
    valid = local_idx >= 0
    idx = jnp.maximum(local_idx, 0)
    # (T, 1, dI, C) gathered along dI by (T, dO, K, 1) -> (T, dO, K, C)
    gathered = jnp.take_along_axis(feats[:, None, :, :], idx[..., None], axis=2)
    gathered = jnp.where(valid[..., None], gathered, 0)
    out = jnp.einsum(
        "tokc,kcn->ton", gathered, weights, preferred_element_type=jnp.float32
    )
    return out.astype(feats.dtype)
