"""SSpNNA tile kernel: fused gather-GEMM over weight planes (Pallas, TPU).

TPU adaptation of the SSpNNA core (§IV-D):

* **WAVES front-end** (weight-plane active-voxel scheduling): the tile's
  COIR block ``local_idx`` already names, per output slot and weight plane,
  the partner row in the tile-local feature buffer. The kernel converts each
  plane's index column into a partial-permutation one-hot matrix on the VPU
  (compare-against-iota + select) — this is the pair-selection logic that
  WAVES' smart-lookup performs, 4 voxels/cycle, on the ASIC.
* **SyMAC back-end** (systolic + multicast MACs): both the gather
  (``onehot @ feats``) and the per-plane contraction (``gathered @ W[k]``)
  run on the MXU with f32 accumulation kept VMEM-resident across all K
  planes — the MXU's operand broadcast plays SyMAC's IFM multicast, and the
  persistent accumulator is the PEs' local ACC-OFM buffering.

Why one-hot instead of a dynamic VMEM gather: TPU VMEM has no random
scatter/gather port; a partial-permutation matmul maps irregular access onto
the systolic array at full utilization, which *is* the paper's core move —
turn sparse bookkeeping into dense compute at M-V (here tile-level)
granularity.

Grid: (tiles, N-blocks). Per-cell VMEM: dI*C + dO*K + K*C*dN + dO*dN(f32)
plus a dO*dI one-hot scratch — SPADE's dT budget (Eqn 1) with the one-hot
standing in for the link-list buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(feats_ref, idx_ref, w_ref, out_ref, *, n_planes: int):
    feats = feats_ref[0]          # (dI, C)
    idx = idx_ref[0]              # (dO, K)
    d_i = feats.shape[0]
    d_o = idx.shape[0]
    acc = jnp.zeros((d_o, w_ref.shape[2]), jnp.float32)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (d_o, d_i), 1)
    for k in range(n_planes):  # static unroll: one WAVES plane per step
        col = idx[:, k]
        onehot = (col[:, None] == iota_i).astype(feats.dtype)  # VPU select
        gathered = jnp.dot(onehot, feats, preferred_element_type=jnp.float32)
        acc = acc + jnp.dot(
            gathered.astype(feats.dtype), w_ref[k],
            preferred_element_type=jnp.float32,
        )
    out_ref[0] = acc.astype(out_ref.dtype)


def sspnna_tiles(
    feats: jax.Array,      # (T, dI, C)
    local_idx: jax.Array,  # (T, dO, K)
    weights: jax.Array,    # (K, C, N)
    *,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Run the SSpNNA kernel over a stack of tiles -> (T, dO, N).

    ``interpret`` resolves *before* the jit boundary so the cache is keyed
    on the concrete mode (late env-var changes retrace instead of being
    silently ignored)."""
    return _sspnna_tiles(feats, local_idx, weights, block_n=block_n,
                         interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _sspnna_tiles(
    feats: jax.Array,
    local_idx: jax.Array,
    weights: jax.Array,
    *,
    block_n: int | None,
    interpret: bool,
) -> jax.Array:
    t, d_i, c = feats.shape
    _, d_o, k = local_idx.shape
    n = weights.shape[2]
    bn = block_n or n
    assert n % bn == 0, (n, bn)
    grid = (t, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, n_planes=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d_i, c), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, d_o, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((k, c, bn), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, d_o, bn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((t, d_o, n), feats.dtype),
        interpret=interpret,
    )(feats, local_idx, weights)
