"""SSpNNA kernels: fused gather-GEMM-scatter over weight planes (Pallas, TPU).

TPU adaptation of the SSpNNA core (§IV-D, §V-A):

* **DMA front-end** (§V-A-3): the fused kernel takes the *global* ``(V, C)``
  feature array plus scalar-prefetched ``in_rows``/``out_rows`` DMA tables
  (``pltpu.PrefetchScalarGridSpec``) and streams each tile's working set
  HBM→VMEM with per-voxel async copies — the unordered-datatype DMA engine.
  Tile *t+1*'s gather is issued before tile *t*'s MACs run (manual double
  buffering over a 2-slot VMEM working-set scratch), and tile outputs are
  DMA'd straight to their global rows (ordered-datatype engine) — no
  ``(T, dI, C)`` HBM intermediate, no post-kernel scatter.
* **WAVES front-end** (weight-plane active-voxel scheduling): the tile's
  COIR block ``local_idx`` names, per output slot and weight plane, the
  partner row in the tile-local working set. The kernel converts the whole
  block into a single ``(dO*K, dI)`` partial-permutation one-hot matrix on
  the VPU (compare-against-iota) — the pair-selection logic WAVES'
  smart-lookup performs, 4 voxels/cycle, on the ASIC.
* **SyMAC back-end** (systolic + multicast MACs): the gather
  (``onehot @ feats``) and the plane-blocked contraction
  (``(dO, Kb*C) @ (Kb*C, N)``) run on the MXU with f32 accumulation — the
  MXU's operand broadcast plays SyMAC's IFM multicast. With the default
  ``block_k=None`` the contraction is one flattened ``(K*C)`` reduction,
  bitwise identical to ``sspnna_tile_ref``; smaller ``block_k`` bounds the
  one-hot scratch at the cost of a per-block f32 accumulate.

Why one-hot instead of a dynamic VMEM gather: TPU VMEM has no random
scatter/gather port; a partial-permutation matmul maps irregular access onto
the systolic array at full utilization, which *is* the paper's core move —
turn sparse bookkeeping into dense compute at M-V (here tile-level)
granularity.

Dead tiles (``pair_counts == 0`` — the budgeted serving planner pads the
tile stack heavily) skip their DMAs and MACs entirely via ``pl.when``; their
output rows stay on the zero-initialized trash-row buffer.

Per-cell VMEM (SPADE's dT budget, Eqn 1): ``2*dI*C`` (double-buffered
working set) + ``dO*K`` (COIR block) + ``K*C*dN`` (weight slab) + ``dO*dN``
(output staging) plus the transient ``dO*Kb*dI`` one-hot.

``sspnna_tiles`` keeps the pre-gathered ``(T, dI, C)`` stack API (used by
the benchmark baseline and direct tests); it shares ``_tile_compute`` with
the fused kernel, so both are bitwise identical to the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret


def _tile_compute(feats, idx, w, *, block_k=None):
    """One tile's MACs: feats (dI, C), idx (dO, K) -1 holes, w (K, C, dN)
    -> f32 (dO, dN).

    A single ``(dO*Kb, dI)`` partial-permutation matmul gathers each plane
    block's partners, then one flattened ``(Kb*C)`` contraction hits the
    weights. ``block_k=None`` (one block) reduces over all ``K*C`` at once —
    the bitwise-pinned oracle order; smaller blocks add one f32 accumulate
    per extra block.
    """
    d_i, c = feats.shape
    d_o, k = idx.shape
    d_n = w.shape[2]
    kb = block_k or k
    parts = []
    for k0 in range(0, k, kb):
        kk = min(kb, k - k0)
        col = idx[:, k0:k0 + kk].reshape(d_o * kk)
        iota_i = jax.lax.broadcasted_iota(jnp.int32, (d_o * kk, d_i), 1)
        onehot = (col[:, None] == iota_i).astype(feats.dtype)  # VPU select
        gathered = jnp.dot(onehot, feats, preferred_element_type=jnp.float32)
        gathered = gathered.astype(feats.dtype).reshape(d_o, kk * c)
        parts.append(jnp.dot(
            gathered, w[k0:k0 + kk].reshape(kk * c, d_n),
            preferred_element_type=jnp.float32,
        ))
    acc = parts[0]
    for p in parts[1:]:
        acc = acc + p
    return acc


# ---------------------------------------------------------------------------
# Pre-gathered tile-stack kernel (baseline; direct (T, dI, C) API)
# ---------------------------------------------------------------------------

def _pregathered_kernel(feats_ref, idx_ref, w_ref, out_ref, *, block_k):
    out_ref[0] = _tile_compute(
        feats_ref[0], idx_ref[0], w_ref[...], block_k=block_k
    ).astype(out_ref.dtype)


def sspnna_tiles(
    feats: jax.Array,      # (T, dI, C)
    local_idx: jax.Array,  # (T, dO, K)
    weights: jax.Array,    # (K, C, N)
    *,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Run the SSpNNA kernel over a pre-gathered stack of tiles -> (T, dO, N).

    ``interpret`` resolves *before* the jit boundary so the cache is keyed
    on the concrete mode (late env-var changes retrace instead of being
    silently ignored)."""
    return _sspnna_tiles(feats, local_idx, weights, block_n=block_n,
                         block_k=block_k, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def _sspnna_tiles(
    feats: jax.Array,
    local_idx: jax.Array,
    weights: jax.Array,
    *,
    block_n: int | None,
    block_k: int | None,
    interpret: bool,
) -> jax.Array:
    t, d_i, c = feats.shape
    _, d_o, k = local_idx.shape
    n = weights.shape[2]
    bn = block_n or n
    assert n % bn == 0, (n, bn)
    grid = (t, n // bn)
    return pl.pallas_call(
        functools.partial(_pregathered_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d_i, c), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, d_o, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((k, c, bn), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, d_o, bn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((t, d_o, n), feats.dtype),
        interpret=interpret,
    )(feats, local_idx, weights)


# ---------------------------------------------------------------------------
# Fused gather-GEMM-scatter kernel (global features in, global rows out)
# ---------------------------------------------------------------------------

def _fused_kernel(in_rows_ref, out_rows_ref, counts_ref, idx_ref, feats_hbm,
                  zeros_hbm, w_ref, out_hbm, ws, obuf, in_sems, out_sem,
                  *, n_tiles, block_k):
    del zeros_hbm  # aliased into out_hbm: provides the zero/trash-row init
    i = pl.program_id(0)
    j = pl.program_id(1)
    d_i = ws.shape[1]
    d_o, bn = obuf.shape

    def row_dma(tile, slot, r):
        """Per-voxel entry of the unordered-datatype DMA table (§V-A-3)."""
        row = in_rows_ref[tile, r]
        return pltpu.make_async_copy(
            feats_hbm.at[pl.ds(row, 1), :],
            ws.at[slot, pl.ds(r, 1), :],
            in_sems.at[slot],
        )

    def issue_gather(tile, slot):
        jax.lax.fori_loop(
            0, d_i, lambda r, _: (row_dma(tile, slot, r).start(), 0)[1], 0)

    def wait_gather(tile, slot):
        jax.lax.fori_loop(
            0, d_i, lambda r, _: (row_dma(tile, slot, r).wait(), 0)[1], 0)

    # N-blocks revisit the same working set: DMA choreography runs once per
    # tile (j == 0). Double buffering: tile i+1's gather is in flight while
    # tile i's MACs run; dead tiles (pair_counts == 0) issue nothing.
    @pl.when(j == 0)
    def _():
        @pl.when((i == 0) & (counts_ref[0] > 0))
        def _():
            issue_gather(0, 0)

        @pl.when((i + 1 < n_tiles) & (counts_ref[i + 1] > 0))
        def _():
            issue_gather(i + 1, (i + 1) % 2)

        @pl.when(counts_ref[i] > 0)
        def _():
            wait_gather(i, i % 2)

    @pl.when(counts_ref[i] > 0)
    def _():
        acc = _tile_compute(ws[i % 2], idx_ref[0], w_ref[...], block_k=block_k)
        obuf[...] = acc.astype(obuf.dtype)

        def out_dma(o):
            # ordered-datatype DMA: each output slot streams straight to its
            # global row (pad slots land on the trash row and are sliced off)
            row = out_rows_ref[i, o]
            return pltpu.make_async_copy(
                obuf.at[pl.ds(o, 1), :],
                out_hbm.at[pl.ds(row, 1), pl.ds(j * bn, bn)],
                out_sem,
            )

        # start all d_o row copies, then drain: latencies overlap instead of
        # serializing; obuf reuse is safe since every wait precedes the next
        # grid step's write
        jax.lax.fori_loop(0, d_o, lambda o, _: (out_dma(o).start(), 0)[1], 0)
        jax.lax.fori_loop(0, d_o, lambda o, _: (out_dma(o).wait(), 0)[1], 0)


def sspnna_fused(
    feats: jax.Array,        # (V, C) global input features
    weights: jax.Array,      # (K, C, N)
    out_rows: jax.Array,     # (T, dO) global output rows (-1 pad ok)
    in_rows: jax.Array,      # (T, dI) global input rows (-1 pad ok)
    local_idx: jax.Array,    # (T, dO, K) tile-local partner indices, -1 holes
    pair_counts: jax.Array,  # (T,) valid pairs per tile (0 => dead tile)
    *,
    n_out: int,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused gather-GEMM-scatter sparse conv -> (n_out, N) (no bias/mask).

    Accepts tile tables in either the raw ``TilePlan`` layout (-1 pads) or
    the DMA-table layout of ``core.tiles.dma_tile_tables`` — normalization
    is idempotent integer ops. Tiles must own disjoint output rows (the
    output DMA overwrites): plans with ``n_row_splits > 0`` need the
    accumulating pre-gathered path instead.

    ``interpret`` resolves *before* the jit boundary (see
    ``kernels.runtime.resolve_interpret``)."""
    return _sspnna_fused(feats, weights, out_rows, in_rows, local_idx,
                         pair_counts, n_out=n_out, block_n=block_n,
                         block_k=block_k,
                         interpret=resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("n_out", "block_n", "block_k", "interpret"))
def _sspnna_fused(
    feats: jax.Array,
    weights: jax.Array,
    out_rows: jax.Array,
    in_rows: jax.Array,
    local_idx: jax.Array,
    pair_counts: jax.Array,
    *,
    n_out: int,
    block_n: int | None,
    block_k: int | None,
    interpret: bool,
) -> jax.Array:
    _, c = feats.shape
    t, d_o, k = local_idx.shape
    d_i = in_rows.shape[1]
    n = weights.shape[2]
    bn = block_n or n
    assert n % bn == 0, (n, bn)
    # normalize to DMA-table layout (idempotent when the caller already
    # holds `dma_tile_tables` output): every in-entry a safe HBM source,
    # every out-entry a real row or the trash row n_out
    in_dma = jnp.maximum(in_rows, 0).astype(jnp.int32)
    out_dma = jnp.where(out_rows < 0, n_out, out_rows).astype(jnp.int32)
    counts = pair_counts.astype(jnp.int32)
    zeros = jnp.zeros((n_out + 1, n), feats.dtype)
    if t == 0:
        return zeros[:n_out]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(t, n // bn),
        in_specs=[
            pl.BlockSpec((1, d_o, k), lambda i, j, *_: (i, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # feats stay in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # zero-init (aliased)
            pl.BlockSpec((k, c, bn), lambda i, j, *_: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((2, d_i, c), feats.dtype),   # double-buffered dM set
            pltpu.VMEM((d_o, bn), feats.dtype),     # output staging
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fused_kernel, n_tiles=t, block_k=block_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out + 1, n), feats.dtype),
        # input index 5 = zeros (scalar-prefetch args count in the numbering)
        input_output_aliases={5: 0},
        interpret=interpret,
    )(in_dma, out_dma, counts, local_idx, feats, zeros, weights)
    return out[:n_out]
