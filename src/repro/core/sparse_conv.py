"""Spatially-sparse 3D convolution on COIR metadata (gather-GEMM-scatter).

Three layer types, matching SCN U-Nets (Graham et al. 2018):

* **submanifold** (k=3, s=1): output active set == input active set; only
  active neighbours contribute (Valid Sparse Convolution).
* **strided** (k=2, s=2): output set = unique(coords // 2); downsamples.
* **transposed** (k=2, s=2): restores a saved finer active set; upsamples.

The reference dataflow is the paper's coarse M-V dispatch batched to a full
einsum: gather partner features per weight plane, one fused
``(V, K, C) x (K, C, N)`` contraction, which XLA maps onto the MXU — the
whole layer is a single coarse dispatch (Table III taken to its limit).
``repro/kernels/sspnna`` provides the tiled Pallas version driven by SPADE
tile plans; this module is also its numerical oracle.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coir import COIR, build_cirf, build_corf
from repro.core.hashgrid import downsample_coords, kernel_offsets
from repro.sparse.tensor import SparseVoxelTensor


class SparseConvParams(NamedTuple):
    weight: jax.Array  # (K, C, N)
    bias: jax.Array    # (N,)


def init_sparse_conv(
    key: jax.Array, kernel_volume: int, c_in: int, c_out: int, dtype=jnp.float32
) -> SparseConvParams:
    fan_in = kernel_volume * c_in
    w = jax.random.normal(key, (kernel_volume, c_in, c_out), dtype) / np.sqrt(fan_in)
    return SparseConvParams(w, jnp.zeros((c_out,), dtype))


def gather_partners(feats: jax.Array, coir: COIR) -> jax.Array:
    """(V, K, C) partner features; zeros at holes. The 'Input Gather' stage
    that dominates the CPU profile (Fig 4) — here a single vectorized take."""
    idx = jnp.maximum(coir.indices, 0)
    g = jnp.take(feats, idx, axis=0)  # (V, K, C)
    return jnp.where(coir.valid()[..., None], g, 0)


def reference_conv_cirf(
    feats_in: jax.Array, coir: COIR, params: SparseConvParams
) -> jax.Array:
    """Out-major (CIRF) evaluation: gather + one fused contraction.

    This is the engine's ``backend="reference"`` implementation and the
    numerical oracle for the tiled SSpNNA path (``repro.engine.sparse_conv``).
    """
    g = gather_partners(feats_in, coir)
    out = jnp.einsum(
        "okc,kcn->on", g, params.weight, preferred_element_type=jnp.float32
    ).astype(feats_in.dtype)
    out = out + params.bias.astype(out.dtype)
    return out * coir.mask[:, None].astype(out.dtype)


def sparse_conv_cirf(
    feats_in: jax.Array, coir: COIR, params: SparseConvParams
) -> jax.Array:
    """Deprecated: call ``repro.engine.sparse_conv`` with a plan instead."""
    warnings.warn(
        "sparse_conv_cirf is deprecated; use repro.engine.sparse_conv with a "
        "ConvPlan (backend='reference' reproduces these numerics exactly)",
        DeprecationWarning, stacklevel=2)
    from repro.engine import api as engine_api  # local: engine imports us

    # omitting ctx= dispatches through the ambient ExecutionContext's
    # registry, exactly like a modern call site
    return engine_api.sparse_conv(
        feats_in, params, engine_api.reference_plan(coir),
        backend="reference")


def masked_batchnorm_relu(x, mask, scale, offset, eps: float = 1e-5):
    """BN + ReLU over active rows only (the SCN conv-block epilogue)."""
    m = mask[:, None].astype(x.dtype)
    n = jnp.maximum(jnp.sum(m), 1.0)
    mean = jnp.sum(x * m, axis=0) / n
    var = jnp.sum(jnp.square(x - mean) * m, axis=0) / n
    y = (x - mean) * jax.lax.rsqrt(var + eps) * scale + offset
    return jax.nn.relu(y) * m


def sparse_conv_corf(
    feats_in: jax.Array,
    coir_in_major: COIR,
    params: SparseConvParams,
    n_out: int,
) -> jax.Array:
    """In-major (CORF) evaluation: per-plane product then scatter-add to the
    response field ('Output Write' in the paper's profile)."""
    contrib = jnp.einsum(
        "ic,kcn->ikn",
        feats_in * coir_in_major.mask[:, None].astype(feats_in.dtype),
        params.weight,
        preferred_element_type=jnp.float32,
    )
    idx = coir_in_major.indices  # (Vi, K) -> output rows
    ok = coir_in_major.valid()
    rows = jnp.where(ok, idx, n_out)
    out = jnp.zeros((n_out, params.weight.shape[-1]), jnp.float32)
    out = out.at[rows.reshape(-1)].add(
        jnp.where(ok[..., None], contrib, 0).reshape(-1, params.weight.shape[-1]),
        mode="drop",
    )
    out = out.astype(feats_in.dtype) + params.bias.astype(feats_in.dtype)
    valid_row = jnp.zeros((n_out,), bool).at[rows.reshape(-1)].set(
        ok.reshape(-1), mode="drop"
    )
    return out * valid_row[:, None].astype(out.dtype)


# ---------------------------------------------------------------------------
# Layer-level helpers on SparseVoxelTensor
# ---------------------------------------------------------------------------

def submanifold_coir(
    t: SparseVoxelTensor, resolution: int, kernel_size: int = 3
) -> COIR:
    offs = jnp.asarray(kernel_offsets(kernel_size))
    return build_cirf(t.coords, t.mask, t.coords, t.mask, offs, resolution)


def submanifold_conv(
    t: SparseVoxelTensor, coir: COIR, params: SparseConvParams
) -> SparseVoxelTensor:
    return t.replace_feats(reference_conv_cirf(t.feats, coir, params))


def strided_conv(
    t: SparseVoxelTensor,
    resolution: int,
    params: SparseConvParams,
    kernel_size: int = 2,
    stride: int = 2,
    capacity_out: int | None = None,
):
    """Downsampling conv; returns (out tensor, out resolution, coir)."""
    out_coords, out_mask = downsample_coords(
        t.coords, t.mask, resolution, stride, capacity_out
    )
    offs = jnp.asarray(kernel_offsets(kernel_size, centered=False))
    coir = build_cirf(
        out_coords, out_mask, t.coords, t.mask, offs, resolution, stride
    )
    feats = reference_conv_cirf(t.feats, coir, params)
    return SparseVoxelTensor(out_coords, feats, out_mask), resolution // stride, coir


def transposed_coir(
    coarse: SparseVoxelTensor,
    fine_coords: jax.Array,
    fine_mask: jax.Array,
    fine_resolution: int,
    kernel_size: int = 2,
    stride: int = 2,
) -> COIR:
    """CIRF of a transposed conv restoring the saved finer active set.

    Fine output o draws from coarse input i when ``o == i*stride + d``; this
    is exactly the CORF probe with roles swapped.
    """
    offs = jnp.asarray(kernel_offsets(kernel_size, centered=False))
    return build_corf(
        coarse.coords, coarse.mask, fine_coords, fine_mask, offs,
        fine_resolution, stride,
    )


def transposed_conv(
    coarse: SparseVoxelTensor,
    coir_fine_major: COIR,
    fine_coords: jax.Array,
    fine_mask: jax.Array,
    params: SparseConvParams,
) -> SparseVoxelTensor:
    feats = reference_conv_cirf(coarse.feats, coir_fine_major, params)
    return SparseVoxelTensor(fine_coords, feats, fine_mask)


def batchnorm_relu(
    t: SparseVoxelTensor, scale: jax.Array, offset: jax.Array, eps: float = 1e-5
) -> SparseVoxelTensor:
    """Masked batch-norm + ReLU over active voxels only."""
    return t.replace_feats(
        masked_batchnorm_relu(t.feats, t.mask, scale, offset, eps))


# ---------------------------------------------------------------------------
# Dense oracle (for property tests): sparse conv == masked dense conv
# ---------------------------------------------------------------------------

def dense_submanifold_reference(
    dense: np.ndarray, weight: np.ndarray, bias: np.ndarray
) -> np.ndarray:
    """O(R^3 K C N) dense evaluation of a submanifold conv, numpy oracle.

    dense: (R, R, R, C); weight: (K^3, C, N) in lexicographic offset order.
    Output voxel active iff input voxel active (submanifold rule).
    """
    r = dense.shape[0]
    occ = np.any(dense != 0, axis=-1)
    k3 = weight.shape[0]
    k = round(k3 ** (1 / 3))
    offs = kernel_offsets(k)
    out = np.zeros(dense.shape[:3] + (weight.shape[-1],), np.float32)
    for ki, (dx, dy, dz) in enumerate(offs):
        src = np.zeros_like(dense, dtype=np.float32)
        xs = slice(max(0, -dx), r - max(0, dx))
        xd = slice(max(0, dx), r - max(0, -dx))
        ys = slice(max(0, -dy), r - max(0, dy))
        yd = slice(max(0, dy), r - max(0, -dy))
        zs = slice(max(0, -dz), r - max(0, dz))
        zd = slice(max(0, dz), r - max(0, -dz))
        src[xs, ys, zs] = dense[xd, yd, zd]
        out += src.astype(np.float32) @ weight[ki].astype(np.float32)
    out += bias.astype(np.float32)
    return out * occ[..., None]
