"""AdMAC: adjacency-map / neighbourhood-search accelerator, TPU adaptation.

The paper's AdMAC (Section IV-E) streams voxels through a two-level banked
spatial hash so 26 neighbours resolve in one SRAM cycle. TPUs have no banked
random-access scratchpad, so the TPU-idiomatic equivalent is *sorted linear
keys + vectorized binary search*: every (voxel, kernel-offset) pair issues one
``searchsorted`` probe, fully batched on the VPU. Complexity O(V*K*log V) with
perfect vectorization — this is the role the 8-banked {y,z}-interleaved hash
plays on the ASIC.

All functions are jit-compatible with static capacities.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.tensor import PAD_COORD, linear_key


def kernel_offsets(kernel_size: int, centered: bool | None = None) -> np.ndarray:
    """Lexicographic (K^3, 3) integer offsets for a cubic kernel.

    Odd kernels default to centered offsets (submanifold convs); even kernels
    to [0, K) offsets (strided down/up-sampling convs), matching SCN.
    """
    if centered is None:
        centered = kernel_size % 2 == 1
    lo = -(kernel_size // 2) if centered else 0
    rng = np.arange(lo, lo + kernel_size)
    grid = np.stack(np.meshgrid(rng, rng, rng, indexing="ij"), axis=-1)
    return grid.reshape(-1, 3).astype(np.int32)


class SortedGrid:
    """Sorted-key index over an active-voxel set (the adjacency 'hash')."""

    def __init__(self, coords: jax.Array, mask: jax.Array, resolution: int):
        self.coords = coords
        self.mask = mask
        self.resolution = resolution
        keys = linear_key(coords, resolution, mask)
        order = jnp.argsort(keys)
        self.sorted_keys = keys[order]
        self.sorted_idx = order.astype(jnp.int32)

    def lookup(self, query_coords: jax.Array, query_valid: jax.Array) -> jax.Array:
        """Indices into the voxel list for each query coord; -1 if absent."""
        r = self.resolution
        in_bounds = jnp.all((query_coords >= 0) & (query_coords < r), axis=-1)
        valid = query_valid & in_bounds
        qkey = linear_key(query_coords, r, valid)
        pos = jnp.searchsorted(self.sorted_keys, qkey)
        pos = jnp.clip(pos, 0, self.sorted_keys.shape[0] - 1)
        found = valid & (self.sorted_keys[pos] == qkey)
        return jnp.where(found, self.sorted_idx[pos], -1)


@functools.partial(jax.jit, static_argnames=("resolution", "stride"))
def query_neighbors(
    out_coords: jax.Array,
    out_mask: jax.Array,
    in_coords: jax.Array,
    in_mask: jax.Array,
    offsets: jax.Array,
    resolution: int,
    stride: int = 1,
) -> jax.Array:
    """For each output voxel, index of the input voxel at each kernel offset.

    input coordinate probed for output o and offset d is ``o * stride + d``
    (in input-space units). Returns (V_out, K) int32 with -1 where the input
    voxel is inactive / out of bounds / the output row is padding.
    """
    grid = SortedGrid(in_coords, in_mask, resolution)
    probe = out_coords[:, None, :] * stride + offsets[None, :, :]  # (Vo, K, 3)
    valid = out_mask[:, None] & jnp.ones(offsets.shape[0], bool)[None, :]
    return grid.lookup(probe, valid)


@functools.partial(jax.jit, static_argnames=("resolution",))
def build_neighbor_table(
    coords: jax.Array, mask: jax.Array, offsets: jax.Array, resolution: int
) -> jax.Array:
    """Adjacency map of an active set against itself (submanifold case)."""
    return query_neighbors(coords, mask, coords, mask, offsets, resolution, stride=1)


@functools.partial(jax.jit, static_argnames=("factor", "capacity_out", "resolution"))
def downsample_coords(
    coords: jax.Array,
    mask: jax.Array,
    resolution: int,
    factor: int = 2,
    capacity_out: int | None = None,
):
    """Output active set of a strided conv: unique(floor(coords / factor)).

    Returns (out_coords (Vo,3) int32, out_mask (Vo,)) with Vo = capacity_out
    (defaults to the input capacity). Output rows are sorted by linear key,
    giving a deterministic canonical order.
    """
    cap_out = capacity_out or coords.shape[0]
    down = jnp.where(mask[:, None], coords // factor, PAD_COORD)
    keys = linear_key(down, max(resolution // factor, 1), mask)
    sorted_keys = jnp.sort(keys)
    is_first = jnp.concatenate(
        [jnp.array([True]), sorted_keys[1:] != sorted_keys[:-1]]
    ) & (sorted_keys < jnp.int32(max(resolution // factor, 1)) ** 3)
    # Compact first-occurrences into the output prefix.
    dest = jnp.cumsum(is_first.astype(jnp.int32)) - 1
    out_keys = jnp.full((cap_out,), jnp.int32(2**31 - 1))
    out_keys = out_keys.at[jnp.where(is_first, dest, cap_out)].set(
        sorted_keys, mode="drop"
    )
    n_out = jnp.sum(is_first.astype(jnp.int32))
    out_mask = jnp.arange(cap_out) < n_out
    r_out = max(resolution // factor, 1)
    out_coords = jnp.stack(
        [
            out_keys // (r_out * r_out),
            (out_keys // r_out) % r_out,
            out_keys % r_out,
        ],
        axis=-1,
    ).astype(jnp.int32)
    out_coords = jnp.where(out_mask[:, None], out_coords, PAD_COORD)
    return out_coords, out_mask


class UpdatableSortedGrid:
    """Updatable sorted-key index: the streaming seam of the AdMAC search.

    ``SortedGrid`` / ``host_meta.SortedGridNp`` re-sort the full key set per
    scene — fine for i.i.d. uploads, wasteful for a 10–20 Hz LiDAR stream
    where frame t+1 keeps most of frame t's voxels. This numpy structure
    keeps only the *active* keys sorted (paired with their row ids) and
    supports the three stream mutations without a full re-sort:

    * ``shift(key_offset)`` — uniform ego motion. Linear keys are linear in
      the coordinate, so a constant coordinate shift is a constant key
      offset and preserves sorted order entirely (O(n) add).
    * ``delete(keys)`` — batched removal by sorted key (O(n) compress).
    * ``insert(keys, rows)`` — batched insertion of sorted new keys at
      their ``searchsorted`` positions (O(n + m log n) merge, no re-sort).

    ``lookup`` returns bit-identical results to ``SortedGridNp.lookup`` on
    the same active set: active keys are unique, and the sentinel rows the
    capacity-shaped variant carries can never match a valid query, so
    dropping them changes nothing.
    """

    def __init__(self, resolution: int, keys: np.ndarray | None = None,
                 rows: np.ndarray | None = None):
        self.resolution = resolution
        self.keys = (np.empty((0,), np.int32) if keys is None
                     else np.asarray(keys, np.int32))
        self.rows = (np.empty((0,), np.int32) if rows is None
                     else np.asarray(rows, np.int32))
        if self.keys.shape != self.rows.shape:
            raise ValueError(
                f"keys {self.keys.shape} / rows {self.rows.shape} mismatch")

    @classmethod
    def from_coords(cls, coords: np.ndarray, mask: np.ndarray,
                    resolution: int) -> "UpdatableSortedGrid":
        from repro.core.host_meta import linear_key_np

        mask = np.asarray(mask)
        rows = np.flatnonzero(mask).astype(np.int32)
        keys = linear_key_np(np.asarray(coords)[rows], resolution)
        order = np.argsort(keys, kind="stable")
        return cls(resolution, keys[order], rows[order])

    def __len__(self) -> int:
        return int(self.keys.shape[0])

    def shift(self, key_offset: int) -> None:
        """Apply a uniform key offset (ego motion after removals: every
        remaining coordinate stays in bounds, so no per-component borrow
        can break the linear-key arithmetic)."""
        if key_offset:
            self.keys = self.keys + np.int32(key_offset)

    def delete(self, keys: np.ndarray) -> None:
        """Remove ``keys`` (sorted or not; must all be present)."""
        keys = np.asarray(keys, np.int32)
        if not keys.size:
            return
        pos = np.searchsorted(self.keys, keys)
        if (pos >= len(self.keys)).any() or (self.keys[np.minimum(
                pos, len(self.keys) - 1)] != keys).any():
            raise KeyError("delete of keys not present in the grid")
        keep = np.ones(len(self.keys), bool)
        keep[pos] = False
        self.keys = self.keys[keep]
        self.rows = self.rows[keep]

    def insert(self, keys: np.ndarray, rows: np.ndarray) -> None:
        """Insert new (key, row) pairs (keys must be sorted + absent)."""
        keys = np.asarray(keys, np.int32)
        rows = np.asarray(rows, np.int32)
        if not keys.size:
            return
        pos = np.searchsorted(self.keys, keys)
        self.keys = np.insert(self.keys, pos, keys)
        self.rows = np.insert(self.rows, pos, rows)

    def lookup(self, query_coords: np.ndarray,
               query_valid: np.ndarray) -> np.ndarray:
        """Row ids for query coords; -1 if absent (``SortedGridNp`` twin)."""
        from repro.core.host_meta import linear_key_np

        r = self.resolution
        q = np.asarray(query_coords)
        in_bounds = np.all((q >= 0) & (q < r), axis=-1)
        valid = np.asarray(query_valid) & in_bounds
        qkey = linear_key_np(q, r, valid)
        if not len(self.keys):
            return np.full(qkey.shape, -1, np.int32)
        pos = np.searchsorted(self.keys, qkey)
        pos = np.minimum(pos, len(self.keys) - 1)
        found = valid & (self.keys[pos] == qkey)
        return np.where(found, self.rows[pos], -1).astype(np.int32)


def upsample_coords(coords: jax.Array, mask: jax.Array):
    """Output set of a transposed (deconv) layer restoring a finer level.

    SCN U-Nets restore the *saved* finer-level active set rather than
    expanding; callers pass the skip connection's coords, so this is just a
    passthrough that documents the contract.
    """
    return coords, mask
