"""CAROM: Constrained-Access Reuse-Opportunity Maximization (§V-B).

Hierarchical dataflow search over a multi-level memory hierarchy that avoids
the classic greedy failure (minimizing outer-level accesses can starve inner
levels of reuse). At each level L_q (outer -> inner):

  1. Candidate set  D^Lq = { D : DA(D) <= DA_th } ∪ { argmin DA }   (Eqn 6)
  2. DA_th = Ops^Lq * BW^Lq / TotalComp^Lq                           (Eqn 7)
     with Ops^Lq = SA_MO(O^Lq) * O^Lq * N^Lq * C^Lq                  (Eqn 8)
  3. Pick the candidate maximizing reuse opportunity for L_{q-1}, i.e. the
     ops available on the chosen working set (Eqn 9); the chosen tile is the
     next level's working set.
  4. Innermost level: plain argmin DA.

TPU mapping: levels = [HBM->VMEM, VMEM->VREG]; BW in elements/cycle and
compute in MACs/cycle are taken from the v5e constants in
``repro.launch.roofline`` by default.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.spade import (
    FLAVORS,
    WALK_PATTERNS,
    Dataflow,
    LayerSpec,
    SparsityAttributes,
    _pow2_range,
    data_accesses,
    tile_footprint,
)


@dataclass(frozen=True)
class MemLevel:
    name: str
    capacity_bytes: int
    bw_elems_per_cycle: float   # toward the next-outer level
    macs_per_cycle: float       # compute fed from this level


def _candidates(
    layer: LayerSpec,
    attrs_by_flavor: dict[str, SparsityAttributes],
    budget_bytes: int,
    tiling: str,
) -> list[Dataflow]:
    budget_elems = budget_bytes / layer.dtype_bytes
    out = []
    for flavor in FLAVORS:
        if flavor not in attrs_by_flavor:
            continue
        attrs = attrs_by_flavor[flavor]
        majors = layer.n_out if flavor == "CIRF" else layer.n_in
        for dm in _pow2_range(max(majors, 8), 32):
            for dc in _pow2_range(layer.c_in, 8):
                for dn in _pow2_range(layer.c_out, 8):
                    t = tile_footprint(layer, attrs, dm, dc, dn, flavor, tiling)
                    if t > budget_elems:
                        continue
                    for wp in WALK_PATTERNS:
                        da, br = data_accesses(layer, attrs, dm, dc, dn, wp, flavor)
                        out.append(
                            Dataflow(dm, dc, dn, wp, flavor, tiling, t, da, br)
                        )
    return out


def _ops(layer: LayerSpec, attrs: SparsityAttributes, d: Dataflow) -> float:
    """Ops on the working set defined by candidate d (Eqn 8 analogue for a
    tile): MACs = ARF * dMajor * dC * dN."""
    arf = attrs.at(d.delta_major, "arf_avg")
    return arf * d.delta_major * d.delta_c * d.delta_n


def carom_search(
    layer: LayerSpec,
    attrs_by_flavor: dict[str, SparsityAttributes],
    levels: list[MemLevel],
    tiling: str = "RST",
) -> list[Dataflow]:
    """Outer->inner search. Returns one Dataflow per level; level i's tile is
    level i+1's working set (its totals replace I/O/C/N)."""
    plans: list[Dataflow] = []
    cur_layer = layer
    for qi, level in enumerate(levels):
        cands = _candidates(cur_layer, attrs_by_flavor, level.capacity_bytes, tiling)
        if not cands:
            break
        innermost = qi == len(levels) - 1
        if innermost:
            best = min(cands, key=lambda d: d.da_elems)
        else:
            attrs0 = attrs_by_flavor.get("CIRF") or next(iter(attrs_by_flavor.values()))
            total_ops = (
                attrs0.at(attrs0.delta_majors[-1], "arf_avg")
                * cur_layer.n_out * cur_layer.c_in * cur_layer.c_out
            )
            da_min = min(d.da_elems for d in cands)
            da_th = max(
                total_ops * level.bw_elems_per_cycle / max(level.macs_per_cycle, 1e-9),
                da_min,
            )
            feasible = [d for d in cands if d.da_elems <= da_th]
            if not feasible:
                feasible = [min(cands, key=lambda d: d.da_elems)]
            best = max(
                feasible,
                key=lambda d: _ops(cur_layer, attrs_by_flavor[d.flavor], d),
            )
        plans.append(best)
        # The chosen tile becomes the next level's layer totals.
        attrs_b = attrs_by_flavor[best.flavor]
        sa = attrs_b.at(best.delta_major, "sa_minor_avg")
        if best.flavor == "CIRF":
            n_out = best.delta_major
            n_in = max(int(sa * best.delta_major), 1)
        else:
            n_in = best.delta_major
            n_out = max(int(sa * best.delta_major), 1)
        cur_layer = LayerSpec(
            name=f"{cur_layer.name}@{level.name}",
            n_in=n_in,
            n_out=n_out,
            kernel_volume=cur_layer.kernel_volume,
            c_in=best.delta_c,
            c_out=best.delta_n,
            dtype_bytes=cur_layer.dtype_bytes,
        )
    return plans


def greedy_search(
    layer: LayerSpec,
    attrs_by_flavor: dict[str, SparsityAttributes],
    levels: list[MemLevel],
    tiling: str = "RST",
) -> list[Dataflow]:
    """Baseline hierarchical search: plain min-DA at every level (the
    strategy CAROM improves on — used by the Fig 22 ablation)."""
    plans: list[Dataflow] = []
    cur_layer = layer
    for level in levels:
        cands = _candidates(cur_layer, attrs_by_flavor, level.capacity_bytes, tiling)
        if not cands:
            break
        best = min(cands, key=lambda d: d.da_elems)
        plans.append(best)
        attrs_b = attrs_by_flavor[best.flavor]
        sa = attrs_b.at(best.delta_major, "sa_minor_avg")
        n_major = best.delta_major
        n_minor = max(int(sa * best.delta_major), 1)
        cur_layer = LayerSpec(
            name=f"{cur_layer.name}@{level.name}",
            n_in=n_minor if best.flavor == "CIRF" else n_major,
            n_out=n_major if best.flavor == "CIRF" else n_minor,
            kernel_volume=cur_layer.kernel_volume,
            c_in=best.delta_c,
            c_out=best.delta_n,
            dtype_bytes=cur_layer.dtype_bytes,
        )
    return plans
