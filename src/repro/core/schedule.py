"""Ops-sorted multi-core tile scheduling (§V-A-4, Fig 14-b).

SPADE produces uniform tile *shapes* but region-dependent sparsity makes
ops-per-tile asymmetric. The paper sorts spatial tiles by ops descending and
round-robins them over core groups; this evens out core finish times and
keeps the shared DMA bus busy — on a 1000-node system the same policy is the
first line of straggler mitigation for sparse work (slow shards get fewer
heavy tiles, not fewer tiles).

Also provides the greedy LPT variant (beyond-paper) and a phase-overlap
makespan model of the paper's serialized-DMA execution (Fig 14-a).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Assignment:
    core_of_tile: np.ndarray      # (T,) core id per tile
    order_within: list[np.ndarray]  # execution order per core
    makespan: float
    per_core_work: np.ndarray


def ops_per_tile(pair_counts: np.ndarray, delta_c: int, delta_n: int) -> np.ndarray:
    """MACs per tile: pairs(tile) * dC * dN (the M-V dispatch granularity)."""
    return pair_counts.astype(np.float64) * delta_c * delta_n


def schedule_round_robin_sorted(work: np.ndarray, n_cores: int) -> Assignment:
    """The paper's policy: sort by work desc, deal round-robin."""
    order = np.argsort(-work, kind="stable")
    core_of = np.empty(len(work), np.int32)
    core_of[order] = np.arange(len(work)) % n_cores
    per_core = np.zeros(n_cores)
    np.add.at(per_core, core_of, work)
    order_within = [order[np.flatnonzero(core_of[order] == c)] for c in range(n_cores)]
    return Assignment(core_of, order_within, float(per_core.max()), per_core)


def schedule_lpt(work: np.ndarray, n_cores: int) -> Assignment:
    """Longest-Processing-Time greedy (beyond-paper refinement)."""
    order = np.argsort(-work, kind="stable")
    load = np.zeros(n_cores)
    core_of = np.empty(len(work), np.int32)
    for t in order:
        c = int(np.argmin(load))
        core_of[t] = c
        load[c] += work[t]
    order_within = [order[np.flatnonzero(core_of[order] == c)] for c in range(n_cores)]
    return Assignment(core_of, order_within, float(load.max()), load)


def schedule_naive(work: np.ndarray, n_cores: int) -> Assignment:
    """Unsorted round-robin baseline (Fig 14-b left)."""
    core_of = (np.arange(len(work)) % n_cores).astype(np.int32)
    per_core = np.zeros(n_cores)
    np.add.at(per_core, core_of, work)
    order_within = [np.flatnonzero(core_of == c) for c in range(n_cores)]
    return Assignment(core_of, order_within, float(per_core.max()), per_core)


def phase_overlap_makespan(
    assign: Assignment,
    work: np.ndarray,
    xfer: np.ndarray,
    macs_per_cycle: float,
    bus_elems_per_cycle: float,
) -> float:
    """Model of the paper's distinct compute/data-exchange phases with a
    shared round-robin L1<->L2 bus (Fig 14-a): each core alternates
    (transfer tile_i+1) -> (compute tile_i), transfers serialized on the bus.

    Returns modeled cycles. `work` in MACs and `xfer` in elements per tile.
    """
    n_cores = len(assign.order_within)
    core_time = np.zeros(n_cores)
    bus_free = 0.0
    # interleave transfers in round-robin over cores, in each core's order
    ptrs = [0] * n_cores
    pending = sum(len(o) for o in assign.order_within)
    while pending:
        for c in range(n_cores):
            o = assign.order_within[c]
            if ptrs[c] >= len(o):
                continue
            t = o[ptrs[c]]
            ptrs[c] += 1
            pending -= 1
            start = max(bus_free, core_time[c])
            t_xfer = xfer[t] / max(bus_elems_per_cycle, 1e-9)
            bus_free = start + t_xfer
            core_time[c] = bus_free + work[t] / max(macs_per_cycle, 1e-9)
    return float(core_time.max())
