"""COIR: Compressed Output-response / Input-receptive Field metadata (§IV-A).

Two flavors, exactly as in the paper:

* **CIRF** (out-major): one entry per unique *output* voxel — the indices of
  every active *input* voxel in its receptive field, plus a K-bit weight mask
  whose set bits name the kernel offset (weight plane) of each partner.
* **CORF** (in-major): one entry per unique *input* voxel — the indices of
  every *output* voxel in its response field, plus the weight mask.

The paper stores variable-length index lists; for fixed-shape jit we store a
dense ``(V, K)`` index block with -1 holes and keep the bitmask as the header
word (the WAVES front-end consumes exactly this header). Logical
(variable-length) metadata sizes for bandwidth accounting are computed from
the bitmask popcounts, so compression numbers match the paper's definition,
not the padded layout.

For a submanifold conv the two flavors are transposes of one another; for
resolution-changing convs they differ and SPADE picks the cheaper one.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashgrid import SortedGrid, query_neighbors


class COIR(NamedTuple):
    """COIR metadata block (either flavor; flavor tracked by the caller).

    indices: (V, K) int32 — partner voxel index per weight plane, -1 absent.
    bitmask: (V,) uint32  — bit k set iff indices[:, k] >= 0.
    mask:    (V,)  bool   — active rows of the major point set.
    """

    indices: jax.Array
    bitmask: jax.Array
    mask: jax.Array

    @property
    def n_weight_planes(self) -> int:
        return self.indices.shape[1]

    def valid(self) -> jax.Array:
        return self.indices >= 0

    def popcount(self) -> jax.Array:
        """Active partners per entry (receptive/response field size)."""
        return jnp.sum((self.indices >= 0).astype(jnp.int32), axis=1)

    def arf(self) -> jax.Array:
        """Average Receptive (or Response) Field over active entries —
        the paper's ARF, a.k.a. sparsity attribute SA_MO."""
        pc = self.popcount() * self.mask.astype(jnp.int32)
        n = jnp.maximum(jnp.sum(self.mask.astype(jnp.int32)), 1)
        return jnp.sum(pc) / n

    def n_pairs(self) -> jax.Array:
        return jnp.sum(self.popcount() * self.mask.astype(jnp.int32))


def _pack_bitmask(indices: jax.Array) -> jax.Array:
    k = indices.shape[1]
    bits = (indices >= 0).astype(jnp.uint32) << jnp.arange(k, dtype=jnp.uint32)[None, :]
    return jnp.sum(bits, axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("resolution", "stride"))
def build_cirf(
    out_coords: jax.Array,
    out_mask: jax.Array,
    in_coords: jax.Array,
    in_mask: jax.Array,
    offsets: jax.Array,
    resolution: int,
    stride: int = 1,
) -> COIR:
    """CIRF: out-major receptive-field metadata.

    ``indices[o, k]`` is the input voxel at ``out_coords[o]*stride + offsets[k]``.
    """
    idx = query_neighbors(
        out_coords, out_mask, in_coords, in_mask, offsets, resolution, stride
    )
    return COIR(idx, _pack_bitmask(idx), out_mask)


@functools.partial(jax.jit, static_argnames=("resolution", "stride"))
def build_corf(
    out_coords: jax.Array,
    out_mask: jax.Array,
    in_coords: jax.Array,
    in_mask: jax.Array,
    offsets: jax.Array,
    resolution: int,
    stride: int = 1,
) -> COIR:
    """CORF: in-major response-field metadata.

    Output o is in the response field of input i at plane k iff
    ``o*stride + offsets[k] == i``, i.e. ``o == (i - offsets[k]) / stride``
    where the division is exact and in-bounds.
    """
    out_res = max(resolution // stride, 1) if stride > 1 else resolution
    grid = SortedGrid(out_coords, out_mask, out_res)
    diff = in_coords[:, None, :] - offsets[None, :, :]  # (Vi, K, 3)
    exact = jnp.all(diff % stride == 0, axis=-1)
    probe = diff // stride
    valid = in_mask[:, None] & exact
    idx = grid.lookup(probe, valid)
    return COIR(idx, _pack_bitmask(idx), in_mask)


def transpose_flavor(
    coir: COIR, minor_capacity: int
) -> COIR:
    """Convert CIRF<->CORF by inverting the (major, minor, plane) relation.

    Each (major m, plane k) -> minor i pair becomes (i, k) -> m. Weight-plane
    slot is preserved, so at most one partner per (minor, plane) exists for
    convolution metadata and the scatter is collision-free.
    """
    v, k = coir.indices.shape
    minor = coir.indices  # (V, K)
    major = jnp.broadcast_to(jnp.arange(v, dtype=jnp.int32)[:, None], (v, k))
    plane = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None, :], (v, k))
    ok = minor >= 0
    out = jnp.full((minor_capacity, k), -1, jnp.int32)
    flat_rows = jnp.where(ok, minor, minor_capacity)  # drop invalid
    out = out.at[flat_rows.reshape(-1), plane.reshape(-1)].set(
        jnp.where(ok, major, -1).reshape(-1), mode="drop"
    )
    row_mask = jnp.any(out >= 0, axis=1)
    return COIR(out, _pack_bitmask(out), row_mask)


# ---------------------------------------------------------------------------
# Metadata size accounting (paper §IV-A compression claim; benchmarks use it)
# ---------------------------------------------------------------------------

def coir_size_words(coir: COIR) -> jax.Array:
    """Logical COIR size in 32-bit words: per active entry, 1 header word
    (bitmask) + 1 self index + one word per active partner."""
    act = coir.mask.astype(jnp.int32)
    return jnp.sum((2 + coir.popcount()) * act)


def rulebook_size_words(coir: COIR) -> jax.Array:
    """Size of the baseline per-weight-plane rulebook (SCN reference impl):
    every valid (in, out) pair appears as 2 words in some weight plane list."""
    return 2 * coir.n_pairs()


def kernel_offsets_np(kernel_size: int, centered: bool | None = None) -> np.ndarray:
    from repro.core.hashgrid import kernel_offsets

    return kernel_offsets(kernel_size, centered)
