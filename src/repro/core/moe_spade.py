"""AccSS3D technique transfer: SPADE/COIR machinery applied to MoE dispatch.

Expert routing is token-level spatial sparsity: which (token, expert) pairs
are valid depends only on the data, the unit of work per valid pair is a
matrix-vector product (token activation x expert matrix), and per-expert load
is as skewed as per-region ARF. The mapping:

  AccSS3D                      MoE
  ----------------------       -------------------------------
  active voxels                routed tokens
  weight plane (1 of 27)       expert (1 of E)
  ARF / SA_MO                  tokens-per-expert load
  RST q-quantile tile alloc    capacity factor = q-quantile load
  COIR index list + bitmask    dispatch table (E, cap) + validity
  ops-sorted tile schedule     experts sorted by load over cores

``plan_capacity`` is the paper's RST applied to router statistics;
``build_dispatch`` builds the COIR-style (expert-major = "CIRF over experts")
dispatch metadata used by ``repro.models.moe`` and by the grouped-GEMM
kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def plan_capacity(
    expert_loads: np.ndarray,
    n_experts: int,
    tokens_per_batch: int,
    top_k: int,
    mode: str = "RST",
    quantile: float = 0.90,
    round_to: int = 8,
) -> int:
    """Static expert capacity from observed load samples.

    expert_loads: (samples, E) token counts per expert per batch.
    SST allocates the observed max (never drops, wastes memory); RST
    allocates the q-quantile (the paper's relaxed static tiling; overshoot
    tokens are dropped-to-residual exactly like overshooting tiles split).
    """
    loads = np.asarray(expert_loads, np.float64)
    if mode == "SST":
        cap = float(loads.max())
    else:
        cap = float(np.quantile(loads, quantile))
    cap = max(cap, 1.0)
    uniform = tokens_per_batch * top_k / n_experts
    cap = max(cap, uniform)  # never below perfectly-balanced load
    return int(np.ceil(cap / round_to) * round_to)


def capacity_factor(capacity: int, tokens: int, top_k: int, n_experts: int) -> float:
    return capacity * n_experts / max(tokens * top_k, 1)


@functools.partial(jax.jit, static_argnames=("n_experts", "capacity"))
def build_dispatch(expert_idx: jax.Array, n_experts: int, capacity: int):
    """COIR-style dispatch metadata for top-k routing.

    expert_idx: (T, k) int32 expert of each token assignment.
    Returns (slot (T, k) int32 position within the expert's capacity or -1 if
    dropped, table (E, capacity) int32 token id or -1) — the expert-major
    index list (CIRF analogue) plus the token-major slots (CORF analogue).
    """
    t, k = expert_idx.shape
    flat = expert_idx.reshape(-1)
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                        # slot per assignment
    slot = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    keep = slot < capacity
    slot = jnp.where(keep, slot, -1).reshape(t, k)
    token_of = jnp.broadcast_to(
        jnp.arange(t, dtype=jnp.int32)[:, None], (t, k)
    ).reshape(-1)
    rows = jnp.where(keep, flat, n_experts)
    cols = jnp.where(keep, slot.reshape(-1), 0)
    table = jnp.full((n_experts, capacity), -1, jnp.int32)
    table = table.at[rows, cols].set(
        jnp.where(keep, token_of, -1), mode="drop"
    )
    return slot, table


def expert_load_stats(expert_idx: np.ndarray, n_experts: int) -> np.ndarray:
    """(E,) token counts — the MoE 'sparsity attribute' extraction pass."""
    return np.bincount(np.asarray(expert_idx).reshape(-1), minlength=n_experts)
