"""Numpy mirrors of the jitted AdMAC metadata builders (host plan pass).

The jitted builders in ``core.hashgrid`` / ``core.coir`` run *on the
device* — on CPU they share the XLA stream and thread pool with model
execution, so an async serving pipeline that builds plans in host threads
would queue its metadata computations behind the waves it is trying to
overlap with. These mirrors reproduce the same sorted-key binary-search
flow op-for-op in numpy, keeping the whole offline pass (AdMAC + SOAR +
SPADE + tiles) on the host until ``engine.plan.upload_scene_plan`` moves
the finished plan to the device.

Contract: bit-identical outputs to the jax versions (same index tables,
same bitmasks, same canonical orders). ``tests/test_engine.py`` pins this
transitively — the legacy jax-built metadata path and the engine's
numpy-built plans must produce ``assert_array_equal`` U-Net logits.
"""
from __future__ import annotations

import numpy as np

from repro.core.coir import COIR
from repro.core.hashgrid import kernel_offsets
from repro.sparse.tensor import MAX_RESOLUTION, PAD_COORD


def linear_key_np(coords: np.ndarray, resolution: int,
                  mask: np.ndarray | None = None) -> np.ndarray:
    """Numpy twin of ``sparse.tensor.linear_key`` (int32, same sentinel)."""
    if resolution > MAX_RESOLUTION:
        raise ValueError(
            f"resolution {resolution} > int32-safe max {MAX_RESOLUTION}")
    r = np.int32(resolution)
    c = np.asarray(coords).astype(np.int32)
    key = (c[..., 0] * r + c[..., 1]) * r + c[..., 2]
    sentinel = np.int32(resolution) ** 3
    if mask is not None:
        key = np.where(np.asarray(mask), key, sentinel)
    else:
        key = np.where(np.all(c >= 0, axis=-1), key, sentinel)
    return key.astype(np.int32)


class SortedGridNp:
    """Numpy twin of ``hashgrid.SortedGrid`` (sorted keys + binary search)."""

    def __init__(self, coords: np.ndarray, mask: np.ndarray, resolution: int):
        self.resolution = resolution
        keys = linear_key_np(coords, resolution, mask)
        order = np.argsort(keys, kind="stable")
        self.sorted_keys = keys[order]
        self.sorted_idx = order.astype(np.int32)

    def lookup(self, query_coords: np.ndarray,
               query_valid: np.ndarray) -> np.ndarray:
        r = self.resolution
        q = np.asarray(query_coords)
        in_bounds = np.all((q >= 0) & (q < r), axis=-1)
        valid = np.asarray(query_valid) & in_bounds
        qkey = linear_key_np(q, r, valid)
        pos = np.searchsorted(self.sorted_keys, qkey)
        pos = np.clip(pos, 0, self.sorted_keys.shape[0] - 1)
        found = valid & (self.sorted_keys[pos] == qkey)
        return np.where(found, self.sorted_idx[pos], -1).astype(np.int32)


def query_neighbors_np(
    out_coords: np.ndarray,
    out_mask: np.ndarray,
    in_coords: np.ndarray,
    in_mask: np.ndarray,
    offsets: np.ndarray,
    resolution: int,
    stride: int = 1,
) -> np.ndarray:
    """Numpy twin of ``hashgrid.query_neighbors``."""
    grid = SortedGridNp(in_coords, in_mask, resolution)
    out_coords = np.asarray(out_coords)
    offsets = np.asarray(offsets)
    probe = out_coords[:, None, :] * stride + offsets[None, :, :]
    valid = np.broadcast_to(np.asarray(out_mask)[:, None],
                            (out_coords.shape[0], offsets.shape[0]))
    return grid.lookup(probe, valid)


def _pack_bitmask_np(indices: np.ndarray) -> np.ndarray:
    k = indices.shape[1]
    bits = ((indices >= 0).astype(np.uint32)
            << np.arange(k, dtype=np.uint32)[None, :])
    return bits.sum(axis=1, dtype=np.uint32)


def build_cirf_np(
    out_coords: np.ndarray,
    out_mask: np.ndarray,
    in_coords: np.ndarray,
    in_mask: np.ndarray,
    offsets: np.ndarray,
    resolution: int,
    stride: int = 1,
) -> COIR:
    """Numpy twin of ``coir.build_cirf`` (COIR with numpy leaves)."""
    idx = query_neighbors_np(out_coords, out_mask, in_coords, in_mask,
                             offsets, resolution, stride)
    return COIR(idx, _pack_bitmask_np(idx), np.asarray(out_mask))


def build_corf_np(
    out_coords: np.ndarray,
    out_mask: np.ndarray,
    in_coords: np.ndarray,
    in_mask: np.ndarray,
    offsets: np.ndarray,
    resolution: int,
    stride: int = 1,
) -> COIR:
    """Numpy twin of ``coir.build_corf``."""
    out_res = max(resolution // stride, 1) if stride > 1 else resolution
    grid = SortedGridNp(out_coords, out_mask, out_res)
    in_coords = np.asarray(in_coords)
    offsets = np.asarray(offsets)
    diff = in_coords[:, None, :] - offsets[None, :, :]
    exact = np.all(diff % stride == 0, axis=-1)
    probe = diff // stride
    valid = np.asarray(in_mask)[:, None] & exact
    idx = grid.lookup(probe, valid)
    return COIR(idx, _pack_bitmask_np(idx), np.asarray(in_mask))


def transposed_coir_np(
    coarse_coords: np.ndarray,
    coarse_mask: np.ndarray,
    fine_coords: np.ndarray,
    fine_mask: np.ndarray,
    fine_resolution: int,
    kernel_size: int = 2,
    stride: int = 2,
) -> COIR:
    """Numpy twin of ``sparse_conv.transposed_coir``."""
    offs = kernel_offsets(kernel_size, centered=False)
    return build_corf_np(coarse_coords, coarse_mask, fine_coords, fine_mask,
                         offs, fine_resolution, stride)


def shard_halo_tables_np(
    indices: np.ndarray,
    n_shards: int,
    halo: int = 0,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Split an out-major ``(V, K)`` COIR index block over ``n_shards``
    contiguous capacity shards, producing per-shard local metadata plus the
    all-to-all send tables a halo exchange consumes.

    Shard ``s`` owns global rows ``[s*Vs, (s+1)*Vs)`` (``Vs = V //
    n_shards``). An output row's receptive field may reference input rows
    owned by other shards — the *halo*. For every (owner ``d``, consumer
    ``s``) pair we collect the sorted unique global rows ``s`` needs from
    ``d``; ``halo`` pads each pair slot to a fixed budget (0 = size to this
    block's worst pair; a positive budget is validated and raised on
    overflow so a pinned serving signature can never silently drop rows).

    Returns ``(local_idx, send_rows, n_halo_rows)``:

    * ``local_idx`` ``(S, Vs, K)`` int32 — the index block remapped into
      each shard's local buffer ``concat([own rows (Vs), halo rows
      (S*H)])``: ``[0, Vs)`` shard-local, ``Vs + d*H + j`` the j-th row
      received from shard ``d``, ``-1`` holes (unchanged).
    * ``send_rows`` ``(S, S, H)`` int32 — ``send_rows[d, s]`` lists the
      rows shard ``d`` sends to shard ``s``, *local to d*; ``-1`` pads.
    * ``n_halo_rows`` — total real (non-pad) cross-shard rows, the wire
      traffic a halo exchange of this conv moves (x feature row bytes).
    """
    idx = np.asarray(indices)
    V, _ = idx.shape
    S = int(n_shards)
    if S < 1 or V % S:
        raise ValueError(
            f"capacity {V} not divisible into {S} equal shards")
    Vs = V // S
    send_lists: list[list[np.ndarray]] = [[None] * S for _ in range(S)]
    h_needed = 0
    for s in range(S):
        blk = idx[s * Vs:(s + 1) * Vs]
        rows = np.unique(blk[blk >= 0])
        remote = rows[(rows < s * Vs) | (rows >= (s + 1) * Vs)]
        owners = remote // Vs
        for d in range(S):
            send_lists[d][s] = remote[owners == d]
            h_needed = max(h_needed, len(send_lists[d][s]))
    H = int(halo) if halo else max(h_needed, 1)
    if h_needed > H:
        raise ValueError(
            f"halo budget {H} rows/pair < required {h_needed}; raise the "
            "ShardLayout halo (or re-pin it from representative scenes)")
    send_rows = np.full((S, S, H), -1, np.int32)
    local_idx = np.empty((S, Vs, len(idx[0])), np.int32)
    n_halo = 0
    for s in range(S):
        glob2loc = np.full((V,), -1, np.int32)
        glob2loc[s * Vs:(s + 1) * Vs] = np.arange(Vs, dtype=np.int32)
        for d in range(S):
            rows = send_lists[d][s]
            n_halo += len(rows)
            send_rows[d, s, :len(rows)] = (rows - d * Vs).astype(np.int32)
            glob2loc[rows] = Vs + d * H + np.arange(len(rows), dtype=np.int32)
        blk = idx[s * Vs:(s + 1) * Vs]
        local_idx[s] = np.where(blk >= 0, glob2loc[np.maximum(blk, 0)], -1)
    return local_idx, send_rows, n_halo


def downsample_coords_np(
    coords: np.ndarray,
    mask: np.ndarray,
    resolution: int,
    factor: int = 2,
    capacity_out: int | None = None,
):
    """Numpy twin of ``hashgrid.downsample_coords`` (same canonical order)."""
    coords = np.asarray(coords)
    mask = np.asarray(mask)
    cap_out = capacity_out or coords.shape[0]
    r_out = max(resolution // factor, 1)
    down = np.where(mask[:, None], coords // factor, PAD_COORD)
    keys = linear_key_np(down, r_out, mask)
    sorted_keys = np.sort(keys)
    is_first = np.concatenate(
        [[True], sorted_keys[1:] != sorted_keys[:-1]]
    ) & (sorted_keys < np.int32(r_out) ** 3)
    dest = np.cumsum(is_first.astype(np.int32)) - 1
    out_keys = np.full((cap_out,), np.int32(2**31 - 1))
    keep = is_first & (dest < cap_out)
    out_keys[dest[keep]] = sorted_keys[keep]
    n_out = int(is_first.sum())
    out_mask = np.arange(cap_out) < n_out
    out_coords = np.stack(
        [
            out_keys // (r_out * r_out),
            (out_keys // r_out) % r_out,
            out_keys % r_out,
        ],
        axis=-1,
    ).astype(np.int32)
    out_coords = np.where(out_mask[:, None], out_coords, PAD_COORD)
    return out_coords, out_mask
