"""Numpy mirrors of the jitted AdMAC metadata builders (host plan pass).

The jitted builders in ``core.hashgrid`` / ``core.coir`` run *on the
device* — on CPU they share the XLA stream and thread pool with model
execution, so an async serving pipeline that builds plans in host threads
would queue its metadata computations behind the waves it is trying to
overlap with. These mirrors reproduce the same sorted-key binary-search
flow op-for-op in numpy, keeping the whole offline pass (AdMAC + SOAR +
SPADE + tiles) on the host until ``engine.plan.upload_scene_plan`` moves
the finished plan to the device.

Contract: bit-identical outputs to the jax versions (same index tables,
same bitmasks, same canonical orders). ``tests/test_engine.py`` pins this
transitively — the legacy jax-built metadata path and the engine's
numpy-built plans must produce ``assert_array_equal`` U-Net logits.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.coir import COIR
from repro.core.hashgrid import UpdatableSortedGrid, kernel_offsets
from repro.sparse.tensor import MAX_RESOLUTION, PAD_COORD


def linear_key_np(coords: np.ndarray, resolution: int,
                  mask: np.ndarray | None = None) -> np.ndarray:
    """Numpy twin of ``sparse.tensor.linear_key`` (int32, same sentinel)."""
    if resolution > MAX_RESOLUTION:
        raise ValueError(
            f"resolution {resolution} > int32-safe max {MAX_RESOLUTION}")
    r = np.int32(resolution)
    c = np.asarray(coords).astype(np.int32)
    key = (c[..., 0] * r + c[..., 1]) * r + c[..., 2]
    sentinel = np.int32(resolution) ** 3
    if mask is not None:
        key = np.where(np.asarray(mask), key, sentinel)
    else:
        key = np.where(np.all(c >= 0, axis=-1), key, sentinel)
    return key.astype(np.int32)


class SortedGridNp:
    """Numpy twin of ``hashgrid.SortedGrid`` (sorted keys + binary search)."""

    def __init__(self, coords: np.ndarray, mask: np.ndarray, resolution: int):
        self.resolution = resolution
        keys = linear_key_np(coords, resolution, mask)
        order = np.argsort(keys, kind="stable")
        self.sorted_keys = keys[order]
        self.sorted_idx = order.astype(np.int32)

    def lookup(self, query_coords: np.ndarray,
               query_valid: np.ndarray) -> np.ndarray:
        r = self.resolution
        q = np.asarray(query_coords)
        in_bounds = np.all((q >= 0) & (q < r), axis=-1)
        valid = np.asarray(query_valid) & in_bounds
        qkey = linear_key_np(q, r, valid)
        pos = np.searchsorted(self.sorted_keys, qkey)
        pos = np.clip(pos, 0, self.sorted_keys.shape[0] - 1)
        found = valid & (self.sorted_keys[pos] == qkey)
        return np.where(found, self.sorted_idx[pos], -1).astype(np.int32)


def query_neighbors_np(
    out_coords: np.ndarray,
    out_mask: np.ndarray,
    in_coords: np.ndarray,
    in_mask: np.ndarray,
    offsets: np.ndarray,
    resolution: int,
    stride: int = 1,
) -> np.ndarray:
    """Numpy twin of ``hashgrid.query_neighbors``."""
    grid = SortedGridNp(in_coords, in_mask, resolution)
    out_coords = np.asarray(out_coords)
    offsets = np.asarray(offsets)
    probe = out_coords[:, None, :] * stride + offsets[None, :, :]
    valid = np.broadcast_to(np.asarray(out_mask)[:, None],
                            (out_coords.shape[0], offsets.shape[0]))
    return grid.lookup(probe, valid)


def _pack_bitmask_np(indices: np.ndarray) -> np.ndarray:
    k = indices.shape[1]
    bits = ((indices >= 0).astype(np.uint32)
            << np.arange(k, dtype=np.uint32)[None, :])
    return bits.sum(axis=1, dtype=np.uint32)


def build_cirf_np(
    out_coords: np.ndarray,
    out_mask: np.ndarray,
    in_coords: np.ndarray,
    in_mask: np.ndarray,
    offsets: np.ndarray,
    resolution: int,
    stride: int = 1,
) -> COIR:
    """Numpy twin of ``coir.build_cirf`` (COIR with numpy leaves)."""
    idx = query_neighbors_np(out_coords, out_mask, in_coords, in_mask,
                             offsets, resolution, stride)
    return COIR(idx, _pack_bitmask_np(idx), np.asarray(out_mask))


def build_corf_np(
    out_coords: np.ndarray,
    out_mask: np.ndarray,
    in_coords: np.ndarray,
    in_mask: np.ndarray,
    offsets: np.ndarray,
    resolution: int,
    stride: int = 1,
) -> COIR:
    """Numpy twin of ``coir.build_corf``."""
    out_res = max(resolution // stride, 1) if stride > 1 else resolution
    grid = SortedGridNp(out_coords, out_mask, out_res)
    in_coords = np.asarray(in_coords)
    offsets = np.asarray(offsets)
    diff = in_coords[:, None, :] - offsets[None, :, :]
    exact = np.all(diff % stride == 0, axis=-1)
    probe = diff // stride
    valid = np.asarray(in_mask)[:, None] & exact
    idx = grid.lookup(probe, valid)
    return COIR(idx, _pack_bitmask_np(idx), np.asarray(in_mask))


def transposed_coir_np(
    coarse_coords: np.ndarray,
    coarse_mask: np.ndarray,
    fine_coords: np.ndarray,
    fine_mask: np.ndarray,
    fine_resolution: int,
    kernel_size: int = 2,
    stride: int = 2,
) -> COIR:
    """Numpy twin of ``sparse_conv.transposed_coir``."""
    offs = kernel_offsets(kernel_size, centered=False)
    return build_corf_np(coarse_coords, coarse_mask, fine_coords, fine_mask,
                         offs, fine_resolution, stride)


def shard_halo_tables_np(
    indices: np.ndarray,
    n_shards: int,
    halo: int = 0,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Split an out-major ``(V, K)`` COIR index block over ``n_shards``
    contiguous capacity shards, producing per-shard local metadata plus the
    all-to-all send tables a halo exchange consumes.

    Shard ``s`` owns global rows ``[s*Vs, (s+1)*Vs)`` (``Vs = V //
    n_shards``). An output row's receptive field may reference input rows
    owned by other shards — the *halo*. For every (owner ``d``, consumer
    ``s``) pair we collect the sorted unique global rows ``s`` needs from
    ``d``; ``halo`` pads each pair slot to a fixed budget (0 = size to this
    block's worst pair; a positive budget is validated and raised on
    overflow so a pinned serving signature can never silently drop rows).

    Returns ``(local_idx, send_rows, n_halo_rows)``:

    * ``local_idx`` ``(S, Vs, K)`` int32 — the index block remapped into
      each shard's local buffer ``concat([own rows (Vs), halo rows
      (S*H)])``: ``[0, Vs)`` shard-local, ``Vs + d*H + j`` the j-th row
      received from shard ``d``, ``-1`` holes (unchanged).
    * ``send_rows`` ``(S, S, H)`` int32 — ``send_rows[d, s]`` lists the
      rows shard ``d`` sends to shard ``s``, *local to d*; ``-1`` pads.
    * ``n_halo_rows`` — total real (non-pad) cross-shard rows, the wire
      traffic a halo exchange of this conv moves (x feature row bytes).
    """
    idx = np.asarray(indices)
    V, _ = idx.shape
    S = int(n_shards)
    if S < 1 or V % S:
        raise ValueError(
            f"capacity {V} not divisible into {S} equal shards")
    Vs = V // S
    send_lists: list[list[np.ndarray]] = [[None] * S for _ in range(S)]
    h_needed = 0
    for s in range(S):
        blk = idx[s * Vs:(s + 1) * Vs]
        rows = np.unique(blk[blk >= 0])
        remote = rows[(rows < s * Vs) | (rows >= (s + 1) * Vs)]
        owners = remote // Vs
        for d in range(S):
            send_lists[d][s] = remote[owners == d]
            h_needed = max(h_needed, len(send_lists[d][s]))
    H = int(halo) if halo else max(h_needed, 1)
    if h_needed > H:
        raise ValueError(
            f"halo budget {H} rows/pair < required {h_needed}; raise the "
            "ShardLayout halo (or re-pin it from representative scenes)")
    send_rows = np.full((S, S, H), -1, np.int32)
    local_idx = np.empty((S, Vs, len(idx[0])), np.int32)
    n_halo = 0
    for s in range(S):
        glob2loc = np.full((V,), -1, np.int32)
        glob2loc[s * Vs:(s + 1) * Vs] = np.arange(Vs, dtype=np.int32)
        for d in range(S):
            rows = send_lists[d][s]
            n_halo += len(rows)
            send_rows[d, s, :len(rows)] = (rows - d * Vs).astype(np.int32)
            glob2loc[rows] = Vs + d * H + np.arange(len(rows), dtype=np.int32)
        blk = idx[s * Vs:(s + 1) * Vs]
        local_idx[s] = np.where(blk >= 0, glob2loc[np.maximum(blk, 0)], -1)
    return local_idx, send_rows, n_halo


def downsample_coords_np(
    coords: np.ndarray,
    mask: np.ndarray,
    resolution: int,
    factor: int = 2,
    capacity_out: int | None = None,
):
    """Numpy twin of ``hashgrid.downsample_coords`` (same canonical order)."""
    coords = np.asarray(coords)
    mask = np.asarray(mask)
    cap_out = capacity_out or coords.shape[0]
    r_out = max(resolution // factor, 1)
    down = np.where(mask[:, None], coords // factor, PAD_COORD)
    keys = linear_key_np(down, r_out, mask)
    sorted_keys = np.sort(keys)
    is_first = np.concatenate(
        [[True], sorted_keys[1:] != sorted_keys[:-1]]
    ) & (sorted_keys < np.int32(r_out) ** 3)
    dest = np.cumsum(is_first.astype(np.int32)) - 1
    out_keys = np.full((cap_out,), np.int32(2**31 - 1))
    keep = is_first & (dest < cap_out)
    out_keys[dest[keep]] = sorted_keys[keep]
    n_out = int(is_first.sum())
    out_mask = np.arange(cap_out) < n_out
    out_coords = np.stack(
        [
            out_keys // (r_out * r_out),
            (out_keys // r_out) % r_out,
            out_keys % r_out,
        ],
        axis=-1,
    ).astype(np.int32)
    out_coords = np.where(out_mask[:, None], out_coords, PAD_COORD)
    return out_coords, out_mask


# ---------------------------------------------------------------------------
# Streaming: delta-based incremental metadata for overlapping LiDAR frames
# ---------------------------------------------------------------------------

_OFFS3 = kernel_offsets(3)                   # centered 3^3 submanifold stencil
_OFFS2 = kernel_offsets(2, centered=False)   # [0,2)^3 down/up pair stencil
_K3 = _OFFS3.shape[0]                        # 27
_K2 = _OFFS2.shape[0]                        # 8


def _key_offset(shift: np.ndarray, resolution: int) -> int:
    """Linear-key delta of a uniform coordinate shift (valid while every
    shifted coordinate stays inside ``[0, resolution)^3``)."""
    s = np.asarray(shift, np.int64)
    r = int(resolution)
    return int((s[0] * r + s[1]) * r + s[2])


def _decode_keys(keys: np.ndarray, resolution: int) -> np.ndarray:
    """Coordinates of valid linear keys (inverse of ``linear_key_np``)."""
    k = np.asarray(keys)
    r = resolution
    return np.stack([k // (r * r), (k // r) % r, k % r], axis=-1).astype(
        np.int32)


def _prefix_lookup(keys_sorted: np.ndarray, probe_coords: np.ndarray,
                   resolution: int) -> np.ndarray:
    """Neighbour lookup against a sorted-prefix active set (row == rank).

    Bit-identical to ``SortedGridNp.lookup`` when the voxel list is laid out
    as its own sorted-key prefix (the ``downsample_coords_np`` canonical
    order): the capacity-shaped grid's sentinel rows sort after every valid
    key and can never match a valid query, so the prefix alone suffices.
    """
    q = np.asarray(probe_coords)
    in_bounds = np.all((q >= 0) & (q < resolution), axis=-1)
    qkey = linear_key_np(q, resolution, in_bounds)
    if not len(keys_sorted):
        return np.full(qkey.shape, -1, np.int32)
    pos = np.searchsorted(keys_sorted, qkey)
    pos = np.minimum(pos, len(keys_sorted) - 1)
    found = in_bounds & (keys_sorted[pos] == qkey)
    return np.where(found, pos, -1).astype(np.int32)


@dataclass
class SceneDelta:
    """Row-level diff between consecutive frames of one stream.

    Rows of the previous frame refer to the stream's *canonical* (packed)
    layout; rows of the new frame refer to the caller's layout. Retained
    pairs are aligned (``retained_prev_rows[i]`` is the same voxel as
    ``retained_new_rows[i]``) and ordered by ascending new-frame linear key,
    as are ``added_new_rows``. Coordinates must be unique per frame.
    """

    retained_prev_rows: np.ndarray
    retained_new_rows: np.ndarray
    added_new_rows: np.ndarray
    removed_prev_rows: np.ndarray
    n_prev: int
    n_new: int

    @property
    def overlap(self) -> float:
        """Retained fraction relative to the larger of the two frames."""
        return len(self.retained_prev_rows) / max(self.n_prev, self.n_new, 1)


def diff_scene_np(
    prev_coords: np.ndarray,
    prev_mask: np.ndarray,
    new_coords: np.ndarray,
    new_mask: np.ndarray,
    resolution: int,
    ego_shift=(0, 0, 0),
) -> SceneDelta:
    """Added/removed/retained voxel sets after ego-motion re-basing.

    ``ego_shift`` is the sensor translation in voxel units: a previous-frame
    voxel at ``c`` re-bases to ``c - ego_shift`` in the new frame's local
    coordinates. Previous voxels shifted outside ``[0, resolution)^3`` are
    removed; the rest match against the new frame by linear key.
    """
    shift = np.asarray(ego_shift, np.int32).reshape(3)
    prev_coords = np.asarray(prev_coords)
    new_coords = np.asarray(new_coords)
    prev_act = np.flatnonzero(np.asarray(prev_mask)).astype(np.int32)
    new_act = np.flatnonzero(np.asarray(new_mask)).astype(np.int32)
    nk = linear_key_np(new_coords[new_act], resolution)
    order = np.argsort(nk, kind="stable")
    snk, snr = nk[order], new_act[order]
    reb = prev_coords[prev_act] - shift
    inb = np.all((reb >= 0) & (reb < resolution), axis=-1) \
        if len(prev_act) else np.zeros((0,), bool)
    rk = linear_key_np(reb[inb], resolution)
    order = np.argsort(rk, kind="stable")
    srk, spr = rk[order], prev_act[inb][order]
    if len(srk):
        pos = np.searchsorted(srk, snk)
        hit = srk[np.minimum(pos, len(srk) - 1)] == snk
    else:
        pos = np.zeros(len(snk), np.int64)
        hit = np.zeros(len(snk), bool)
    if len(snk):
        back = np.searchsorted(snk, srk)
        kept = snk[np.minimum(back, len(snk) - 1)] == srk
    else:
        kept = np.zeros(len(srk), bool)
    removed = np.concatenate([prev_act[~inb], spr[~kept]])
    removed.sort()
    return SceneDelta(
        retained_prev_rows=spr[np.minimum(pos, max(len(srk) - 1, 0))][hit]
        if len(srk) else spr[:0],
        retained_new_rows=snr[hit],
        added_new_rows=snr[~hit],
        removed_prev_rows=removed.astype(np.int32),
        n_prev=int(len(prev_act)),
        n_new=int(len(new_act)),
    )


def pack_stream_frame_np(frame_rows: np.ndarray,
                         values: np.ndarray) -> np.ndarray:
    """Permute caller-layout per-row values into the stream's canonical
    layout (``frame_rows[i]`` = canonical row of caller row i, -1 inactive).
    Inactive canonical rows are zero-filled."""
    frame_rows = np.asarray(frame_rows)
    values = np.asarray(values)
    out = np.zeros(values.shape, values.dtype)
    act = frame_rows >= 0
    out[frame_rows[act]] = values[act]
    return out


@dataclass
class StreamFrameMeta:
    """One stream step's geometry + patched metadata, ready for assembly.

    ``levels[li] = (coords, mask, sub_coir)``; ``pairs[li] = (down_coir,
    up_coir)`` for the (li, li+1) strided pair. ``changed`` / ``pair_changed``
    say which tables differ from the previous frame's (unchanged entries are
    the *same array objects*, enabling device-upload memoization upstream).
    """

    mode: str                       # "rebuilt" | "patched" | "reused"
    overlap: float
    frame_rows: np.ndarray          # caller row -> canonical row (-1 pad)
    levels: list = field(default_factory=list)
    pairs: list = field(default_factory=list)
    changed: list = field(default_factory=list)
    pair_changed: list = field(default_factory=list)
    info: dict = field(default_factory=dict)


class StreamMetaState:
    """Per-stream incremental host-metadata state (the tentpole's core).

    Holds the previous frame's canonical geometry, per-level sorted key
    prefixes, active-child counts and COIR tables, plus a level-0
    ``UpdatableSortedGrid``. ``step`` diffs the incoming frame against the
    cached state and *patches* the tables — O(copy + churn·K·log V) instead
    of the from-scratch O(V·K·log V) searchsorted sweep — falling back to a
    full rebuild on high churn, empty frames, or an ego shift that is not
    divisible by the coarsest level's stride product.

    Patched tables are bitwise-identical to ``build_cirf_np`` /
    ``transposed_coir_np`` on the packed frame (property-tested in
    ``tests/test_streaming.py``).
    """

    def __init__(self, resolution: int, capacity: int, n_levels: int):
        if resolution % (1 << (n_levels - 1)):
            raise ValueError(
                f"resolution {resolution} not divisible by 2^{n_levels - 1}")
        self.resolution = resolution
        self.capacity = capacity
        self.n_levels = n_levels
        self.n: list | None = None  # None until the first frame

    # -- full (re)build ----------------------------------------------------

    def reset(self, coords: np.ndarray, mask: np.ndarray) -> None:
        """Adopt ``(coords, mask)`` as the canonical layout, from scratch."""
        coords = np.ascontiguousarray(np.asarray(coords, np.int32))
        mask = np.ascontiguousarray(np.asarray(mask, bool))
        geo = []
        c, m, res = coords, mask, self.resolution
        for li in range(self.n_levels):
            geo.append((c, m, res))
            if li < self.n_levels - 1:
                c, m = downsample_coords_np(c, m, res, 2)
                res = max(res // 2, 1)
        self.coords = [g[0] for g in geo]
        self.mask = [g[1] for g in geo]
        self.n = [int(g[1].sum()) for g in geo]
        self.keys = [None]
        self.counts: list = [None]
        self.grid = UpdatableSortedGrid.from_coords(coords, mask,
                                                    self.resolution)
        self.sub = []
        self.down = []
        self.up = []
        for li, (c, m, res) in enumerate(geo):
            self.sub.append(build_cirf_np(c, m, c, m, _OFFS3, res))
            if li > 0:
                self.keys.append(
                    linear_key_np(c[: self.n[li]], res))
                fc, fm, fres = geo[li - 1]
                pk = linear_key_np(
                    np.asarray(fc)[np.asarray(fm)] // 2, res)
                rows = np.searchsorted(self.keys[li], pk)
                self.counts.append(np.bincount(
                    rows, minlength=self.capacity).astype(np.int32))
        for li in range(self.n_levels - 1):
            fc, fm, fres = geo[li]
            cc, cm, _ = geo[li + 1]
            self.down.append(
                build_cirf_np(cc, cm, fc, fm, _OFFS2, fres, stride=2))
            self.up.append(
                transposed_coir_np(cc, cm, fc, fm, fres, 2, 2))

    # -- one stream step ---------------------------------------------------

    def step(self, coords: np.ndarray, mask: np.ndarray,
             ego_shift=(0, 0, 0), *,
             min_overlap: float = 0.5) -> StreamFrameMeta:
        """Advance the stream by one frame; returns patched metadata.

        ``coords``/``mask`` are the caller's layout; the returned
        ``frame_rows`` maps caller rows into the canonical layout (identity
        on a rebuild, retained-row-preserving on a patch).
        """
        coords = np.asarray(coords, np.int32)
        mask = np.asarray(mask, bool)
        if coords.shape[0] != self.capacity:
            raise ValueError(
                f"frame capacity {coords.shape[0]} != {self.capacity}")
        shift = np.asarray(ego_shift, np.int32).reshape(3)
        div = 1 << (self.n_levels - 1)
        fallback = None
        delta = None
        if self.n is None:
            fallback = "first_frame"
        elif np.any(shift % div):
            fallback = "ego_shift_alignment"
        else:
            delta = diff_scene_np(self.coords[0], self.mask[0], coords, mask,
                                  self.resolution, shift)
            if delta.n_new == 0 or delta.n_prev == 0:
                fallback = "empty_frame"
            elif delta.overlap < min_overlap:
                fallback = "churn"
        if fallback is not None:
            self.reset(coords, mask)
            frame_rows = np.where(
                mask, np.arange(self.capacity, dtype=np.int32), np.int32(-1))
            meta = self._emit("rebuilt", 0.0 if delta is None
                              else delta.overlap, frame_rows,
                              [True] * self.n_levels,
                              [True] * (self.n_levels - 1))
            meta.info["fallback"] = fallback
            return meta
        if (not len(delta.added_new_rows) and not len(delta.removed_prev_rows)
                and not shift.any()):
            frame_rows = np.full((self.capacity,), -1, np.int32)
            frame_rows[delta.retained_new_rows] = delta.retained_prev_rows
            return self._emit("reused", delta.overlap, frame_rows,
                              [False] * self.n_levels,
                              [False] * (self.n_levels - 1))
        return self._patch(coords, shift, delta)

    def _emit(self, mode, overlap, frame_rows, changed,
              pair_changed) -> StreamFrameMeta:
        return StreamFrameMeta(
            mode=mode, overlap=float(overlap), frame_rows=frame_rows,
            levels=[(self.coords[li], self.mask[li], self.sub[li])
                    for li in range(self.n_levels)],
            pairs=[(self.down[li], self.up[li])
                   for li in range(self.n_levels - 1)],
            changed=list(changed), pair_changed=list(pair_changed),
            info={"n_active": self.n[0]},
        )

    def _patch(self, coords: np.ndarray, shift: np.ndarray,
               delta: SceneDelta) -> StreamFrameMeta:
        cap, res = self.capacity, self.resolution
        ret_p, ret_n = delta.retained_prev_rows, delta.retained_new_rows
        add_n, rem = delta.added_new_rows, delta.removed_prev_rows
        A, R = len(add_n), len(rem)
        changed = [False] * self.n_levels
        pair_changed = [False] * (self.n_levels - 1)

        # ---- level 0: rows are stable identities, patch copy in place ----
        prev_c0, prev_m0 = self.coords[0], self.mask[0]
        rem_coords_prev = prev_c0[rem]           # previous coordinate space
        rem_keys_prev = linear_key_np(rem_coords_prev, res)
        freeable = ~prev_m0.copy()
        freeable[rem] = True
        free = np.flatnonzero(freeable)
        assigned = free[:A].astype(np.int32)     # ascending rows for
        add_coords = coords[add_n]               # ascending added keys
        frame_rows = np.full((cap,), -1, np.int32)
        frame_rows[ret_n] = ret_p
        frame_rows[add_n] = assigned
        m0 = prev_m0.copy()
        m0[rem] = False
        m0[assigned] = True
        c0 = prev_c0.copy()
        c0[~m0] = PAD_COORD
        c0[ret_p] = coords[ret_n]
        c0[assigned] = add_coords
        # grid: delete removed (previous keys) -> ego shift -> insert added
        self.grid.delete(np.sort(rem_keys_prev))
        self.grid.shift(-_key_offset(shift, res))
        self.grid.insert(linear_key_np(add_coords, res), assigned)
        if A or R:
            sub = self.sub[0]
            T = np.asarray(sub.indices).copy()
            bm = np.asarray(sub.bitmask).copy()
            k_ar = np.arange(_K3, dtype=np.int32)
            touched = [rem, assigned]
            if R:
                # drop reciprocal entries pointing at removed voxels
                rv = T[rem]
                rvm = rv >= 0
                jj = rv[rvm]
                kk = np.broadcast_to(k_ar, rv.shape)[rvm]
                T[jj, _K3 - 1 - kk] = -1
                T[rem] = -1
                touched.append(jj)
            if A:
                probe = add_coords[:, None, :] + _OFFS3[None, :, :]
                add_idx = self.grid.lookup(probe, np.ones((A, _K3), bool))
                T[assigned] = add_idx
                avm = add_idx >= 0
                jj = add_idx[avm]
                kk = np.broadcast_to(k_ar, add_idx.shape)[avm]
                aa = np.broadcast_to(assigned[:, None], add_idx.shape)[avm]
                T[jj, _K3 - 1 - kk] = aa
                touched.append(jj)
            touched = np.unique(np.concatenate(
                [np.asarray(t, np.int32) for t in touched]))
            bm[touched] = _pack_bitmask_np(T[touched])
            self.sub[0] = COIR(T, bm, m0)
            changed[0] = True
        else:
            self.sub[0] = COIR(np.asarray(self.sub[0].indices),
                               np.asarray(self.sub[0].bitmask), m0)
        self.coords[0], self.mask[0] = c0, m0
        self.n[0] = int(delta.n_new)

        # fine-level delta threaded up the pyramid
        f_add_rows, f_add_coords = assigned, add_coords        # new space
        f_rem_rows, f_rem_coords = rem, rem_coords_prev        # prev space
        f_remap = np.arange(cap, dtype=np.int32)
        f_remap[rem] = -1
        # retained level-0 rows: active before AND not removed (a freed row
        # reused by an added voxel is active in both masks but not retained)
        f_kept = np.flatnonzero(prev_m0 & (f_remap >= 0)).astype(np.int32)
        f_kept_prev, f_kept_new = f_kept, f_kept
        f_mask = m0

        for li in range(1, self.n_levels):
            r_l = res >> li
            s_l = shift // (1 << li)
            n_prev = self.n[li]
            pkeys = self.keys[li]
            counts = self.counts[li]
            # removals (previous coordinate space)
            if len(f_rem_rows):
                rpk = linear_key_np(f_rem_coords // 2, r_l)
                dec = np.bincount(np.searchsorted(pkeys, rpk),
                                  minlength=n_prev).astype(np.int32)
            else:
                dec = np.zeros(n_prev, np.int32)
            c_after = counts[:n_prev] - dec
            kept = c_after > 0
            kept_prev_rows = np.flatnonzero(kept).astype(np.int32)
            rem_c_rows = np.flatnonzero(~kept).astype(np.int32)
            kept_keys = (pkeys[kept] - np.int32(
                _key_offset(s_l, r_l))).astype(np.int32)
            # additions (new coordinate space)
            if len(f_add_rows):
                upar, ucnt = np.unique(
                    linear_key_np(f_add_coords // 2, r_l),
                    return_counts=True)
            else:
                upar = np.empty(0, np.int32)
                ucnt = np.empty(0, np.int64)
            if len(kept_keys) and len(upar):
                pos = np.searchsorted(kept_keys, upar)
                hit = kept_keys[np.minimum(
                    pos, len(kept_keys) - 1)] == upar
            else:
                pos = np.zeros(len(upar), np.int64)
                hit = np.zeros(len(upar), bool)
            ins_keys = upar[~hit].astype(np.int32)
            ins_cnt = ucnt[~hit].astype(np.int32)
            n_ins = len(ins_keys)
            # merged sorted layout (no re-sort: two searchsorted merges)
            ins_before = np.searchsorted(ins_keys, kept_keys)
            kept_new_rows = (np.arange(len(kept_keys)) +
                             ins_before).astype(np.int32)
            ins_new_rows = (np.searchsorted(kept_keys, ins_keys) +
                            np.arange(n_ins)).astype(np.int32)
            new_keys = np.empty(len(kept_keys) + n_ins, np.int32)
            new_keys[kept_new_rows] = kept_keys
            new_keys[ins_new_rows] = ins_keys
            n_new = len(new_keys)
            if n_new > cap:
                raise AssertionError("coarse level overflow")  # unreachable
            c_remap = np.full(cap, -1, np.int32)
            c_remap[kept_prev_rows] = kept_new_rows
            new_counts = np.zeros(cap, np.int32)
            new_counts[kept_new_rows] = c_after[kept]
            if hit.any():
                new_counts[kept_new_rows[pos[hit]]] += ucnt[hit].astype(
                    np.int32)
            new_counts[ins_new_rows] = ins_cnt
            c_changed = bool(n_ins or len(rem_c_rows))
            shifted = bool(s_l.any())
            # geometry, mirroring downsample_coords_np's decode exactly
            if c_changed or shifted:
                out_keys = np.full((cap,), np.int32(2**31 - 1))
                out_keys[:n_new] = new_keys
                m_l = np.arange(cap) < n_new
                c_l = np.stack(
                    [out_keys // (r_l * r_l),
                     (out_keys // r_l) % r_l,
                     out_keys % r_l], axis=-1).astype(np.int32)
                c_l = np.where(m_l[:, None], c_l, PAD_COORD)
                if not c_changed:
                    m_l = self.mask[li]     # same n: reuse the mask leaf
            else:
                c_l, m_l = self.coords[li], self.mask[li]
            # coarse submanifold table: gather kept rows, probe inserted
            if c_changed:
                prev_T = np.asarray(self.sub[li].indices)
                T = np.empty((cap, _K3), np.int32)
                T[n_new:] = -1      # every row < n_new is kept or inserted
                pv = prev_T[kept_prev_rows]
                T[kept_new_rows] = np.where(
                    pv >= 0, c_remap[np.maximum(pv, 0)], -1)
                if n_ins:
                    ins_coords = c_l[ins_new_rows]
                    probe = ins_coords[:, None, :] + _OFFS3[None, :, :]
                    ins_idx = _prefix_lookup(new_keys, probe, r_l)
                    T[ins_new_rows] = ins_idx
                    k_ar = np.arange(_K3, dtype=np.int32)
                    ivm = ins_idx >= 0
                    jj = ins_idx[ivm]
                    kk = np.broadcast_to(k_ar, ins_idx.shape)[ivm]
                    aa = np.broadcast_to(
                        ins_new_rows[:, None], ins_idx.shape)[ivm]
                    T[jj, _K3 - 1 - kk] = aa
                bm = np.zeros(cap, np.uint32)
                bm[:n_new] = _pack_bitmask_np(T[:n_new])
                self.sub[li] = COIR(T, bm, m_l)
                changed[li] = True
            elif m_l is not self.mask[li]:
                self.sub[li] = COIR(np.asarray(self.sub[li].indices),
                                    np.asarray(self.sub[li].bitmask), m_l)
            # down/up pair (li-1, li): changed iff the fine delta is nonempty
            if len(f_add_rows) or len(f_rem_rows):
                prev_D = np.asarray(self.down[li - 1].indices)
                D = np.empty((cap, _K2), np.int32)
                D[n_new:] = -1
                D[ins_new_rows] = -1    # filled by the added-child scatter
                dv = prev_D[kept_prev_rows]
                D[kept_new_rows] = np.where(
                    dv >= 0, f_remap[np.maximum(dv, 0)], -1)
                # up table: each active fine row has exactly one valid entry,
                # at k* = (c mod 2) lexicographic, pointing at its parent —
                # no 8-wide gather or bitmask pack needed.
                prev_U = np.asarray(self.up[li - 1].indices)
                U = np.full((cap, _K2), -1, np.int32)
                fine_c = self.coords[li - 1]
                if len(f_kept_prev):
                    kc = fine_c[f_kept_new]
                    kst = (kc[:, 0] % 2) * 4 + (kc[:, 1] % 2) * 2 \
                        + (kc[:, 2] % 2)
                    U[f_kept_new, kst] = c_remap[
                        prev_U[f_kept_prev].max(axis=1)]
                if len(f_add_rows):
                    ac = f_add_coords
                    kk = (ac[:, 0] % 2) * 4 + (ac[:, 1] % 2) * 2 \
                        + (ac[:, 2] % 2)
                    prow = np.searchsorted(
                        new_keys, linear_key_np(ac // 2, r_l)).astype(
                            np.int32)
                    D[prow, kk] = f_add_rows
                    U[f_add_rows, kk] = prow
                dbm = np.zeros(cap, np.uint32)
                dbm[:n_new] = _pack_bitmask_np(D[:n_new])
                fact = np.flatnonzero(f_mask)
                fc_act = fine_c[fact]
                ubm = np.zeros(cap, np.uint32)
                ubm[fact] = np.uint32(1) << (
                    (fc_act[:, 0] % 2) * 4 + (fc_act[:, 1] % 2) * 2
                    + (fc_act[:, 2] % 2)).astype(np.uint32)
                self.down[li - 1] = COIR(D, dbm, m_l)
                self.up[li - 1] = COIR(U, ubm, f_mask)
                pair_changed[li - 1] = True
            # thread this level's delta up as the next level's fine delta
            if len(rem_c_rows):
                f_rem_coords = _decode_keys(pkeys[rem_c_rows], r_l)
            else:
                f_rem_coords = np.empty((0, 3), np.int32)
            f_rem_rows = rem_c_rows
            f_add_rows = ins_new_rows
            f_add_coords = (c_l[ins_new_rows] if n_ins
                            else np.empty((0, 3), np.int32))
            f_remap = c_remap
            f_kept_prev, f_kept_new = kept_prev_rows, kept_new_rows
            f_mask = m_l
            self.keys[li] = new_keys
            self.counts[li] = new_counts
            self.coords[li], self.mask[li] = c_l, m_l
            self.n[li] = n_new

        return self._emit("patched", delta.overlap, frame_rows,
                          changed, pair_changed)
