"""SOAR: Surface-Orientation-Aware Reordering of pointclouds (§IV-B).

Host-side (numpy) offline pass, exactly the paper's algorithm:

1. Build the adjacency map (from ``repro.core.hashgrid`` neighbour tables).
2. Pick the unselected voxel with the minimum number of neighbours as the
   root (a surface corner).
3. Grow an m-ary tree in breadth-first order: pop voxels from the Neighbour
   Queue; skip already-selected ones; otherwise append to the chunk, mark
   selected, and push all its neighbours.
4. When the chunk reaches the size bound, emit it; the next root is the
   minimum-degree voxel in the Neighbour Queue, which is then flushed.

Hierarchical SOAR (§V-B): chunks are reinterpreted as points (adjacent iff
any member voxels are adjacent) and SOAR recurses with the outer level's
size bound, innermost to outermost.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np


@dataclass
class SoarResult:
    order: np.ndarray        # (n_active,) permutation: new position -> old index
    chunk_starts: np.ndarray  # (n_chunks + 1,) boundaries into `order`

    @property
    def n_chunks(self) -> int:
        return len(self.chunk_starts) - 1


def _neighbor_lists(neighbor_table: np.ndarray) -> list[np.ndarray]:
    """Per-voxel neighbour index lists from a (V, K) table (-1 holes),
    excluding self-edges."""
    v = neighbor_table.shape[0]
    lists = []
    for i in range(v):
        nb = neighbor_table[i]
        nb = nb[(nb >= 0) & (nb != i)]
        lists.append(nb)
    return lists


def soar_order(
    neighbor_table: np.ndarray,
    active_mask: np.ndarray,
    max_chunk_voxels: int,
) -> SoarResult:
    """Chunked breadth-first reordering of the active voxels."""
    v = neighbor_table.shape[0]
    nbrs = _neighbor_lists(neighbor_table)
    degree = np.array([len(n) for n in nbrs])
    active = np.asarray(active_mask, bool).copy()
    selected = np.zeros(v, bool)
    # min-degree order among active voxels, used for root selection
    root_order = np.argsort(degree + np.where(active, 0, 1 << 30), kind="stable")
    root_ptr = 0

    order: list[int] = []
    chunk_starts = [0]
    queue: deque[int] = deque()
    n_active = int(active.sum())
    chunk_count = 0

    def next_root() -> int:
        nonlocal root_ptr
        # prefer min-degree voxel from the Neighbour Queue (paper), else the
        # globally min-degree unselected voxel
        if queue:
            cands = [q for q in queue if active[q] and not selected[q]]
            if cands:
                return min(cands, key=lambda q: degree[q])
        while root_ptr < v:
            r = root_order[root_ptr]
            root_ptr += 1
            if active[r] and not selected[r]:
                return int(r)
        return -1

    while len(order) < n_active:
        root = next_root()
        if root < 0:
            break
        queue.clear()
        queue.append(root)
        while queue and chunk_count < max_chunk_voxels:
            u = queue.popleft()
            if selected[u] or not active[u]:
                continue
            selected[u] = True
            order.append(u)
            chunk_count += 1
            for w in nbrs[u]:
                if active[w] and not selected[w]:
                    queue.append(int(w))
        if chunk_count >= max_chunk_voxels or not queue:
            if chunk_count:
                chunk_starts.append(len(order))
                chunk_count = 0
            # queue is flushed after root selection of next chunk (paper);
            # we keep it until next_root() has inspected it, then clear there
    if chunk_starts[-1] != len(order):
        chunk_starts.append(len(order))
    return SoarResult(np.array(order, np.int64), np.array(chunk_starts, np.int64))


def soar_hierarchical(
    neighbor_table: np.ndarray,
    active_mask: np.ndarray,
    chunk_sizes: list[int],
) -> SoarResult:
    """Multi-level SOAR: innermost chunk size first (§V-B).

    Returns the flattened voxel order with chunk boundaries of the
    *innermost* level; outer levels permute whole inner chunks.
    """
    assert chunk_sizes, "need at least one level"
    inner = soar_order(neighbor_table, active_mask, chunk_sizes[0])
    if len(chunk_sizes) == 1:
        return inner
    # Build chunk-level adjacency: chunks adjacent iff any voxel pair is.
    n_chunks = inner.n_chunks
    chunk_of = np.full(neighbor_table.shape[0], -1, np.int64)
    for c in range(n_chunks):
        seg = inner.order[inner.chunk_starts[c]:inner.chunk_starts[c + 1]]
        chunk_of[seg] = c
    adj = [set() for _ in range(n_chunks)]
    for i in np.flatnonzero(np.asarray(active_mask)):
        ci = chunk_of[i]
        if ci < 0:
            continue
        for w in neighbor_table[i]:
            if w >= 0 and chunk_of[w] >= 0 and chunk_of[w] != ci:
                adj[ci].add(int(chunk_of[w]))
    kmax = max((len(a) for a in adj), default=1) or 1
    chunk_nbr = np.full((n_chunks, kmax), -1, np.int64)
    for c, a in enumerate(adj):
        lst = sorted(a)
        chunk_nbr[c, : len(lst)] = lst
    outer_budget = max(chunk_sizes[1] // max(chunk_sizes[0], 1), 1)
    outer = soar_hierarchical(
        chunk_nbr, np.ones(n_chunks, bool), [outer_budget] + [
            s // max(chunk_sizes[0], 1) for s in chunk_sizes[2:]
        ],
    )
    # Flatten: permute inner chunks by the outer order.
    order = np.concatenate(
        [
            inner.order[inner.chunk_starts[c]:inner.chunk_starts[c + 1]]
            for c in outer.order
        ]
    )
    sizes = np.diff(inner.chunk_starts)[outer.order]
    chunk_starts = np.concatenate([[0], np.cumsum(sizes)])
    return SoarResult(order, chunk_starts)


def raster_order(coords: np.ndarray, active_mask: np.ndarray,
                 axes=(0, 1, 2)) -> np.ndarray:
    """Raster-scan baseline orderings (Fig 23): lexicographic sort along the
    given axis priority."""
    act = np.flatnonzero(np.asarray(active_mask))
    keycols = [coords[act, a] for a in reversed(axes)]
    return act[np.lexsort(keycols)]


def tiled_unique_input_accesses(
    order: np.ndarray, cirf_indices: np.ndarray, tile_out: int
) -> int:
    """Data-access cost model used for Fig 23: process outputs in `order` in
    tiles of `tile_out`; each tile fetches its unique input partners once.
    Returns total input-row fetches across tiles."""
    total = 0
    for s in range(0, len(order), tile_out):
        rows = cirf_indices[order[s:s + tile_out]]
        ids = rows[rows >= 0]
        total += len(np.unique(ids))
    return total
