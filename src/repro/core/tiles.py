"""Tiled COIR metadata (dM) for SSpNNA execution (§V-C processing flow).

OTF-SPADE re-groups the adjacency/COIR entries into per-tile metadata blocks
sized by the SPADE plan: each tile owns a run of dO consecutive SOAR-ordered
outputs, the tile's unique input rows (its L1 working set), and *tile-local*
partner indices. Tiles whose unique-input count overshoots the RST
allocation are split in two (next power of two), exactly the paper's
overshoot rule.

Host-side numpy; the result is a stack of fixed-shape arrays consumed by the
Pallas kernel (``repro.kernels.sspnna``) and by the DMA-table generator.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass
class TilePlan:
    out_rows: np.ndarray    # (T, dO) int32 global output row per tile slot, -1 pad
    in_rows: np.ndarray     # (T, dI) int32 global input rows (tile working set), -1 pad
    local_idx: np.ndarray   # (T, dO, K) int32 index into the tile's in_rows, -1 hole
    pair_counts: np.ndarray  # (T,) valid pairs per tile (ops-per-tile / dC / dN)

    @property
    def n_tiles(self) -> int:
        return self.out_rows.shape[0]

    @property
    def delta_o(self) -> int:
        return self.out_rows.shape[1]

    @property
    def delta_i(self) -> int:
        return self.in_rows.shape[1]


def max_tiles(n_rows: int, delta_o: int, delta_i: int, kernel_volume: int) -> int:
    """Upper bound on the tile count of the budgeted (``n_tiles``) planner.

    A tile closes either full-by-rows (at most ceil(n/dO) such tiles) or
    full-by-inputs, holding more than ``delta_i - K`` unique inputs; since
    per-tile unique inputs sum to at most ``n_rows * K`` pairs, the second
    kind is bounded too. Used to pin static shapes for the serving engine.
    """
    n = max(n_rows, 1)
    by_rows = math.ceil(n / delta_o)
    by_inputs = math.ceil(n * kernel_volume / max(delta_i - kernel_volume + 1, 1))
    return by_rows + by_inputs + 1


def build_tile_plan(
    cirf_indices: np.ndarray,
    order: np.ndarray,
    delta_o: int,
    delta_i: int,
    n_tiles: int | None = None,
) -> TilePlan:
    """Regroup out-major COIR into fixed-shape tile metadata.

    cirf_indices: (V, K) global partner indices (-1 holes).
    order: SOAR (or raster) ordering of active output rows.
    n_tiles: when given, use the budgeted greedy planner — every tile fits
        ``delta_i`` by construction (close a tile before a row would
        overflow it) — and pad the tile stack to exactly ``n_tiles`` so the
        output shapes are scene-independent (serving-engine mode). Raises
        ``ValueError`` if the scene needs more tiles than that.
    """
    cirf_indices = np.asarray(cirf_indices)
    k = cirf_indices.shape[1]

    tiles: list[np.ndarray] = []

    if n_tiles is not None:
        if delta_i < k:
            raise ValueError(f"delta_i {delta_i} < kernel volume {k}")
        cur: list[int] = []
        cur_uniq: set[int] = set()
        for r in np.asarray(order, np.int64):
            part = cirf_indices[r]
            new = set(part[part >= 0].tolist())
            if cur and (len(cur) == delta_o or len(cur_uniq | new) > delta_i):
                tiles.append(np.asarray(cur, np.int64))
                cur, cur_uniq = [], set()
            cur.append(int(r))
            cur_uniq |= new
        if cur:
            tiles.append(np.asarray(cur, np.int64))
        if len(tiles) > n_tiles:
            raise ValueError(
                f"scene needs {len(tiles)} tiles > budget {n_tiles} "
                f"(delta_o={delta_o}, delta_i={delta_i})")
    else:
        def emit(rows: np.ndarray):
            """Split until the unique-input working set fits delta_i."""
            part = cirf_indices[rows]
            uniq = np.unique(part[part >= 0])
            if len(uniq) > delta_i and len(rows) > 1:
                mid = len(rows) // 2
                emit(rows[:mid])
                emit(rows[mid:])
            else:
                tiles.append(rows)

        for s in range(0, len(order), delta_o):
            emit(np.asarray(order[s:s + delta_o], np.int64))

    t = n_tiles if n_tiles is not None else len(tiles)
    out_rows = np.full((t, delta_o), -1, np.int32)
    in_rows = np.full((t, delta_i), -1, np.int32)
    local_idx = np.full((t, delta_o, k), -1, np.int32)
    pair_counts = np.zeros((t,), np.int64)
    for ti, rows in enumerate(tiles):
        out_rows[ti, : len(rows)] = rows
        part = cirf_indices[rows]  # (r, K)
        valid = part >= 0
        uniq = np.unique(part[valid])
        if len(uniq) > delta_i:  # single row overshoot: truncate working set
            uniq = uniq[:delta_i]
        in_rows[ti, : len(uniq)] = uniq
        loc = np.searchsorted(uniq, part)
        loc = np.clip(loc, 0, max(len(uniq) - 1, 0))
        hit = valid & (uniq[loc] == part) if len(uniq) else np.zeros_like(valid)
        local_idx[ti, : len(rows)] = np.where(hit, loc, -1)
        pair_counts[ti] = int(hit.sum())
    return TilePlan(out_rows, in_rows, local_idx, pair_counts)


def plan_dma_tables(plan: TilePlan) -> dict:
    """DMA descriptor accounting (§V-A-3): ordered datatype -> one block
    entry per tile; unordered datatype -> one entry per voxel. Returns entry
    counts + transferred elements for the energy/bandwidth model."""
    t = plan.n_tiles
    in_valid = (plan.in_rows >= 0).sum()
    out_valid = (plan.out_rows >= 0).sum()
    return {
        "block_entries": t,            # ordered side: 1 per tile
        "voxel_entries": int(in_valid),  # unordered side: per voxel
        "in_rows_transferred": int(in_valid),
        "out_rows_transferred": int(out_valid),
    }
