"""Tiled COIR metadata (dM) for SSpNNA execution (§V-C processing flow).

OTF-SPADE re-groups the adjacency/COIR entries into per-tile metadata blocks
sized by the SPADE plan: each tile owns a run of dO consecutive SOAR-ordered
outputs, the tile's unique input rows (its L1 working set), and *tile-local*
partner indices. Tiles whose unique-input count overshoots the RST
allocation are split in two (next power of two), exactly the paper's
overshoot rule. A *single row* whose working set overshoots ``delta_i`` is
split across plane groups (unbudgeted mode) or is a hard planning error
(budgeted mode) — pairs are never silently dropped; ``TilePlan`` carries
the accounting (``n_row_splits`` / ``dropped_pairs``) so callers can assert
the no-drop invariant.

Host-side numpy; the result is a stack of fixed-shape arrays consumed by
the Pallas kernel (``repro.kernels.sspnna``) and, via ``dma_tile_tables``,
by the fused kernel's scalar-prefetched DMA engines (§V-A-3): the ordered
datatype gets one block entry per tile, the unordered datatype one
per-voxel entry — exactly the two tables the fused kernel walks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np


@dataclass
class TilePlan:
    out_rows: np.ndarray    # (T, dO) int32 global output row per tile slot, -1 pad
    in_rows: np.ndarray     # (T, dI) int32 global input rows (tile working set), -1 pad
    local_idx: np.ndarray   # (T, dO, K) int32 index into the tile's in_rows, -1 hole
    pair_counts: np.ndarray  # (T,) int32 valid pairs per tile (ops-per-tile / dC / dN)
    n_row_splits: int = 0   # tiles created by splitting one row across planes
    dropped_pairs: int = 0  # invariant: always 0 (kept so callers can assert it)

    @property
    def n_tiles(self) -> int:
        return self.out_rows.shape[0]

    @property
    def delta_o(self) -> int:
        return self.out_rows.shape[1]

    @property
    def delta_i(self) -> int:
        return self.in_rows.shape[1]


class DmaTileTables(NamedTuple):
    """``TilePlan`` re-emitted in the layout the fused kernel's DMA engines
    walk (scalar-prefetch arguments, §V-A-3):

    * ``in_rows``: (T, dI) int32, pad slots clamped to row 0 — every entry is
      a safe HBM source; validity lives in ``local_idx`` (no hole ever
      references a pad slot, so the clamped rows are gathered-and-ignored).
    * ``out_rows``: (T, dO) int32, pad slots redirected to the trash row
      ``n_out`` — the kernel scatters every slot unconditionally into an
      ``(n_out + 1)``-row buffer and the caller slices the trash row off.
    * ``pair_counts``: (T,) int32, the dead-tile predicate (0 ⇒ the kernel
      skips the tile's DMAs and MACs entirely).
    """

    in_rows: np.ndarray
    out_rows: np.ndarray
    pair_counts: np.ndarray


def dma_tile_tables(plan: TilePlan, n_out: int) -> DmaTileTables:
    """Emit ``plan``'s tables in DMA-table layout for an ``n_out``-row scene."""
    in_rows = np.maximum(plan.in_rows, 0).astype(np.int32)
    out_rows = np.where(plan.out_rows < 0, n_out, plan.out_rows).astype(np.int32)
    return DmaTileTables(in_rows, out_rows,
                         plan.pair_counts.astype(np.int32))


def max_tiles(n_rows: int, delta_o: int, delta_i: int, kernel_volume: int) -> int:
    """Upper bound on the tile count of the budgeted (``n_tiles``) planner.

    A tile closes either full-by-rows (at most ceil(n/dO) such tiles) or
    full-by-inputs, holding more than ``delta_i - K`` unique inputs; since
    per-tile unique inputs sum to at most ``n_rows * K`` pairs, the second
    kind is bounded too. Used to pin static shapes for the serving engine.
    """
    n = max(n_rows, 1)
    by_rows = math.ceil(n / delta_o)
    by_inputs = math.ceil(n * kernel_volume / max(delta_i - kernel_volume + 1, 1))
    return by_rows + by_inputs + 1


def _split_row_by_planes(part: np.ndarray, delta_i: int) -> list[np.ndarray]:
    """Partition one row's K planes into groups whose unique partner sets fit
    ``delta_i``. Each plane contributes at most one partner, so the greedy
    walk needs at most ceil(n_unique / delta_i) groups and drops nothing."""
    k = part.shape[0]
    groups: list[list[int]] = []
    cur: list[int] = []
    cur_uniq: set[int] = set()
    for p in range(k):
        partner = int(part[p])
        new = {partner} if partner >= 0 else set()
        if cur and len(cur_uniq | new) > delta_i:
            groups.append(cur)
            cur, cur_uniq = [], set()
        cur.append(p)
        cur_uniq |= new
    if cur:
        groups.append(cur)
    return [np.asarray(g, np.int64) for g in groups]


def build_tile_plan(
    cirf_indices: np.ndarray,
    order: np.ndarray,
    delta_o: int,
    delta_i: int,
    n_tiles: int | None = None,
) -> TilePlan:
    """Regroup out-major COIR into fixed-shape tile metadata.

    cirf_indices: (V, K) global partner indices (-1 holes).
    order: SOAR (or raster) ordering of active output rows.
    n_tiles: when given, use the budgeted greedy planner — every tile fits
        ``delta_i`` by construction (close a tile before a row would
        overflow it) — and pad the tile stack to exactly ``n_tiles`` so the
        output shapes are scene-independent (serving-engine mode). Raises
        ``ValueError`` if the scene needs more tiles than that, or if a
        single row's working set cannot fit ``delta_i`` (pairs are never
        silently dropped).

    In unbudgeted mode a single row whose unique partners overshoot
    ``delta_i`` (only possible when ``delta_i < K``) is split across plane
    groups into several tiles that share the output row; such plans require
    an accumulating scatter (``TilePlan.n_row_splits > 0`` flags them, and
    the fused kernel's overwrite-DMA path refuses them).
    """
    cirf_indices = np.asarray(cirf_indices)
    k = cirf_indices.shape[1]

    # each planned tile: (rows, planes) — planes is None for "all K planes"
    tiles: list[tuple[np.ndarray, np.ndarray | None]] = []
    n_row_splits = 0

    if n_tiles is not None:
        if delta_i < k:
            raise ValueError(f"delta_i {delta_i} < kernel volume {k}")
        cur: list[int] = []
        cur_uniq: set[int] = set()
        for r in np.asarray(order, np.int64):
            part = cirf_indices[r]
            new = set(part[part >= 0].tolist())
            if len(new) > delta_i:  # can't happen while delta_i >= K; be loud
                raise ValueError(
                    f"row {int(r)} working set {len(new)} > delta_i {delta_i} "
                    "in budgeted mode (would drop pairs)")
            if cur and (len(cur) == delta_o or len(cur_uniq | new) > delta_i):
                tiles.append((np.asarray(cur, np.int64), None))
                cur, cur_uniq = [], set()
            cur.append(int(r))
            cur_uniq |= new
        if cur:
            tiles.append((np.asarray(cur, np.int64), None))
        if len(tiles) > n_tiles:
            raise ValueError(
                f"scene needs {len(tiles)} tiles > budget {n_tiles} "
                f"(delta_o={delta_o}, delta_i={delta_i})")
    else:
        def emit(rows: np.ndarray):
            """Split until the unique-input working set fits delta_i."""
            part = cirf_indices[rows]
            uniq = np.unique(part[part >= 0])
            if len(uniq) > delta_i:
                if len(rows) > 1:
                    mid = len(rows) // 2
                    emit(rows[:mid])
                    emit(rows[mid:])
                else:  # single-row overshoot: split across plane groups
                    nonlocal n_row_splits
                    groups = _split_row_by_planes(part[0], delta_i)
                    n_row_splits += len(groups) - 1
                    for g in groups:
                        tiles.append((rows, g))
            else:
                tiles.append((rows, None))

        for s in range(0, len(order), delta_o):
            emit(np.asarray(order[s:s + delta_o], np.int64))

    t = n_tiles if n_tiles is not None else len(tiles)
    out_rows = np.full((t, delta_o), -1, np.int32)
    in_rows = np.full((t, delta_i), -1, np.int32)
    local_idx = np.full((t, delta_o, k), -1, np.int32)
    pair_counts = np.zeros((t,), np.int32)
    for ti, (rows, planes) in enumerate(tiles):
        out_rows[ti, : len(rows)] = rows
        part = cirf_indices[rows].copy()  # (r, K)
        if planes is not None:  # plane-split tile: hole the other planes
            keep = np.zeros((k,), bool)
            keep[planes] = True
            part[:, ~keep] = -1
        valid = part >= 0
        uniq = np.unique(part[valid])
        assert len(uniq) <= delta_i, "planner invariant: working set fits"
        in_rows[ti, : len(uniq)] = uniq
        loc = np.searchsorted(uniq, part)
        loc = np.clip(loc, 0, max(len(uniq) - 1, 0))
        hit = valid & (uniq[loc] == part) if len(uniq) else np.zeros_like(valid)
        local_idx[ti, : len(rows)] = np.where(hit, loc, -1)
        pair_counts[ti] = int(hit.sum())
    return TilePlan(out_rows, in_rows, local_idx, pair_counts,
                    n_row_splits=n_row_splits, dropped_pairs=0)


def plan_dma_tables(plan: TilePlan) -> dict:
    """DMA descriptor accounting (§V-A-3): ordered datatype -> one block
    entry per tile; unordered datatype -> one entry per voxel. Returns entry
    counts + transferred elements for the energy/bandwidth model."""
    t = plan.n_tiles
    in_valid = (plan.in_rows >= 0).sum()
    out_valid = (plan.out_rows >= 0).sum()
    return {
        "block_entries": t,            # ordered side: 1 per tile
        "voxel_entries": int(in_valid),  # unordered side: per voxel
        "in_rows_transferred": int(in_valid),
        "out_rows_transferred": int(out_valid),
    }


def modeled_hbm_bytes(plan: TilePlan, c_in: int, n_out: int,
                      itemsize: int = 4) -> dict:
    """Modeled HBM feature traffic of the three execution paths for one conv
    with ``c_in`` input and ``n_out`` output channels (§V-A).

    The fused kernel streams every DMA-table slot of every *alive* tile —
    pad slots are clamped entries and transfer too, so the model charges
    the padded ``dI`` / ``dO`` widths, exactly what ``_fused_kernel``'s DMA
    loops issue; dead tiles are skipped. The pre-gathered paths transfer
    the valid entries through the gather/scatter *and* round-trip the full
    ``(T, dI, C)`` working-set copy and ``(T, dO, N)`` tile-output stack
    through HBM (padded, dead tiles included — XLA can't skip them).
    Metadata (int32 tables) is counted once for every path.
    """
    d = plan_dma_tables(plan)
    t, d_o, d_i = plan.n_tiles, plan.delta_o, plan.delta_i
    k = plan.local_idx.shape[2]
    meta = (t * d_i + t * d_o + t * d_o * k + t) * 4  # int32 tables
    valid_read = d["in_rows_transferred"] * c_in * itemsize
    valid_write = d["out_rows_transferred"] * n_out * itemsize
    alive = int((plan.pair_counts > 0).sum())
    gathered = t * d_i * c_in * itemsize       # full (T, dI, C) copy
    tile_out = t * d_o * n_out * itemsize      # full (T, dO, N) stack
    # gather write + kernel read of the copy, tile-out write + scatter read
    roundtrip = meta + valid_read + valid_write + 2 * gathered + 2 * tile_out
    return {
        "alive_tiles": alive,
        "fused": meta + alive * (d_i * c_in + d_o * n_out) * itemsize,
        "pregathered": roundtrip,
        "reference_gather": roundtrip,
    }
