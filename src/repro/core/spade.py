"""SPADE: SParsity-Aware Dataflow Explorer (§IV-C, §V-C).

The first sparsity-aware dataflow optimizer: it decouples *sparsity
attributes* (extracted in one cheap pass over COIR metadata) from the
*analytical data-access model* (Eqn 5), so the full (tile x walk-pattern x
metadata-flavor) design space is explored without reprocessing the
pointcloud.

Definitions (paper notation):
  I, O, K, C, N, M : layer totals (input/output voxels, kernel volume,
                     channels, metadata words)
  SA_I(R, dO)  = f_I / dO   : unique minor points fetched per major point in
                              a region of dO consecutive (SOAR-ordered)
                              majors — takes the form 1 + beta (boundary
                              fraction)
  SA_MO(R, dO) = f_MO / dO  : average receptive/response field (ARF)

Tile footprint (Eqn 1):  dT = dI*dC + dO*dN + K*dC*dN + dM
Data accesses (Eqn 5):
  DA = F_WS(WP, ceil(O/dO)) * (C*N*K)
     + F_IS(WP, ceil(N/dN)) * (SA_I_avg(dO) * O * C)
     + F_OS(WP, ceil(C/dC)) * (O*N + SA_MO_avg(dO) * O)
  with F_X(Y, Z) = 1 if Y == X else Z.

Static tiling: SST allocates for the worst-case region; RST allocates the
q-th quantile (default 90) and models overshooting tiles as split-in-two
(next power of two), per the paper.

Offline mode (§V-C): SA_I is a *meta* attribute (MSA_I, consistent across
pointclouds — it tracks the surface-to-volume ratio alpha_m / v^(1/m));
ARF is the input-specific attribute (JSA). offline_table() precomputes the
optimal dataflow per ARF bin; OTF-SPADE then only measures ARF (one popcount
pass) and looks the plan up.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

WALK_PATTERNS = ("IS", "OS", "WS")
FLAVORS = ("CIRF", "CORF")


# ---------------------------------------------------------------------------
# Sparsity attributes
# ---------------------------------------------------------------------------

@dataclass
class SparsityAttributes:
    """Per-(region-size) attribute summaries for one layer + one ordering."""

    delta_majors: np.ndarray          # (D,) region sizes examined
    sa_minor_avg: np.ndarray          # (D,) mean SA_I over regions
    sa_minor_alloc_sst: np.ndarray    # (D,) max  SA_I (SST allocation)
    sa_minor_alloc_rst: np.ndarray    # (D,) q-quantile SA_I (RST)
    arf_avg: np.ndarray               # (D,) mean SA_MO
    arf_alloc_sst: np.ndarray
    arf_alloc_rst: np.ndarray
    rst_overshoot_frac: np.ndarray    # (D,) fraction of tiles above quantile
    quantile: float = 0.90

    def at(self, delta: int, name: str) -> float:
        i = int(np.searchsorted(self.delta_majors, delta))
        i = min(i, len(self.delta_majors) - 1)
        return float(getattr(self, name)[i])


def extract_attributes(
    major_indices: np.ndarray,
    major_mask: np.ndarray,
    order: np.ndarray | None = None,
    deltas: tuple[int, ...] = (64, 128, 256, 512, 1024, 2048, 4096),
    quantile: float = 0.90,
) -> SparsityAttributes:
    """One pass over COIR metadata -> sparsity attributes for all region
    sizes. ``major_indices`` is COIR.indices (V, K) as numpy."""
    act = np.flatnonzero(np.asarray(major_mask))
    if order is None:
        order = act
    rows = np.asarray(major_indices)[order]
    n = len(order)
    d_list, sa_avg, sa_max, sa_q, arf_a, arf_m, arf_q, over = ([] for _ in range(8))
    for d in deltas:
        d_eff = min(d, max(n, 1))
        sa_i, sa_mo = [], []
        for s in range(0, n, d_eff):
            blk = rows[s:s + d_eff]
            ids = blk[blk >= 0]
            cnt = len(blk)
            if cnt == 0:
                continue
            sa_i.append(len(np.unique(ids)) / cnt)
            sa_mo.append(len(ids) / cnt)
        sa_i = np.array(sa_i) if sa_i else np.array([1.0])
        sa_mo = np.array(sa_mo) if sa_mo else np.array([1.0])
        d_list.append(d)
        sa_avg.append(sa_i.mean())
        sa_max.append(sa_i.max())
        sa_q.append(np.quantile(sa_i, quantile))
        arf_a.append(sa_mo.mean())
        arf_m.append(sa_mo.max())
        arf_q.append(np.quantile(sa_mo, quantile))
        over.append(float(np.mean(sa_i > np.quantile(sa_i, quantile))))
    return SparsityAttributes(
        np.array(d_list), np.array(sa_avg), np.array(sa_max), np.array(sa_q),
        np.array(arf_a), np.array(arf_m), np.array(arf_q), np.array(over),
        quantile,
    )


def surface_ratio_model(delta_o: np.ndarray, alpha: float, m: int = 3) -> np.ndarray:
    """The paper's observed fit: SA_I(v) ~ 1 + alpha_m / v^(1/m)
    (surface-to-volume ratio of an m-cube)."""
    return 1.0 + alpha / np.maximum(delta_o, 1) ** (1.0 / m)


def fit_surface_ratio(attrs: SparsityAttributes, m: int = 3) -> tuple[float, float]:
    """Least-squares alpha and correlation of SA_I_avg against the
    surface-ratio model (reproduces the Fig 15 observation)."""
    x = 1.0 / attrs.delta_majors ** (1.0 / m)
    y = attrs.sa_minor_avg - 1.0
    alpha = float(np.dot(x, y) / max(np.dot(x, x), 1e-12))
    pred = alpha * x
    corr = float(np.corrcoef(pred, y)[0, 1]) if len(x) > 2 else 1.0
    return alpha, corr


# ---------------------------------------------------------------------------
# Layer spec + dataflow candidates
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerSpec:
    name: str
    n_in: int        # I
    n_out: int       # O
    kernel_volume: int
    c_in: int
    c_out: int
    dtype_bytes: int = 2


@dataclass(frozen=True)
class Dataflow:
    delta_major: int     # dO (CIRF) or dI (CORF)
    delta_c: int
    delta_n: int
    walk: str            # IS | OS | WS
    flavor: str          # CIRF | CORF
    tiling: str          # SST | RST
    tile_elems: float
    da_elems: float
    da_breakdown: tuple[float, float, float] = (0.0, 0.0, 0.0)

    @property
    def da_bytes(self) -> float:
        return self.da_elems  # caller scales by dtype


def _f(cur: str, want: str, repeats: float) -> float:
    return 1.0 if cur == want else repeats


def data_accesses(
    layer: LayerSpec,
    attrs: SparsityAttributes,
    delta_major: int,
    delta_c: int,
    delta_n: int,
    walk: str,
    flavor: str,
) -> tuple[float, tuple[float, float, float]]:
    """Eqn 5, in elements. For CORF, I and O swap roles (paper §IV-C note)."""
    k, c, n = layer.kernel_volume, layer.c_in, layer.c_out
    if flavor == "CIRF":
        majors, minor_ch, major_ch = layer.n_out, c, n
    else:
        majors, minor_ch, major_ch = layer.n_in, n, c
    sa_i = attrs.at(delta_major, "sa_minor_avg")
    arf = attrs.at(delta_major, "arf_avg")
    w_term = _f(walk, "WS", math.ceil(majors / delta_major)) * (c * n * k)
    i_term = _f(walk, "IS", math.ceil((n if flavor == "CIRF" else c) / delta_n)) * (
        sa_i * majors * minor_ch
    )
    o_term = _f(walk, "OS", math.ceil((c if flavor == "CIRF" else n) / delta_c)) * (
        majors * major_ch + arf * majors
    )
    return w_term + i_term + o_term, (w_term, i_term, o_term)


def tile_footprint(
    layer: LayerSpec,
    attrs: SparsityAttributes,
    delta_major: int,
    delta_c: int,
    delta_n: int,
    flavor: str,
    tiling: str,
) -> float:
    """Eqn 1 in elements, using SST/RST allocation attributes."""
    which = "sa_minor_alloc_sst" if tiling == "SST" else "sa_minor_alloc_rst"
    arf_which = "arf_alloc_sst" if tiling == "SST" else "arf_alloc_rst"
    sa_alloc = attrs.at(delta_major, which)
    arf_alloc = attrs.at(delta_major, arf_which)
    d_minor = sa_alloc * delta_major
    d_m = (2.0 + arf_alloc) * delta_major  # COIR words (header + self + list)
    if flavor == "CIRF":
        return (
            d_minor * delta_c
            + delta_major * delta_n
            + layer.kernel_volume * delta_c * delta_n
            + d_m
        )
    return (
        delta_major * delta_c
        + d_minor * delta_n
        + layer.kernel_volume * delta_c * delta_n
        + d_m
    )


def _pow2_range(hi: int, lo: int = 8) -> list[int]:
    vals, v = [], lo
    while v < hi:
        vals.append(v)
        v *= 2
    vals.append(hi)
    return sorted(set(vals))


def explore(
    layer: LayerSpec,
    attrs_by_flavor: dict[str, SparsityAttributes],
    mem_budget_bytes: int,
    tiling: str = "RST",
    walks: tuple[str, ...] = WALK_PATTERNS,
    flavors: tuple[str, ...] = FLAVORS,
) -> Dataflow:
    """Full design-space sweep (Fig 10): min-DA dataflow under the footprint
    constraint. ``attrs_by_flavor`` maps flavor -> attributes extracted from
    that flavor's COIR (CORF attrs describe the scatter side)."""
    budget_elems = mem_budget_bytes / layer.dtype_bytes
    best: Dataflow | None = None
    for flavor in flavors:
        if flavor not in attrs_by_flavor:
            continue
        attrs = attrs_by_flavor[flavor]
        majors = layer.n_out if flavor == "CIRF" else layer.n_in
        for dm in _pow2_range(max(majors, 8), 32):
            for dc in _pow2_range(layer.c_in, 8):
                for dn in _pow2_range(layer.c_out, 8):
                    t = tile_footprint(layer, attrs, dm, dc, dn, flavor, tiling)
                    if t > budget_elems:
                        continue
                    for wp in walks:
                        da, br = data_accesses(layer, attrs, dm, dc, dn, wp, flavor)
                        if tiling == "RST":
                            # overshooting tiles split in two -> extra weight
                            # refetches on the split fraction
                            over = attrs.at(dm, "rst_overshoot_frac")
                            da = da * (1.0 + 0.5 * over)
                        cand = Dataflow(dm, dc, dn, wp, flavor, tiling, t, da, br)
                        if best is None or cand.da_elems < best.da_elems:
                            best = cand
    if best is None:  # nothing fits: smallest legal tile, flagged by caller
        flavor = flavors[0]
        attrs = attrs_by_flavor[flavor]
        t = tile_footprint(layer, attrs, 32, 8, 8, flavor, tiling)
        da, br = data_accesses(layer, attrs, 32, 8, 8, "OS", flavor)
        best = Dataflow(32, 8, 8, "OS", flavor, tiling, t, da, br)
    return best


# ---------------------------------------------------------------------------
# Offline SPADE (MSA tables indexed by ARF)  — §V-C
# ---------------------------------------------------------------------------

@dataclass
class OfflineTable:
    arf_bins: np.ndarray                     # bin upper edges
    plans: dict[tuple[str, int], Dataflow] = field(default_factory=dict)

    def lookup(self, layer_name: str, arf: float) -> Dataflow:
        b = int(np.searchsorted(self.arf_bins, arf))
        b = min(b, len(self.arf_bins) - 1)
        return self.plans[(layer_name, b)]


def meta_attributes(per_cloud: list[SparsityAttributes]) -> SparsityAttributes:
    """MSA: average SA_I across a representative pointcloud set (Eqn 10),
    keeping the most conservative allocation columns."""
    ref = per_cloud[0]
    stack = lambda name: np.stack([getattr(a, name) for a in per_cloud])
    return SparsityAttributes(
        ref.delta_majors,
        stack("sa_minor_avg").mean(0),
        stack("sa_minor_alloc_sst").max(0),
        stack("sa_minor_alloc_rst").mean(0),
        stack("arf_avg").mean(0),
        stack("arf_alloc_sst").max(0),
        stack("arf_alloc_rst").mean(0),
        stack("rst_overshoot_frac").mean(0),
        ref.quantile,
    )


def build_offline_table(
    layers: list[LayerSpec],
    msa: SparsityAttributes,
    mem_budget_bytes: int,
    arf_bins: np.ndarray | None = None,
) -> OfflineTable:
    """Precompute optimal dataflows per (layer, ARF bin) using MSA_I and a
    synthetic constant-ARF attribute per bin (ARF is the JSA)."""
    bins = arf_bins if arf_bins is not None else np.array(
        [2, 4, 6, 8, 10, 13, 16, 20, 27], float
    )
    table = OfflineTable(bins)
    for layer in layers:
        for b, arf in enumerate(bins):
            synth = SparsityAttributes(
                msa.delta_majors,
                msa.sa_minor_avg,
                msa.sa_minor_alloc_sst,
                msa.sa_minor_alloc_rst,
                np.full_like(msa.arf_avg, arf),
                np.full_like(msa.arf_avg, arf),
                np.full_like(msa.arf_avg, arf),
                msa.rst_overshoot_frac,
                msa.quantile,
            )
            table.plans[(layer.name, b)] = explore(
                layer, {"CIRF": synth, "CORF": synth}, mem_budget_bytes
            )
    return table


def otf_lookup(table: OfflineTable, layer: LayerSpec, arf: float) -> Dataflow:
    """On-the-fly SPADE: one ARF measurement -> table lookup (near-zero
    latency; the paper overlaps this with first-layer execution)."""
    return table.lookup(layer.name, arf)
