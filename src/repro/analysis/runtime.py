"""Runtime lock-order assertions (opt-in via ``REPRO_LOCK_CHECK=1``).

Every lock in the serving/engine stack is created through
``ordered_lock``/``ordered_rlock``/``ordered_condition`` with a canonical
name from :data:`LOCK_ORDER` — the repo's single documented global lock
order (also enforced statically by ``repro.analysis.concurrency``). With
``REPRO_LOCK_CHECK`` unset the factories return plain ``threading``
primitives: zero overhead, identical semantics. With ``REPRO_LOCK_CHECK=1``
they return checked wrappers that raise :class:`LockOrderViolation` the
moment any thread acquires a lock while holding one that ranks *after* it
— turning a would-be deadlock into a deterministic, attributable failure
at the acquisition site.

The environment variable is read at lock-creation time, so module-level
locks (``serving.api._SERVE_LOCK``, ``serving.faults._ACTIVE_LOCK``) are
only checked when the variable is set before the first ``repro`` import;
per-instance locks (plan cache, scheduler pool, breakers, autotune,
streams) are checked for any object created while it is set. This module
must stay dependency-free (``os``/``threading`` only): every lock-owning
module in ``src/repro`` imports it.
"""
from __future__ import annotations

import os
import threading

#: The documented global lock order. A thread holding lock at rank *i* may
#: only acquire locks at rank > *i*. Outer (coarse, long-lived scopes)
#: first, inner (leaf, short critical sections) last.
LOCK_ORDER = (
    "serving.serve",     # serving.api._SERVE_LOCK (one resident loop/proc)
    "scheduler.pool",    # WaveScheduler._pool_lock (planner pool lifecycle)
    "stream.handle",     # StreamHandle._lock (per-stream frame numbering)
    "stream.plan",       # StreamPlanState._cond (per-stream frame gating)
    "plan_cache",        # PlanCache._lock (entry map + coalescing table)
    "plan_cache.dev",    # per-entry device-upload memo lock
    "breakers",          # BreakerBoard._lock (per-backend breaker state)
    "autotune",          # CostTable._lock (measured-cost table)
    "faults.injector",   # FaultInjector._lock (seeded trial counters)
    "faults.install",    # serving.faults._ACTIVE_LOCK (ambient injector)
)

_RANK = {name: i for i, name in enumerate(LOCK_ORDER)}


class LockOrderViolation(RuntimeError):
    """A thread acquired locks against :data:`LOCK_ORDER`."""


def enabled() -> bool:
    return os.environ.get("REPRO_LOCK_CHECK", "") == "1"


def lock_rank(name: str) -> int:
    try:
        return _RANK[name]
    except KeyError:
        raise ValueError(
            f"unknown lock name {name!r}; register it in "
            f"repro.analysis.runtime.LOCK_ORDER") from None


_tls = threading.local()


def _held() -> list:
    h = getattr(_tls, "held", None)
    if h is None:
        h = _tls.held = []
    return h


class _CheckedLock:
    """Lock/RLock wrapper asserting :data:`LOCK_ORDER` on every acquire.

    Implements ``_is_owned`` so ``threading.Condition`` can wrap it (the
    condition's ``wait`` releases and re-acquires through the wrapper, so
    held-lock bookkeeping stays correct across waits).
    """

    def __init__(self, name: str, *, reentrant: bool = False):
        self.name = name
        self.rank = lock_rank(name)
        self._reentrant = reentrant
        self._lk = threading.RLock() if reentrant else threading.Lock()
        self._owner: int | None = None
        self._count = 0

    def _check(self) -> None:
        me = threading.get_ident()
        if self._owner == me:
            if not self._reentrant:
                raise LockOrderViolation(
                    f"non-reentrant lock {self.name!r} re-acquired by the "
                    f"holding thread (self-deadlock)")
            return
        for other in _held():
            if other.rank > self.rank or (
                    other.rank == self.rank and other is not self):
                raise LockOrderViolation(
                    f"acquired {self.name!r} (rank {self.rank}) while "
                    f"holding {other.name!r} (rank {other.rank}); "
                    f"documented order: {' < '.join(LOCK_ORDER)}")

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._check()
        ok = self._lk.acquire(blocking, timeout)
        if ok:
            self._owner = threading.get_ident()
            self._count += 1
            _held().append(self)
        return ok

    def release(self) -> None:
        self._count -= 1
        if self._count == 0:
            self._owner = None
        h = _held()
        for i in range(len(h) - 1, -1, -1):
            if h[i] is self:
                del h[i]
                break
        self._lk.release()

    # threading.Condition picks this up, avoiding its try-acquire probe
    # (which would trip the re-acquire check on a non-reentrant lock)
    def _is_owned(self) -> bool:
        return self._owner == threading.get_ident()

    def locked(self) -> bool:
        return self._count > 0

    def __enter__(self) -> "_CheckedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<ordered {self.name!r} rank={self.rank}>"


def ordered_lock(name: str):
    """A ``threading.Lock`` registered at ``name``'s rank in the global
    order (checked wrapper when ``REPRO_LOCK_CHECK=1``)."""
    lock_rank(name)  # unknown names fail fast even when disabled
    if enabled():
        return _CheckedLock(name)
    return threading.Lock()


def ordered_rlock(name: str):
    """Reentrant variant of :func:`ordered_lock`."""
    lock_rank(name)
    if enabled():
        return _CheckedLock(name, reentrant=True)
    return threading.RLock()


def ordered_condition(name: str):
    """A ``threading.Condition`` whose underlying lock participates in the
    global order. ``wait()`` releases the lock, so waiting never holds a
    rank (matching the static checker's condvar-wait exemption)."""
    lock_rank(name)
    if enabled():
        return threading.Condition(_CheckedLock(name))
    return threading.Condition()
