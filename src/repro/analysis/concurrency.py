"""AST lock-graph extraction + blocking-call-under-lock detection.

Walks every module under ``src/repro``, finds the locks (created through
``repro.analysis.runtime.ordered_lock``/``ordered_rlock``/
``ordered_condition``, which carry their canonical name in the call), and
records which locks are acquired while which are held — both directly
(nested ``with``) and one call-graph closure deep (a ``with`` body calling
a method that itself takes a lock). Every extracted edge must go strictly
*forward* in :data:`repro.analysis.runtime.LOCK_ORDER`; a backward or
same-rank edge is a potential deadlock and fails the pass. Order-respecting
edges also guarantee the graph is acyclic.

Rules:

* ``REPRO-C001`` — lock acquired out of documented order (cycle risk).
* ``REPRO-C002`` — blocking call (``.wait()``/``.result()``/``.join()``/
  ``time.sleep``/``block_until_ready``/``device_get``) while holding a
  lock. Exemption: a condition variable's own ``wait()`` inside ``with
  cond:`` (wait releases the lock).
* ``REPRO-C003`` — raw ``threading.Lock``/``RLock``/``Condition`` in
  ``src/repro``: every lock must be created via ``ordered_lock`` (et al.)
  so it has a rank, shows up in this graph, and is runtime-checkable under
  ``REPRO_LOCK_CHECK=1``.

Call resolution is name-based and deliberately conservative: ``self.x()``
resolves within the enclosing class, bare names within the module, and
``obj.meth()`` to every class method of that name in the tree (minus the
enclosing class) — unions over candidates can only add edges, so a clean
report is trustworthy. ``# analysis: allow[RULE]`` suppresses per line.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

from repro.analysis.findings import Finding
from repro.analysis.lint import _ALLOW_RE, iter_python_files
from repro.analysis.runtime import LOCK_ORDER, _RANK

_ORDERED_FACTORIES = {"ordered_lock": False, "ordered_rlock": True,
                      "ordered_condition": False}
_RAW_FACTORIES = {"Lock", "RLock", "Condition"}
_BLOCKING_ATTRS = {"wait", "result", "join"}
_BLOCKING_NAMES = {"sleep", "block_until_ready", "device_get"}
# receiver-method names never resolved through the call graph (container /
# stdlib methods that shadow real method names would fan edges everywhere)
_CALL_STOPLIST = {
    "get", "pop", "popitem", "append", "extend", "items", "keys", "values",
    "setdefault", "move_to_end", "add", "discard", "remove", "insert",
    "index", "count", "sort", "copy", "clear", "update", "format", "split",
    "strip", "startswith", "endswith", "sum", "mean", "reshape", "astype",
    "set", "is_set", "acquire", "release", "notify", "notify_all",
}


def _dotted(func: ast.expr) -> str:
    parts: list[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


@dataclass
class LockGraph:
    """Extraction result: lock definition sites + acquisition edges."""

    locks: dict[str, list[str]] = field(default_factory=dict)
    reentrant: set[str] = field(default_factory=set)
    #: (held, acquired, where) — "where" is the acquisition site
    edges: set[tuple[str, str, str]] = field(default_factory=set)

    def order_violations(self) -> list[Finding]:
        out = []
        for src, dst, where in sorted(self.edges):
            if src == dst and src in self.reentrant:
                continue  # RLock re-entry
            if _RANK[src] >= _RANK[dst]:
                out.append(Finding(
                    "REPRO-C001", where,
                    f"acquires {dst!r} (rank {_RANK[dst]}) while holding "
                    f"{src!r} (rank {_RANK[src]}); documented order: "
                    f"{' < '.join(LOCK_ORDER)}"))
        return out


@dataclass
class _CallSite:
    name: str          # dotted call name as written
    held: tuple[str, ...]
    where: str


@dataclass
class _FuncInfo:
    qualname: str      # "module:Class.meth" or "module:func"
    module: str
    cls: str | None
    direct_locks: set[str] = field(default_factory=set)
    calls: list[_CallSite] = field(default_factory=list)


class _ModuleScan(ast.NodeVisitor):
    """One pass over a module in one of two modes: ``defs`` collects lock
    definitions (and raw-lock findings); ``uses`` records per-function
    acquisition info, direct nested-with edges, and blocking-call findings.
    Definitions are gathered across *all* modules before any uses pass runs
    so forward and cross-module lock references resolve."""

    def __init__(self, ext: "Extractor", module: str, rel: str,
                 source: str, mode: str = "uses"):
        self.ext = ext
        self.module = module
        self.rel = rel
        #: "defs" registers lock definitions only; "uses" records
        #: acquisitions/calls (definitions from every module are already
        #: known, so forward/cross-module references resolve)
        self.mode = mode
        self.allowed = {
            i: {m.group(1) for m in _ALLOW_RE.finditer(line)}
            for i, line in enumerate(source.splitlines(), start=1)
            if _ALLOW_RE.search(line)}
        self.cls: str | None = None
        self.func: _FuncInfo | None = None
        # held stack entries: (lock_name, ast.dump of the lock expression)
        self.held: list[tuple[str, str]] = []

    def _where(self, node: ast.AST) -> str:
        return f"{self.rel}:{getattr(node, 'lineno', 0)}"

    def _suppressed(self, node: ast.AST, rule: str) -> bool:
        return rule in self.allowed.get(getattr(node, "lineno", 0), ())

    def _emit(self, rule: str, node: ast.AST, msg: str) -> None:
        if not self._suppressed(node, rule):
            self.ext.findings.append(Finding(rule, self._where(node), msg))

    # -- definitions -------------------------------------------------------

    def _lock_from_call(self, call: ast.Call) -> tuple[str, bool] | None:
        short = _dotted(call.func).rsplit(".", 1)[-1]
        if short in _ORDERED_FACTORIES:
            if call.args and isinstance(call.args[0], ast.Constant) \
                    and isinstance(call.args[0].value, str):
                return call.args[0].value, _ORDERED_FACTORIES[short]
        return None

    def _register(self, name: str, reentrant: bool, node: ast.AST) -> None:
        if name not in _RANK:
            self._emit("REPRO-C001", node,
                       f"lock name {name!r} not in runtime.LOCK_ORDER")
            return
        self.ext.graph.locks.setdefault(name, []).append(self._where(node))
        if reentrant:
            self.ext.graph.reentrant.add(name)

    def visit_Assign(self, node: ast.Assign) -> None:
        if self.mode != "defs":
            self.generic_visit(node)
            return
        if isinstance(node.value, ast.Call):
            got = self._lock_from_call(node.value)
            raw = (_dotted(node.value.func).rsplit(".", 1)[-1]
                   in _RAW_FACTORIES
                   and _dotted(node.value.func) in (
                       "threading.Lock", "threading.RLock",
                       "threading.Condition", "Lock", "RLock", "Condition"))
            for tgt in node.targets:
                if got is not None:
                    name, reentrant = got
                    self._register(name, reentrant, node)
                    if isinstance(tgt, ast.Attribute) and \
                            isinstance(tgt.value, ast.Name) and \
                            tgt.value.id == "self" and self.cls:
                        self.ext.attr_locks[(self.cls, tgt.attr)] = name
                        self.ext.attr_fallback.setdefault(
                            tgt.attr, set()).add(name)
                    elif isinstance(tgt, ast.Name):
                        self.ext.global_locks[
                            (self.module, tgt.id)] = name
                elif raw and not self.rel.endswith("analysis/runtime.py"):
                    self._emit(
                        "REPRO-C003", node,
                        "raw threading lock; create it via repro.analysis"
                        ".runtime.ordered_lock/ordered_rlock/"
                        "ordered_condition so it has a documented rank")
        self.generic_visit(node)

    def visit_Dict(self, node: ast.Dict) -> None:
        if self.mode != "defs":
            self.generic_visit(node)
            return
        for k, v in zip(node.keys, node.values):
            if isinstance(k, ast.Constant) and isinstance(k.value, str) \
                    and isinstance(v, ast.Call):
                got = self._lock_from_call(v)
                if got is not None:
                    self._register(got[0], got[1], node)
                    self.ext.subscript_locks[k.value] = got[0]
        self.generic_visit(node)

    # -- scope tracking ----------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        prev, self.cls = self.cls, node.name
        self.generic_visit(node)
        self.cls = prev

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if self.mode == "defs":
            self.generic_visit(node)
            return
        prev = self.func
        qual = (f"{self.module}:{self.cls}.{node.name}" if self.cls
                else f"{self.module}:{node.name}")
        self.func = _FuncInfo(qual, self.module, self.cls)
        self.ext.funcs[qual] = self.func
        if self.cls:
            self.ext.methods.setdefault(node.name, set()).add(qual)
        else:
            self.ext.module_funcs[(self.module, node.name)] = qual
        held_prev, self.held = self.held, []  # locks don't cross def scopes
        self.generic_visit(node)
        self.held = held_prev
        self.func = prev

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    # -- acquisition tracking ----------------------------------------------

    def _resolve_lock_expr(self, expr: ast.expr) -> str | None:
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and expr.value.id == "self":
            name = self.ext.attr_locks.get((self.cls or "", expr.attr))
            if name is None:
                cands = self.ext.attr_fallback.get(expr.attr, set())
                name = next(iter(cands)) if len(cands) == 1 else None
            return name
        if isinstance(expr, ast.Name):
            return self.ext.global_locks.get((self.module, expr.id))
        if isinstance(expr, ast.Subscript):
            sl = expr.slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
                return self.ext.subscript_locks.get(sl.value)
        return None

    def visit_With(self, node: ast.With) -> None:
        if self.mode == "defs":
            self.generic_visit(node)
            return
        acquired: list[tuple[str, str]] = []
        for item in node.items:
            name = self._resolve_lock_expr(item.context_expr)
            if name is not None:
                for held_name, _ in self.held:
                    self.ext.graph.edges.add(
                        (held_name, name, self._where(node)))
                acquired.append((name, ast.dump(item.context_expr)))
                if self.func is not None:
                    self.func.direct_locks.add(name)
        self.held.extend(acquired)
        for stmt in node.body:
            self.visit(stmt)
        if acquired:
            del self.held[-len(acquired):]

    visit_AsyncWith = visit_With  # type: ignore[assignment]

    def visit_Call(self, node: ast.Call) -> None:
        if self.mode == "defs":
            self.generic_visit(node)
            return
        name = _dotted(node.func)
        short = name.rsplit(".", 1)[-1]
        if self.held:
            blocking = None
            if short in _BLOCKING_ATTRS and \
                    isinstance(node.func, ast.Attribute):
                recv = ast.dump(node.func.value)
                if not (short == "wait" and
                        any(recv == d for _, d in self.held)):
                    blocking = f".{short}()"
            elif short in _BLOCKING_NAMES:
                blocking = f"{short}()"
            if blocking is not None:
                self._emit(
                    "REPRO-C002", node,
                    f"blocking call {blocking} while holding "
                    f"{[h for h, _ in self.held]!r}")
        if self.func is not None and name and \
                short not in _CALL_STOPLIST and self.held:
            self.func.calls.append(_CallSite(
                name, tuple(h for h, _ in self.held), self._where(node)))
        elif self.func is not None and name and \
                short not in _CALL_STOPLIST:
            self.func.calls.append(_CallSite(name, (), self._where(node)))
        self.generic_visit(node)


class Extractor:
    def __init__(self) -> None:
        self.graph = LockGraph()
        self.findings: list[Finding] = []
        self.attr_locks: dict[tuple[str, str], str] = {}
        self.attr_fallback: dict[str, set[str]] = {}
        self.global_locks: dict[tuple[str, str], str] = {}
        self.subscript_locks: dict[str, str] = {}
        self.funcs: dict[str, _FuncInfo] = {}
        self.methods: dict[str, set[str]] = {}
        self.module_funcs: dict[tuple[str, str], str] = {}

    # -- call resolution ---------------------------------------------------

    def _callees(self, site: _CallSite, caller: _FuncInfo) -> set[str]:
        parts = site.name.split(".")
        short = parts[-1]
        if parts[0] == "self" and len(parts) == 2 and caller.cls:
            q = f"{caller.module}:{caller.cls}.{short}"
            return {q} if q in self.funcs else set()
        if len(parts) == 1:
            q = self.module_funcs.get((caller.module, short))
            return {q} if q else set()
        # obj.meth / self.obj.meth: every class method of that name,
        # excluding the caller's own class (the receiver is not self)
        cands = {q for q in self.methods.get(short, set())
                 if not (caller.cls and
                         q.startswith(f"{caller.module}:{caller.cls}."))}
        return cands

    def _transitive_locks(self) -> dict[str, set[str]]:
        locks = {q: set(f.direct_locks) for q, f in self.funcs.items()}
        changed = True
        while changed:
            changed = False
            for q, f in self.funcs.items():
                for site in f.calls:
                    for callee in self._callees(site, f):
                        extra = locks.get(callee, set()) - locks[q]
                        if extra:
                            locks[q] |= extra
                            changed = True
        return locks

    def close_over_calls(self) -> None:
        """Add edges held-lock -> every lock a called function (transitively)
        acquires."""
        locks = self._transitive_locks()
        for f in self.funcs.values():
            for site in f.calls:
                if not site.held:
                    continue
                acquired: set[str] = set()
                for callee in self._callees(site, f):
                    acquired |= locks.get(callee, set())
                for held in site.held:
                    for name in acquired:
                        self.graph.edges.add((held, name, site.where))


def extract(root: Path, subdirs: tuple[str, ...] = ("src/repro",)
            ) -> tuple[list[Finding], LockGraph]:
    """Extract the lock graph and return (findings, graph)."""
    ext = Extractor()
    parsed: list[tuple[str, str, str, ast.Module]] = []
    for p in iter_python_files(root, subdirs):
        rel = p.relative_to(root).as_posix()
        module = rel[:-3].replace("/", ".")
        if module.startswith("src.repro"):
            module = module[len("src."):]
        source = p.read_text()
        try:
            tree = ast.parse(source)
        except SyntaxError as e:
            ext.findings.append(Finding(
                "REPRO-C000", f"{rel}:{e.lineno or 0}",
                f"syntax error: {e.msg}"))
            continue
        parsed.append((module, rel, source, tree))
    for mode in ("defs", "uses"):
        for module, rel, source, tree in parsed:
            _ModuleScan(ext, module, rel, source, mode=mode).visit(tree)
    ext.close_over_calls()
    ext.findings.extend(ext.graph.order_violations())
    return ext.findings, ext.graph


def analyze_repo(root: Path) -> list[Finding]:
    return extract(root)[0]
