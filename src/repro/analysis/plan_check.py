"""Structural validation of built plans.

The paper's metadata chain (COIR bitmasks -> SOAR orderings -> SSpNNA DMA
tables) is exactly where a silent bug becomes either a wrong answer or a
lost speedup. This pass takes *built* plan objects and checks the chain
end to end:

* ``REPRO-P001`` — COIR block inconsistent: indices out of ``[-1, n_in)``,
  non-integer dtype, or a bitmask that disagrees with the index holes.
* ``REPRO-P002`` — SOAR/tile coverage broken: some active (row, offset)
  pair is executed more than once across tiles (double accumulation).
* ``REPRO-P003`` — DMA table out of bounds for its capacity bucket:
  ``out_rows`` beyond the trash row, ``in_rows`` outside the input
  capacity, ``local_idx`` outside the working set, or tile shapes that
  disagree with the plan's ``Dispatch``.
* ``REPRO-P004`` — pair accounting broken: ``pair_counts`` disagrees with
  ``local_idx`` holes, a pair is dropped (the planner's ``dropped_pairs ==
  0`` invariant), pairs attached to a pad output slot, or the DMA chain
  resolves a pair to the wrong source row.
* ``REPRO-P005`` — sharded halo tables broken: send rows outside the
  sender's shard, references to halo slots nobody sends, self-halo.
* ``REPRO-P006`` — cache keys don't rotate: a ``PlanCache`` key that fails
  to mix ``_PLAN_VERSION``, topology, or the autotune/breaker generations
  serves stale plans after a flip.

``check_plan`` dispatches on plan type; every check returns
``list[Finding]`` (empty = clean) and never raises on malformed input.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.findings import Finding


def _np(x):
    return None if x is None else np.asarray(x)


def check_coir(coir, n_in: int, path: str) -> list[Finding]:
    out: list[Finding] = []
    idx = _np(coir.indices)
    if idx is None:
        return [Finding("REPRO-P001", path, "COIR has no indices")]
    if not np.issubdtype(idx.dtype, np.integer):
        out.append(Finding("REPRO-P001", f"{path}.indices",
                           f"non-integer dtype {idx.dtype}"))
        return out
    if idx.ndim != 2:
        out.append(Finding("REPRO-P001", f"{path}.indices",
                           f"expected (V, K), got shape {idx.shape}"))
        return out
    lo, hi = int(idx.min(initial=0)), int(idx.max(initial=-1))
    if lo < -1 or hi >= n_in:
        out.append(Finding(
            "REPRO-P001", f"{path}.indices",
            f"values span [{lo}, {hi}], outside [-1, {n_in})"))
    bm = getattr(coir, "bitmask", None)
    if bm is not None and idx.shape[1] <= 32:
        k = idx.shape[1]
        want = ((idx >= 0).astype(np.uint32)
                * (np.uint32(1) << np.arange(k, dtype=np.uint32))).sum(
                    axis=1, dtype=np.uint64)
        got = _np(bm).astype(np.uint64)
        if got.shape == want.shape and not np.array_equal(got, want):
            n_bad = int((got != want).sum())
            out.append(Finding(
                "REPRO-P001", f"{path}.bitmask",
                f"bitmask disagrees with index holes on {n_bad} rows"))
    return out


def check_tiles(tiles, coir, mask, n_out: int, n_in: int, dispatch,
                path: str) -> list[Finding]:
    """Validate one conv's SSpNNA tile tables against its COIR block.

    The complete invariant: every active (out_row, offset) pair in the
    COIR block is executed exactly once across all tiles, and the DMA
    chain (``local_idx`` -> ``in_rows``) resolves it to the row COIR
    recorded. Plane-split rows (one row over several tiles) pass as long
    as no pair is duplicated or dropped.
    """
    out: list[Finding] = []
    orow, irow = _np(tiles.out_rows), _np(tiles.in_rows)
    li, pc = _np(tiles.local_idx), _np(tiles.pair_counts)
    if orow.ndim != 2 or irow.ndim != 2 or li.ndim != 3:
        return [Finding("REPRO-P003", path,
                        f"bad tile table ranks: out_rows{orow.shape} "
                        f"in_rows{irow.shape} local_idx{li.shape}")]
    t, d_o = orow.shape
    d_i = irow.shape[1]
    k = li.shape[2]
    if li.shape[:2] != (t, d_o) or irow.shape[0] != t or pc.shape != (t,):
        return [Finding("REPRO-P003", path,
                        f"tile table shapes disagree: out_rows{orow.shape} "
                        f"in_rows{irow.shape} local_idx{li.shape} "
                        f"pair_counts{pc.shape}")]
    if dispatch is not None:
        for attr, got in (("n_tiles", t), ("delta_o", d_o),
                          ("delta_i", d_i)):
            want = getattr(dispatch, attr, None)
            if want not in (None, 0) and want != got:
                out.append(Finding(
                    "REPRO-P003", f"{path}.dispatch",
                    f"dispatch.{attr}={want} but tables have {got}"))
    if orow.min(initial=0) < 0 or orow.max(initial=0) > n_out:
        out.append(Finding(
            "REPRO-P003", f"{path}.out_rows",
            f"values outside [0, {n_out}] (n_out={n_out} is the trash "
            f"row); got [{orow.min()}, {orow.max()}]"))
        return out
    if irow.min(initial=0) < 0 or irow.max(initial=0) >= n_in:
        out.append(Finding(
            "REPRO-P003", f"{path}.in_rows",
            f"values outside [0, {n_in}); got "
            f"[{irow.min()}, {irow.max()}]"))
        return out
    if li.min(initial=-1) < -1 or li.max(initial=-1) >= d_i:
        out.append(Finding(
            "REPRO-P003", f"{path}.local_idx",
            f"values outside [-1, {d_i}); got [{li.min()}, {li.max()}]"))
        return out
    valid = li >= 0
    want_counts = valid.sum(axis=(1, 2))
    if not np.array_equal(pc, want_counts):
        bad = np.flatnonzero(pc != want_counts)
        out.append(Finding(
            "REPRO-P004", f"{path}.pair_counts",
            f"disagrees with local_idx holes on tiles {bad[:8].tolist()}"))
    rows = np.broadcast_to(orow[:, :, None], li.shape)
    if bool((valid & (rows == n_out)).any()):
        out.append(Finding(
            "REPRO-P004", f"{path}.local_idx",
            "pairs attached to a pad (trash-row) output slot"))
    live = valid & (rows < n_out)
    if not live.any():
        return out
    tt = np.broadcast_to(np.arange(t)[:, None, None], li.shape)[live]
    kk = np.broadcast_to(np.arange(k)[None, None, :], li.shape)[live]
    rr = rows[live]
    src = irow[tt, li[live]]
    cidx = _np(coir.indices) if coir is not None else None
    if cidx is not None and cidx.shape == (n_out, k):
        want_src = cidx[rr, kk]
        bad = src != want_src
        if bool(bad.any()):
            out.append(Finding(
                "REPRO-P004", f"{path}.in_rows",
                f"DMA chain resolves {int(bad.sum())} pairs to the wrong "
                f"source row (local_idx -> in_rows != COIR)"))
        executed = np.bincount(rr * k + kk, minlength=n_out * k)
        m = _np(mask)
        active = cidx >= 0
        if m is not None and m.shape == (n_out,):
            active = active & m[:, None].astype(bool)
        expected = active.astype(np.int64).ravel()
        over = executed > expected
        under = executed < expected
        if bool(over.any()):
            rows_over = np.unique(np.flatnonzero(over) // k)
            out.append(Finding(
                "REPRO-P002", f"{path}.out_rows",
                f"{int(over.sum())} (row, offset) pairs executed more "
                f"than once (rows {rows_over[:8].tolist()}); SOAR "
                f"coverage must be a per-pair permutation"))
        if bool(under.any()):
            rows_under = np.unique(np.flatnonzero(under) // k)
            out.append(Finding(
                "REPRO-P004", f"{path}.out_rows",
                f"{int(under.sum())} active pairs dropped (rows "
                f"{rows_under[:8].tolist()}); dropped_pairs must be 0"))
    return out


def _check_conv(plan, n_out: int, n_in: int, mask, path: str
                ) -> list[Finding]:
    out = check_coir(plan.coir, n_in, f"{path}.coir")
    if getattr(plan, "tiles", None) is not None:
        out.extend(check_tiles(plan.tiles, plan.coir, mask, n_out, n_in,
                               getattr(plan, "dispatch", None),
                               f"{path}.tiles"))
    return out


def check_scene_plan(plan, path: str = "plan") -> list[Finding]:
    """Validate every conv site of a (host or device) ``ScenePlan``."""
    out: list[Finding] = []
    levels = list(plan.levels)
    if not levels:
        return [Finding("REPRO-P001", path, "plan has no levels")]
    sizes = [int(_np(lvl.mask).shape[0]) for lvl in levels]
    for li, lvl in enumerate(levels):
        v = sizes[li]
        p = f"{path}.levels[{li}]"
        coords, mask = _np(lvl.coords), _np(lvl.mask)
        if coords.shape != (v, 3):
            out.append(Finding("REPRO-P001", f"{p}.coords",
                               f"expected ({v}, 3), got {coords.shape}"))
        out.extend(_check_conv(lvl.sub, v, v, mask, f"{p}.sub"))
        if lvl.down is not None and li + 1 < len(levels):
            n_rows = int(_np(lvl.down.coir.indices).shape[0])
            n_in = sizes[li] if n_rows == sizes[li + 1] else sizes[li + 1]
            dmask = _np(levels[li + 1].mask) if n_rows == sizes[li + 1] \
                else mask
            out.extend(_check_conv(lvl.down, n_rows, n_in, dmask,
                                   f"{p}.down"))
        if lvl.up is not None and li + 1 < len(levels):
            n_rows = int(_np(lvl.up.coir.indices).shape[0])
            n_in = sizes[li + 1] if n_rows == sizes[li] else sizes[li]
            umask = mask if n_rows == sizes[li] else _np(levels[li + 1].mask)
            out.extend(_check_conv(lvl.up, n_rows, n_in, umask, f"{p}.up"))
    for li, st in enumerate(plan.stats or []):
        if isinstance(st, dict) and st.get("dropped_pairs", 0) != 0:
            out.append(Finding(
                "REPRO-P004", f"{path}.stats[{li}]",
                f"dropped_pairs={st['dropped_pairs']} (invariant: 0)"))
    return out


def check_sharded_conv(conv, vs_in: int, vs_out: int, n_shards: int,
                       path: str) -> list[Finding]:
    out: list[Finding] = []
    idx, send = _np(conv.indices), _np(conv.send_rows)
    s = n_shards
    if idx.ndim != 3 or idx.shape[0] != s:
        return [Finding("REPRO-P005", f"{path}.indices",
                        f"expected ({s}, Vs, K), got {idx.shape}")]
    if send.ndim != 3 or send.shape[:2] != (s, s):
        return [Finding("REPRO-P005", f"{path}.send_rows",
                        f"expected ({s}, {s}, H), got {send.shape}")]
    h = send.shape[2]
    if idx.shape[1] != vs_out:
        out.append(Finding("REPRO-P005", f"{path}.indices",
                           f"per-shard rows {idx.shape[1]} != {vs_out}"))
    if send.min(initial=0) < -1 or send.max(initial=-1) >= vs_in:
        out.append(Finding(
            "REPRO-P005", f"{path}.send_rows",
            f"send rows outside [-1, {vs_in}) (must be local to the "
            f"sending shard); got [{send.min()}, {send.max()}]"))
    hi = vs_in + s * h
    if idx.min(initial=-1) < -1 or idx.max(initial=-1) >= hi:
        out.append(Finding(
            "REPRO-P005", f"{path}.indices",
            f"local coding outside [-1, {hi}) "
            f"(own [0, {vs_in}) | halo [{vs_in}, {hi})); "
            f"got [{idx.min()}, {idx.max()}]"))
        return out
    for shard in range(s):
        slots = idx[shard][idx[shard] >= vs_in] - vs_in
        if slots.size == 0:
            continue
        d, j = slots // h, slots % h
        if bool((d == shard).any()):
            out.append(Finding(
                "REPRO-P005", f"{path}.indices",
                f"shard {shard} references a halo slot from itself "
                f"(own rows must use local coding)"))
        unsent = send[d, shard, j] < 0
        if bool(unsent.any()):
            out.append(Finding(
                "REPRO-P005", f"{path}.indices",
                f"shard {shard} references {int(unsent.sum())} halo "
                f"slots its peers never send (send_rows pad)"))
    return out


def check_sharded_scene_plan(plan, path: str = "plan") -> list[Finding]:
    out: list[Finding] = []
    s = plan.layout.n_shards
    levels = list(plan.levels)
    sizes = [int(_np(lvl.mask).shape[1]) for lvl in levels]
    for li, lvl in enumerate(levels):
        p = f"{path}.levels[{li}]"
        vs = sizes[li]
        if _np(lvl.mask).shape[0] != s:
            out.append(Finding("REPRO-P005", f"{p}.mask",
                               f"expected ({s}, Vs), got "
                               f"{_np(lvl.mask).shape}"))
            continue
        out.extend(check_sharded_conv(lvl.sub, vs, vs, s, f"{p}.sub"))
        if lvl.down is not None and li + 1 < len(levels):
            out.extend(check_sharded_conv(
                lvl.down, vs, sizes[li + 1], s, f"{p}.down"))
        if lvl.up is not None and li + 1 < len(levels):
            out.extend(check_sharded_conv(
                lvl.up, sizes[li + 1], vs, s, f"{p}.up"))
    return out


def check_stream_state(state, path: str = "stream") -> list[Finding]:
    from repro.engine.plan import _PLAN_VERSION
    out: list[Finding] = []
    if f"v{_PLAN_VERSION}" not in state._tag:
        out.append(Finding(
            "REPRO-P006", f"{path}._tag",
            f"stream cache tag {state._tag!r} does not mix "
            f"_PLAN_VERSION={_PLAN_VERSION}"))
    if state._prev_plan is not None:
        out.extend(check_scene_plan(state._prev_plan, f"{path}.plan"))
    return out


def check_cache_keys(cache, t, cfg, *, autotune=None, breakers=None,
                     path: str = "plan_cache") -> list[Finding]:
    """Verify ``PlanCache`` keys rotate with everything that must rotate
    them: the table-layout ``_PLAN_VERSION``, the mesh topology, and the
    autotune/breaker generations (mixed in via their ``repr``)."""
    import repro.engine.plan as plan_mod
    out: list[Finding] = []
    base = cache.key_for(t, cfg)
    old = plan_mod._PLAN_VERSION
    try:
        plan_mod._PLAN_VERSION = old + 1
        bumped = cache.key_for(t, cfg)
    finally:
        plan_mod._PLAN_VERSION = old
    if bumped == base:
        out.append(Finding("REPRO-P006", path,
                           "key does not mix _PLAN_VERSION"))
    if cache.key_for(t, cfg, topology="a") == \
            cache.key_for(t, cfg, topology="b"):
        out.append(Finding("REPRO-P006", path,
                           "key does not mix the mesh topology"))
    for label, obj in (("autotune", autotune), ("breakers", breakers)):
        if obj is None:
            continue
        k0 = cache.key_for(t, cfg, **{label: obj})
        if not hasattr(obj, "generation"):
            out.append(Finding(
                "REPRO-P006", path,
                f"{label} object {type(obj).__name__} has no generation "
                f"counter to mix into keys"))
            continue
        obj.generation += 1
        try:
            k1 = cache.key_for(t, cfg, **{label: obj})
        finally:
            obj.generation -= 1
        if k0 == k1:
            out.append(Finding(
                "REPRO-P006", path,
                f"key does not rotate with the {label} generation "
                f"({type(obj).__name__!s}.__repr__ must include it)"))
    return out


def check_plan(plan, path: str = "plan") -> list[Finding]:
    """Dispatch on plan type (``ScenePlan`` / ``ShardedScenePlan`` /
    ``StreamPlanState``)."""
    name = type(plan).__name__
    if name == "ShardedScenePlan" or hasattr(plan, "layout"):
        return check_sharded_scene_plan(plan, path)
    if name == "StreamPlanState" or hasattr(plan, "plan_frame"):
        return check_stream_state(plan, path)
    return check_scene_plan(plan, path)
