"""Shared finding record for every `repro.analysis` pass.

A pass returns ``list[Finding]``; empty means clean. ``rule`` is a stable
id (``REPRO-L003``) documented in the README rule catalog, ``where`` is a
clickable location — ``path/to/file.py:123`` for source passes, a plan
path like ``levels[0].sub.tiles`` for structural passes.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass


@dataclass(frozen=True)
class Finding:
    rule: str
    where: str
    message: str

    def to_dict(self) -> dict:
        return asdict(self)

    def __str__(self) -> str:
        return f"{self.rule} {self.where}: {self.message}"


def render(findings: list[Finding]) -> str:
    return "\n".join(str(f) for f in sorted(
        findings, key=lambda f: (f.rule, f.where)))
