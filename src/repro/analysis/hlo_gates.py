"""Reusable compiled-artifact gates on top of ``launch.hlo_analysis``.

The fused SSpNNA kernel's whole contract is *what the compiled graph does
not contain*: no XLA gather, no scatter, no (T, dI, C) working-set
intermediate in HBM. Until now those assertions lived ad hoc inside
individual tests; these gates make them reusable against any jitted
function (single-device, ``shard_map``-sharded, streaming) and add two
more compiled-artifact budgets:

* ``REPRO-H001`` — forbidden opcode present in the compiled HLO
  (default set: ``gather``, ``scatter`` — collective ``all-gather`` /
  ``reduce-scatter`` are distinct opcodes and pass).
* ``REPRO-H002`` — recompile budget exceeded: a jitted function compiled
  more signatures than its bucket family allows (a silent shape leak
  turns "<=1 compile per bucket" into a compile per scene).
* ``REPRO-H003`` — modeled VMEM footprint of the fused Pallas kernel
  (from the static block shapes a ``Dispatch`` pins) exceeds the budget.
"""
from __future__ import annotations

from repro.analysis.findings import Finding
from repro.launch.hlo_analysis import parse_hlo

DEFAULT_FORBIDDEN = ("gather", "scatter")

#: default VMEM budget for H003 (16 MiB, a TPU core's VMEM)
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


def compiled_text(fn, *args, **kw) -> str:
    """Optimized HLO text of ``fn`` jitted on ``args`` (accepts an already
    jitted function, a plain callable, or a string of HLO)."""
    if isinstance(fn, str):
        return fn
    import jax
    if not hasattr(fn, "lower"):
        fn = jax.jit(fn)
    return fn.lower(*args, **kw).compile().as_text()


def forbidden_ops(hlo_text: str,
                  forbidden: tuple[str, ...] = DEFAULT_FORBIDDEN,
                  *, where: str = "hlo") -> list[Finding]:
    """REPRO-H001 for every instruction whose opcode is in ``forbidden``
    (exact opcode match per computation)."""
    out: list[Finding] = []
    bad = set(forbidden)
    for comp in parse_hlo(hlo_text).values():
        hits: dict[str, int] = {}
        for inst in comp.instructions.values():
            if inst.opcode in bad:
                hits[inst.opcode] = hits.get(inst.opcode, 0) + 1
        for op, n in sorted(hits.items()):
            out.append(Finding(
                "REPRO-H001", f"{where}:{comp.name}",
                f"forbidden op {op!r} appears {n}x in computation "
                f"{comp.name!r}"))
    return out


def gate_forbidden_ops(fn, *args, forbidden=DEFAULT_FORBIDDEN,
                       where: str = "hlo", **kw) -> list[Finding]:
    """Compile ``fn(*args)`` and apply :func:`forbidden_ops`."""
    return forbidden_ops(compiled_text(fn, *args, **kw),
                         forbidden, where=where)


# -- recompile budgets ------------------------------------------------------

def compile_count(fn) -> int:
    """Number of signatures a ``jax.jit`` function has compiled."""
    size = getattr(fn, "_cache_size", None)
    if size is None:
        raise TypeError(f"{fn!r} is not a jitted function")
    return int(size())


def gate_compile_budget(fn_or_count, max_signatures: int,
                        *, where: str = "jit") -> list[Finding]:
    """REPRO-H002 when a jitted function (or a raw signature count — e.g.
    ``SceneEngine.n_compilations``) exceeds its bucket family's budget."""
    n = fn_or_count if isinstance(fn_or_count, int) \
        else compile_count(fn_or_count)
    if n > max_signatures:
        return [Finding(
            "REPRO-H002", where,
            f"{n} compiled signatures exceeds the bucket budget of "
            f"{max_signatures} (shape leak: something varies per call "
            f"that the signature family should pin)")]
    return []


# -- modeled VMEM footprint -------------------------------------------------

def modeled_vmem_bytes(*, delta_o: int, delta_i: int, c_in: int,
                       block_n: int, k: int = 27,
                       itemsize: int = 4) -> int:
    """Static VMEM footprint of the fused SSpNNA kernel for one grid step,
    from the block shapes a ``Dispatch`` pins (see
    ``kernels/sspnna/sspnna.py`` scratch_shapes / in_specs):

    * ``2 * delta_i * c_in`` — double-buffered DMA working set (scratch);
    * ``delta_o * block_n`` — output staging tile (scratch);
    * ``2 * (delta_o * k)`` — pipelined ``local_idx`` block (int32);
    * ``2 * (k * c_in * block_n)`` — pipelined weight block.

    The factor 2 on the in_spec blocks is Pallas's input double buffering.
    """
    scratch = 2 * delta_i * c_in * itemsize + delta_o * block_n * itemsize
    idx_blk = 2 * delta_o * k * 4
    w_blk = 2 * k * c_in * block_n * itemsize
    return scratch + idx_blk + w_blk


def gate_vmem_budget(dispatch, c_in: int, *,
                     budget: int = DEFAULT_VMEM_BUDGET,
                     k: int = 27, where: str = "dispatch"
                     ) -> list[Finding]:
    """REPRO-H003 when a fused-kernel dispatch's modeled VMEM exceeds the
    budget. Non-tile dispatches (no ``delta_i``) pass trivially."""
    d_o = getattr(dispatch, "delta_o", None)
    d_i = getattr(dispatch, "delta_i", None)
    bn = getattr(dispatch, "block_n", None) or c_in
    if not d_o or not d_i:
        return []
    need = modeled_vmem_bytes(delta_o=d_o, delta_i=d_i, c_in=c_in,
                              block_n=bn, k=k)
    if need > budget:
        return [Finding(
            "REPRO-H003", where,
            f"modeled VMEM {need} B > budget {budget} B "
            f"(delta_o={d_o}, delta_i={d_i}, c_in={c_in}, block_n={bn})")]
    return []


def gate_plan_vmem(plan, widths, *, budget: int = DEFAULT_VMEM_BUDGET,
                   where: str = "plan") -> list[Finding]:
    """Apply :func:`gate_vmem_budget` to every tiled conv of a
    ``ScenePlan`` (``widths[li]`` is the level's channel count)."""
    out: list[Finding] = []
    for li, lvl in enumerate(plan.levels):
        conv = lvl.sub
        if getattr(conv, "tiles", None) is None:
            continue
        c_in = widths[li] if li < len(widths) else widths[-1]
        out.extend(gate_vmem_budget(
            conv.dispatch, int(c_in), budget=budget,
            where=f"{where}.levels[{li}].sub"))
    return out
