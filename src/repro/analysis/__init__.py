"""`repro.analysis` — static analysis over the repo's own invariants.

Four passes, one CLI (``python -m repro.analysis``), all returning
:class:`~repro.analysis.findings.Finding` lists:

* ``lint`` — repo-specific AST rules (deprecated shims, host syncs in
  hot paths, unnamed/non-daemon threads, contextvars on serving seams).
* ``concurrency`` — AST lock-graph extraction over ``src/repro`` checked
  against the documented global lock order
  (:data:`repro.analysis.runtime.LOCK_ORDER`), plus blocking-call-under-
  lock detection; the runtime counterpart is ``REPRO_LOCK_CHECK=1``.
* ``plan_check`` — structural validation of built
  ``ScenePlan``/``ShardedScenePlan``/``StreamPlanState`` objects: COIR
  bounds, SOAR/tile pair coverage, DMA table bounds, halo send tables,
  cache-key version/generation mixing.
* ``hlo_gates`` — compiled-artifact gates on top of
  ``launch.hlo_analysis``: forbidden-op sets, recompile budgets, modeled
  VMEM footprints.

Submodules are imported lazily: lock-owning modules under ``src/repro``
import ``repro.analysis.runtime`` at module load, and the passes import
those same modules — eager imports here would cycle.
"""
from __future__ import annotations

from repro.analysis.findings import Finding, render

_SUBMODULES = ("concurrency", "findings", "hlo_gates", "lint",
               "plan_check", "runtime")

__all__ = ["Finding", "render", *_SUBMODULES]


def __getattr__(name: str):
    if name in _SUBMODULES:
        import importlib
        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
