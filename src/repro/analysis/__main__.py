"""``python -m repro.analysis`` — run every static-analysis pass.

Passes (select with ``--only`` / drop with ``--skip``):

* ``lint``  — AST rules over src/repro, examples/, benchmarks/.
* ``locks`` — lock-graph extraction + order check over src/repro.
* ``plans`` — build canonical plans (batched spec'd, sharded, streaming)
  from a small synthetic scene and run every structural invariant.
* ``hlo``   — compile the fused SSpNNA kernel on a real tile plan and run
  the forbidden-op / VMEM / recompile gates.

Exit status is the number of findings (0 = clean, capped at 125).
``--json`` additionally writes findings + the extracted lock graph.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.findings import Finding, render

PASSES = ("lint", "locks", "plans", "hlo")


def find_root(start: Path | None = None) -> Path:
    """Repo root: the nearest ancestor holding ``src/repro`` (falls back
    to the package's own checkout layout)."""
    cands = [start] if start else []
    cands += [Path.cwd(), Path(__file__).resolve().parents[3]]
    for c in cands:
        if c is not None and (c / "src" / "repro").is_dir():
            return c
    raise SystemExit("cannot locate repo root (need a src/repro dir); "
                     "pass --root")


def run_lint(root: Path) -> list[Finding]:
    from repro.analysis.lint import lint_repo
    return lint_repo(root)


def run_locks(root: Path):
    from repro.analysis.concurrency import extract
    return extract(root)


def _canonical_scene(seed: int = 0, resolution: int = 16,
                     capacity: int = 512):
    import jax.numpy as jnp

    from repro.data.scenes import make_scene
    from repro.sparse.tensor import SparseVoxelTensor
    coords, feats, _, mask = make_scene(seed, resolution=resolution,
                                        capacity=capacity)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


def run_plans(root: Path) -> list[Finding]:
    """Build one plan of each kind from a canonical synthetic scene and
    validate every structural invariant, plus the cache-key rotations."""
    del root
    from repro import engine
    from repro.analysis.plan_check import (
        check_cache_keys,
        check_scene_plan,
        check_sharded_scene_plan,
        check_stream_state,
    )
    from repro.data.scenes import N_CLASSES
    from repro.engine.autotune import CostTable
    from repro.engine.backends import BreakerBoard, default_registry
    from repro.engine.plan import PlanCache, StreamPlanState
    from repro.engine.shard import ShardLayout
    from repro.models.scn import UNetConfig

    res, cap = 16, 512
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=res, capacity=cap,
                     n_classes=N_CLASSES)
    t = _canonical_scene(0, res, cap)
    out: list[Finding] = []

    # batched, SPADE-planned with tile tables (the fused-kernel shape)
    spec = engine.build_plan_spec([t], cfg, mem_budget=64 * 1024)
    plan = engine.build_scene_plan_host(t, cfg, spec=spec, plan_tiles=True)
    out.extend(check_scene_plan(plan, "scene_plan"))

    # reference-dispatch plan (no tiles) exercises the COIR-only checks
    ref = engine.build_scene_plan_host(t, cfg, plan_tiles=False)
    out.extend(check_scene_plan(ref, "reference_plan"))

    # sharded plan with halo send tables
    layout = ShardLayout(n_shards=2, halo=256)
    splan = engine.build_sharded_scene_plan_host(t, cfg, layout=layout)
    out.extend(check_sharded_scene_plan(splan, "sharded_plan"))

    # streaming: frame 0 rebuild, frame 1 patched under an ego shift
    state = StreamPlanState(cfg, spec=spec, wait_s=30.0)
    state.plan_frame(t, 0)
    state.plan_frame(t, 1, ego_shift=(1, 0, 0))
    out.extend(check_stream_state(state, "stream"))

    # cache keys must rotate with version/topology/generations
    cache = PlanCache(capacity=cap)
    out.extend(check_cache_keys(
        cache, t, cfg, autotune=CostTable(),
        breakers=BreakerBoard(default_registry())))
    return out


def run_hlo(root: Path) -> list[Finding]:
    """Compile the fused SSpNNA path on a real budgeted tile plan from the
    canonical scene; gate forbidden ops, VMEM, and the compile count."""
    del root
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.hlo_gates import (
        compiled_text,
        forbidden_ops,
        gate_compile_budget,
        gate_vmem_budget,
    )
    from repro.core import soar
    from repro.core.hashgrid import build_neighbor_table, kernel_offsets
    from repro.core.sparse_conv import submanifold_coir
    from repro.core.tiles import build_tile_plan, dma_tile_tables
    from repro.kernels.sspnna.ops import run_sspnna_conv

    res = 16
    t = _canonical_scene(0, res, 512)
    coir = submanifold_coir(t, res, 3)
    nbr = np.asarray(build_neighbor_table(
        t.coords, t.mask, jnp.asarray(kernel_offsets(3)), res))
    order = soar.soar_order(nbr, np.asarray(t.mask), 128).order
    tp = build_tile_plan(np.asarray(coir.indices), order, 16, 48)
    dma = dma_tile_tables(tp, t.capacity)
    rng = np.random.default_rng(0)
    c_in, c_out = 8, 8
    feats = jnp.asarray(rng.normal(size=(t.capacity, c_in)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(27, c_in, c_out)) * 0.1, jnp.float32)
    orow, irow = jnp.asarray(dma.out_rows), jnp.asarray(dma.in_rows)
    li, pc = jnp.asarray(tp.local_idx), jnp.asarray(dma.pair_counts)

    def fused(f, ww):
        return run_sspnna_conv(f, ww, orow, irow, li, n_out=t.capacity,
                               pair_counts=pc, use_kernel=True)

    jit = jax.jit(fused)
    out = forbidden_ops(compiled_text(jit, feats, w), where="sspnna_fused")
    out.extend(gate_compile_budget(jit, 1, where="sspnna_fused"))

    class _D:
        delta_o, delta_i, block_n = 16, 48, c_out
    out.extend(gate_vmem_budget(_D, c_in, where="sspnna_fused"))
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detect)")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write findings + lock graph as JSON")
    ap.add_argument("--only", choices=PASSES, action="append",
                    help="run only these passes")
    ap.add_argument("--skip", choices=PASSES, action="append", default=[],
                    help="skip these passes")
    args = ap.parse_args(argv)
    root = find_root(args.root)
    selected = [p for p in (args.only or PASSES) if p not in args.skip]

    findings: list[Finding] = []
    graph_json = None
    for name in selected:
        if name == "locks":
            got, graph = run_locks(root)
            graph_json = {
                "locks": graph.locks,
                "reentrant": sorted(graph.reentrant),
                "edges": sorted(list(e) for e in graph.edges),
            }
        else:
            got = {"lint": run_lint, "plans": run_plans,
                   "hlo": run_hlo}[name](root)
        print(f"[analysis] {name}: "
              f"{'clean' if not got else f'{len(got)} finding(s)'}")
        findings.extend(got)

    if findings:
        print(render(findings), file=sys.stderr)
    if args.json is not None:
        args.json.write_text(json.dumps({
            "passes": selected,
            "n_findings": len(findings),
            "findings": [f.to_dict() for f in findings],
            "lock_graph": graph_json,
        }, indent=2) + "\n")
        print(f"[analysis] wrote {args.json}")
    return min(len(findings), 125)


if __name__ == "__main__":
    sys.exit(main())
