"""Repo-specific AST lint rules.

Rules (ids are stable; catalog in README "Static analysis"):

* ``REPRO-L001`` — internal use of a deprecated shim. The shims exist for
  external callers mid-migration; repo code (src, examples, benchmarks)
  must use the replacement APIs. The defining module is exempt.
* ``REPRO-L002`` — host sync inside a serving hot path. ``_dispatch_stage``
  methods run on the wave pipeline's critical path and must only *enqueue*
  device work: ``np.asarray`` readbacks, ``.item()``, and
  ``block_until_ready`` stall the async pipeline.
* ``REPRO-L003`` — unnamed or non-daemon thread. Every
  ``threading.Thread`` must pass ``name=`` and ``daemon=True`` (watchdog
  traces, lock reports and ``health()`` snapshots attribute work by thread
  name; non-daemon threads wedge interpreter shutdown on crashed runs).
  ``ThreadPoolExecutor`` must pass ``thread_name_prefix=``.
* ``REPRO-L004`` — ``contextvars`` in ``serving/``. Ambient state consulted
  from planner/watchdog threads (the fault injector seam) must be a module
  global: a contextvar silently resets in pool threads (the PR 9 lesson).
* ``REPRO-L005`` — host readback (``np.asarray``/``.item()``) inside a
  timed benchmark closure (an argument to ``time_fn``/``measure``).
  Readbacks time the transfer, not the kernel; ``block_until_ready`` is
  the correct way to fence timed device work.

A line comment ``# analysis: allow[RULE-ID]`` suppresses that rule on that
line (use sparingly; say why next to it).
"""
from __future__ import annotations

import ast
import re
from pathlib import Path

from repro.analysis.findings import Finding

# shim module -> names deprecated there (the module itself is exempt)
DEPRECATED_SHIMS: dict[str, frozenset[str]] = {
    "repro.models.scn": frozenset({"build_unet_metadata", "apply_unet"}),
    "repro.core.sparse_conv": frozenset({"sparse_conv_cirf"}),
    "repro.kernels.sspnna.ops": frozenset(
        {"sspnna_conv", "sspnna_conv_from_plan"}),
    "benchmarks.common": frozenset({"autotune_block_n"}),
}

_HOT_FUNCS = ("_dispatch_stage",)
_TIMER_NAMES = ("time_fn", "measure")
_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\[([A-Z0-9-]+)\]")


def _allowed_lines(source: str) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        for m in _ALLOW_RE.finditer(line):
            out.setdefault(i, set()).add(m.group(1))
    return out


def _call_name(func: ast.expr) -> str:
    """Dotted name of a call target, best effort ('' when dynamic)."""
    parts: list[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class _ModuleLint(ast.NodeVisitor):
    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.allowed = _allowed_lines(source)
        self.findings: list[Finding] = []
        self.in_serving = "/serving/" in rel.replace("\\", "/")
        self.module_name = self._module_name(rel)
        # alias -> fully qualified module (import repro.models.scn as scn)
        self.mod_alias: dict[str, str] = {}
        # hot-path / timed-closure function stack
        self._hot_depth = 0
        self._timed_depth = 0
        self._local_funcs: dict[str, ast.AST] = {}

    @staticmethod
    def _module_name(rel: str) -> str:
        p = rel.replace("\\", "/")
        if p.endswith(".py"):
            p = p[:-3]
        if p.endswith("/__init__"):
            p = p[: -len("/__init__")]
        parts = p.split("/")
        if "src" in parts:
            parts = parts[parts.index("src") + 1:]
        return ".".join(parts)

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        if rule in self.allowed.get(line, ()):
            return
        self.findings.append(Finding(rule, f"{self.rel}:{line}", message))

    # -- shims (L001) ------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.mod_alias[alias.asname or alias.name.split(".")[0]] = \
                alias.name
            if alias.name == "contextvars":
                self._l004(node)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        if mod == "contextvars":
            self._l004(node)
        names = DEPRECATED_SHIMS.get(mod)
        if names and self.module_name != mod:
            for alias in node.names:
                if alias.name in names:
                    self._emit(
                        "REPRO-L001", node,
                        f"import of deprecated shim "
                        f"{mod}.{alias.name}; use the replacement API")
        for alias in node.names:
            # from repro.models import scn  ->  scn -> repro.models.scn
            candidate = f"{mod}.{alias.name}" if mod else alias.name
            if candidate in DEPRECATED_SHIMS:
                self.mod_alias[alias.asname or alias.name] = candidate
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if isinstance(node.value, ast.Name):
            mod = self.mod_alias.get(node.value.id)
            # also catch dotted access: repro.models.scn.apply_unet
            names = DEPRECATED_SHIMS.get(mod or "")
            if names and node.attr in names and self.module_name != mod:
                self._emit(
                    "REPRO-L001", node,
                    f"use of deprecated shim {mod}.{node.attr}; "
                    f"use the replacement API")
        self.generic_visit(node)

    def _l004(self, node: ast.AST) -> None:
        if self.in_serving:
            self._emit(
                "REPRO-L004", node,
                "contextvars in serving/: ambient seams consulted from "
                "planner threads must be module globals (see "
                "serving.faults._ACTIVE)")

    # -- threads (L003) ----------------------------------------------------

    def _check_thread_call(self, node: ast.Call) -> None:
        name = _call_name(node.func)
        short = name.rsplit(".", 1)[-1]
        if short == "Thread" and name in ("Thread", "threading.Thread"):
            kw = {k.arg: k.value for k in node.keywords}
            if "name" not in kw:
                self._emit("REPRO-L003", node,
                           "threading.Thread without name=; name every "
                           "thread so traces and health() attribute it")
            d = kw.get("daemon")
            if d is None or not (isinstance(d, ast.Constant)
                                 and d.value is True):
                self._emit("REPRO-L003", node,
                           "threading.Thread without daemon=True; "
                           "non-daemon threads wedge interpreter shutdown")
        if short == "ThreadPoolExecutor":
            if not any(k.arg == "thread_name_prefix" for k in node.keywords):
                self._emit("REPRO-L003", node,
                           "ThreadPoolExecutor without thread_name_prefix=")

    # -- host syncs (L002 / L005) ------------------------------------------

    def _check_host_sync(self, node: ast.Call, rule: str,
                         ban_block_until_ready: bool) -> None:
        name = _call_name(node.func)
        # attr-based so chained receivers (``f(x).item()``) still match
        attr = node.func.attr if isinstance(node.func, ast.Attribute) else ""
        what = None
        if name.rsplit(".", 1)[-1] == "asarray" and \
                name.split(".")[0] in ("np", "numpy"):
            what = f"{name}() host readback"
        elif attr == "item":
            what = ".item() host readback"
        elif attr == "block_until_ready" and ban_block_until_ready:
            what = "block_until_ready() device sync"
        if what is not None:
            where = ("dispatch stage" if rule == "REPRO-L002"
                     else "timed benchmark closure")
            self._emit(rule, node, f"{what} inside {where}")

    def visit_Call(self, node: ast.Call) -> None:
        self._check_thread_call(node)
        if self._hot_depth:
            self._check_host_sync(node, "REPRO-L002",
                                  ban_block_until_ready=True)
        elif self._timed_depth:
            self._check_host_sync(node, "REPRO-L005",
                                  ban_block_until_ready=False)
        # timed closures: time_fn(fn, ...) / measure(fn, ...)
        name = _call_name(node.func).rsplit(".", 1)[-1]
        if name in _TIMER_NAMES and node.args:
            target = node.args[0]
            body = None
            if isinstance(target, ast.Lambda):
                body = target
            elif isinstance(target, ast.Name):
                body = self._local_funcs.get(target.id)
            if body is not None:
                self._timed_depth += 1
                for child in ast.iter_child_nodes(body):
                    self.visit(child)
                self._timed_depth -= 1
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._local_funcs[node.name] = node
        hot = node.name in _HOT_FUNCS
        if hot:
            self._hot_depth += 1
        self.generic_visit(node)
        if hot:
            self._hot_depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


def lint_source(source: str, rel: str, path: Path | None = None
                ) -> list[Finding]:
    """Lint one module's source; ``rel`` is the repo-relative path (used
    for scope decisions and finding locations)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("REPRO-L000", f"{rel}:{e.lineno or 0}",
                        f"syntax error: {e.msg}")]
    v = _ModuleLint(path or Path(rel), rel, source)
    v.visit(tree)
    return v.findings


def iter_python_files(root: Path, subdirs: tuple[str, ...]) -> list[Path]:
    out: list[Path] = []
    for sub in subdirs:
        base = root / sub
        if not base.exists():
            continue
        for p in sorted(base.rglob("*.py")):
            if "__pycache__" in p.parts:
                continue
            out.append(p)
    return out


def lint_repo(root: Path,
              subdirs: tuple[str, ...] = ("src/repro", "examples",
                                          "benchmarks")) -> list[Finding]:
    """Run every lint rule over the repo's own code (tests are exempt:
    they exercise shims and seeded violations deliberately)."""
    findings: list[Finding] = []
    for p in iter_python_files(root, subdirs):
        rel = p.relative_to(root).as_posix()
        findings.extend(lint_source(p.read_text(), rel, p))
    return findings
