"""Gemma-2 2B dense decoder.

[arXiv:2408.00118; hf] — alternating local(4096)/global attention, logit
softcapping (attn 50, final 30), GeGLU, embedding scaling, tied embeddings.
8 q-heads don't divide the 16-wide model axis -> sequence attention sharding.
long_500k is skipped: the global layers are full attention (DESIGN.md §5).
"""
from repro.configs.base import GLOBAL, LOCAL, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        attn_pattern=(LOCAL, GLOBAL),
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        rope_theta=10000.0,
        act="geglu",
        scale_embeddings=True,
        tie_embeddings=True,
        attn_sharding="sequence",
    )
)
