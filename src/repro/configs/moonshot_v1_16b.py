"""Moonshot/Moonlight 16B-A3B fine-grained MoE decoder.

[hf:moonshotai/Moonlight-16B-A3B; hf] — 64 experts, top-6, narrow experts
(d_ff=1408, DeepSeek-style fine-grained).
"""
from repro.configs.base import GLOBAL, ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163840,
        attn_pattern=(GLOBAL,),
        rope_theta=50000.0,
        act="swiglu",
        tie_embeddings=True,
        moe=MoEConfig(n_experts=64, top_k=6, capacity_factor=1.25),
        attn_sharding="heads",
    )
)
