"""StableLM-2 1.6B dense decoder.

[hf:stabilityai/stablelm-2-1_6b; unverified] — full MHA (kv == heads).
"""
from repro.configs.base import GLOBAL, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=5632,
        vocab_size=100352,
        attn_pattern=(GLOBAL,),
        rope_theta=10000.0,
        act="swiglu",
        tie_embeddings=False,
        attn_sharding="heads",
    )
)
