"""SeamlessM4T-medium encoder-decoder (speech frontend stubbed).

[arXiv:2308.11596; hf] — 12 encoder + 12 decoder layers; ``input_specs``
supplies precomputed frame embeddings as the encoder input (assignment
spec: modality frontend is a STUB). vocab 256206 is padded to 256256 for
16-way TP (logits masked) — the only config deviation.
"""
from repro.configs.base import GLOBAL, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        n_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256206,
        attn_pattern=(GLOBAL,),
        rope_theta=10000.0,
        act="gelu",
        tie_embeddings=True,
        encoder_layers=12,
        frontend="audio",
        attn_sharding="heads",
    )
)
