"""RWKV-6 (Finch) 7B — attention-free, data-dependent-decay linear RNN.

[arXiv:2404.05892; hf] — 64 wkv heads of size 64; time-mix replaces
attention, channel-mix (d_ff=14336) replaces the FFN. O(1) decode state:
runs long_500k.
"""
from repro.configs.base import RWKV, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,
        n_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        attn_pattern=(RWKV,),
        rwkv_head_dim=64,
        act="rwkv_cm",
        tie_embeddings=False,
        attn_sharding="heads",
        sub_quadratic=True,
    )
)
