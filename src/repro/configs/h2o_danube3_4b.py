"""H2O Danube-3 4B dense decoder with sliding-window attention.

[arXiv:2401.16818; unverified] — llama+mistral mix; SWA(4096) on every
layer makes decode state O(window): runs long_500k.
"""
from repro.configs.base import LOCAL, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        head_dim=120,
        d_ff=10240,
        vocab_size=32000,
        attn_pattern=(LOCAL,),
        window=4096,
        rope_theta=10000.0,
        act="swiglu",
        tie_embeddings=False,
        attn_sharding="heads",
        sub_quadratic=True,
    )
)
