"""Llama-4 Maverick 400B-A17B class MoE decoder.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — assigned spec taken
literally: every layer MoE, 128 experts, top-1 routing (Switch-style).
40 q-heads do not divide the 16-wide model axis, so attention uses sequence
sharding (DESIGN.md §5); experts shard 8-per-device (EP).
"""
from repro.configs.base import GLOBAL, ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        attn_pattern=(GLOBAL,),
        rope_theta=500000.0,
        act="swiglu",
        tie_embeddings=False,
        moe=MoEConfig(n_experts=128, top_k=1, capacity_factor=1.25),
        optimizer="adafactor",   # fits single-pod 16 GB/chip (DESIGN.md §6)
        attn_sharding="sequence",
        sub_quadratic=False,
    )
)
