"""Model configuration schema + arch registry.

One ``ModelConfig`` covers every assigned family (dense / moe / ssm / hybrid
/ encdec / vlm). ``reduced()`` produces the family-preserving small config
used by the per-arch smoke tests; full configs are only ever lowered via
ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax.numpy as jnp

# layer kinds usable in attn_pattern (cycled over layers)
GLOBAL, LOCAL, RWKV, RGLRU = "global", "local", "rwkv", "rglru"


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25   # SST-ish default; RST planning can lower it
    moe_layer_period: int = 1       # every n-th layer is MoE


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    attn_pattern: tuple[str, ...] = (GLOBAL,)
    window: int = 4096
    attn_softcap: float | None = None
    final_softcap: float | None = None
    rope_theta: float = 10000.0
    act: str = "swiglu"              # swiglu|geglu|gelu
    norm_eps: float = 1e-6
    scale_embeddings: bool = False
    tie_embeddings: bool = True
    moe: MoEConfig = field(default_factory=MoEConfig)
    # ssm / hybrid extras
    rglru_dim: int = 0
    conv1d_width: int = 4
    rwkv_head_dim: int = 64
    # encoder-decoder
    encoder_layers: int = 0
    # modality frontend stub: number of prefix embeddings supplied externally
    frontend: str | None = None      # None|"vision"|"audio"
    n_frontend_tokens: int = 0
    # numerics / training
    dtype: str = "bfloat16"
    attn_dtype: str = "float32"      # online-softmax accumulation dtype
    remat: bool = True
    remat_policy: str = "full"       # full | dots
    optimizer: str = "adamw"         # adamw|adafactor
    # distribution
    attn_sharding: str = "heads"     # heads|sequence (set per §5 of DESIGN.md)
    sub_quadratic: bool = False      # may run long_500k

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.moe.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def vocab_padded(self) -> int:
        return ((self.vocab_size + 255) // 256) * 256

    def layer_kind(self, i: int) -> str:
        return self.attn_pattern[i % len(self.attn_pattern)]

    def param_count(self) -> int:
        """Analytical parameter count (used for 6ND roofline numbers)."""
        d, v = self.d_model, self.vocab_padded
        att = (d * self.n_heads * self.head_dim * 2
               + d * self.n_kv_heads * self.head_dim * 2)
        if self.act in ("swiglu", "geglu"):
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        total = 0
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind in (GLOBAL, LOCAL):
                total += att
            elif kind == RWKV:
                total += 4 * d * d + 2 * d * self.d_ff + d * d  # tm + cm approx
                continue  # rwkv channel-mix replaces ffn
            elif kind == RGLRU:
                r = self.rglru_dim or d
                total += 2 * d * r + r * d + 2 * r * self.conv1d_width
            if self.is_moe and (i % self.moe.moe_layer_period == 0):
                total += self.moe.n_experts * ffn + d * self.moe.n_experts
            else:
                total += ffn
        total += v * d * (1 if self.tie_embeddings else 2)
        enc_att = att
        total += self.encoder_layers * (enc_att + ffn + (att if self.is_encdec else 0))
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        ffn = (3 if self.act in ("swiglu", "geglu") else 2) * d * self.d_ff
        n_moe_layers = self.n_layers // self.moe.moe_layer_period
        dense_total = self.param_count() - n_moe_layers * (
            self.moe.n_experts * ffn
        )
        return dense_total + self.n_layers // self.moe.moe_layer_period * (
            self.moe.top_k * ffn
        )

    def reduced(self) -> "ModelConfig":
        """Family-preserving small config for CPU smoke tests."""
        n_kv = max(1, min(self.n_kv_heads,
                          4 * self.n_kv_heads // max(self.n_heads, 1), 4))
        if self.n_kv_heads == self.n_heads:
            n_kv = 4
        moe = self.moe
        if self.is_moe:
            # capacity 4.0: no dropped tokens -> smoke tests are exactly
            # length-invariant (drops are exercised by dedicated MoE tests)
            moe = replace(moe, n_experts=min(8, moe.n_experts),
                          top_k=min(2, moe.top_k), capacity_factor=4.0)
        return replace(
            self,
            n_layers=min(self.n_layers, 2 if self.is_encdec else 3),
            d_model=128,
            n_heads=4,
            n_kv_heads=n_kv,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            window=32,
            rglru_dim=128 if self.rglru_dim else 0,
            encoder_layers=min(self.encoder_layers, 2),
            n_frontend_tokens=min(self.n_frontend_tokens, 8),
            moe=moe,
            dtype="float32",
            remat=False,
        )


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from repro import configs  # noqa: F401  (populates registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs  # noqa: F401

    return sorted(_REGISTRY)
