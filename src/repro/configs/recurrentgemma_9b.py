"""RecurrentGemma 9B (Griffin): RG-LRU + local attention, 1:2 pattern.

[arXiv:2402.19427; unverified] — two RG-LRU recurrent blocks then one
local-MQA block (window 2048), GeGLU MLP, embedding scaling. O(state)
decode: runs long_500k.
"""
from repro.configs.base import LOCAL, RGLRU, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        attn_pattern=(RGLRU, RGLRU, LOCAL),
        window=2048,
        rope_theta=10000.0,
        act="geglu",
        scale_embeddings=True,
        tie_embeddings=True,
        rglru_dim=4096,
        conv1d_width=4,
        attn_sharding="heads",
        sub_quadratic=True,
    )
)
