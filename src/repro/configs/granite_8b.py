"""IBM Granite 8B (code) dense decoder.

[arXiv:2405.04324; hf] — llama-arch GQA.
"""
from repro.configs.base import GLOBAL, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=49152,
        attn_pattern=(GLOBAL,),
        rope_theta=10000.0,
        act="swiglu",
        tie_embeddings=True,
        attn_sharding="heads",
    )
)
