"""Pixtral 12B multimodal decoder (backbone only; ViT frontend stubbed).

[hf:mistralai/Pixtral-12B-2409; unverified] — mistral-nemo-style decoder;
``input_specs`` supplies 256 precomputed patch embeddings per sequence that
replace the first 256 token embeddings (assignment spec: frontend is a STUB).
"""
from repro.configs.base import GLOBAL, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="pixtral-12b",
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        attn_pattern=(GLOBAL,),
        rope_theta=1000000.0,
        act="swiglu",
        tie_embeddings=False,
        frontend="vision",
        n_frontend_tokens=256,
        attn_sharding="heads",
    )
)
