"""Arch registry: one module per assigned architecture (+ the paper's SCN).

``get_config(name)`` returns the exact public config; ``cfg.reduced()`` the
smoke-test size. The SCN U-Net (the paper's own workload) lives in
``repro.models.scn.UNetConfig``.
"""
from repro.configs import (  # noqa: F401  — registration side effects
    gemma2_2b,
    granite_8b,
    h2o_danube3_4b,
    llama4_maverick_400b,
    moonshot_v1_16b,
    pixtral_12b,
    recurrentgemma_9b,
    rwkv6_7b,
    seamless_m4t_medium,
    stablelm_1_6b,
)
from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    get_config,
    list_configs,
    register,
)

ARCH_NAMES = list_configs()

__all__ = ["ModelConfig", "MoEConfig", "get_config", "list_configs",
           "register", "ARCH_NAMES"]
