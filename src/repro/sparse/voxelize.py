"""Pointcloud -> voxel grid quantization (host-side data pipeline).

Deduplicates points landing in the same voxel by averaging their features,
mirroring the standard SCN preprocessing (Graham et al. 2018).
"""
from __future__ import annotations

import numpy as np

from .tensor import PAD_COORD


def voxelize(
    points: np.ndarray,
    features: np.ndarray,
    resolution: int,
    capacity: int | None = None,
):
    """Quantize points in [0, 1)^3 onto a resolution^3 grid.

    Returns (coords (V,3) int32, feats (V,C), mask (V,)) padded to capacity.
    """
    if points.ndim != 2 or points.shape[1] != 3:
        raise ValueError(f"points must be (N, 3), got {points.shape}")
    ijk = np.clip((points * resolution).astype(np.int64), 0, resolution - 1)
    key = (ijk[:, 0] * resolution + ijk[:, 1]) * resolution + ijk[:, 2]
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    uniq_key, start, counts = np.unique(key_s, return_index=True, return_counts=True)
    n = len(uniq_key)
    cap = capacity if capacity is not None else n
    if n > cap:
        # Keep the densest voxels first (deterministic truncation policy).
        keep = np.argsort(-counts, kind="stable")[:cap]
        keep.sort()
        uniq_key, start, counts = uniq_key[keep], start[keep], counts[keep]
        n = cap
    coords = np.full((cap, 3), PAD_COORD, np.int32)
    feats = np.zeros((cap, features.shape[1]), features.dtype)
    mask = np.zeros((cap,), bool)
    coords[:n, 0] = uniq_key // (resolution * resolution)
    coords[:n, 1] = (uniq_key // resolution) % resolution
    coords[:n, 2] = uniq_key % resolution
    # Mean feature per voxel via segment sums over the sorted order.
    seg_id = np.repeat(np.arange(n), counts)
    f_sorted = features[order]
    # order was truncated potentially: rebuild the slice covering kept voxels
    rows = (np.concatenate([np.arange(s, s + c)
                            for s, c in zip(start, counts)])
            if n else np.zeros(0, np.int64))
    sums = np.zeros((n, features.shape[1]), np.float64)
    np.add.at(sums, seg_id, f_sorted[rows])
    feats[:n] = (sums / counts[:, None]).astype(features.dtype)
    mask[:n] = True
    return coords, feats, mask
