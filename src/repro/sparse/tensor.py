"""Fixed-capacity padded sparse voxel tensor.

The on-device representation of a spatially-sparse 3D feature map: a padded
list of active voxel coordinates plus a feature row per voxel. Fixed capacity
keeps every shape static for jit/pjit; padding slots have ``mask == False``
and ``coords == -1``.

The paper stores the same information as a "list of active voxels" behind a
spatial hash (Section II); here the hash is replaced by sorted linear keys
(see ``repro.core.hashgrid``) which is the TPU-idiomatic equivalent.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PAD_COORD = -1


class SparseVoxelTensor(NamedTuple):
    """Padded sparse voxel tensor.

    coords: (V, 3) int32 voxel coordinates, PAD_COORD on padding rows.
    feats:  (V, C) features.
    mask:   (V,)   bool, True on active rows.
    """

    coords: jax.Array
    feats: jax.Array
    mask: jax.Array

    @property
    def capacity(self) -> int:
        return self.coords.shape[0]

    @property
    def channels(self) -> int:
        return self.feats.shape[-1]

    def n_active(self) -> jax.Array:
        return jnp.sum(self.mask.astype(jnp.int32))

    def replace_feats(self, feats: jax.Array) -> "SparseVoxelTensor":
        return SparseVoxelTensor(self.coords, feats, self.mask)


def compact_to_capacity(
    t: SparseVoxelTensor, capacity: int,
) -> tuple[SparseVoxelTensor, np.ndarray]:
    """Re-pack a scene into a (possibly different) fixed capacity: active
    rows first in their original order, padding after. Host-side numpy —
    this is the bucketed serving path's plan-stage re-pack, so a scene a
    client padded to any capacity serves from the smallest signature
    bucket its *active* voxels fit.

    Returns ``(compacted tensor with numpy leaves, active_idx)`` where
    ``active_idx`` maps compacted row ``i`` back to source row
    ``active_idx[i]`` (scatter results back with it at drain time).
    """
    mask = np.asarray(t.mask)
    idx = np.flatnonzero(mask)
    n = len(idx)
    if n > capacity:
        raise ValueError(
            f"capacity {capacity} < active voxels {n}; pick a larger bucket")
    coords_src = np.asarray(t.coords)
    feats_src = np.asarray(t.feats)
    coords = np.full((capacity, 3), PAD_COORD, np.int32)
    feats = np.zeros((capacity, feats_src.shape[-1]), feats_src.dtype)
    out_mask = np.zeros((capacity,), bool)
    coords[:n] = coords_src[idx]
    feats[:n] = feats_src[idx]
    out_mask[:n] = True
    return SparseVoxelTensor(coords, feats, out_mask), idx


MAX_RESOLUTION = 1290  # largest R with R**3 < 2**31 (int32-safe linear keys)


def linear_key(coords: jax.Array, resolution: int,
               mask: jax.Array | None = None) -> jax.Array:
    """Linear voxel key; inactive/padding rows map to the largest key.

    Keys are strictly monotone in (x, y, z) lexicographic order, so sorted
    keys support binary-search neighbour lookup (AdMAC's hash analogue).
    Resolution is capped so keys fit int32 (enable jax x64 to lift).
    """
    if resolution > MAX_RESOLUTION:
        raise ValueError(f"resolution {resolution} > int32-safe max {MAX_RESOLUTION}")
    r = jnp.int32(resolution)
    c = coords.astype(jnp.int32)
    key = (c[..., 0] * r + c[..., 1]) * r + c[..., 2]
    sentinel = jnp.int32(resolution) ** 3
    if mask is not None:
        key = jnp.where(mask, key, sentinel)
    else:
        key = jnp.where(jnp.all(coords >= 0, axis=-1), key, sentinel)
    return key


def from_dense(dense: np.ndarray, capacity: int | None = None) -> SparseVoxelTensor:
    """Build a SparseVoxelTensor from a dense (X, Y, Z, C) array (host side).

    A voxel is active iff any channel is non-zero.
    """
    occ = np.any(dense != 0, axis=-1)
    xs, ys, zs = np.nonzero(occ)
    n = len(xs)
    cap = capacity if capacity is not None else max(n, 1)
    if n > cap:
        raise ValueError(f"capacity {cap} < active voxels {n}")
    coords = np.full((cap, 3), PAD_COORD, np.int32)
    feats = np.zeros((cap, dense.shape[-1]), dense.dtype)
    mask = np.zeros((cap,), bool)
    coords[:n, 0], coords[:n, 1], coords[:n, 2] = xs, ys, zs
    feats[:n] = dense[xs, ys, zs]
    mask[:n] = True
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats), jnp.asarray(mask))


def to_dense(t: SparseVoxelTensor, resolution: int) -> np.ndarray:
    """Materialize to a dense (R, R, R, C) numpy array (host side)."""
    coords = np.asarray(t.coords)
    feats = np.asarray(t.feats)
    mask = np.asarray(t.mask)
    out = np.zeros((resolution, resolution, resolution, feats.shape[-1]), feats.dtype)
    c = coords[mask]
    out[c[:, 0], c[:, 1], c[:, 2]] = feats[mask]
    return out
