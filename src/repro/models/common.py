"""Shared model components: norms, RoPE, initializers, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
