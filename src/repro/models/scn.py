"""SCN U-Net: submanifold sparse conv network for 3D semantic segmentation.

The paper's primary workload (Graham et al. 2018 [18]): a U-net over a
sparse voxel grid — submanifold 3^3 conv blocks at each level, 2^3-stride-2
convs down, transposed convs back up with skip concatenation, and a linear
classifier over active voxels.

Metadata (COIR per level + level active sets) is built once per input by
``build_unet_metadata`` — the AdMAC pass — and reused by every conv at that
level, which is exactly the paper's motivation for amortizing adjacency
construction. ``apply_unet`` is a pure jittable function of (params, feats,
metadata).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coir as coir_lib
from repro.core.coir import COIR
from repro.core.hashgrid import downsample_coords, kernel_offsets
from repro.core.sparse_conv import (
    init_sparse_conv,
    sparse_conv_cirf,
    submanifold_coir,
    transposed_coir,
)
from repro.sparse.tensor import SparseVoxelTensor


@dataclass(frozen=True)
class UNetConfig:
    name: str = "scn_unet"
    in_channels: int = 4
    n_classes: int = 20
    widths: tuple[int, ...] = (16, 32, 48, 64)
    reps: int = 2
    resolution: int = 64
    capacity: int = 8192
    dtype: Any = jnp.float32

    @property
    def n_levels(self) -> int:
        return len(self.widths)


class LevelMeta(NamedTuple):
    coords: jax.Array
    mask: jax.Array
    sub_coir: COIR          # submanifold 3^3 metadata at this level
    down_coir: COIR | None  # strided 2^3 s2 conv to the next level
    up_coir: COIR | None    # transposed conv back to this level


def build_unet_metadata(t: SparseVoxelTensor, cfg: UNetConfig) -> list[LevelMeta]:
    """One AdMAC pass per level: active sets + all COIR blocks."""
    levels: list[LevelMeta] = []
    coords, mask = t.coords, t.mask
    res = cfg.resolution
    offs2 = jnp.asarray(kernel_offsets(2, centered=False))
    for li in range(cfg.n_levels):
        cur = SparseVoxelTensor(coords, jnp.zeros((coords.shape[0], 1)), mask)
        sub = submanifold_coir(cur, res, 3)
        down = up = None
        if li < cfg.n_levels - 1:
            dn_coords, dn_mask = downsample_coords(coords, mask, res, 2)
            down = coir_lib.build_cirf(
                dn_coords, dn_mask, coords, mask, offs2, res, stride=2
            )
            coarse = SparseVoxelTensor(
                dn_coords, jnp.zeros((dn_coords.shape[0], 1)), dn_mask
            )
            up = transposed_coir(coarse, coords, mask, res, 2, 2)
            levels.append(LevelMeta(coords, mask, sub, down, up))
            coords, mask, res = dn_coords, dn_mask, res // 2
        else:
            levels.append(LevelMeta(coords, mask, sub, None, None))
    return levels


def init_unet(key: jax.Array, cfg: UNetConfig) -> dict:
    keys = iter(jax.random.split(key, 1024))
    w = cfg.widths
    params: dict = {"levels": []}
    params["stem"] = init_sparse_conv(next(keys), 27, cfg.in_channels, w[0], cfg.dtype)
    for li in range(cfg.n_levels):
        lvl = {
            "enc": [
                _block_params(next(keys), w[li], w[li], cfg.dtype)
                for _ in range(cfg.reps)
            ]
        }
        if li < cfg.n_levels - 1:
            lvl["down"] = init_sparse_conv(next(keys), 8, w[li], w[li + 1], cfg.dtype)
            lvl["up"] = init_sparse_conv(next(keys), 8, w[li + 1], w[li], cfg.dtype)
            # decoder blocks see concat(skip, upsampled) = 2*w[li]
            lvl["dec"] = [
                _block_params(next(keys), 2 * w[li] if r == 0 else w[li],
                              w[li], cfg.dtype)
                for r in range(cfg.reps)
            ]
        params["levels"].append(lvl)
    params["head"] = {
        "w": jax.random.normal(next(keys), (w[0], cfg.n_classes), cfg.dtype)
        / np.sqrt(w[0]),
        "b": jnp.zeros((cfg.n_classes,), cfg.dtype),
    }
    return params


def _block_params(key, c_in, c_out, dtype):
    k1, _ = jax.random.split(key)
    return {
        "conv": init_sparse_conv(k1, 27, c_in, c_out, dtype),
        "bn_scale": jnp.ones((c_out,), dtype),
        "bn_offset": jnp.zeros((c_out,), dtype),
    }


def _bn_relu(x, mask, scale, offset, eps=1e-5):
    m = mask[:, None].astype(x.dtype)
    n = jnp.maximum(jnp.sum(m), 1.0)
    mean = jnp.sum(x * m, axis=0) / n
    var = jnp.sum(jnp.square(x - mean) * m, axis=0) / n
    y = (x - mean) * jax.lax.rsqrt(var + eps) * scale + offset
    return jax.nn.relu(y) * m


def _block(x, mask, coir, p):
    y = sparse_conv_cirf(x, coir, p["conv"])
    return _bn_relu(y, mask, p["bn_scale"], p["bn_offset"])


def apply_unet(params: dict, feats: jax.Array, meta: list[LevelMeta]) -> jax.Array:
    """-> (V, n_classes) logits on the level-0 active set."""
    x = sparse_conv_cirf(feats, meta[0].sub_coir, params["stem"])
    skips = []
    for li, lvl in enumerate(meta):
        p = params["levels"][li]
        for blk in p["enc"]:
            x = _block(x, lvl.mask, lvl.sub_coir, blk)
        if lvl.down_coir is not None:
            skips.append(x)
            x = sparse_conv_cirf(x, lvl.down_coir, p["down"])
    for li in range(len(meta) - 2, -1, -1):
        lvl, p = meta[li], params["levels"][li]
        up = sparse_conv_cirf(x, lvl.up_coir, p["up"])
        x = jnp.concatenate([skips[li], up], axis=-1)
        for blk in p["dec"]:
            x = _block(x, lvl.mask, lvl.sub_coir, blk)
    return x @ params["head"]["w"] + params["head"]["b"]


def segmentation_loss(logits, labels, mask):
    """Masked mean CE over active voxels + accuracy/mIoU-ready predictions."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    loss = -jnp.sum(ll * m) / jnp.maximum(jnp.sum(m), 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * m) / jnp.maximum(jnp.sum(m), 1)
    return loss, acc


def miou(pred: np.ndarray, labels: np.ndarray, mask: np.ndarray,
         n_classes: int) -> float:
    pred, labels = np.asarray(pred)[mask], np.asarray(labels)[mask]
    ious = []
    for c in range(n_classes):
        inter = np.sum((pred == c) & (labels == c))
        union = np.sum((pred == c) | (labels == c))
        if union:
            ious.append(inter / union)
    return float(np.mean(ious)) if ious else 0.0
