"""SCN U-Net: submanifold sparse conv network for 3D semantic segmentation.

The paper's primary workload (Graham et al. 2018 [18]): a U-net over a
sparse voxel grid — submanifold 3^3 conv blocks at each level, 2^3-stride-2
convs down, transposed convs back up with skip concatenation, and a linear
classifier over active voxels.

Execution lives in ``repro.engine``: build a ``ScenePlan`` once per input
(``engine.build_scene_plan`` — the AdMAC + SOAR + SPADE pass) and run
``engine.apply_unet(params, feats, plan)``. This module keeps the model
definition (config, parameter init, losses) plus deprecation shims for the
pre-engine entry points ``build_unet_metadata`` / ``apply_unet``.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core.coir import COIR
from repro.core.sparse_conv import init_sparse_conv
from repro.sparse.tensor import SparseVoxelTensor


@dataclass(frozen=True)
class UNetConfig:
    name: str = "scn_unet"
    in_channels: int = 4
    n_classes: int = 20
    widths: tuple[int, ...] = (16, 32, 48, 64)
    reps: int = 2
    resolution: int = 64
    capacity: int = 8192
    dtype: Any = jnp.float32

    @property
    def n_levels(self) -> int:
        return len(self.widths)


class LevelMeta(NamedTuple):
    """Pre-engine per-level metadata bundle (kept for the shims)."""

    coords: jax.Array
    mask: jax.Array
    sub_coir: COIR          # submanifold 3^3 metadata at this level
    down_coir: COIR | None  # strided 2^3 s2 conv to the next level
    up_coir: COIR | None    # transposed conv back to this level


def meta_to_plan(meta: list[LevelMeta]) -> engine.ScenePlan:
    """Adapt legacy LevelMeta lists to an (all-reference) engine ScenePlan."""
    levels = tuple(
        engine.LevelPlan(
            m.coords, m.mask, engine.ConvPlan(m.sub_coir),
            engine.ConvPlan(m.down_coir) if m.down_coir is not None else None,
            engine.ConvPlan(m.up_coir) if m.up_coir is not None else None,
        )
        for m in meta
    )
    return engine.ScenePlan(levels)


def build_unet_metadata(t: SparseVoxelTensor, cfg: UNetConfig) -> list[LevelMeta]:
    """Deprecated: use ``repro.engine.build_scene_plan`` (same AdMAC pass,
    plus SOAR/SPADE planning when requested)."""
    warnings.warn(
        "build_unet_metadata is deprecated; use repro.engine.build_scene_plan",
        DeprecationWarning, stacklevel=2)
    plan = engine.build_scene_plan(t, cfg, plan_tiles=False)
    return [
        LevelMeta(lvl.coords, lvl.mask, lvl.sub.coir,
                  lvl.down.coir if lvl.down is not None else None,
                  lvl.up.coir if lvl.up is not None else None)
        for lvl in plan.levels
    ]


def apply_unet(params: dict, feats: jax.Array,
               meta: "list[LevelMeta] | engine.ScenePlan") -> jax.Array:
    """Deprecated: use ``repro.engine.apply_unet`` with a ScenePlan."""
    warnings.warn(
        "models.scn.apply_unet is deprecated; use repro.engine.apply_unet",
        DeprecationWarning, stacklevel=2)
    plan = meta if isinstance(meta, engine.ScenePlan) else meta_to_plan(meta)
    # the pre-engine semantics were the reference einsum on every layer;
    # omitting ctx= dispatches through the ambient ExecutionContext
    return engine.apply_unet(params, feats, plan, backend="reference")


def init_unet(key: jax.Array, cfg: UNetConfig) -> dict:
    keys = iter(jax.random.split(key, 1024))
    w = cfg.widths
    params: dict = {"levels": []}
    params["stem"] = init_sparse_conv(next(keys), 27, cfg.in_channels, w[0], cfg.dtype)
    for li in range(cfg.n_levels):
        lvl = {
            "enc": [
                _block_params(next(keys), w[li], w[li], cfg.dtype)
                for _ in range(cfg.reps)
            ]
        }
        if li < cfg.n_levels - 1:
            lvl["down"] = init_sparse_conv(next(keys), 8, w[li], w[li + 1], cfg.dtype)
            lvl["up"] = init_sparse_conv(next(keys), 8, w[li + 1], w[li], cfg.dtype)
            # decoder blocks see concat(skip, upsampled) = 2*w[li]
            lvl["dec"] = [
                _block_params(next(keys), 2 * w[li] if r == 0 else w[li],
                              w[li], cfg.dtype)
                for r in range(cfg.reps)
            ]
        params["levels"].append(lvl)
    params["head"] = {
        "w": jax.random.normal(next(keys), (w[0], cfg.n_classes), cfg.dtype)
        / np.sqrt(w[0]),
        "b": jnp.zeros((cfg.n_classes,), cfg.dtype),
    }
    return params


def _block_params(key, c_in, c_out, dtype):
    k1, _ = jax.random.split(key)
    return {
        "conv": init_sparse_conv(k1, 27, c_in, c_out, dtype),
        "bn_scale": jnp.ones((c_out,), dtype),
        "bn_offset": jnp.zeros((c_out,), dtype),
    }


def segmentation_loss(logits, labels, mask):
    """Masked mean CE over active voxels + accuracy/mIoU-ready predictions."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    loss = -jnp.sum(ll * m) / jnp.maximum(jnp.sum(m), 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * m) / jnp.maximum(jnp.sum(m), 1)
    return loss, acc


def miou(pred: np.ndarray, labels: np.ndarray, mask: np.ndarray,
         n_classes: int) -> float:
    pred, labels = np.asarray(pred)[mask], np.asarray(labels)[mask]
    ious = []
    for c in range(n_classes):
        inter = np.sum((pred == c) & (labels == c))
        union = np.sum((pred == c) | (labels == c))
        if union:
            ious.append(inter / union)
    return float(np.mean(ious)) if ious else 0.0
