"""Unified LM: decoder-only / enc-dec / hybrid assembly from ModelConfig.

Layers are grouped into *cycles* (one full ``attn_pattern`` repetition) and
scanned with stacked parameters — one compiled layer body regardless of
depth, which bounds HLO size and compile time for the 40-cell dry-run.
``n_layers % cycle`` remainder layers are unrolled.

Three modes share one layer implementation:
  * train:   full-sequence forward, no cache, optional remat;
  * prefill: full-sequence forward that also emits the per-layer cache;
  * decode:  one-token step consuming + updating the cache.

Caches are plain pytrees shaped (n_cycles, ...) per cycle position so the
decode scan zips (params, cache) together. KV caches are stored at the true
kv-head count; TP for archs whose heads don't divide the model axis is done
by sharding the cache *length* axis instead (flash-decoding style — GSPMD
turns the softmax reductions into the 2-stage psum automatically). See
DESIGN.md §5/§6.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import GLOBAL, LOCAL, RGLRU, RWKV, ModelConfig
from repro.dist.hints import DP, constrain
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.attention import (
    chunked_attention,
    decode_attention,
)
from repro.models.common import (
    apply_rope,
    dense_init,
    rms_norm,
    softcap,
    split_keys,
)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, dtype):
    ks = split_keys(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype),
    }


def _init_ffn(key, cfg: ModelConfig, layer_idx: int, dtype):
    if cfg.is_moe and layer_idx % cfg.moe.moe_layer_period == 0:
        return {"moe": moe_lib.init_moe(
            key, cfg.d_model, cfg.d_ff, cfg.moe.n_experts, cfg.act, dtype)}
    return {"mlp": mlp_lib.init_mlp(key, cfg.d_model, cfg.d_ff, cfg.act, dtype)}


def _init_layer(key, cfg: ModelConfig, kind: str, layer_idx: int,
                cross: bool, dtype):
    ks = split_keys(key, 6)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": jnp.ones((d,), dtype)}
    if kind in (GLOBAL, LOCAL):
        p["attn"] = _init_attn(ks[0], cfg, dtype)
    elif kind == RWKV:
        p["tm"] = rwkv_lib.init_time_mix(
            ks[0], d, cfg.n_heads, cfg.rwkv_head_dim, dtype)
    elif kind == RGLRU:
        p["rec"] = rglru_lib.init_rglru_block(
            ks[0], d, cfg.rglru_dim or d, cfg.conv1d_width, dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["ln_cross"] = jnp.ones((d,), dtype)
        p["cross"] = _init_attn(ks[1], cfg, dtype)
    p["ln2"] = jnp.ones((d,), dtype)
    if kind == RWKV:
        p["cm"] = mlp_lib.init_mlp(ks[2], d, cfg.d_ff, "rwkv_cm", dtype)
    else:
        p.update(_init_ffn(ks[2], cfg, layer_idx, dtype))
    return p


def _cycle_split(cfg: ModelConfig) -> tuple[int, int, int]:
    cycle = len(cfg.attn_pattern)
    return cycle, cfg.n_layers // cycle, cfg.n_layers % cycle


def init_lm(key, cfg: ModelConfig) -> dict:
    dtype = cfg.jnp_dtype
    ks = split_keys(key, 8)
    cycle, n_cycles, rem = _cycle_split(cfg)
    params: dict[str, Any] = {
        "embed": dense_init(ks[0], (cfg.vocab_padded, cfg.d_model), dtype, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_padded), dtype)

    def stack_layers(key, n, kinds, base_idx, cross):
        cols = []
        for j, kind in enumerate(kinds):
            keys = split_keys(jax.random.fold_in(key, j), max(n, 1))
            per = [
                _init_layer(keys[i], cfg, kind, base_idx + i * len(kinds) + j,
                            cross, dtype)
                for i in range(n)
            ]
            cols.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
        return cols

    cross = cfg.is_encdec
    params["cycles"] = stack_layers(ks[2], n_cycles, cfg.attn_pattern, 0, cross)
    params["rem"] = [
        _init_layer(jax.random.fold_in(ks[3], j), cfg,
                    cfg.layer_kind(n_cycles * cycle + j),
                    n_cycles * cycle + j, cross, dtype)
        for j in range(rem)
    ]
    if cfg.is_encdec:
        enc = {
            "final_norm": jnp.ones((cfg.d_model,), dtype),
            "cycles": stack_layers(ks[4], cfg.encoder_layers, (GLOBAL,), 0, False),
            "rem": [],
        }
        params["encoder"] = enc
    return params


# ---------------------------------------------------------------------------
# Layer application (shared by all modes)
# ---------------------------------------------------------------------------

def _attn_qkv(p, x, cfg: ModelConfig, positions):
    b, s, d = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _self_attention(p, x, cfg: ModelConfig, kind: str, mode: str,
                    cache, pos, causal=True, cache_pad=0):
    b, s, _ = x.shape
    window = cfg.window if kind == LOCAL else None
    if mode == "decode":
        positions = jnp.full((b, 1), pos, jnp.int32)
        q, k, v = _attn_qkv(p, x, cfg, positions)
        ring = kind == LOCAL
        ck, cv = cache["k"], cache["v"]
        from repro.models.attention import cache_update_decode

        ck, cv = cache_update_decode(ck, cv, k.astype(ck.dtype),
                                     v.astype(cv.dtype), pos, ring)
        o = decode_attention(q, ck, cv, pos, ring=ring, window=window,
                             logit_cap=cfg.attn_softcap)
        new_cache = {"k": ck, "v": cv}
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        q, k, v = _attn_qkv(p, x, cfg, positions)
        o = chunked_attention(
            q, k, v, causal=causal, window=window,
            logit_cap=cfg.attn_softcap,
            q_chunk=min(512, s), kv_chunk=min(512, s),
            acc_dtype=jnp.dtype(cfg.attn_dtype),
        )
        new_cache = None
        if mode == "prefill":
            if kind == LOCAL and s >= cfg.window:
                # ring addressing: position p lives at slot p % window
                shift = (s - cfg.window) % cfg.window
                new_cache = {
                    "k": jnp.roll(k[:, -cfg.window:], shift, axis=1),
                    "v": jnp.roll(v[:, -cfg.window:], shift, axis=1),
                }
            else:
                pad = [(0, 0), (0, cache_pad), (0, 0), (0, 0)]
                new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    out = o.reshape(b, o.shape[1], -1) @ p["wo"]
    return out, new_cache


def _cross_attention(p, x, enc_out, cfg: ModelConfig, mode, cache):
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    if mode == "decode":
        k, v = cache["ck"], cache["cv"]
        new_cache = cache
        o = decode_attention(q, k, v, k.shape[1] - 1, ring=False, window=None)
    else:
        se = enc_out.shape[1]
        k = (enc_out @ p["wk"]).reshape(b, se, cfg.n_kv_heads, hd)
        v = (enc_out @ p["wv"]).reshape(b, se, cfg.n_kv_heads, hd)
        o = chunked_attention(q, k, v, causal=False,
                              q_chunk=min(512, s), kv_chunk=min(512, se))
        new_cache = {"ck": k, "cv": v} if mode == "prefill" else None
    return o.reshape(b, s, -1) @ p["wo"], new_cache


def _ffn(p, x, cfg: ModelConfig, moe_groups: int | None):
    aux = {}
    if "moe" in p:
        b, s, d = x.shape
        g = moe_groups or b
        xg = x.reshape(g, (b * s) // g, d)
        cap = moe_lib.moe_capacity((b * s) // g, cfg.moe.top_k,
                                   cfg.moe.n_experts, cfg.moe.capacity_factor)
        y, aux = moe_lib.apply_moe(p["moe"], xg, top_k=cfg.moe.top_k,
                                   capacity=cap, act=cfg.act)
        return y.reshape(b, s, d), aux
    return mlp_lib.apply_mlp(p["mlp"], x, cfg.act), aux


def apply_layer(p, x, kind: str, cfg: ModelConfig, mode: str,
                cache=None, pos=0, enc_out=None, causal=True,
                moe_groups: int | None = None, cache_pad=0):
    """Returns (x, new_cache, aux)."""
    new_cache: dict[str, Any] = {}
    aux = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in (GLOBAL, LOCAL):
        o, c = _self_attention(p["attn"], h, cfg, kind, mode,
                               cache.get("attn") if cache else None, pos, causal,
                               cache_pad)
        if c is not None:
            new_cache["attn"] = c
    elif kind == RWKV:
        st = cache["rwkv"] if cache else None
        if mode == "decode":
            o, (xprev, s_new) = rwkv_lib.apply_time_mix_decode(
                p["tm"], h, st["x_tm"], st["s"], n_heads=cfg.n_heads)
        else:
            b = h.shape[0]
            hd = cfg.n_heads * cfg.rwkv_head_dim
            s0 = (st["s"] if st else
                  jnp.zeros((b, cfg.n_heads, cfg.rwkv_head_dim,
                             cfg.rwkv_head_dim), jnp.float32))
            xp = st["x_tm"] if st else jnp.zeros_like(h[:, 0])
            o, (xprev, s_new) = rwkv_lib.apply_time_mix(
                p["tm"], h, xp, s0, n_heads=cfg.n_heads)
        if mode in ("decode", "prefill"):
            new_cache["rwkv"] = {"s": s_new, "x_tm": xprev}
    elif kind == RGLRU:
        b = h.shape[0]
        r = cfg.rglru_dim or cfg.d_model
        st = (cache["rec"] if cache else
              {"h": jnp.zeros((b, r), jnp.float32),
               "conv": jnp.zeros((b, cfg.conv1d_width - 1, r), cfg.jnp_dtype)})
        if mode == "decode":
            o, st_new = rglru_lib.apply_rglru_block_decode(p["rec"], h, st)
        else:
            o, st_new = rglru_lib.apply_rglru_block(p["rec"], h, st)
        if mode in ("decode", "prefill"):
            new_cache["rec"] = st_new
    else:
        raise ValueError(kind)
    x = x + o

    if "cross" in p and (enc_out is not None
                         or (cache is not None and "cross" in cache)):
        hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        oc, cc = _cross_attention(p["cross"], hc, enc_out, cfg, mode,
                                  cache.get("cross") if cache else None)
        x = x + oc
        if cc is not None:
            new_cache["cross"] = cc

    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == RWKV:
        if mode == "decode":
            xp = cache["rwkv_cm"]["x_cm"]
            shifted = xp[:, None]
            o2 = mlp_lib.apply_rwkv_channel_mix(p["cm"], h, shifted)
            new_cache["rwkv_cm"] = {"x_cm": h[:, 0]}
        else:
            xp = (cache["rwkv_cm"]["x_cm"] if cache else jnp.zeros_like(h[:, 0]))
            shifted = jnp.concatenate([xp[:, None], h[:, :-1]], axis=1)
            o2 = mlp_lib.apply_rwkv_channel_mix(p["cm"], h, shifted)
            if mode == "prefill":
                new_cache["rwkv_cm"] = {"x_cm": h[:, -1]}
    else:
        o2, aux = _ffn(p, h, cfg, moe_groups)
    x = x + o2
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens, frontend_embeds):
    x = jnp.take(params["embed"], tokens, axis=0)
    sp = "model" if cfg.attn_sharding == "sequence" and tokens.shape[1] > 1 else None
    x = constrain(x, DP, sp, None)
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
    if cfg.frontend == "vision" and frontend_embeds is not None:
        p = frontend_embeds.shape[1]
        x = jax.lax.dynamic_update_slice_in_dim(
            x, frontend_embeds.astype(x.dtype), 0, axis=1)
    return x


def _logits(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = (x @ head).astype(jnp.float32)
    logits = constrain(logits, DP, None, "model")
    logits = softcap(logits, cfg.final_softcap)
    if cfg.vocab_padded != cfg.vocab_size:
        pad = cfg.vocab_padded - cfg.vocab_size
        neg = jnp.full((pad,), -1e30, jnp.float32)
        logits = logits.at[..., cfg.vocab_size:].set(neg)
    return logits


def _run_encoder(params, cfg: ModelConfig, frames):
    """frames: (B, S_src, d) precomputed frame/patch embeddings (stub)."""
    x = frames.astype(cfg.jnp_dtype)
    enc = params["encoder"]

    def body(x, lp):
        x, _, _ = apply_layer(lp, x, GLOBAL, cfg, "train", causal=False)
        return x, None

    f = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(f, x, enc["cycles"][0])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, *, frontend_embeds=None,
            enc_frames=None, mode: str = "train",
            moe_groups: int | None = None, cache_pad: int = 0):
    """tokens: (B, S). Returns (logits, cache_or_None, aux)."""
    assert mode in ("train", "prefill")
    x = _embed(params, cfg, tokens, frontend_embeds)
    enc_out = (_run_encoder(params, cfg, enc_frames)
               if cfg.is_encdec else None)
    cycle, n_cycles, rem = _cycle_split(cfg)
    aux_sum: dict[str, Any] = {}

    def merge_aux(a):
        for k_, v_ in a.items():
            aux_sum[k_] = aux_sum.get(k_, 0) + v_

    def cycle_body(x, lps):
        caches, auxes = [], []
        for j, kind in enumerate(cfg.attn_pattern):
            x, c, a = apply_layer(lps[j], x, kind, cfg, mode,
                                  enc_out=enc_out, moe_groups=moe_groups,
                                  cache_pad=cache_pad)
            caches.append(c)
            auxes.append(a)
        aux = {}
        for a in auxes:
            for k_, v_ in a.items():
                aux[k_] = aux.get(k_, 0) + v_
        return x, (caches, aux)

    if cfg.remat and mode == "train":
        if cfg.remat_policy == "dots":
            body = jax.checkpoint(
                cycle_body,
                policy=jax.checkpoint_policies.checkpoint_dots)
        else:
            body = jax.checkpoint(cycle_body)
    else:
        body = cycle_body
    if n_cycles > 0:
        xs = tuple(params["cycles"])
        x, (cyc_caches, cyc_aux) = jax.lax.scan(
            lambda x, lp: body(x, lp), x, xs)
        merge_aux(jax.tree.map(lambda v: jnp.sum(v, axis=0) if v.ndim else v,
                               cyc_aux))
    else:
        cyc_caches = None
    rem_caches = []
    for j, lp in enumerate(params["rem"]):
        kind = cfg.layer_kind(n_cycles * cycle + j)
        x, c, a = apply_layer(lp, x, kind, cfg, mode,
                              enc_out=enc_out, moe_groups=moe_groups,
                              cache_pad=cache_pad)
        rem_caches.append(c)
        merge_aux(a)
    logits = _logits(params, cfg, x)
    cache = None
    if mode == "prefill":
        cache = {"cycles": cyc_caches, "rem": rem_caches,
                 "pos": jnp.int32(tokens.shape[1])}
    return logits, cache, aux_sum


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      src_len: int = 0) -> dict:
    """Zeroed cache for serve_step dry-runs (shape-only is fine)."""
    dtype = cfg.jnp_dtype
    cycle, n_cycles, rem = _cycle_split(cfg)

    def one(kind):
        c: dict[str, Any] = {}
        if kind in (GLOBAL, LOCAL):
            buf = min(cfg.window, cache_len) if kind == LOCAL else cache_len
            shape = (batch, buf, cfg.n_kv_heads, cfg.head_dim)
            c["attn"] = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        elif kind == RWKV:
            c["rwkv"] = {
                "s": jnp.zeros((batch, cfg.n_heads, cfg.rwkv_head_dim,
                                cfg.rwkv_head_dim), jnp.float32),
                "x_tm": jnp.zeros((batch, cfg.d_model), dtype)}
            c["rwkv_cm"] = {"x_cm": jnp.zeros((batch, cfg.d_model), dtype)}
        elif kind == RGLRU:
            r = cfg.rglru_dim or cfg.d_model
            c["rec"] = {"h": jnp.zeros((batch, r), jnp.float32),
                        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, r), dtype)}
        if cfg.is_encdec:
            shape = (batch, src_len, cfg.n_kv_heads, cfg.head_dim)
            c["cross"] = {"ck": jnp.zeros(shape, dtype),
                          "cv": jnp.zeros(shape, dtype)}
        return c

    cyc = [jax.tree.map(lambda x: jnp.stack([x] * n_cycles), one(kind))
           for kind in cfg.attn_pattern] if n_cycles else None
    remc = [one(cfg.layer_kind(n_cycles * cycle + j)) for j in range(rem)]
    return {"cycles": cyc, "rem": remc, "pos": jnp.int32(cache_len)}


def decode_step(params, cfg: ModelConfig, token, cache, *,
                moe_groups: int | None = None):
    """token: (B, 1) -> (logits (B, 1, Vp), new_cache)."""
    x = _embed(params, cfg, token, None)
    pos = cache["pos"]
    cycle, n_cycles, rem = _cycle_split(cfg)

    new_cycles = None
    if n_cycles:
        def body(x, lp_c):
            lps, cs = lp_c
            new_cs = []
            for j, kind in enumerate(cfg.attn_pattern):
                x, c, _ = apply_layer(lps[j], x, kind, cfg, "decode",
                                      cache=cs[j], pos=pos,
                                      moe_groups=moe_groups)
                new_cs.append(c)
            return x, new_cs

        x, new_cycles = jax.lax.scan(
            body, x, (tuple(params["cycles"]), tuple(cache["cycles"])))
    new_rem = []
    for j, lp in enumerate(params["rem"]):
        kind = cfg.layer_kind(n_cycles * cycle + j)
        x, c, _ = apply_layer(lp, x, kind, cfg, "decode",
                              cache=cache["rem"][j], pos=pos,
                              moe_groups=moe_groups)
        new_rem.append(c)
    logits = _logits(params, cfg, x)
    return logits, {"cycles": new_cycles, "rem": new_rem, "pos": pos + 1}


def lm_loss(logits, targets, cfg: ModelConfig, mask=None):
    """Next-token CE over real vocab; mask: (B, S) optional.

    Vocab-sharding friendly: the target log-prob is extracted with a one-hot
    contraction, so every reduction runs *over* the (possibly model-sharded)
    vocab axis — no cross-shard gather (DESIGN.md §6).
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
    ll = tgt - lse
    if mask is None:
        mask = jnp.ones_like(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
