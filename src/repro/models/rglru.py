"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block:  x -> {linear -> conv1d(width) -> RG-LRU} ⊙ {linear -> GeLU} -> linear

RG-LRU:
    r_t = sigmoid(W_a x_t)            (recurrence gate)
    i_t = sigmoid(W_x x_t)            (input gate)
    log a_t = -c * r_t * softplus(Λ)  (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t)

Training/prefill evaluates the linear recurrence with
``lax.associative_scan`` (log-depth, O(T r) memory); decode is one step.
The depthwise causal conv keeps a (width-1)-token state for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys

_C = 8.0


def init_rglru_block(key, d_model: int, r_dim: int, conv_width: int, dtype):
    ks = split_keys(key, 6)
    return {
        "w_in_x": dense_init(ks[0], (d_model, r_dim), dtype),
        "w_in_gate": dense_init(ks[1], (d_model, r_dim), dtype),
        "w_out": dense_init(ks[2], (r_dim, d_model), dtype),
        "conv_w": dense_init(ks[3], (conv_width, r_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((r_dim,), dtype),
        "w_a": dense_init(ks[4], (r_dim, r_dim), jnp.float32),
        "w_x": dense_init(ks[5], (r_dim, r_dim), jnp.float32),
        # Λ init so a ~ U(0.9, 0.999)-ish at r=0.5 (Griffin appendix)
        "lam": jnp.linspace(2.0, 5.0, r_dim, dtype=jnp.float32),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B, T, r); w: (W, r); state: (B, W-1, r)."""
    width = w.shape[0]
    pad = state if state is not None else jnp.zeros(
        (x.shape[0], width - 1, x.shape[2]), x.dtype
    )
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[width - 1 - i] for i in range(width)
    ) + b
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return out, new_state


def _rglru_gates(p, x):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"])
    log_a = -_C * r * jax.nn.softplus(p["lam"])
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * xf


def rglru_scan(p, x, h0):
    """x: (B, T, r) -> (y (B, T, r) f32, h_last). Linear recurrence via
    associative scan: h_t = a_t h_{t-1} + b_t."""
    a, bterm = _rglru_gates(p, x)
    # seed carry-in state through the first element
    bterm = bterm.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    return h, h[:, -1]


def rglru_step(p, x, h):
    """x: (B, r) one token; h: (B, r)."""
    a, bterm = _rglru_gates(p, x[:, None])
    h_new = a[:, 0] * h.astype(jnp.float32) + bterm[:, 0]
    return h_new, h_new


def apply_rglru_block(p, x, state):
    """x: (B, T, d); state: {"h": (B, r) f32, "conv": (B, W-1, r)}.
    Returns (out (B, T, d), new_state)."""
    u = x @ p["w_in_x"]
    gate = jax.nn.gelu(x @ p["w_in_gate"])
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"], state["conv"])
    y, h_last = rglru_scan(p, u, state["h"])
    out = (y.astype(x.dtype) * gate) @ p["w_out"]
    return out, {"h": h_last, "conv": conv_state}


def apply_rglru_block_decode(p, x, state):
    """x: (B, 1, d)."""
    u = x @ p["w_in_x"]
    gate = jax.nn.gelu(x @ p["w_in_gate"])
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"], state["conv"])
    y, h_last = rglru_step(p, u[:, 0], state["h"])
    out = (y[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return out, {"h": h_last, "conv": conv_state}
