"""RWKV-6 (Finch) time-mix with data-dependent decay — chunked linear attn.

Recurrence per head (state S in R^{D x D}):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
with per-(token, channel) decay w_t = exp(-exp(w0 + lora(x_shift-mix)))
(the RWKV-6 novelty) and per-head bonus u.

Training/prefill uses the *chunked* formulation (the linear-attention
analogue of SPADE tiling — see DESIGN.md §5): within a chunk of length L the
pairwise decay exponents la_{t-1} - la_s (s <= t-1) are always <= 0, so the
direct masked computation is numerically stable (only graceful underflow);
across chunks a small f32 state is carried by ``lax.scan``. Decode is the
one-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys


def init_time_mix(key, d_model: int, n_heads: int, head_dim: int, dtype,
                  lora_rank: int = 64):
    ks = split_keys(key, 8)
    return {
        "w_r": dense_init(ks[0], (d_model, n_heads * head_dim), dtype),
        "w_k": dense_init(ks[1], (d_model, n_heads * head_dim), dtype),
        "w_v": dense_init(ks[2], (d_model, n_heads * head_dim), dtype),
        "w_g": dense_init(ks[3], (d_model, n_heads * head_dim), dtype),
        "w_o": dense_init(ks[4], (n_heads * head_dim, d_model), dtype),
        "mu": jnp.zeros((5, d_model), dtype),            # r,k,v,g,w shift-mix
        "w0": jnp.full((n_heads * head_dim,), -1.0, jnp.float32),
        "w_lora_a": dense_init(ks[5], (d_model, lora_rank), jnp.float32),
        "w_lora_b": dense_init(ks[6], (lora_rank, n_heads * head_dim),
                               jnp.float32, scale=0.1),
        "u": jnp.zeros((n_heads, head_dim), jnp.float32),  # bonus
        "ln_x_scale": jnp.ones((n_heads * head_dim,), jnp.float32),
        "ln_x_bias": jnp.zeros((n_heads * head_dim,), jnp.float32),
    }


def _group_norm_heads(x, scale, bias, n_heads, eps=64e-5):
    """Per-head LayerNorm of the wkv output (RWKV's ln_x)."""
    b, t, hd = x.shape
    xh = x.reshape(b, t, n_heads, hd // n_heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = ((xh - mu) ** 2).mean(-1, keepdims=True)
    y = ((xh - mu) * jax.lax.rsqrt(var + eps)).reshape(b, t, hd)
    return y * scale + bias


def chunked_wkv(r, k, v, logw, u, s0, chunk: int):
    """r/k/v/logw: (B, T, H, D); u: (H, D); s0: (B, H, D, D) f32.

    Returns (o (B, T, H, D) f32, s_final). logw = log(decay) <= 0.
    """
    b, t, h, d = r.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    def to_chunks(x):
        return x.reshape(b, nc, chunk, h, d).transpose(1, 0, 3, 2, 4)  # (nc,B,H,L,D)

    r_, k_, v_ = (to_chunks(x.astype(jnp.float32)) for x in (r, k, v))
    lw = to_chunks(logw.astype(jnp.float32))
    la = jnp.cumsum(lw, axis=3)         # inclusive within chunk
    lap = la - lw                       # la_{t-1} (exclusive)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # s < t

    def body(s, xs):
        rc, kc, vc, lac, lapc, lwc = xs  # (B,H,L,D)
        # inter-chunk: o += (r ⊙ exp(la_{t-1})) @ S
        qt = rc * jnp.exp(lapc)
        o = jnp.einsum("bhld,bhde->bhle", qt, s)
        # intra-chunk, strictly-lower scores (exponent <= 0 -> stable)
        expo = jnp.exp(lapc[:, :, :, None, :] - lac[:, :, None, :, :])
        score = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rc, kc, expo)
        score = jnp.where(tri[None, None], score, 0.0)
        o = o + jnp.einsum("bhts,bhse->bhte", score, vc)
        # diagonal bonus term
        dscore = jnp.einsum("bhtd,bhtd->bht", rc * u[None, :, None, :], kc)
        o = o + dscore[..., None] * vc
        # state: S' = diag(exp(la_L)) S + sum_s (k_s ⊙ exp(la_L - la_s)) v_s^T
        la_l = lac[:, :, -1:, :]
        kd = kc * jnp.exp(la_l - lac)
        s_new = jnp.exp(la_l.squeeze(2))[..., None] * s + jnp.einsum(
            "bhsd,bhse->bhde", kd, vc
        )
        return s_new, o

    s_fin, os = jax.lax.scan(body, s0.astype(jnp.float32), (r_, k_, v_, la, lap, lw))
    o = os.transpose(1, 0, 3, 2, 4).reshape(b, t, h, d)
    return o, s_fin


def wkv_decode_step(r, k, v, logw, u, s):
    """Single-token recurrence. r/k/v/logw: (B, H, D); s: (B, H, D, D)."""
    r, k, v, logw = (x.astype(jnp.float32) for x in (r, k, v, logw))
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    o = jnp.einsum("bhd,bhde->bhe", r, s + u[None, :, :, None] * kv)
    s_new = jnp.exp(logw)[..., None] * s + kv
    return o, s_new


def apply_time_mix(params, x, x_prev, s0, *, n_heads: int, chunk: int = 64):
    """x: (B, T, d); x_prev: (B, d) (token before the window, zeros at t=0).
    Returns (out (B, T, d), (last_x (B, d), s_final))."""
    b, t, d = x.shape
    hd = params["w_r"].shape[1]
    head_dim = hd // n_heads
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    mu = params["mu"]
    mixes = [x + (shifted - x) * mu[i] for i in range(5)]
    xr, xk, xv, xg, xw = mixes
    r = (xr @ params["w_r"]).reshape(b, t, n_heads, head_dim)
    k = (xk @ params["w_k"]).reshape(b, t, n_heads, head_dim)
    v = (xv @ params["w_v"]).reshape(b, t, n_heads, head_dim)
    g = jax.nn.silu(xg @ params["w_g"])
    # data-dependent decay (RWKV-6): log w in (-inf, 0)
    w_raw = params["w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ params["w_lora_a"]
    ) @ params["w_lora_b"]
    logw = -jnp.exp(w_raw).reshape(b, t, n_heads, head_dim)
    o, s_fin = chunked_wkv(r, k, v, logw, params["u"], s0, min(chunk, t))
    o = _group_norm_heads(o.reshape(b, t, hd), params["ln_x_scale"],
                          params["ln_x_bias"], n_heads)
    out = (o * g.astype(jnp.float32)).astype(x.dtype) @ params["w_o"]
    return out, (x[:, -1], s_fin)


def apply_time_mix_decode(params, x, x_prev, s, *, n_heads: int):
    """x: (B, 1, d) single token. Returns (out, (x (B,d), s'))."""
    b, _, d = x.shape
    hd = params["w_r"].shape[1]
    head_dim = hd // n_heads
    xt = x[:, 0]
    mu = params["mu"]
    mixes = [xt + (x_prev - xt) * mu[i] for i in range(5)]
    xr, xk, xv, xg, xw = mixes
    r = (xr @ params["w_r"]).reshape(b, n_heads, head_dim)
    k = (xk @ params["w_k"]).reshape(b, n_heads, head_dim)
    v = (xv @ params["w_v"]).reshape(b, n_heads, head_dim)
    g = jax.nn.silu(xg @ params["w_g"])
    w_raw = params["w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ params["w_lora_a"]
    ) @ params["w_lora_b"]
    logw = -jnp.exp(w_raw).reshape(b, n_heads, head_dim)
    o, s_new = wkv_decode_step(r, k, v, logw, params["u"], s)
    o = _group_norm_heads(o.reshape(b, 1, hd), params["ln_x_scale"],
                          params["ln_x_bias"], n_heads)
    out = (o * g[:, None].astype(jnp.float32)).astype(x.dtype) @ params["w_o"]
    return out, (xt, s_new)
