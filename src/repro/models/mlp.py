"""Feed-forward blocks: SwiGLU / GeGLU / GELU-MLP + RWKV channel-mix."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype):
    ks = split_keys(key, 3)
    if act in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    if act == "gelu":
        return {
            "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
        }
    if act == "rwkv_cm":
        return {
            "w_k": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_v": dense_init(ks[1], (d_ff, d_model), dtype),
            "w_r": dense_init(ks[2], (d_model, d_model), dtype),
            "mu_k": jnp.zeros((d_model,), dtype),
            "mu_r": jnp.zeros((d_model,), dtype),
        }
    raise ValueError(act)


def apply_mlp(params, x: jax.Array, act: str) -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
        return h @ params["w_down"]
    if act == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
        return h @ params["w_down"]
    if act == "gelu":
        return jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]
    raise ValueError(act)


def apply_rwkv_channel_mix(params, x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """RWKV channel-mix with token shift. x/x_prev: (B, S, d) where x_prev is
    x shifted right by one (x_{t-1})."""
    xk = x + (x_prev - x) * params["mu_k"]
    xr = x + (x_prev - x) * params["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    return jax.nn.sigmoid(xr @ params["w_r"]) * (k @ params["w_v"])
