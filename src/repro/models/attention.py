"""GQA attention: chunked online-softmax (train/prefill) + cached decode.

Design constraints (DESIGN.md §6):
* never materialize (Sq, Skv) scores — prefill_32k at full size would need
  petabytes; instead a flash-style two-level loop: ``lax.map`` over q chunks,
  ``lax.scan`` over kv chunks with running (max, sum, acc) in f32.
* local (sliding-window) layers slice only the kv window each q chunk needs,
  so SWA costs O(S * window), not O(S^2) masked.
* logit softcapping (gemma-2) applied before the online max.
* decode: single-token query against a ring (local) or linear (global)
  cache; scores are (B, H, S_cache) — small, computed in one shot.

Everything is pure jnp: GSPMD shards batch/heads; sequence-sharded variants
are provided by ``repro.dist.sharding`` wrappers. A Pallas flash kernel with
identical semantics lives in ``repro.kernels.flash``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import softcap as _softcap

NEG_INF = -1e30


def _chunk_attend(q, k, v, q_pos, k_pos, causal, window, cap,
                  acc_dtype=jnp.float32):
    """One (q-chunk, kv-chunk) tile -> (scores-applied partial, m, l).

    q: (B, Cq, Hkv, G, D); k/v: (B, Ckv, Hkv, D). Partials in acc_dtype —
    bf16 halves the dominant HBM score traffic at ~1e-2 logit error
    (EXPERIMENTS.md §Perf).
    """
    # emit scores directly in acc_dtype: with bf16 this halves the dominant
    # HBM score traffic at the dot output itself (not just downstream)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=acc_dtype
    )
    s = s / jnp.sqrt(q.shape[-1]).astype(s.dtype)
    s = _softcap(s, cap)
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], jnp.asarray(s),
                  jnp.asarray(NEG_INF, s.dtype))
    m = jnp.max(s, axis=-1).astype(jnp.float32)  # (B,H,G,Cq) stats in f32
    p = jnp.exp((s.astype(jnp.float32) - m[..., None])).astype(acc_dtype)
    p = jnp.where(mask[None, None, None], p, jnp.asarray(0.0, acc_dtype))
    l = jnp.sum(p.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def chunked_attention(
    q: jax.Array,             # (B, Sq, Hq, D)
    k: jax.Array,             # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    logit_cap: float | None = None,
    q_offset: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Flash-style attention; O(Sq*(window|Skv)) compute, O(chunk^2) memory."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    n_q = sq // q_chunk
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, q_chunk, skv, kv_chunk)

    local = window is not None and window + q_chunk < skv
    if local:
        # only the kv span [q_start - window, q_end) can be unmasked
        span = window + q_chunk
        span = ((span + kv_chunk - 1) // kv_chunk) * kv_chunk

    def do_q_chunk(qi):
        q_start = qi * q_chunk
        q_pos = q_offset + q_start + jnp.arange(q_chunk)
        qc = jax.lax.dynamic_slice_in_dim(qg, q_start, q_chunk, axis=1)
        if local:
            k_start = jnp.clip(q_offset + q_start + q_chunk - span, 0, skv - span)
            kc = jax.lax.dynamic_slice_in_dim(k, k_start, span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, k_start, span, axis=1)
            k_pos = k_start + jnp.arange(span)
            o, m, l = _chunk_attend(qc, kc, vc, q_pos, k_pos, True, window,
                                    logit_cap, acc_dtype)
            out = o / jnp.maximum(l[..., None], 1e-30)
        else:
            n_kv = skv // kv_chunk

            def body(carry, ki):
                m_run, l_run, acc = carry
                k_start = ki * kv_chunk
                kc = jax.lax.dynamic_slice_in_dim(k, k_start, kv_chunk, axis=1)
                vc = jax.lax.dynamic_slice_in_dim(v, k_start, kv_chunk, axis=1)
                k_pos = k_start + jnp.arange(kv_chunk)
                o, m, l = _chunk_attend(
                    qc, kc, vc, q_pos, k_pos, causal, window, logit_cap,
                    acc_dtype,
                )
                m_new = jnp.maximum(m_run, m)
                a = jnp.exp(m_run - m_new)
                bcoef = jnp.exp(m - m_new)
                l_new = l_run * a + l * bcoef
                acc = acc * a[..., None] + o * bcoef[..., None]
                return (m_new, l_new, acc), None

            m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
            a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
            (m_f, l_f, acc), _ = jax.lax.scan(
                body, (m0, l0, a0), jnp.arange(n_kv)
            )
            out = acc / jnp.maximum(l_f[..., None], 1e-30)
        return out  # (B, Hkv, G, Cq, D)

    outs = jax.lax.map(do_q_chunk, jnp.arange(n_q))  # (n_q, B, Hkv, G, Cq, D)
    out = jnp.moveaxis(outs, 0, 3)  # (B, Hkv, G, n_q, Cq, D)
    out = out.reshape(b, hkv, g, sq, d).transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Per-layer stack of caches. ``k``/``v``: (L, B, S_buf, Hkv, D);
    for local layers S_buf == window (ring addressing)."""

    k: jax.Array
    v: jax.Array

    @property
    def buf_len(self) -> int:
        return self.k.shape[2]


def init_kv_cache(n_layers, batch, buf_len, n_kv, head_dim, dtype) -> KVCache:
    shape = (n_layers, batch, buf_len, n_kv, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def cache_update_decode(cache_k, cache_v, k_new, v_new, t, ring: bool):
    """Insert one token at position t (ring: t % buf)."""
    buf = cache_k.shape[1]
    slot = (t % buf) if ring else t
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, axis=1)
    return ck, cv


def decode_attention(
    q: jax.Array,        # (B, 1, Hq, D)
    cache_k: jax.Array,  # (B, S_buf, Hkv, D) — already includes token t
    cache_v: jax.Array,
    t,                   # current position (token t is at slot t or t%buf)
    *,
    ring: bool,
    window: int | None = None,
    logit_cap: float | None = None,
) -> jax.Array:
    b, sbuf, hkv, d = cache_k.shape
    hq = q.shape[2]
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, d)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qg[:, 0], cache_k, preferred_element_type=jnp.float32
    )
    s = s / jnp.sqrt(d).astype(jnp.float32)
    s = _softcap(s, logit_cap)
    slots = jnp.arange(sbuf)
    if ring:
        # slot holds position: p = t - ((t - slot) mod buf); valid if p >= 0
        pos = t - ((t - slots) % sbuf)
    else:
        pos = slots
    valid = (pos >= 0) & (pos <= t)
    if window is not None:
        valid &= pos > t - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, cache_v, preferred_element_type=jnp.float32)
    return o.reshape(b, 1, hq, d).astype(q.dtype)
