"""Mixture-of-Experts layer with COIR-style dispatch + SPADE capacity.

Expert-parallel layout (DESIGN.md §4): tokens are organized in *groups* (one
per data shard — the batch axis), experts shard over the model axis. Because
activations are replicated across the model axis, each device can gather its
own experts' tokens group-locally — dispatch needs **no explicit collective**
(the a2a variant lives in ``repro.dist.collectives`` as a hillclimb option).

The dispatch table is the MoE instance of the paper's metadata structure
(``repro.core.moe_spade.build_dispatch``), and the capacity is planned with
the paper's RST quantile rule instead of a fixed factor.

``apply_moe(..., mesh=..., dispatch="a2a")`` switches to the explicit
expert-major exchange (``dist.collectives.expert_all_to_all`` over the
mesh's ``"model"`` axis) — numerically identical to the group-local gather,
compared head-to-head in ``benchmarks/bench_moe.py``.

Load-balance aux loss + router z-loss included (production training).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.moe_spade import build_dispatch
from repro.dist.collectives import expert_all_to_all
from repro.dist.hints import DP, constrain
from repro.models.common import dense_init, split_keys

DISPATCH_MODES = ("gather", "a2a")


def init_moe(key, d_model: int, d_ff: int, n_experts: int, act: str, dtype):
    ks = split_keys(key, 4)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (n_experts, d_model, d_ff), dtype),
        "w_up": dense_init(ks[2], (n_experts, d_model, d_ff), dtype),
        "w_down": dense_init(ks[3], (n_experts, d_ff, d_model), dtype),
    }
    if act == "gelu":
        del p["w_gate"]
    return p


def moe_capacity(tokens_per_group: int, top_k: int, n_experts: int,
                 capacity_factor: float, round_to: int = 4) -> int:
    cap = int(tokens_per_group * top_k * capacity_factor / n_experts) + 1
    return max((cap + round_to - 1) // round_to * round_to, round_to)


def apply_moe(params, x: jax.Array, *, top_k: int, capacity: int, act: str,
              mesh=None, dispatch: str = "gather"):
    """x: (G, Tg, d) -> (out (G, Tg, d), aux dict).

    G = token groups (== data shards), Tg tokens per group.
    dispatch: "gather" (default) keeps the collective-free group-local
    gather; "a2a" exchanges the dispatch tensor expert-major over ``mesh``'s
    ``"model"`` axis before the expert GEMMs and inverts afterwards
    (requires G and E divisible by the axis size; identity on 1 device).
    """
    if dispatch not in DISPATCH_MODES:
        raise ValueError(f"dispatch {dispatch!r} not one of {DISPATCH_MODES}")
    if dispatch == "a2a" and mesh is None:
        raise ValueError("dispatch='a2a' needs a mesh with a 'model' axis")
    g_, tg, d = x.shape
    n_experts = params["router"].shape[1]
    logits = (x.astype(jnp.float32) @ params["router"])  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)              # (G, Tg, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # COIR-style dispatch metadata per group.
    slot, table = jax.vmap(
        lambda ii: build_dispatch(ii, n_experts, capacity)
    )(idx.astype(jnp.int32))
    # slot: (G, Tg, k); table: (G, E, cap)

    tok_ok = table >= 0
    gather_idx = jnp.maximum(table, 0)                    # (G, E, cap)
    xin = jnp.take_along_axis(
        x[:, None], gather_idx[..., None], axis=2
    )  # x (G,1,Tg,d) gathered along Tg by (G,E,cap,1) -> (G,E,cap,d)
    xin = jnp.where(tok_ok[..., None], xin, 0)
    if dispatch == "a2a":
        # expert-major exchange: each device ends up holding every group's
        # tokens for its local experts (global values unchanged)
        xin = expert_all_to_all(mesh, xin, split_axis=1, concat_axis=0)
    else:
        xin = constrain(xin, DP, "model", None, None)  # EP: experts on model

    if act in ("swiglu", "geglu"):
        a = jnp.einsum("gecd,edf->gecf", xin, params["w_gate"],
                       preferred_element_type=jnp.float32)
        b = jnp.einsum("gecd,edf->gecf", xin, params["w_up"],
                       preferred_element_type=jnp.float32)
        inner = (jax.nn.silu(a) if act == "swiglu" else jax.nn.gelu(a)) * b
    else:
        inner = jax.nn.gelu(
            jnp.einsum("gecd,edf->gecf", xin, params["w_up"],
                       preferred_element_type=jnp.float32)
        )
    h = jnp.einsum("gecf,efd->gecd", inner.astype(x.dtype), params["w_down"],
                   preferred_element_type=jnp.float32).astype(x.dtype)

    # Combine: per assignment j, token t reads h[idx[t,j], slot[t,j]].
    if dispatch == "a2a":
        # inverse exchange: back to group-major for the combine gather
        h = expert_all_to_all(mesh, h, split_axis=0, concat_axis=1)
    else:
        h = constrain(h, DP, "model", None, None)
    flat = h.reshape(g_, n_experts * capacity, d)
    lin = idx * capacity + jnp.maximum(slot, 0)           # (G, Tg, k)
    picked = jnp.take_along_axis(
        flat[:, None], lin.transpose(0, 2, 1)[..., None], axis=2
    )  # flat (G,1,EC,d) by (G,k,Tg,1) -> (G,k,Tg,d)
    picked = jnp.where((slot >= 0).transpose(0, 2, 1)[..., None], picked, 0)
    out = jnp.einsum("gktd,gtk->gtd", picked.astype(jnp.float32),
                     gates.astype(jnp.float32)).astype(x.dtype)

    # aux losses (Switch): load-balance + router z-loss
    me = probs.mean(axis=1)                               # (G, E)
    onehot = jax.nn.one_hot(idx[..., 0], n_experts)
    ce = onehot.mean(axis=1)
    lb_loss = n_experts * jnp.mean(jnp.sum(me * ce, axis=-1))
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = jnp.mean((slot < 0).astype(jnp.float32))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss, "moe_dropped": dropped,
           "expert_load": onehot.sum(axis=(0, 1))}
    return out, aux
