"""Optimizers (hand-rolled, functional): AdamW + Adafactor.

* AdamW: configurable moment dtype (bf16 moments halve optimizer memory —
  the default for >100B configs, DESIGN.md §6).
* Adafactor: factored second moment for rank>=2 tensors (row/col RMS), no
  first moment — what lets llama4-maverick train on a single 16 GB/chip pod.

States are pytrees mirroring params, so they shard with the same
NamedShardings as the parameters (ZeRO-style).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptHParams:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32
    min_dim_factored: int = 128   # adafactor: factor axes >= this


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


# -------------------------------- AdamW -----------------------------------

def adamw_init(params, hp: OptHParams):
    zeros = lambda p: jnp.zeros(p.shape, hp.moment_dtype)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def adamw_update(params, grads, state, step, hp: OptHParams):
    grads, gn = clip_by_global_norm(grads, hp.grad_clip)
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - hp.b1 ** t
    c2 = 1.0 - hp.b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = hp.b1 * m.astype(jnp.float32) + (1 - hp.b1) * g32
        v32 = hp.b2 * v.astype(jnp.float32) + (1 - hp.b2) * jnp.square(g32)
        u = (m32 / c1) / (jnp.sqrt(v32 / c2) + hp.eps)
        if p.ndim >= 2:
            u = u + hp.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - hp.lr * u).astype(p.dtype),
                m32.astype(hp.moment_dtype), v32.astype(hp.moment_dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gn}


# ------------------------------ Adafactor ---------------------------------

def _factored(p, hp):
    return p.ndim >= 2 and p.shape[-1] >= hp.min_dim_factored and \
        p.shape[-2] >= hp.min_dim_factored


def adafactor_init(params, hp: OptHParams):
    def one(p):
        if _factored(p, hp):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(one, params)}


def adafactor_update(params, grads, state, step, hp: OptHParams):
    grads, gn = clip_by_global_norm(grads, hp.grad_clip)
    t = (step + 1).astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8

    def upd(p, g, v):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + 1e-30
        if _factored(p, hp):
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            rms = (vr[..., None] / jnp.maximum(denom[..., None], 1e-30)
                   ) * vc[..., None, :]
            u = g32 * jax.lax.rsqrt(jnp.maximum(rms, 1e-30))
            nv = {"vr": vr, "vc": vc}
        else:
            vf = beta2 * v["v"] + (1 - beta2) * g2
            u = g32 * jax.lax.rsqrt(jnp.maximum(vf, 1e-30))
            nv = {"v": vf}
        # update clipping (Adafactor d=1.0)
        urms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, urms)
        if p.ndim >= 2:
            u = u + hp.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - hp.lr * u).astype(p.dtype), nv)

    # state["v"] has a small dict *subtree* at each param leaf; jax.tree.map
    # passes it whole because params' structure is a prefix of state's.
    out = jax.tree.map(upd, params, grads, state["v"])
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"v": new_v}, {"grad_norm": gn}


def make_optimizer(name: str, hp: OptHParams):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(name)
