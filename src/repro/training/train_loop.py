"""train_step builder: microbatched grad accumulation, remat, aux losses.

``make_train_step(cfg)`` returns a pure function
    train_step(state, batch) -> (state, metrics)
suitable for ``jax.jit`` with in/out shardings from ``repro.dist.sharding``.

Batch layout: tokens (B, S+1) — inputs are [:, :-1], targets [:, 1:].
Microbatching: the global batch is split into ``n_microbatches`` along B and
grad-accumulated with ``lax.scan`` (bounds activation memory; DESIGN.md §6).
Optional EF-int8 gradient compression applies to the accumulated gradient
(the tensor that crosses pods in the DP reduction).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import forward, lm_loss
from repro.training import grad_compress
from repro.training.optimizer import OptHParams, make_optimizer

AUX_WEIGHTS = {"moe_lb_loss": 1e-2, "moe_z_loss": 1e-3}


def init_train_state(key, cfg: ModelConfig, hp: OptHParams | None = None,
                     params=None) -> dict:
    from repro.models.transformer import init_lm

    hp = hp or OptHParams()
    params = params if params is not None else init_lm(key, cfg)
    opt_init, _ = make_optimizer(cfg.optimizer, hp)
    state = {
        "params": params,
        "opt": opt_init(params, hp),
        "step": jnp.zeros((), jnp.int32),
    }
    return state


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        kw = {}
        if cfg.frontend == "vision" and "frontend_embeds" in batch:
            kw["frontend_embeds"] = batch["frontend_embeds"]
        if cfg.is_encdec:
            kw["enc_frames"] = batch["enc_frames"]
        tokens = batch["tokens"]
        logits, _, aux = forward(params, cfg, tokens[:, :-1], mode="train", **kw)
        loss = lm_loss(logits, tokens[:, 1:], cfg, batch.get("mask"))
        total = loss
        for k, w in AUX_WEIGHTS.items():
            if k in aux:
                total = total + w * aux[k]
        metrics = {"loss": loss}
        for k in ("moe_lb_loss", "moe_z_loss", "moe_dropped"):
            if k in aux:
                metrics[k] = aux[k]
        return total, metrics

    return loss_fn


def make_train_step(cfg: ModelConfig, hp: OptHParams | None = None,
                    n_microbatches: int = 1, compress_grads: bool = False,
                    grad_shardings=None, accum_dtype=jnp.float32):
    """grad_shardings: optional pytree (params structure) of NamedShardings;
    constrains the microbatch gradient accumulator so grad reductions become
    per-shard reduce-scatters instead of replicated all-reduces (§Perf)."""
    hp = hp or OptHParams()
    _, opt_update = make_optimizer(cfg.optimizer, hp)
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(state, batch):
        params = state["params"]
        if n_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))

            def acc_body(carry, mb):
                g_acc, m_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = _constrain(jax.tree.map(
                    lambda a, b_: a + b_.astype(accum_dtype), g_acc,
                    _constrain(g)))
                metrics = dict(metrics, loss=loss)
                m_acc = jax.tree.map(lambda a, b_: a + b_, m_acc,
                                     {k: jnp.asarray(v, jnp.float32)
                                      for k, v in metrics.items()})
                return (g_acc, m_acc), None

            m0 = {"loss": jnp.zeros((), jnp.float32)}
            probe = jax.eval_shape(
                lambda p, mb: grad_fn(p, mb)[0][1], params,
                jax.tree.map(lambda x: x[0], micro))
            m0 = {k: jnp.zeros((), jnp.float32) for k in probe}
            (grads, msum), _ = jax.lax.scan(acc_body, (zeros, m0), micro)
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) / n_microbatches), grads)
            metrics = {k: v / n_microbatches for k, v in msum.items()}
            loss = metrics["loss"]

        new_err = None
        if compress_grads:
            grads, new_err = grad_compress.compress_decompress(
                grads, state["err"])

        new_params, new_opt, opt_metrics = opt_update(
            params, grads, state["opt"], state["step"], hp)
        metrics = dict(metrics, **opt_metrics)
        new_state = dict(state, params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        if new_err is not None:
            new_state["err"] = new_err
        return new_state, metrics

    return train_step
