"""Error-feedback int8 gradient compression (cross-pod traffic reduction).

Production rationale (DESIGN.md §6): at 1000+ nodes the pod-to-pod
data-parallel all-reduce rides the slowest links; int8 with per-block scales
cuts that traffic 4x vs f32 (2x vs bf16) at negligible quality loss when the
quantization error is fed back into the next step (Seide et al. 2014-style
EF). The quantize/dequantize pair is inserted around the DP gradient
reduction; the residual lives with the optimizer state and shards like the
parameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize_int8(x: jax.Array):
    """Per-block symmetric int8. Returns (q int8, scales f32)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize(q, scale, shape):
    x = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return x[:n].reshape(shape)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads, error_state):
    """Apply EF-int8 round-trip: g' = Q(g + e); e' = (g + e) - g'.

    In a multi-host deployment Q's int8 payload is what crosses the pod
    links; numerically the round-trip below is identical, so training-quality
    effects are exactly reproduced on one host.
    """
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = _quantize_int8(x)
        deq = _dequantize(q, s, g.shape)
        return deq, x - deq

    out = jax.tree.map(one, grads, error_state)
    g2 = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    e2 = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g2, e2


def compression_ratio(params, from_dtype_bytes: int = 2) -> float:
    """Wire-bytes ratio of the compressed DP reduction (int8 + scales)."""
    total = sum(p.size for p in jax.tree.leaves(params))
    comp = total * 1 + (total // BLOCK + 1) * 4
    return (total * from_dtype_bytes) / comp
