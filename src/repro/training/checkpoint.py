"""Sharded checkpointing with elastic (re-mesh) restore.

Layout on disk:
    <dir>/step_<N>/manifest.json     tree structure, shapes, dtypes, mesh,
                                     data-pipeline state, step
    <dir>/step_<N>/arrays.npz        one entry per leaf (key = leaf path)

Fault-tolerance contract (DESIGN.md §6):
  * atomic: written to a tmp dir, fsync'd, then renamed — a crash mid-save
    never corrupts the latest checkpoint;
  * elastic: ``restore`` takes the *target* shardings (any mesh shape), so a
    512-chip checkpoint restores onto 256 chips or vice versa — leaves are
    saved as full logical arrays and re-device_put under the new sharding;
  * async: ``save_async`` snapshots to host then writes in a thread so the
    TPUs keep stepping;
  * the data-pipeline state rides along, so restart resumes the stream
    exactly (no repeated/skipped batches).

On a real multi-host pod each host writes only its addressable shards; here
(single process) the gather is a no-op. The manifest records the source mesh
for audit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def save(state, ckpt_dir: str, step: int, data_state: dict | None = None,
         mesh_shape=None) -> str:
    keys, leaves, _ = _leaf_paths(state)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = {}
    for k, leaf in zip(keys, leaves):
        arrays[k] = np.asarray(jax.device_get(leaf))
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": keys,
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "mesh_shape": list(mesh_shape) if mesh_shape else None,
        "data_state": data_state or {},
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


_SAVE_THREADS: list[threading.Thread] = []


def save_async(state, ckpt_dir: str, step: int, **kw) -> threading.Thread:
    """Snapshot to host synchronously, write in a background thread."""
    keys, leaves, _ = _leaf_paths(state)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    snapshot = jax.tree_util.tree_unflatten(_leaf_paths(state)[2], host)
    # daemon is safe: save() lands atomically (tmp dir + rename), so a
    # writer killed at interpreter exit leaves no partial checkpoint —
    # callers that need durability join via the handle / wait_for_saves()
    th = threading.Thread(target=save, args=(snapshot, ckpt_dir, step),
                          kwargs=kw, name=f"ckpt-save-{step}", daemon=True)
    th.start()
    _SAVE_THREADS.append(th)
    return th


def wait_for_saves():
    for th in _SAVE_THREADS:
        th.join()
    _SAVE_THREADS.clear()


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template, shardings=None):
    """Restore into ``template``'s structure; ``shardings`` (same structure
    or a single sharding) re-places leaves under any target mesh (elastic)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    keys, leaves, treedef = _leaf_paths(template)
    out = []
    for k, leaf in zip(keys, leaves):
        a = arrays[k]
        if list(a.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {k}: {a.shape} vs {leaf.shape}")
        a = a.astype(leaf.dtype)
        out.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        if not isinstance(shardings, (dict, list, tuple)):
            tree = jax.tree.map(lambda x: jax.device_put(x, shardings), tree)
        else:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest
