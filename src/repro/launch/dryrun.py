import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
devices stand in for 2 pods x 256 chips. For each cell:

    jit(step, in_shardings, out_shardings).lower(specs).compile()
    -> memory_analysis()   (fits?)
    -> cost_analysis()     (per-device flops / bytes)
    -> HLO collective scan (collective bytes)  -> §Roofline terms

Usage:
    python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod --out results.json
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.dist.hints import use_mesh
from repro.dist.sharding import ShardingRules
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_roofline
from repro.launch.shapes import SHAPES, cell_supported, input_specs
from repro.serving.engine import make_serve_step
from repro.training.optimizer import OptHParams
from repro.training.train_loop import init_train_state, make_train_step

N_MICROBATCHES = 8  # train grad-accumulation steps (per-device micro <= 2)

# Cumulative optimization variants for the SPerf hillclimb:
#   v1: shard the grad accumulator like the params (RS instead of replicated AR)
#   v2: v1 + bf16 online-softmax score traffic
#   v3: v2 + 2 microbatches + bf16 grad accumulator
#   v4: v3 + full-mesh DP (model axis -> data parallelism; small archs)
#   v5: v1 + bf16 scores + 4 microbatches (memory-bounded MoE compromise)
VARIANTS = ("baseline", "v1", "v2", "v3", "v4", "v5")


def _train_lowered(cfg, mesh, specs, variant="baseline",
                   n_micro=N_MICROBATCHES):
    hp = OptHParams(moment_dtype=jnp.bfloat16)
    rules = ShardingRules(cfg, mesh, full_dp=(variant == "v4"))
    accum_dtype = jnp.float32
    grad_sh = None
    if variant in ("v2", "v3", "v4", "v5"):
        cfg = dataclasses.replace(cfg, attn_dtype="bfloat16")
    if variant == "v5":
        n_micro = 4
        accum_dtype = jnp.bfloat16
    if variant == "v3":
        n_micro = 2
        accum_dtype = jnp.bfloat16
    if variant == "v4":
        # full-mesh DP: every device needs >= 1 batch row per microbatch
        n_micro = 1
        accum_dtype = jnp.bfloat16
    state_specs = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg, hp))
    state_sh = rules.state_shardings(state_specs)
    if variant in ("v1", "v2", "v3", "v5"):
        grad_sh = rules.params_shardings(state_specs["params"])
    batch_sh = rules.batch_shardings(specs["batch"])
    step = make_train_step(cfg, hp, n_microbatches=n_micro,
                           grad_shardings=grad_sh, accum_dtype=accum_dtype)
    jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    return jitted.lower(state_specs, specs["batch"])


def _prefill_lowered(cfg, mesh, specs):
    from repro.models.transformer import init_lm
    from repro.serving.engine import make_prefill

    rules = ShardingRules(cfg, mesh)
    params_specs = jax.eval_shape(
        lambda: init_lm(jax.random.PRNGKey(0), cfg))
    params_sh = rules.params_shardings(params_specs)
    args = [specs["tokens"]]
    arg_sh = [rules.batch_shardings(specs["tokens"])]
    kw_names = []
    for k in ("frontend_embeds", "enc_frames"):
        if k in specs:
            args.append(specs[k])
            arg_sh.append(rules.batch_shardings(specs[k]))
            kw_names.append(k)
    fn = make_prefill(cfg)

    def wrapped(params, tokens, *extra):
        kw = dict(zip(kw_names, extra))
        return fn(params, tokens, **kw)

    jitted = jax.jit(wrapped, in_shardings=(params_sh, *arg_sh))
    return jitted.lower(params_specs, *args)


def _decode_lowered(cfg, mesh, specs):
    from repro.models.transformer import init_lm

    rules = ShardingRules(cfg, mesh)
    params_specs = jax.eval_shape(
        lambda: init_lm(jax.random.PRNGKey(0), cfg))
    params_sh = rules.params_shardings(params_specs)
    cache_sh = rules.cache_shardings(specs["cache"])
    tok_sh = rules.batch_shardings(specs["token"])
    step = make_serve_step(cfg, moe_groups=1 if cfg.is_moe else None)
    jitted = jax.jit(
        step,
        in_shardings=(params_sh, tok_sh, cache_sh),
        out_shardings=(None, None, cache_sh),
        donate_argnums=(2,),
    )
    return jitted.lower(params_specs, specs["token"], specs["cache"])


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             compile_: bool = True, variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    ok, why = cell_supported(cfg, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "variant": variant}
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    t0 = time.time()
    dp = (("pod", "data", "model") if variant == "v4"
          else ("pod", "data"))
    with use_mesh(mesh, dp=dp):
        if spec.kind == "train":
            lowered = _train_lowered(cfg, mesh, specs, variant)
        elif spec.kind == "prefill":
            lowered = _prefill_lowered(cfg, mesh, specs)
        else:
            lowered = _decode_lowered(cfg, mesh, specs)
    result["lower_s"] = round(time.time() - t0, 1)
    if not compile_:
        result["status"] = "lowered"
        return result
    t0 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    cost = hlo_analysis.xla_cost_dict(compiled)
    hlo = compiled.as_text()
    n_dev = mesh.size
    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    result["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_bytes_per_device": per_dev_bytes,
        "fits_16GB": bool(per_dev_bytes < 16e9),
    }
    rf = build_roofline(arch, shape_name, mesh_name, n_dev, cost, hlo,
                        cfg, spec)
    result["roofline"] = rf.to_dict()
    result["status"] = "ok"
    print(f"[{arch} x {shape_name} x {mesh_name} x {variant}] "
          f"compile={result['compile_s']}s "
          f"mem/dev={per_dev_bytes/1e9:.2f}GB bound={rf.bound} "
          f"terms(c/m/coll)=({rf.compute_s:.4f},{rf.memory_s:.4f},"
          f"{rf.collective_s:.4f})s mfu={rf.mfu:.3f}", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    args = ap.parse_args()

    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    r = run_cell(arch, shape, mp,
                                 compile_=not args.no_compile,
                                 variant=args.variant)
                except Exception as e:  # a failing cell is a bug: record it
                    r = {"arch": arch, "shape": shape,
                         "mesh": "2x16x16" if mp else "16x16",
                         "status": "error", "error": f"{type(e).__name__}: {e}",
                         "trace": traceback.format_exc()[-2000:]}
                    print(f"[{arch} x {shape}] FAILED: {e}", flush=True)
                results.append(r)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    if n_err:
        sys.exit(1)


if __name__ == "__main__":
    main()
