"""Assigned input-shape sets + ShapeDtypeStruct builders per (arch, shape).

Shapes (assignment):
    train_4k     seq 4,096   global_batch 256   (training)
    prefill_32k  seq 32,768  global_batch 32    (inference prefill)
    decode_32k   seq 32,768  global_batch 128   (decode: 1 new token, cache
                                                 holds seq_len)
    long_500k    seq 524,288 global_batch 1     (long-context decode;
                                                 sub-quadratic archs only)

``input_specs`` returns weak-type-correct ShapeDtypeStructs — no device
allocation ever happens for full-size configs; the dry-run lowers + compiles
from specs alone. Decode caches place the last prompt token at the final
slot (pos = seq_len - 1) so the one-token step writes inside the buffer.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: long_500k requires "
                       "sub-quadratic attention (DESIGN.md §5)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Training batch pytree specs (tokens carry the shifted target)."""
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((b, s + 1), jnp.int32)}
    if cfg.frontend == "vision":
        out["frontend_embeds"] = _sds(
            (b, cfg.n_frontend_tokens, cfg.d_model), cfg.jnp_dtype)
    if cfg.is_encdec:
        out["enc_frames"] = _sds((b, s, cfg.d_model), cfg.jnp_dtype)
    return out


def prefill_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.frontend == "vision":
        out["frontend_embeds"] = _sds(
            (b, cfg.n_frontend_tokens, cfg.d_model), cfg.jnp_dtype)
    if cfg.is_encdec:
        out["enc_frames"] = _sds((b, s, cfg.d_model), cfg.jnp_dtype)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """token + cache specs via eval_shape over init_decode_cache."""
    from repro.models.transformer import init_decode_cache

    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: init_decode_cache(cfg, b, s, src_len=s if cfg.is_encdec else 0)
    )
    # pos is a concrete scalar inside the pytree; normalize to a spec
    cache = jax.tree.map(
        lambda x: _sds(x.shape, x.dtype), cache)
    return {"token": _sds((b, 1), jnp.int32), "cache": cache}


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)
