"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state. Single pod: (16, 16) = 256 chips, axes ("data", "model"); multi-pod:
(2, 16, 16) = 512 chips, axes ("pod", "data", "model").
"""
from __future__ import annotations

import jax

from repro.dist.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        raise ValueError(
            f"model={model} must be a positive divisor of the device count "
            f"({n}); a silent 0-sized data axis helps nobody")
    return make_mesh((n // model, model), ("data", "model"))
