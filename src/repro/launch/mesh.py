"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state. Single pod: (16, 16) = 256 chips, axes ("data", "model"); multi-pod:
(2, 16, 16) = 512 chips, axes ("pod", "data", "model").
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
