"""Post-optimization HLO cost walker with correct while-loop accounting.

``compiled.cost_analysis()`` counts every ``while`` body exactly once, which
under-reports layer-scanned/microbatched modules by orders of magnitude
(verified empirically — see tests). This walker parses the SPMD-partitioned
HLO text and:

  * multiplies while-body costs by the loop trip count (jax scans lower to
    whiles whose condition compares the induction variable against a
    constant — the max integer constant in the condition computation);
  * counts dot FLOPs as 2 * |out| * prod(lhs contracting dims);
  * counts HBM traffic as operand+output bytes of every top-level op
    (fusions are the HBM<->VMEM units on TPU; their internals are free);
  * accumulates collective bytes per kind (all-gather uses output bytes —
    the gathered size; others use operand bytes), inside loops included.

All numbers are per-device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
# Type strings may contain /*index=N*/ comments (which include '='), so the
# type group is a lazy match up to the first `opcode(` token.
_INST = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "domain", "opt-barrier",
    # loop-carried copies are elided by buffer donation/aliasing on TPU
    "copy", "copy-start", "copy-done",
}

# Pure-elementwise ops fuse into their producer/consumer on TPU: their
# pass-through traffic is already accounted by the anchor ops' in+out bytes
# (dot reads the fused chain's input, writes its output). Skipping them
# models XLA:TPU fusion; the CPU backend leaves them unfused at top level.
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "sqrt", "rsqrt", "cbrt", "power", "select", "compare", "and",
    "or", "xor", "not", "convert", "broadcast", "reshape", "clamp", "sign",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "cosine",
    "sine", "atan2", "is-finite", "reduce-precision", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "expm1",
    "log1p", "logistic", "erf", "stochastic-convert", "real", "imag", "map",
}

_COLLECTIVES = {
    "all-reduce": "all-reduce", "all-reduce-start": "all-reduce",
    "all-gather": "all-gather", "all-gather-start": "all-gather",
    "reduce-scatter": "reduce-scatter", "all-to-all": "all-to-all",
    "collective-permute": "collective-permute",
    "collective-permute-start": "collective-permute",
    "ragged-all-to-all": "all-to-all",
}


def _shape_dims(type_str: str):
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            yield dt, n


def _type_bytes(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * n for dt, n in _shape_dims(type_str))


def _type_elems(type_str: str) -> int:
    return sum(n for _, n in _shape_dims(type_str))


@dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attributes (raw tail of the line)

    def operand_names(self):
        # `rest` starts *inside* the operand paren group (the opening paren
        # was consumed by the instruction regex); read until it closes.
        depth, cur = 1, []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            cur.append(ch)
        return re.findall(r"%([\w\.\-]+)", "".join(cur))

    def attr(self, key: str):
        m = re.search(rf"{key}=%?([\w\.\-]+)", self.rest)
        return m.group(1) if m else None

    def attr_list(self, key: str):
        m = re.search(rf"{key}=\{{([\d,\s]*)\}}", self.rest)
        if not m:
            return []
        return [int(x) for x in m.group(1).replace(" ", "").split(",") if x]


@dataclass
class Computation:
    name: str
    instructions: dict = field(default_factory=dict)
    order: list = field(default_factory=list)
    is_entry: bool = False


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1),
                                  is_entry=line.strip().startswith("ENTRY"))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST.match(line)
        if m:
            inst = Instruction(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instructions[inst.name] = inst
            cur.order.append(inst.name)
    return comps


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the condition computation (jax scan pattern)."""
    best = 1
    for name in cond.order:
        inst = cond.instructions[name]
        if inst.opcode == "constant":
            m = re.match(r"([\d]+)\)?", inst.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)
    bytes_by_op: dict = field(default_factory=dict)
    n_while: int = 0

    def _badd(self, op: str, b: float):
        self.bytes += b
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0) + b

    def add(self, other: "HloCost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0) + v * times
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * times
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0) + v * times
        self.n_while += other.n_while * times


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: dict[str, HloCost] = {}
        self._anchor_memo: dict[str, bool] = {}
        self._dus_memo: dict[str, bool] = {}
        entries = [c for c in self.comps.values() if c.is_entry]
        self.entry = entries[0] if entries else None

    def cost(self) -> HloCost:
        if self.entry is None:
            return HloCost()
        return self._comp_cost(self.entry.name)

    def _has_anchor(self, name: str) -> bool:
        """True if the computation contains any non-elementwise op."""
        if name not in self._anchor_memo:
            comp = self.comps.get(name)
            self._anchor_memo[name] = False
            if comp is not None:
                for iname in comp.order:
                    inst = comp.instructions[iname]
                    if inst.opcode in _FREE_OPS or inst.opcode in _ELEMENTWISE:
                        continue
                    if inst.opcode == "fusion":
                        callee = inst.attr("calls")
                        if callee and self._has_anchor(callee):
                            self._anchor_memo[name] = True
                            break
                        continue
                    self._anchor_memo[name] = True
                    break
        return self._anchor_memo[name]

    def _fusion_traffic(self, inst: Instruction, comp: Computation,
                        callee: str) -> float:
        """HBM traffic of a fusion, modelling TPU slice/update semantics.

        An operand consumed *only* by dynamic-slice ops streams just the
        sliced regions; an operand that is only a dynamic-update-slice base
        aliases the output (in-place) and streams only the update region.
        """
        cc = self.comps[callee]
        # parameter index -> parameter instruction name
        params: dict[int, str] = {}
        for iname in cc.order:
            ci = cc.instructions[iname]
            if ci.opcode == "parameter":
                m = re.match(r"(\d+)", ci.rest)
                if m:
                    params[int(m.group(1))] = iname
        direct: dict[str, list[Instruction]] = {}
        for iname in cc.order:
            ci = cc.instructions[iname]
            for o in ci.operand_names():
                direct.setdefault(o, []).append(ci)

        _PASS = {"bitcast", "copy", "convert", "reshape"}

        def effective(name, depth=0):
            """[(consumer, via)] where `via` is the operand name that reaches
            the consumer (tracks identity through pass-through unary ops)."""
            out = []
            for c in direct.get(name, []):
                if c.opcode in _PASS and depth < 8:
                    out.extend(effective(c.name, depth + 1))
                else:
                    out.append((c, name))
            return out

        consumers = {n: effective(n) for n in params.values()}
        traffic = 0.0
        operands = inst.operand_names()
        dus_on_param = False
        for i, oname in enumerate(operands):
            if oname not in comp.instructions:
                continue
            ob = _type_bytes(comp.instructions[oname].type_str)
            pname = params.get(i)
            cons = consumers.get(pname, []) if pname else []
            if cons and all(c.opcode == "dynamic-slice" for c, _ in cons):
                traffic += sum(_type_bytes(c.type_str) for c, _ in cons)
            elif cons and all(
                c.opcode == "dynamic-update-slice"
                and (c.operand_names() or [None])[0] == via
                for c, via in cons
            ):
                # aliased base: stream the update regions only
                for c, _ in cons:
                    ops2 = c.operand_names()
                    if len(ops2) > 1 and ops2[1] in cc.instructions:
                        traffic += 2 * _type_bytes(
                            cc.instructions[ops2[1]].type_str)
                dus_on_param = True
            else:
                traffic += ob
        out_b = _type_bytes(inst.type_str)
        if not dus_on_param:
            traffic += out_b
        return traffic

    def _has_dus(self, name: str) -> bool:
        if name not in self._dus_memo:
            comp = self.comps.get(name)
            found = False
            if comp is not None:
                for iname in comp.order:
                    if comp.instructions[iname].opcode == "dynamic-update-slice":
                        found = True
                        break
            self._dus_memo[name] = found
        return self._dus_memo[name]

    def _comp_cost(self, name: str) -> HloCost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = HloCost()
        self._memo[name] = total  # guard recursion
        if comp is None:
            return total
        for iname in comp.order:
            inst = comp.instructions[iname]
            op = inst.opcode
            if op in _FREE_OPS or op in _ELEMENTWISE:
                continue
            if op == "fusion":
                callee = inst.attr("calls")
                # elementwise-only fusions are free at the HBM boundary too
                if callee and not self._has_anchor(callee):
                    continue
            out_bytes = _type_bytes(inst.type_str)
            in_bytes = sum(
                _type_bytes(comp.instructions[o].type_str)
                for o in inst.operand_names() if o in comp.instructions
            )
            if op in _COLLECTIVES:
                kind = _COLLECTIVES[op]
                b = out_bytes if kind == "all-gather" else in_bytes
                total.coll_bytes[kind] = total.coll_bytes.get(kind, 0) + b
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
                total._badd(kind, in_bytes + out_bytes)
                continue
            # slice-family ops touch only the sliced region on TPU (the big
            # operand is NOT streamed): count in-place traffic.
            if op in ("slice", "dynamic-slice"):
                total._badd(op, 2 * out_bytes)
                continue
            if op == "dynamic-update-slice":
                ops_ = inst.operand_names()
                upd = comp.instructions.get(ops_[1]) if len(ops_) > 1 else None
                ub = _type_bytes(upd.type_str) if upd is not None else out_bytes
                total._badd(op, 2 * ub)
                continue
            if op == "gather":
                ops_ = inst.operand_names()
                idxb = (_type_bytes(comp.instructions[ops_[1]].type_str)
                        if len(ops_) > 1 and ops_[1] in comp.instructions else 0)
                total._badd(op, 2 * out_bytes + idxb)
                continue
            if op == "scatter":
                ops_ = inst.operand_names()
                upd_b = (_type_bytes(comp.instructions[ops_[2]].type_str)
                         if len(ops_) > 2 and ops_[2] in comp.instructions else 0)
                idx_b = (_type_bytes(comp.instructions[ops_[1]].type_str)
                         if len(ops_) > 1 and ops_[1] in comp.instructions else 0)
                total._badd(op, 3 * upd_b + idx_b)
                callee = inst.attr("calls")
                if callee and callee in self.comps:
                    total.add(self._comp_cost(callee))
                continue
            if op == "while":
                total.n_while += 1
                body = inst.attr("body")
                cond = inst.attr("condition")
                m = re.search(r'known_trip_count[^\d]*(\d+)', inst.rest)
                if m:
                    trips = int(m.group(1))
                else:
                    trips = _trip_count(self.comps[cond]) \
                        if cond in self.comps else 1
                sub = HloCost()
                sub.add(self._comp_cost(body))
                if cond in self.comps:
                    sub.add(self._comp_cost(cond))
                total.add(sub, times=max(trips, 1))
                continue
            if op == "conditional":
                branches = re.findall(r"%([\w\.\-]+)", inst.rest.split("),", 1)[-1])
                branch_costs = [self._comp_cost(b) for b in branches
                                if b in self.comps]
                if branch_costs:
                    worst = max(branch_costs, key=lambda c: c.flops + c.bytes)
                    total.add(worst)
                total._badd(op, in_bytes + out_bytes)
                continue
            if op in ("fusion", "call", "map", "reduce", "reduce-window",
                      "scatter", "sort", "custom-call", "select-and-scatter"):
                callee = inst.attr("calls")
                if callee and callee in self.comps:
                    total.add(self._comp_cost(callee))
                if op == "fusion" and callee in self.comps:
                    traffic = self._fusion_traffic(inst, comp, callee)
                else:
                    traffic = in_bytes + out_bytes
                total._badd(op, traffic)
                continue
            if op == "dot":
                ops_ = inst.operand_names()
                lhs = comp.instructions.get(ops_[0]) if ops_ else None
                k = 1
                if lhs is not None:
                    dims = list(_SHAPE_RE.findall(lhs.type_str))
                    if dims:
                        shape = [int(x) for x in dims[0][1].split(",") if x]
                        for ci in inst.attr_list("lhs_contracting_dims"):
                            if ci < len(shape):
                                k *= shape[ci]
                total.flops += 2.0 * _type_elems(inst.type_str) * k
                total._badd(op, in_bytes + out_bytes)
                continue
            if op == "convolution":
                # rare here; approximate via output elems * kernel volume
                total.flops += 2.0 * _type_elems(inst.type_str)
                total._badd(op, in_bytes + out_bytes)
                continue
            # default: bytes only
            total._badd(op, in_bytes + out_bytes)
        self._memo[name] = total
        return total


def analyze(text: str) -> HloCost:
    return HloAnalyzer(text).cost()


def xla_cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized across jax versions.

    jaxlib <= 0.4.x returns a one-element list of dicts (one per program);
    newer versions return the dict directly. Either way, hand back a dict.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}
