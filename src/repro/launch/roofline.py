"""Roofline extraction from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch, shape, mesh), from the SPMD-partitioned per-device
module:
    compute    = flops_per_device / PEAK_FLOPS
    memory     = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

``cost_analysis()`` reports per-device flops/bytes (verified empirically:
values shrink with mesh size). Collective bytes are not in cost_analysis —
they are parsed from the compiled HLO text: operand bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
(all-reduce counted twice: ring = reduce-scatter + all-gather).

MODEL_FLOPS uses 6*N*D (dense) / 6*N_active*D (MoE) + the attention term,
so the useful-compute ratio flags remat/dispatch waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

# --- TPU v5e hardware constants (assignment-provided) ---
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device operand bytes per collective kind, from partitioned HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s*((?:\([^)]*\))|(?:[\w\[\],{}:#*\s]*?))\s*"
            r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
            r"reduce-scatter|all-to-all|collective-permute-start|"
            r"collective-permute)\(", line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        kind = op.replace("-start", "")
        if kind not in out:
            continue
        b = _type_bytes(type_str)
        # output-size proxy; for all-gather output == gathered bytes,
        # for all-reduce output == operand
        out[kind] += b
        counts[kind] += 1
    return {"bytes": out, "counts": counts}


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict
    model_flops_global: float
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def collective_s(self) -> float:
        # all-reduce ring = RS + AG: count twice
        ar2 = self.coll_breakdown["bytes"].get("all-reduce", 0)
        return (self.coll_bytes_per_device + ar2) / self.ici_bw

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops_global / max(hlo_global, 1.0)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        t = self.step_time_s
        return self.model_flops_global / (
            self.n_devices * self.peak_flops * max(t, 1e-12))

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
        }


def model_flops(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """6*N_active*D (+ attention quadratic/window term), global per step."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        tokens = batch * seq
        mult = 6.0
    elif shape_kind == "prefill":
        tokens = batch * seq
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = batch * 1
        mult = 2.0
    base = mult * n_active * tokens
    # attention score+value flops: 2 * 2 * H * hd * S_eff per token
    from repro.configs.base import GLOBAL, LOCAL
    attn = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == GLOBAL:
            s_eff = seq / 2 if shape_kind != "decode" else seq
        elif kind == LOCAL:
            s_eff = min(cfg.window, seq)
        else:
            continue
        per_tok = 4.0 * cfg.n_heads * cfg.head_dim * s_eff
        attn += per_tok * tokens * (3.0 if shape_kind == "train" else 1.0)
    return base + attn


def build_roofline(arch, shape, mesh_name, n_devices, cost, hlo_text,
                   cfg, shape_spec) -> Roofline:
    """Terms from the HLO walker (while-loop-correct); xla cost_analysis is
    kept as a cross-check field (it counts loop bodies once)."""
    from repro.launch.hlo_analysis import analyze

    hc = analyze(hlo_text)
    coll = {"bytes": dict(hc.coll_bytes), "counts": dict(hc.coll_counts),
            "xla_cost_flops": float(cost.get("flops", 0.0)),
            "xla_cost_bytes": float(cost.get("bytes accessed", 0.0))}
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=float(hc.flops),
        bytes_per_device=float(hc.bytes),
        coll_bytes_per_device=float(sum(hc.coll_bytes.values())),
        coll_breakdown=coll,
        model_flops_global=model_flops(cfg, shape_spec.kind,
                                       shape_spec.global_batch,
                                       shape_spec.seq_len),
    )
