"""Synthetic ScanNet-like labelled indoor scenes (host-side generator).

Procedurally builds rooms — floor, walls, and furniture primitives (boxes,
cylinders, spheres) — samples surface points with normals, voxelizes, and
labels each voxel by its generating object class. Gives the same *spatial
sparsity structure* the paper exploits (thin 2D surfaces embedded in 3D:
occupancy a few percent, ARF well below 27) without shipping a dataset.

Classes: 0 floor, 1 wall, 2 box, 3 cylinder, 4 sphere (+ optional more box
classes). Features per point: (nx, ny, nz, height).
"""
from __future__ import annotations

import numpy as np

from repro.sparse.tensor import PAD_COORD

N_CLASSES = 5
N_FEATURES = 4


def _box_surface(rng, n, lo, hi):
    """n points on the surface of an axis-aligned box, with outward normals."""
    pts = rng.uniform(lo, hi, (n, 3))
    face = rng.integers(0, 6, n)
    axis, side = face // 2, face % 2
    pts[np.arange(n), axis] = np.where(side == 0, lo[axis], hi[axis])
    normals = np.zeros((n, 3))
    normals[np.arange(n), axis] = np.where(side == 0, -1.0, 1.0)
    return pts, normals


def _sphere_surface(rng, n, center, radius):
    v = rng.normal(size=(n, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True) + 1e-9
    return center + radius * v, v


def _cylinder_surface(rng, n, center, radius, height):
    theta = rng.uniform(0, 2 * np.pi, n)
    z = rng.uniform(0, height, n)
    pts = np.stack(
        [center[0] + radius * np.cos(theta), center[1] + radius * np.sin(theta),
         center[2] + z], axis=1,
    )
    normals = np.stack([np.cos(theta), np.sin(theta), np.zeros(n)], axis=1)
    return pts, normals


def make_scene(
    seed: int,
    resolution: int = 64,
    capacity: int = 8192,
    points_per_unit: float = 60000.0,
    n_objects: int = 4,
):
    """-> coords (V,3) int32, feats (V,4) f32, labels (V,) int32, mask (V,)."""
    rng = np.random.default_rng(seed)
    pts_list, nrm_list, lbl_list = [], [], []

    def add(pts, normals, label, frac):
        pts_list.append(pts)
        nrm_list.append(normals)
        lbl_list.append(np.full(len(pts), label, np.int32))

    # Floor (z ~ 0.02) and two walls.
    nf = int(points_per_unit * 0.015)
    floor = np.stack(
        [rng.uniform(0.02, 0.98, nf), rng.uniform(0.02, 0.98, nf),
         np.full(nf, 0.03) + rng.normal(0, 0.002, nf)], axis=1,
    )
    add(floor, np.tile([0.0, 0.0, 1.0], (nf, 1)), 0, None)
    for wall_axis in (0, 1):
        nw = int(points_per_unit * 0.01)
        w = np.stack(
            [rng.uniform(0.02, 0.98, nw), rng.uniform(0.02, 0.98, nw),
             rng.uniform(0.03, 0.7, nw)], axis=1,
        )
        w[:, wall_axis] = 0.03 + rng.normal(0, 0.002, nw)
        nrm = np.zeros((nw, 3)); nrm[:, wall_axis] = 1.0
        add(w, nrm, 1, None)

    for _ in range(n_objects):
        kind = rng.integers(2, 5)
        npts = int(points_per_unit * 0.004)
        cx, cy = rng.uniform(0.2, 0.8, 2)
        if kind == 2:
            size = rng.uniform(0.06, 0.18, 3)
            lo = np.array([cx, cy, 0.03])
            pts, nrm = _box_surface(rng, npts, lo, lo + size)
        elif kind == 3:
            pts, nrm = _cylinder_surface(
                rng, npts, np.array([cx, cy, 0.03]),
                rng.uniform(0.03, 0.08), rng.uniform(0.1, 0.3),
            )
        else:
            r = rng.uniform(0.04, 0.1)
            pts, nrm = _sphere_surface(rng, npts, np.array([cx, cy, 0.03 + r]), r)
        add(pts, nrm, int(kind), None)

    pts = np.clip(np.concatenate(pts_list), 0.0, 0.999)
    nrm = np.concatenate(nrm_list)
    lbl = np.concatenate(lbl_list)
    feats = np.concatenate([nrm, pts[:, 2:3]], axis=1).astype(np.float32)

    # Voxelize with per-voxel majority label.
    ijk = np.clip((pts * resolution).astype(np.int64), 0, resolution - 1)
    key = (ijk[:, 0] * resolution + ijk[:, 1]) * resolution + ijk[:, 2]
    order = np.argsort(key, kind="stable")
    key_s, lbl_s, feat_s = key[order], lbl[order], feats[order]
    uniq, start, counts = np.unique(key_s, return_index=True, return_counts=True)
    n = min(len(uniq), capacity)
    coords = np.full((capacity, 3), PAD_COORD, np.int32)
    out_feats = np.zeros((capacity, N_FEATURES), np.float32)
    out_lbl = np.zeros((capacity,), np.int32)
    mask = np.zeros((capacity,), bool)
    coords[:n, 0] = (uniq[:n] // (resolution * resolution))
    coords[:n, 1] = (uniq[:n] // resolution) % resolution
    coords[:n, 2] = uniq[:n] % resolution
    for i in range(n):
        s, c = start[i], counts[i]
        out_feats[i] = feat_s[s:s + c].mean(0)
        out_lbl[i] = np.bincount(lbl_s[s:s + c], minlength=N_CLASSES).argmax()
    mask[:n] = True
    return coords, out_feats, out_lbl, mask


def _world_feats(wcoords: np.ndarray) -> np.ndarray:
    """Deterministic per-world-voxel features: a voxel retained between
    sweep frames carries bit-identical features in both (what a mapped
    static world looks like to the network)."""
    x = wcoords.astype(np.float64)
    f = np.stack(
        [np.sin(0.37 * x[:, 0] + 0.1), np.cos(0.53 * x[:, 1] + 0.2),
         np.sin(0.71 * x[:, 2] + 0.3), (x[:, 2] % 7) / 7.0], axis=1)
    return f.astype(np.float32)


def make_lidar_sweep(
    seed: int,
    n_frames: int,
    resolution: int = 32,
    capacity: int = 1024,
    *,
    step: int = 4,
    churn: float = 0.05,
    fill: float = 0.6,
):
    """Synthetic LiDAR sweep: an ego window sliding over a persistent world.

    A static "world" corridor of voxels (span ``resolution + step *
    (n_frames-1)`` along x) is sampled once from ``seed``; frame *i* sees
    the window ``[i*step, i*step + resolution)`` re-based to the ego frame
    (world x minus ``i*step``). Two churn mechanisms perturb the static
    picture per frame: a ``churn`` fraction of visible world voxels is
    dropped (occlusion / dynamic objects leaving) and a matching number of
    frame-local voxels appears. Steady-state voxel overlap between
    consecutive frames is roughly ``(1 - step/resolution) * (1-churn)^2``
    — tune ``step`` and ``churn`` to sweep it.

    Active voxels land on *random rows* each frame (no canonical order),
    so consumers exercise the streaming planner's row re-packing. Features
    are a deterministic function of *world* position (retained voxels are
    bit-identical across frames); labels likewise. Everything derives from
    ``seed``.

    ``step`` should stay divisible by ``2**(n_levels-1)`` of the consuming
    U-Net (the default 4 covers 3 levels) — an unaligned ego shift makes
    the incremental planner fall back to full rebuilds.

    Returns ``(frames, ego_shifts)``: ``frames[i] = (coords (V,3) int32,
    feats (V,4) f32, labels (V,) int32, mask (V,))`` with ``V=capacity``,
    and ``ego_shifts[i]`` the ego translation since frame *i-1*
    (``(0,0,0)`` for frame 0).
    """
    if n_frames < 1:
        raise ValueError(f"n_frames must be >= 1, got {n_frames}")
    rng = np.random.default_rng(seed)
    span = resolution + step * (n_frames - 1)
    total = span * resolution * resolution
    n_world = min(int(fill * capacity * span / resolution), total)
    wkeys = np.sort(rng.choice(total, size=n_world, replace=False))
    wx = (wkeys // (resolution * resolution)).astype(np.int64)

    def decode(keys):
        r = resolution
        return np.stack([keys // (r * r), (keys // r) % r, keys % r],
                        axis=1).astype(np.int64)

    frames = []
    ego_shifts = []
    for i in range(n_frames):
        f_rng = np.random.default_rng((seed, 1000 + i))
        x0 = i * step
        vis = wkeys[(wx >= x0) & (wx < x0 + resolution)]
        keep = f_rng.random(len(vis)) >= churn
        statics = vis[keep]
        # frame-local appearances: window cells outside the static world
        n_dyn = int(round(churn * len(vis)))
        cand = (f_rng.integers(x0, x0 + resolution, size=4 * n_dyn + 8)
                * resolution * resolution
                + f_rng.integers(0, resolution * resolution,
                                 size=4 * n_dyn + 8))
        cand = np.unique(cand)
        cand = cand[~np.isin(cand, wkeys)][:n_dyn]
        keys = np.concatenate([statics, cand])
        if len(keys) > capacity:
            keys = keys[np.sort(f_rng.choice(len(keys), size=capacity,
                                             replace=False))]
        wc = decode(keys)
        n = len(keys)
        rows = f_rng.choice(capacity, size=n, replace=False)
        coords = np.full((capacity, 3), PAD_COORD, np.int32)
        feats = np.zeros((capacity, N_FEATURES), np.float32)
        labels = np.zeros((capacity,), np.int32)
        mask = np.zeros((capacity,), bool)
        ego = wc.copy()
        ego[:, 0] -= x0
        coords[rows] = ego.astype(np.int32)
        feats[rows] = _world_feats(wc)
        labels[rows] = (wc.sum(axis=1) % N_CLASSES).astype(np.int32)
        mask[rows] = True
        frames.append((coords, feats, labels, mask))
        ego_shifts.append((step, 0, 0) if i else (0, 0, 0))
    return frames, ego_shifts


def scene_batch_iterator(seed: int, batch: int, resolution: int, capacity: int):
    """Deterministic, restartable scene stream (state = next seed)."""
    step = 0
    while True:
        out = [make_scene(seed + step * batch + b, resolution, capacity)
               for b in range(batch)]
        coords, feats, labels, mask = (np.stack(x) for x in zip(*out))
        yield {"coords": coords, "feats": feats, "labels": labels,
               "mask": mask, "state": {"seed": seed, "step": step + 1}}
        step += 1
