"""Deterministic synthetic token pipeline (checkpointable, shardable).

A Zipf-ish unigram stream with planted bigram structure so models show a
clearly decreasing loss (learnable signal) without shipping a corpus.
State = (seed, step): restart-exact after checkpoint restore. Each host
slices its data-parallel shard by process index (single process here).
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0,
                 step: int = 0, process_index: int = 0, process_count: int = 1):
        self.vocab, self.batch, self.seq_len = vocab, batch, seq_len
        self.seed, self.step = seed, step
        self.process_index, self.process_count = process_index, process_count
        # planted bigram table: token t prefers (t*a+c) % V
        self.a = 31, 17

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, vocab, batch, seq_len, state, **kw):
        return cls(vocab, batch, seq_len, seed=state["seed"],
                   step=state["step"], **kw)

    def __next__(self):
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.step) * self.process_count
            + self.process_index
        )
        b = self.batch // self.process_count
        # zipf-ish marginals
        ranks = np.arange(1, self.vocab + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = np.empty((b, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.choice(self.vocab, size=b, p=probs)
        noise = rng.random((b, self.seq_len))
        fresh = rng.choice(self.vocab, size=(b, self.seq_len), p=probs)
        a, c = self.a
        for t in range(1, self.seq_len + 1):
            follow = (toks[:, t - 1] * a + c) % self.vocab
            toks[:, t] = np.where(noise[:, t - 1] < 0.7, follow, fresh[:, t - 1])
        self.step += 1
        return {"tokens": toks}

    def __iter__(self):
        return self
