"""End-to-end driver: train the SCN U-Net on synthetic labelled scenes.

The paper's workload (3D semantic segmentation) learning on the sparse-conv
stack; scene metadata is built once per scene as an engine ScenePlan and
reused by every step. Run:
    PYTHONPATH=src python examples/train_scn.py [--steps 300] [--res 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.models.scn import UNetConfig, init_unet, miou, segmentation_loss
from repro.sparse.tensor import SparseVoxelTensor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--res", type=int, default=32)
    ap.add_argument("--cap", type=int, default=4096)
    ap.add_argument("--scenes", type=int, default=8)
    args = ap.parse_args()

    cfg = UNetConfig(widths=(16, 32, 48), reps=1, resolution=args.res,
                     capacity=args.cap, n_classes=N_CLASSES)
    # pre-build a small dataset of scenes + plans (AdMAC pass per scene)
    data = []
    for s in range(args.scenes):
        coords, feats, labels, mask = make_scene(s, args.res, args.cap)
        t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                              jnp.asarray(mask))
        plan = engine.build_scene_plan(t, cfg, plan_tiles=False)
        data.append((t, plan, jnp.asarray(labels)))
    params = init_unet(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, feats, plan, labels, mask):
        return segmentation_loss(engine.apply_unet(p, feats, plan),
                                 labels, mask)

    grads = [jax.jit(jax.value_and_grad(
        lambda p, f, lbl, pl=plan: loss_fn(p, f, pl, lbl, pl.levels[0].mask),
        has_aux=True)) for _, plan, _ in data]

    lr = 0.3
    t0 = time.time()
    for step in range(args.steps):
        t, plan, labels = data[step % len(data)]
        (loss, acc), g = grads[step % len(data)](params, t.feats, labels)
        params = jax.tree.map(lambda p, gr: p - lr * gr, params, g)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):.4f} acc {float(acc):.3f} "
                  f"({time.time() - t0:.0f}s)")

    # held-out scene
    coords, feats, labels, mask = make_scene(999, args.res, args.cap)
    t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                          jnp.asarray(mask))
    plan = engine.build_scene_plan(t, cfg, plan_tiles=False)
    pred = np.asarray(jnp.argmax(engine.apply_unet(params, t.feats, plan), -1))
    m = miou(pred, labels, mask, N_CLASSES)
    print(f"held-out mIoU: {m:.3f}")


if __name__ == "__main__":
    main()
