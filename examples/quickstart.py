"""Quickstart: the AccSS3D pipeline on one synthetic scene.

pointcloud -> voxelize -> AdMAC adjacency -> SOAR reorder -> COIR metadata
-> SPADE dataflow plan -> engine dispatch (reference einsum vs SSpNNA
Pallas kernel, one ``sparse_conv`` entry point).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import soar, spade
from repro.core.hashgrid import build_neighbor_table, kernel_offsets
from repro.core.sparse_conv import init_sparse_conv, submanifold_coir
from repro.data.scenes import make_scene
from repro.sparse.tensor import SparseVoxelTensor

RES, CAP = 48, 16384

coords, feats, labels, mask = make_scene(0, RES, CAP)
t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats), jnp.asarray(mask))
print(f"scene: {int(t.n_active())} active voxels "
      f"({int(t.n_active()) / RES**3:.1%} occupancy — spatial sparsity)")

# AdMAC: adjacency + COIR metadata
coir = submanifold_coir(t, RES, 3)
print(f"COIR: ARF = {float(coir.arf()):.2f} active neighbours / voxel (of 27)")

# SOAR reordering
nbr = np.asarray(build_neighbor_table(
    t.coords, t.mask, jnp.asarray(kernel_offsets(3)), RES))
order = soar.soar_order(nbr, np.asarray(t.mask), 512)
print(f"SOAR: {order.n_chunks} chunks")

# SPADE dataflow plan (64 KB L1 budget, like the paper)
attrs = spade.extract_attributes(np.asarray(coir.indices), np.asarray(t.mask),
                                 order.order)
layer = spade.LayerSpec("demo", int(t.n_active()), int(t.n_active()),
                        27, 4, 32, 2)
plan_df = spade.explore(layer, {"CIRF": attrs, "CORF": attrs}, 64 * 1024)
print(f"SPADE: walk={plan_df.walk} flavor={plan_df.flavor} "
      f"tile dO={plan_df.delta_major} -> {plan_df.da_elems:.2e} data accesses")

# Engine: one ConvPlan, two backends through the same entry point
d_i = int(plan_df.delta_major * attrs.at(plan_df.delta_major,
                                         "sa_minor_alloc_rst")) + 27
conv_plan = engine.conv_plan_for_layer(coir, order.order,
                                       plan_df.delta_major, d_i,
                                       walk=plan_df.walk)
params = init_sparse_conv(jax.random.PRNGKey(0), 27, 4, 32)
out = engine.sparse_conv(t.feats, params, conv_plan, backend="sspnna",
                         use_kernel=True)
ref = engine.sparse_conv(t.feats, params, conv_plan, backend="reference")
err = float(jnp.max(jnp.abs(out[np.asarray(t.mask)] - ref[np.asarray(t.mask)])))
print(f"SSpNNA kernel over {conv_plan.dispatch.n_tiles} tiles: "
      f"max |err| vs reference = {err:.2e}")
print("OK")
