"""Batched 3D-segmentation serving with SPADE-planned dataflow.

Serves a stream of pointcloud "requests": per request, run the AdMAC
metadata pass, OTF-SPADE dataflow lookup (offline table, §V-C), and the
U-Net forward — the paper's end-to-end inference flow.

Run:  PYTHONPATH=src python examples/segment_scene.py [--requests 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spade
from repro.core.sparse_conv import submanifold_coir
from repro.data.scenes import N_CLASSES, make_scene
from repro.models.scn import UNetConfig, apply_unet, build_unet_metadata, init_unet
from repro.sparse.tensor import SparseVoxelTensor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--res", type=int, default=32)
    ap.add_argument("--cap", type=int, default=4096)
    args = ap.parse_args()

    cfg = UNetConfig(widths=(16, 32, 48), reps=1, resolution=args.res,
                     capacity=args.cap, n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)

    # offline-SPADE: precompute the dataflow table once (ARF-binned)
    coords, feats, labels, mask = make_scene(123, args.res, args.cap)
    rep = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                            jnp.asarray(mask))
    coir = submanifold_coir(rep, args.res, 3)
    attrs = spade.extract_attributes(np.asarray(coir.indices), np.asarray(mask))
    msa = spade.meta_attributes([attrs])
    layer = spade.LayerSpec("serve", args.cap, args.cap, 27,
                            cfg.widths[0], cfg.widths[0], 2)
    table = spade.build_offline_table([layer], msa, 64 * 1024)
    print("offline-SPADE table ready")

    for rid in range(args.requests):
        t_req = time.time()
        coords, feats, labels, mask = make_scene(1000 + rid, args.res, args.cap)
        t = SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                              jnp.asarray(mask))
        meta = build_unet_metadata(t, cfg)         # AdMAC (on-the-fly)
        arf = float(meta[0].sub_coir.arf())
        plan = spade.otf_lookup(table, layer, arf)  # OTF-SPADE: table lookup
        logits = apply_unet(params, t.feats, meta)
        pred = np.asarray(jnp.argmax(logits, -1))
        n = int(mask.sum())
        print(f"req {rid}: {n} voxels, ARF={arf:.1f}, "
              f"plan(dO={plan.delta_major},{plan.walk},{plan.flavor}), "
              f"classes={np.bincount(pred[mask], minlength=N_CLASSES).tolist()} "
              f"({time.time() - t_req:.1f}s)")


if __name__ == "__main__":
    main()
