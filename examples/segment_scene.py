"""Batched 3D-segmentation serving through ``repro.engine``.

The paper's end-to-end inference flow as a serving loop: representative
scenes pin the SPADE dataflow decisions once (offline-SPADE, §V-C), then
``serving.scene_engine.SceneEngine`` serves waves of pointcloud requests —
per scene one cached AdMAC/SOAR plan build, one shared jit compilation for
every wave.

Run:  PYTHONPATH=src python examples/segment_scene.py [--requests 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.models.scn import UNetConfig, init_unet
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.sparse.tensor import SparseVoxelTensor


def load_scene(seed, res, cap):
    coords, feats, labels, mask = make_scene(seed, res, cap)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--res", type=int, default=32)
    ap.add_argument("--cap", type=int, default=4096)
    args = ap.parse_args()

    cfg = UNetConfig(widths=(16, 32, 48), reps=1, resolution=args.res,
                     capacity=args.cap, n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)

    # offline-SPADE: pin the per-level dataflow from representative scenes
    t0 = time.time()
    reps = [load_scene(123 + i, args.res, args.cap) for i in range(2)]
    spec = engine.build_plan_spec(reps, cfg, mem_budget=64 * 1024)
    for li, d in enumerate(spec.levels):
        print(f"spec level{li}: {d.backend} walk={d.walk} "
              f"dO={d.delta_o} dI={d.delta_i} tiles={d.n_tiles}")
    print(f"plan spec pinned in {time.time() - t0:.1f}s")

    eng = SceneEngine(cfg, params, batch=args.batch, spec=spec)
    for wave_start in range(0, args.requests, args.batch):
        t_wave = time.time()
        reqs = [SceneRequest(rid, load_scene(1000 + rid, args.res, args.cap))
                for rid in range(wave_start,
                                 min(wave_start + args.batch, args.requests))]
        eng.submit(reqs)
        eng.run()
        for r in reqs:
            n = int(np.asarray(r.scene.mask).sum())
            hist = np.bincount(r.pred[np.asarray(r.scene.mask)],
                               minlength=N_CLASSES)
            print(f"req {r.rid}: {n} voxels, classes={hist.tolist()}")
        print(f"wave done in {time.time() - t_wave:.1f}s "
              f"(compilations={eng.n_compilations}, "
              f"plan cache {eng.cache.hits} hits / {eng.cache.misses} misses)")


if __name__ == "__main__":
    main()
