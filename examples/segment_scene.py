"""Batched 3D-segmentation serving through ``repro.engine``.

The paper's end-to-end inference flow as a serving loop: representative
scenes pin the SPADE dataflow decisions once (offline-SPADE, §V-C), then
``serving.scene_engine.SceneEngine`` serves waves of pointcloud requests —
per scene one cached AdMAC/SOAR plan build, one shared jit compilation for
every wave. By default the engine runs its async pipeline (plan builds for
wave k+1 overlap device execution of wave k) and prints the per-stage
timings; ``--sync`` falls back to the blocking wave loop for comparison.

``--shards N`` serves each scene mesh-sharded instead: the capacity axis
splits over an N-way mesh axis, per-shard plans (local COIR + halo send
tables) build on the planner threads, and every conv exchanges only its
halo rows (run under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
to get a real multi-device mesh on CPU; without enough devices the same
program runs serially on one device — bitwise identical either way).

Run:  PYTHONPATH=src python examples/segment_scene.py [--requests 8] [--sync]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.dist.compat import make_mesh
from repro.models.scn import UNetConfig, init_unet
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.sparse.tensor import SparseVoxelTensor


def load_scene(seed, res, cap):
    coords, feats, labels, mask = make_scene(seed, res, cap)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--res", type=int, default=32)
    ap.add_argument("--cap", type=int, default=4096)
    ap.add_argument("--sync", action="store_true",
                    help="serve with the blocking wave loop instead of the "
                         "async plan/dispatch/drain pipeline")
    ap.add_argument("--planner-threads", type=int, default=1)
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--shards", type=int, default=0,
                    help="serve mesh-sharded scenes over this many shards "
                         "(0 = unsharded batched serving)")
    args = ap.parse_args()

    cfg = UNetConfig(widths=(16, 32, 48), reps=1, resolution=args.res,
                     capacity=args.cap, n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)

    t0 = time.time()
    reps = [load_scene(123 + i, args.res, args.cap) for i in range(2)]
    if args.shards:
        # pin the halo budget from representative scenes (one jit signature)
        layout = engine.pin_halo(
            reps, cfg, engine.ShardLayout(n_shards=args.shards))
        mesh = None
        if len(jax.devices()) >= args.shards:
            mesh = make_mesh((args.shards,), ("shard",),
                             devices=jax.devices()[:args.shards])
        ctx = engine.ExecutionContext(mesh=mesh)
        print(f"sharded layout: {layout} on "
              f"{'mesh' if mesh is not None else 'one device (serial)'}; "
              f"halo budget pinned in {time.time() - t0:.1f}s")
        eng = SceneEngine(cfg, params, batch=args.batch, ctx=ctx,
                          layout=layout, sync=args.sync, depth=args.depth,
                          planner_threads=args.planner_threads)
    else:
        # offline-SPADE: pin the per-level dataflow from representative
        # scenes
        spec = engine.build_plan_spec(reps, cfg, mem_budget=64 * 1024)
        for li, d in enumerate(spec.levels):
            print(f"spec level{li}: {d.backend} walk={d.walk} "
                  f"dO={d.delta_o} dI={d.delta_i} tiles={d.n_tiles}")
        print(f"plan spec pinned in {time.time() - t0:.1f}s")
        eng = SceneEngine(cfg, params, batch=args.batch, spec=spec,
                          sync=args.sync, depth=args.depth,
                          planner_threads=args.planner_threads)
    t_serve = time.time()
    reqs = [SceneRequest(rid, load_scene(1000 + rid, args.res, args.cap))
            for rid in range(args.requests)]
    handles = eng.submit(reqs)
    eng.serve()
    for h in handles:
        r = h.result()
        n = int(np.asarray(r.scene.mask).sum())
        hist = np.bincount(r.pred[np.asarray(r.scene.mask)],
                           minlength=N_CLASSES)
        print(f"req {r.rid}: {n} voxels, classes={hist.tolist()}")
    tm = eng.timings()
    mode = "sync" if args.sync else "async"
    print(f"{mode} serve of {args.requests} reqs in "
          f"{time.time() - t_serve:.1f}s over {tm['waves']} waves "
          f"(compilations={eng.n_compilations}, "
          f"plan cache {eng.cache.hits} hits / {eng.cache.misses} misses)")
    print(f"pipeline: plan={tm['plan_ms']:.0f}ms "
          f"(waited {tm['plan_wait_ms']:.0f}ms) "
          f"device={tm['device_ms']:.0f}ms drain={tm['drain_ms']:.0f}ms "
          f"overlap_frac={tm['overlap_frac']:.2f}")
    if args.shards:
        halo = sum(st.notes.get("halo_rows", 0) for st in eng.wave_stats)
        print(f"sharded: {args.shards}-way, "
              f"{halo} halo rows exchanged across all waves")


if __name__ == "__main__":
    main()
