"""Train a ~100M-param LM (scaled stablelm family) on the synthetic stream.

Run:  PYTHONPATH=src python examples/lm_train.py [--steps 200]
(defaults sized to finish on a CPU host; --full bumps to ~100M params)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.training import checkpoint
from repro.training.optimizer import OptHParams
from repro.training.train_loop import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (slower on CPU)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    base = get_config("stablelm-1.6b")
    if args.full:  # ~100M params
        cfg = dataclasses.replace(
            base, n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
            head_dim=64, d_ff=1408, vocab_size=64000, tie_embeddings=False,
            dtype="float32", remat=False)
        batch, seq = 4, 128
    else:
        cfg = dataclasses.replace(
            base.reduced(), n_layers=4, d_model=256, d_ff=512,
            vocab_size=2048)
        batch, seq = 8, 128
    n_params = cfg.param_count()
    print(f"config: {cfg.n_layers}L d={cfg.d_model} ~{n_params/1e6:.0f}M params")

    hp = OptHParams(lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, hp)
    step_fn = jax.jit(make_train_step(cfg, hp, n_microbatches=2))
    ds = TokenStream(cfg.vocab_size, batch, seq, seed=0)
    t0 = time.time()
    for i in range(args.steps):
        batch_data = {k: jnp.asarray(v) for k, v in next(ds).items()}
        state, metrics = step_fn(state, batch_data)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({time.time() - t0:.0f}s)")
        if args.ckpt and i and i % 100 == 0:
            checkpoint.save_async(state, args.ckpt, i, data_state=ds.state())
    if args.ckpt:
        checkpoint.wait_for_saves()
        print("checkpoints:", checkpoint.latest_step(args.ckpt))


if __name__ == "__main__":
    main()
