"""Serve a synthetic LiDAR sweep through the streaming scene engine.

Opens a stream on a ``SceneEngine``, feeds it an ego-motion sweep from
``make_lidar_sweep``, and prints per-frame plan-reuse stats: after the
first frame's full build, each frame's host plan is *patched* from the
previous one (delta-based incremental planning), falling back to a full
rebuild only under heavy churn.

Run:  PYTHONPATH=src python examples/stream_scene.py [--frames 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data.scenes import N_CLASSES, make_lidar_sweep
from repro.models.scn import UNetConfig, init_unet
from repro.serving.scene_engine import SceneEngine
from repro.sparse.tensor import SparseVoxelTensor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=8)
    ap.add_argument("--resolution", type=int, default=48)
    ap.add_argument("--capacity", type=int, default=4096)
    ap.add_argument("--step", type=int, default=4,
                    help="ego translation (voxels) per frame along x")
    ap.add_argument("--churn", type=float, default=0.05,
                    help="fraction of voxels appearing/disappearing per frame")
    ap.add_argument("--sync", action="store_true",
                    help="blocking waves instead of the async pipeline")
    args = ap.parse_args()

    cfg = UNetConfig(widths=(16, 32, 32), reps=1, resolution=args.resolution,
                     capacity=args.capacity, n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    eng = SceneEngine(cfg, params, batch=2, sync=args.sync,
                      depth=2, planner_threads=1)

    frames, shifts = make_lidar_sweep(
        0, args.frames, resolution=args.resolution, capacity=args.capacity,
        step=args.step, churn=args.churn)
    scenes = [SparseVoxelTensor(jnp.asarray(c), jnp.asarray(f),
                                jnp.asarray(m)) for c, f, _, m in frames]

    stream = eng.open_stream(stream_id="lidar0")
    t0 = time.time()
    reqs = eng.serve_stream(scenes, shifts, stream=stream)
    wall = time.time() - t0

    print("frame  mode     overlap  plan_ms  active")
    for r in reqs:
        info = r.plan_info
        n_act = int(jnp.sum(r.scene.mask))
        print(f"{r.frame_no:>5}  {info['mode']:<8} {info['overlap']:>6.3f}"
              f"  {info['plan_ms']:>7.2f}  {n_act:>6}")
    agg = stream.stats()
    print(f"\n{agg['frames']} frames in {wall:.2f}s | "
          f"patched={agg['patched']} rebuilt={agg['rebuilt']} "
          f"reused={agg['reused']} | mean overlap {agg['mean_overlap']:.3f} "
          f"| mean host plan {agg['mean_plan_ms']:.2f} ms")
    notes = [w.notes for w in eng.wave_stats if w.notes]
    if notes:
        print(f"last wave notes: {notes[-1]}")


if __name__ == "__main__":
    main()
