"""Goodput under injected dispatch faults: graceful vs cliff degradation.

Measures what the fault-tolerant runtime is *for*: as the injected
dispatch fault rate rises, a contained engine (retry budget + bisection +
backoff) should lose goodput roughly in proportion to the retry work —
never fall off a cliff, never lose a request. One arm per fault rate
serves the same scene traffic through a hardened ``SceneEngine``
(``AdmissionPolicy(max_retries=2)``) with a seeded
``FaultPlan(dispatch @ rate)``; rows report goodput, p50/p99 latency,
terminal failures and retries charged. The final row asserts the
non-cliff property: ``goodput(rate) >= goodput(0) * (1 - 8 * rate)``.

Standalone CLI (what the CI chaos job runs):

    python -m benchmarks.bench_faults --quick --json BENCH_faults.json
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, standalone_bench_main
from repro.data.scenes import N_CLASSES, make_scene
from repro.models.scn import UNetConfig, init_unet
from repro.serving import (
    AdmissionPolicy,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    RequestShedError,
)
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.sparse.tensor import SparseVoxelTensor

RES, CAP = 16, 1024


def _scene(seed):
    coords, feats, _, mask = make_scene(seed, resolution=RES, capacity=CAP)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


def _serve_arm(cfg, params, scenes, n_requests, rate):
    faults = None
    if rate > 0.0:
        faults = FaultInjector(FaultPlan(seed=7, specs=(
            FaultSpec("dispatch", rate=rate),)))
    eng = SceneEngine(cfg, params, batch=2, sync=True, faults=faults,
                      policy=AdmissionPolicy(max_retries=2,
                                             retry_backoff_ms=1.0))
    handles = [eng.submit(SceneRequest(i, scenes[i % len(scenes)]))
               for i in range(n_requests)]
    eng.serve()
    # conservation is part of the product contract, so the bench enforces
    # it too: every request ends completed or failed, none lost
    n_done = n_failed = 0
    for h in handles:
        try:
            h.result()
            n_done += 1
        except RequestShedError:  # also catches RequestFailedError
            n_failed += 1
    assert n_done + n_failed == n_requests, "requests lost under faults"
    slo = eng.slo_stats()
    assert slo["n_completed"] == n_done and slo["n_failed"] == n_failed
    eng.close()
    return slo


def run(quick: bool = False):
    rates = (0.0, 0.05) if quick else (0.0, 0.01, 0.05, 0.10)
    n_requests = 80 if quick else 240
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    scenes = [_scene(100 + i) for i in range(6)]  # cycled: plan-cache hits

    # warm the jit signature outside the timed arms
    warm = SceneEngine(cfg, params, batch=2, sync=True)
    warm.submit([SceneRequest(i, scenes[i]) for i in range(2)])
    warm.serve()
    warm.close()

    results = {}
    for rate in rates:
        slo = _serve_arm(cfg, params, scenes, n_requests, rate)
        results[rate] = slo
        emit(f"faults/goodput@{rate:.2f}", slo["p99_ms"] * 1e3,
             f"goodput={slo['goodput_frac']:.3f} "
             f"p50={slo['p50_ms']:.1f}ms p99={slo['p99_ms']:.1f}ms "
             f"completed={slo['n_completed']}/{n_requests} "
             f"failed={slo['n_failed']} retries={slo['n_retries']} "
             f"wave_errors={slo['wave_errors']}")

    base = results[0.0]["goodput_frac"]
    worst_margin = 1.0
    for rate in rates[1:]:
        floor = base * (1.0 - 8.0 * rate)
        got = results[rate]["goodput_frac"]
        assert got >= floor, (
            f"cliff at rate {rate}: goodput {got:.3f} < floor {floor:.3f}")
        worst_margin = min(worst_margin, got - floor)
    top = rates[-1]
    emit("faults/degradation", 0.0,
         f"goodput {base:.3f} -> {results[top]['goodput_frac']:.3f} at "
         f"{top:.0%} dispatch faults (non-cliff floor held, worst margin "
         f"{worst_margin:.3f}); p99 {results[0.0]['p99_ms']:.1f}ms -> "
         f"{results[top]['p99_ms']:.1f}ms")


def main(argv=None) -> None:
    standalone_bench_main(run, "bench_faults",
                          "2 fault rates / 80 requests (the CI chaos job)",
                          description=__doc__, argv=argv)


if __name__ == "__main__":
    main()
