"""Fused vs pre-gathered vs XLA gather-einsum SSpNNA paths (§V-A).

Three executions of the same tiled sparse conv, at serving-engine shapes
(budgeted tile stacks padded the way ``build_plan_spec``'s ``tile_margin``
pads them, so the fused kernel's dead-tile skip sees realistic waste):

* **fused** — ``run_sspnna_conv`` with ``pair_counts``: global features
  straight into the Pallas kernel, scalar-prefetched DMA tables gather each
  tile's working set on-chip, outputs DMA'd to their global rows. No
  ``(T, dI, C)`` HBM intermediate, dead tiles skipped.
* **pregathered** — the tile-stack kernel behind an XLA dynamic-gather that
  materializes the full working-set copy in HBM, plus the ``.at[].add``
  scatter back (the pre-PR path).
* **xla** — gather + the jnp oracle einsum + scatter (no Pallas at all):
  what plain XLA makes of the same metadata.

Each row reports measured wall time next to the *modeled* HBM feature
traffic from ``core.tiles.modeled_hbm_bytes`` (driven by the
``plan_dma_tables`` entry counts), so the measured speedup can be read
against the paper's bandwidth argument. All three paths are asserted
allclose before timing.

Standalone CLI (what the CI smoke job runs):

    python -m benchmarks.bench_sspnna --quick --json BENCH_sspnna.json
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    build_scene,
    emit,
    scene_metadata,
    standalone_bench_main,
    time_fn,
)
from repro.core.tiles import build_tile_plan, dma_tile_tables, modeled_hbm_bytes
from repro.kernels.sspnna.ops import run_sspnna_conv

K_SUB = 27
TILE_MARGIN = 2.0  # mirror build_plan_spec's default serving padding


def _sweep_cases(quick: bool):
    # (name, resolution, capacity, C, N, delta_o, delta_i)
    if quick:
        return [("r24_c16", 24, 2048, 16, 16, 32, 128)]
    return [
        ("r24_c16", 24, 2048, 16, 16, 32, 128),
        ("r32_c16", 32, 4096, 16, 16, 64, 192),
        ("r48_c32", 48, 16384, 32, 32, 64, 192),
    ]


def _bench_case(name, res, cap, c, n, d_o, d_i, iters):
    t, _ = build_scene(seed=0, resolution=res, capacity=cap)
    coir, _, order = scene_metadata(t, res)
    n_active = int(np.asarray(t.mask).sum())
    density = n_active / res**3

    # budgeted plan padded like a pinned serving spec (dead tiles included)
    realized = build_tile_plan(np.asarray(coir.indices), order.order, d_o, d_i)
    n_tiles = int(math.ceil(TILE_MARGIN * realized.n_tiles)) + 2
    tp = build_tile_plan(np.asarray(coir.indices), order.order, d_o, d_i,
                         n_tiles=n_tiles)
    dma = dma_tile_tables(tp, cap)
    alive = int((tp.pair_counts > 0).sum())

    rng = np.random.default_rng(1)
    feats = jnp.asarray(rng.normal(size=(cap, c)), jnp.float32)
    weights = jnp.asarray(rng.normal(size=(K_SUB, c, n)) * 0.1, jnp.float32)
    out_rows = jnp.asarray(dma.out_rows)
    in_rows = jnp.asarray(dma.in_rows)
    local_idx = jnp.asarray(tp.local_idx)
    counts = jnp.asarray(dma.pair_counts)

    def fused():
        return run_sspnna_conv(feats, weights, out_rows, in_rows, local_idx,
                               n_out=cap, pair_counts=counts, use_kernel=True)

    def pregathered():
        return run_sspnna_conv(feats, weights, out_rows, in_rows, local_idx,
                               n_out=cap, use_kernel=True, fused=False)

    def xla():
        return run_sspnna_conv(feats, weights, out_rows, in_rows, local_idx,
                               n_out=cap, use_kernel=False, fused=False)

    base = np.asarray(xla())
    for arm, f in (("fused", fused), ("pregathered", pregathered)):
        np.testing.assert_allclose(np.asarray(f()), base, rtol=1e-4,
                                   atol=1e-4, err_msg=f"{name}/{arm}")

    model = modeled_hbm_bytes(tp, c, n)
    # best-of-reps per arm: the CI host is shared, min filters load spikes
    times = {arm: time_fn(f, iters=iters, reps=3)
             for arm, f in (("fused", fused), ("pregathered", pregathered),
                            ("xla", xla))}
    geom = (f"density={density:.4f} T={tp.n_tiles} alive={alive} "
            f"dO={d_o} dI={d_i} C={c} N={n}")
    for arm in ("fused", "pregathered", "xla"):
        key = arm if arm != "xla" else "reference_gather"
        emit(f"sspnna/{name}_{arm}", times[arm],
             f"{geom} modeled_hbm_mb={model[key] / 1e6:.2f}")
    speedup = times["pregathered"] / max(times["fused"], 1e-9)
    emit(f"sspnna/{name}_fused_speedup", 0.0,
         f"fused_vs_pregathered={speedup:.2f}x "
         f"fused_vs_xla={times['xla'] / max(times['fused'], 1e-9):.2f}x "
         f"modeled_traffic_ratio="
         f"{model['pregathered'] / max(model['fused'], 1):.2f}x")
    return speedup


def run(quick: bool = False):
    iters = 3 if quick else 5
    speedups = [
        _bench_case(name, res, cap, c, n, d_o, d_i, iters)
        for name, res, cap, c, n, d_o, d_i in _sweep_cases(quick)
    ]
    emit("sspnna/fused_speedup_min", 0.0,
         f"min_fused_vs_pregathered={min(speedups):.2f}x "
         f"across {len(speedups)} scene shapes")


def main(argv=None) -> None:
    standalone_bench_main(run, "bench_sspnna",
                          "single small scene (the CI smoke job)",
                          description=__doc__, argv=argv)


if __name__ == "__main__":
    main()
