"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is 0.0 for
analytical/model benchmarks; see each module's docstring for the mapping to
the paper's tables and what is measured vs modeled).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        bench_coir,
        bench_dataflow,
        bench_dispatch,
        bench_lm,
        bench_moe,
        bench_scn,
        bench_soar,
        bench_spade_attrs,
    )

    print("name,us_per_call,derived")
    t0 = time.time()
    for mod in (bench_dispatch, bench_coir, bench_soar, bench_spade_attrs,
                bench_dataflow, bench_scn, bench_moe, bench_lm):
        mt = time.time()
        mod.run()
        print(f"# {mod.__name__} done in {time.time() - mt:.1f}s",
              file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
