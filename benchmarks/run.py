"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is 0.0 for
analytical/model benchmarks; see each module's docstring for the mapping to
the paper's tables and what is measured vs modeled).

``--quick`` runs the subset CI uses as a non-blocking smoke (fast modules
only) so perf scripts cannot silently rot; ``--only`` picks modules by name;
``--json PATH`` additionally writes the rows as a JSON artifact (the CI
smoke job uploads it so the perf trajectory accumulates across commits).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

# modules cheap enough for the CI smoke job (reduced configs, small scenes).
# bench_serving, bench_admission, bench_sspnna, bench_sharded_scene,
# bench_streaming, bench_dispatch and bench_faults are smoked separately
# (their own --quick CLIs write BENCH_serving.json / BENCH_admission.json /
# BENCH_sspnna.json / BENCH_sharded_scene.json / BENCH_streaming.json /
# BENCH_dispatch.json / BENCH_faults.json — the last in the chaos job) so
# they aren't duplicated here.
QUICK = ("bench_soar", "bench_spade_attrs", "bench_moe", "bench_dataflow")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fast subset (the CI smoke job)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names, e.g. bench_coir")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact (CI perf log)")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_admission,
        bench_coir,
        bench_dataflow,
        bench_dispatch,
        bench_faults,
        bench_lm,
        bench_moe,
        bench_scn,
        bench_serving,
        bench_sharded_scene,
        bench_soar,
        bench_spade_attrs,
        bench_sspnna,
        bench_streaming,
    )

    modules = [bench_dispatch, bench_coir, bench_soar, bench_spade_attrs,
               bench_dataflow, bench_sspnna, bench_scn, bench_serving,
               bench_admission, bench_sharded_scene, bench_streaming,
               bench_faults, bench_moe, bench_lm]
    if args.only:
        wanted = {m.strip() for m in args.only.split(",")}
        known = {m.__name__.split(".")[-1] for m in modules}
        unknown = wanted - known
        if unknown:
            ap.error(f"unknown modules {sorted(unknown)}; "
                     f"known: {sorted(known)}")
        modules = [m for m in modules if m.__name__.split(".")[-1] in wanted]
    elif args.quick:
        modules = [m for m in modules if m.__name__.split(".")[-1] in QUICK]

    print("name,us_per_call,derived")
    t0 = time.time()
    for mod in modules:
        mt = time.time()
        mod.run()
        print(f"# {mod.__name__} done in {time.time() - mt:.1f}s",
              file=sys.stderr)
    total_s = time.time() - t0
    print(f"# total {total_s:.1f}s", file=sys.stderr)

    if args.json:
        from benchmarks.common import ROWS
        payload = {
            "schema": "bench-rows/v1",
            "unix_time": int(t0),
            "total_seconds": round(total_s, 2),
            "modules": [m.__name__.split(".")[-1] for m in modules],
            "rows": [{"name": n, "us_per_call": u, "derived": d}
                     for n, u, d in ROWS],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload['rows'])} rows to {args.json}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
