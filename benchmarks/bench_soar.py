"""Fig 23: SOAR data-access savings vs raster scan orders (x/y/z major)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_scene, emit, scene_metadata
from repro.core import soar


def run():
    t, _ = build_scene(2, 48, 16384)
    coir, nbr, order = scene_metadata(t, 48)
    idx = np.asarray(coir.indices)
    mask = np.asarray(t.mask)
    coords = np.asarray(t.coords)
    a_soar = soar.tiled_unique_input_accesses(order.order, idx, 256)
    for axes, name in [((0, 1, 2), "x-major"), ((1, 2, 0), "y-major"),
                       ((2, 0, 1), "z-major")]:
        rast = soar.raster_order(coords, mask, axes)
        a_r = soar.tiled_unique_input_accesses(rast, idx, 256)
        emit(f"fig23/soar_vs_{name}", 0.0, f"{a_r / a_soar:.3f}x fewer fetches")
    # hierarchical SOAR (CAROM §V-B extension)
    h = soar.soar_hierarchical(nbr, mask, [128, 2048])
    a_h = soar.tiled_unique_input_accesses(h.order, idx, 256)
    emit("fig23/hierarchical_vs_flat", 0.0, f"{a_soar / a_h:.3f}x")
