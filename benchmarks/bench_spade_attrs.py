"""Fig 15: sparsity attributes across pointclouds + surface-ratio fit."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_scene, emit, scene_metadata
from repro.core import spade


def run():
    attrs_all = []
    for seed in range(3):
        t, _ = build_scene(seed + 10, 48, 16384)
        coir, nbr, order = scene_metadata(t, 48)
        attrs = spade.extract_attributes(
            np.asarray(coir.indices), np.asarray(t.mask), order.order)
        attrs_all.append(attrs)
        alpha, corr = spade.fit_surface_ratio(attrs)
        emit(f"fig15/cloud{seed}/surface_fit", 0.0,
             f"alpha={alpha:.2f} corr={corr:.3f} "
             f"ARF={attrs.arf_avg.mean():.2f} (+/-{attrs.arf_avg.std():.3f})")
    msa = spade.meta_attributes(attrs_all)
    emit("fig15/msa_sa_i", 0.0,
         " ".join(f"{d}:{v:.2f}" for d, v in
                  zip(msa.delta_majors, msa.sa_minor_avg)))
