"""Sharded-scene serving: wall-clock + halo traffic vs the unsharded path.

Three arms over the same scene and parameters:

* ``ref_unsharded`` — the engine's reference einsum U-Net on one device;
* ``serial_SN``     — the deterministic sharded program on one device
  (``vmap(axis_name=...)``), the bitwise oracle for the mesh arm;
* ``mesh_SN``       — the same program ``shard_map``-ed over an N-way mesh
  axis with real halo-exchange/all-gather collectives (runs when the host
  exposes >= N devices, e.g. under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``; the CI smoke job
  sets exactly that).

The interesting number on CPU hosts is not wall-clock (virtual devices
share the same cores; the sharded arms also pay the deterministic
plane-accumulated contraction) but the *wire traffic model* in the derived
column: ``halo_kb`` is what the plan's send tables actually exchange per
forward (plus the chunked BN partial gathers), ``dense_kb`` what a naive
replicated all-gather of every conv input would move. The bitwise
serial==mesh assertion runs whenever both arms do.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro import engine
from repro.dist.compat import make_mesh
from repro.models.scn import UNetConfig, init_unet


def _conv_input_widths(cfg) -> list[dict]:
    """Per level: channel width of each use of the three conv sites."""
    w, reps, n = cfg.widths, cfg.reps, len(cfg.widths)
    out = []
    for li in range(n):
        sub = [w[li]] * reps                       # encoder blocks
        if li == 0:
            sub = [cfg.in_channels] + sub          # stem shares level-0 sub
        down = up = []
        if li < n - 1:
            down = [w[li]]
            up = [w[li + 1]]
            sub = sub + [2 * w[li]] + [w[li]] * (reps - 1)  # decoder blocks
        out.append({"sub": sub, "down": down, "up": up})
    return out


def _traffic_model(plan: engine.ShardedScenePlan, cfg, capacity: int):
    """(halo_bytes, bn_bytes, dense_bytes) one sharded forward moves,
    summed across shards. ``halo_bytes`` counts the *padded* all_to_all
    payload (S x S pair slots x the per-pair budget H) — what actually
    crosses the wire — not just the real halo rows."""
    widths = _conv_input_widths(cfg)
    halo = dense = bn = 0
    S = plan.layout.n_shards
    chunk = plan.layout.bn_chunk
    for lvl_stats, use in zip(plan.stats, widths):
        for site, budget in lvl_stats["halo_budget"].items():
            for c in use[site]:
                halo += S * S * budget * c * 4
                dense += (S - 1) * (capacity // S) * S * c * 4
    # chunked BN partial gathers: 2 per conv block (mean+count, then var)
    for li in range(len(cfg.widths)):
        per_gather = (capacity // chunk) * (cfg.widths[li] + 1) * 4
        bn += 2 * per_gather * cfg.reps  # enc blocks at this level
        if li < len(cfg.widths) - 1:
            bn += 2 * per_gather * cfg.reps  # dec blocks
    return halo, bn, dense


def run(quick: bool = False):
    res, cap = (24, 2048) if quick else (32, 8192)
    n_shards = 4
    cfg = UNetConfig(widths=(16, 32), reps=1, resolution=res, capacity=cap,
                     n_classes=5)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    t, _ = common.build_scene(0, res, cap)

    plan_ref = engine.build_scene_plan(t, cfg, plan_tiles=False)
    layout = engine.ShardLayout(n_shards=n_shards)
    splan = engine.build_sharded_scene_plan(t, cfg, layout=layout)
    halo_b, bn_b, dense_b = _traffic_model(splan, cfg, cap)
    traffic = (f"halo_kb={halo_b / 1024:.0f} bn_kb={bn_b / 1024:.0f} "
               f"dense_kb={dense_b / 1024:.0f} "
               f"saved={1 - (halo_b + bn_b) / max(dense_b, 1):.0%} "
               f"halo_rows={splan.halo_rows()}")

    ref_fn = jax.jit(lambda p, f: engine.apply_unet(
        p, f, plan_ref, backend="reference"))
    us = common.time_fn(ref_fn, params, t.feats, iters=3, reps=2)
    common.emit("sharded_scene/ref_unsharded", us,
                f"V={cap} res={res}")

    serial_fn = jax.jit(lambda p, f: engine.apply_unet(p, f, splan))
    us = common.time_fn(serial_fn, params, t.feats, iters=3, reps=2)
    common.emit(f"sharded_scene/serial_S{n_shards}", us, traffic)

    if len(jax.devices()) >= n_shards:
        mesh = make_mesh((n_shards,), ("shard",),
                         devices=jax.devices()[:n_shards])
        ctx = engine.ExecutionContext(mesh=mesh)
        mesh_fn = jax.jit(lambda p, f: engine.apply_unet(p, f, splan,
                                                         ctx=ctx))
        us = common.time_fn(mesh_fn, params, t.feats, iters=3, reps=2)
        # the mesh execution must be bitwise the serial oracle
        same = np.array_equal(np.asarray(mesh_fn(params, t.feats)),
                              np.asarray(serial_fn(params, t.feats)))
        assert same, "mesh sharded forward diverged from the serial oracle"
        common.emit(f"sharded_scene/mesh_S{n_shards}", us,
                    f"bitwise_vs_serial=ok {traffic}")
    else:
        common.emit(f"sharded_scene/mesh_S{n_shards}", 0.0,
                    f"skipped: {len(jax.devices())} device(s) < {n_shards} "
                    "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def main(argv=None) -> None:
    common.standalone_bench_main(
        run, "bench_sharded_scene",
        quick_help="small scene (the CI smoke job)",
        description=__doc__, argv=argv)


if __name__ == "__main__":
    main()
