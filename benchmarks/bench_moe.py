"""Technique transfer: MoE-SPADE capacity planning (RST vs SST vs fixed).

Measures, across skewed router-load distributions: dropped-token fraction
and dispatch-tensor waste for (a) fixed capacity factor 1.25, (b) SST
(max-load allocation), (c) RST at the paper's 90-quantile.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.moe_spade import (
    build_dispatch,
    expert_load_stats,
    plan_capacity,
)


def run():
    rng = np.random.default_rng(0)
    tokens, n_experts, k = 4096, 64, 2
    for skew, name in [(np.ones(n_experts), "balanced"),
                       (rng.pareto(1.5, n_experts) + 0.1, "pareto-skew")]:
        p = skew / skew.sum()
        samples = [rng.choice(n_experts, size=(tokens, k), p=p)
                   for _ in range(4)]
        loads = np.stack([expert_load_stats(s, n_experts) for s in samples])
        test = jnp.asarray(samples[-1], jnp.int32)
        for mode, cap in [
            ("fixed1.25", int(tokens * k * 1.25 / n_experts)),
            ("SST", plan_capacity(loads[:-1], n_experts, tokens, k, "SST")),
            ("RST90", plan_capacity(loads[:-1], n_experts, tokens, k, "RST")),
        ]:
            slot, table = build_dispatch(test, n_experts, cap)
            dropped = float(jnp.mean((slot < 0).astype(jnp.float32)))
            waste = 1.0 - float(jnp.sum(table >= 0)) / (n_experts * cap)
            emit(f"moe_spade/{name}/{mode}", 0.0,
                 f"cap={cap} dropped={dropped:.3f} slot_waste={waste:.3f}")
