"""Technique transfer: MoE-SPADE capacity planning (RST vs SST vs fixed).

Measures, across skewed router-load distributions: dropped-token fraction
and dispatch-tensor waste for (a) fixed capacity factor 1.25, (b) SST
(max-load allocation), (c) RST at the paper's 90-quantile.

Also compares the two ``apply_moe`` dispatch modes head-to-head: the
collective-free group-local gather vs the expert-major all-to-all
(``dist.collectives.expert_all_to_all``) — wall-clock and max numeric
difference, on a mesh over all local devices (the a2a degenerates to the
identity on one device).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.moe_spade import (
    build_dispatch,
    expert_load_stats,
    plan_capacity,
)
from repro.dist.compat import make_mesh
from repro.models.moe import apply_moe, init_moe, moe_capacity


def run():
    rng = np.random.default_rng(0)
    tokens, n_experts, k = 4096, 64, 2
    for skew, name in [(np.ones(n_experts), "balanced"),
                       (rng.pareto(1.5, n_experts) + 0.1, "pareto-skew")]:
        p = skew / skew.sum()
        samples = [rng.choice(n_experts, size=(tokens, k), p=p)
                   for _ in range(4)]
        loads = np.stack([expert_load_stats(s, n_experts) for s in samples])
        test = jnp.asarray(samples[-1], jnp.int32)
        for mode, cap in [
            ("fixed1.25", int(tokens * k * 1.25 / n_experts)),
            ("SST", plan_capacity(loads[:-1], n_experts, tokens, k, "SST")),
            ("RST90", plan_capacity(loads[:-1], n_experts, tokens, k, "RST")),
        ]:
            slot, table = build_dispatch(test, n_experts, cap)
            dropped = float(jnp.mean((slot < 0).astype(jnp.float32)))
            waste = 1.0 - float(jnp.sum(table >= 0)) / (n_experts * cap)
            emit(f"moe_spade/{name}/{mode}", 0.0,
                 f"cap={cap} dropped={dropped:.3f} slot_waste={waste:.3f}")

    # gather vs a2a dispatch (ROADMAP hillclimb arm)
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev,), ("model",))
    g, tg, d, topk = n_dev, 512 // n_dev, 64, 2
    e = 8 if 8 % n_dev == 0 else 8 * n_dev  # a2a splits E over the mesh
    params = init_moe(jax.random.PRNGKey(0), d, 4 * d, e, "swiglu", jnp.float32)
    x = jnp.asarray(rng.normal(size=(g, tg, d)), jnp.float32)
    cap = moe_capacity(tg, topk, e, 1.25)
    gather_fn = jax.jit(lambda p, xx: apply_moe(
        p, xx, top_k=topk, capacity=cap, act="swiglu")[0])
    a2a_fn = jax.jit(lambda p, xx: apply_moe(
        p, xx, top_k=topk, capacity=cap, act="swiglu",
        mesh=mesh, dispatch="a2a")[0])
    us_gather = time_fn(gather_fn, params, x)
    us_a2a = time_fn(a2a_fn, params, x)
    diff = float(jnp.max(jnp.abs(gather_fn(params, x) - a2a_fn(params, x))))
    emit("moe_dispatch/gather", us_gather,
         f"group-local gather, G={g} E={e} cap={cap} ndev={n_dev}")
    emit("moe_dispatch/a2a", us_a2a,
         f"{us_gather / us_a2a:.2f}x vs gather, max|diff|={diff:.1e}")
