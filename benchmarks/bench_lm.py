"""Wall-clock microbench of reduced-arch train/decode steps (CPU host)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.serving.engine import make_prefill, make_serve_step
from repro.training.optimizer import OptHParams
from repro.training.train_loop import init_train_state, make_train_step

ARCHS = ["stablelm-1.6b", "gemma2-2b", "rwkv6-7b", "moonshot-v1-16b-a3b"]


def run():
    rng = np.random.default_rng(0)
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        hp = OptHParams()
        state = init_train_state(jax.random.PRNGKey(0), cfg, hp)
        step = jax.jit(make_train_step(cfg, hp))
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 65)), jnp.int32)}
        us = time_fn(lambda s, b: step(s, b)[1]["loss"], state, batch)
        emit(f"lm/{arch}/train_step", us, "reduced cfg, b=4 s=64, CPU")
        params = state["params"]
        prefill = jax.jit(make_prefill(cfg, cache_pad=4))
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
        _, cache = prefill(params, toks)
        serve = jax.jit(make_serve_step(cfg))
        tok = jnp.zeros((2, 1), jnp.int32)
        us = time_fn(lambda p, t, c: serve(p, t, c)[0], params, tok, cache)
        emit(f"lm/{arch}/decode_step", us, "reduced cfg, b=2, CPU")
