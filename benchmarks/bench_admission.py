"""Continuous batching + SLO admission vs single-signature FIFO serving.

Measures the ROADMAP "continuous batching" item under heavy mixed traffic:
a bursty-Poisson arrival process with a diurnal rate ramp submits scenes of
mixed sizes (mostly small scans, some large) from two tenants (a low-
priority "free" flood and a weighted, deadline-carrying "paid" tenant) into
two serving arms over identical request content:

* **fifo** — the pre-redesign baseline: one pinned signature at the max
  capacity, FIFO waves, no admission policy. Every 150-voxel scan pays a
  full-capacity wave, and the burst backlog head-of-line blocks everyone.
* **bucketed** — a two-tier ``SignatureFamily`` (small scans serve from the
  small-capacity signature) plus an ``AdmissionPolicy``: priority/deadline
  ordering, weighted tenant fairness, backpressure, and deadline shedding.

Each arm is driven tick-by-tick (``submit(group)`` + ``serve(max_waves=1)``
per tick, then a full drain) so queue backlog builds exactly as the arrival
process dictates. Rows report per-arm p50/p99 end-to-end latency, deadline
goodput, shed counts and compile counts; the headline row derives the
bucketed-over-fifo p99 speedup and goodput delta.

Standalone CLI (what the CI smoke job runs):

    python -m benchmarks.bench_admission --quick --json BENCH_admission.json
"""
from __future__ import annotations

import math

import jax
import numpy as np

from benchmarks.common import emit, standalone_bench_main
from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.models.scn import UNetConfig, init_unet
from repro.serving import AdmissionPolicy
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.sparse.tensor import SparseVoxelTensor

RES, CAP, SMALL_CAP = 16, 1024, 256


def _scene_with(seed: int, n_active: int) -> SparseVoxelTensor:
    """A CAP-capacity scene trimmed to exactly ``n_active`` active voxels
    (the client over-pads; bucketing works off active counts)."""
    coords, feats, _, mask = make_scene(seed, resolution=RES, capacity=CAP)
    mask = np.asarray(mask).copy()
    idx = np.flatnonzero(mask)
    n_active = min(n_active, len(idx))
    mask[idx[n_active:]] = False
    return SparseVoxelTensor(np.asarray(coords), np.asarray(feats), mask)


def _traffic(rng, n_ticks: int, base_rate: float, deadlines: dict):
    """Per-tick request groups: bursty Poisson counts whose rate follows a
    diurnal ramp (quiet -> 3x peak mid-run -> quiet), mixed sizes/tenants.

    Returns ``[(tenant, priority, deadline_ms, scene), ...]`` per tick —
    request *content* only, so each serving arm gets its own fresh
    ``SceneRequest`` objects over identical scenes.
    """
    groups = []
    seed = 0
    for t in range(n_ticks):
        diurnal = 1.0 + 2.0 * math.sin(math.pi * t / max(n_ticks - 1, 1))
        group = []
        for _ in range(rng.poisson(base_rate * diurnal)):
            seed += 1
            small = rng.random() < 0.75  # traffic is mostly small scans
            paid = rng.random() < 0.30
            n_active = int(rng.integers(100, 220) if small
                           else rng.integers(400, 600))
            group.append((
                "paid" if paid else "free",
                1 if paid else 0,
                deadlines["paid" if paid else "free"],
                _scene_with(seed, n_active),
            ))
        groups.append(group)
    return groups


def _drive(eng, groups):
    """Tick-driven serve: submit each tick's arrivals, admit one wave per
    tick (backlog builds through the ramp), then drain the remainder."""
    handles = []
    for group in groups:
        handles += [eng.submit(SceneRequest(len(handles) + i, scene,
                                            tenant=tenant, priority=prio,
                                            deadline_ms=dl))
                    for i, (tenant, prio, dl, scene) in enumerate(group)]
        eng.serve(max_waves=1)
    eng.serve()  # drain the backlog
    return handles


def _emit_arm(arm: str, eng, n_submitted: int):
    slo = eng.slo_stats()
    shed = ",".join(f"{k}:{v}" for k, v in
                    sorted(slo["shed_by_reason"].items())) or "none"
    emit(f"admission/{arm}_p99_ms", slo["p99_ms"] * 1e3,
         f"p50={slo['p50_ms']:.0f}ms p99={slo['p99_ms']:.0f}ms "
         f"goodput={slo['goodput_frac']:.2f} "
         f"({slo['n_completed']}/{n_submitted} done, shed {shed}) "
         f"compilations={eng.n_compilations}")
    return slo


def run(quick: bool = False):
    # base_rate is chosen to overload one-wave-per-tick service: backlog
    # builds through the diurnal peak, which is exactly where admission
    # (cheap small-bucket waves + deadline shedding) has something to win
    n_ticks, base_rate = (10, 4.0) if quick else (24, 5.0)
    batch = 2
    cfg = UNetConfig(widths=(8, 16), reps=1, resolution=RES, capacity=CAP,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    family = engine.SignatureFamily((SMALL_CAP, CAP))
    policy = AdmissionPolicy(max_queue=None, shed_expired=True,
                             tenant_weights={"paid": 3.0, "free": 1.0})

    def fifo_engine():
        # pre-redesign baseline: every scene padded to one max-capacity
        # signature, FIFO admission, no SLO awareness
        return SceneEngine(cfg, params, batch=batch, sync=True)

    def bucketed_engine():
        return SceneEngine(cfg, params, batch=batch, sync=True,
                           family=family, policy=policy)

    # warm both arms' jit signatures on throwaway waves, then calibrate
    # deadlines off a measured warm full-capacity wave (fresh scenes, so
    # plan build is included) — SLOs track the host instead of hardcoding
    # milliseconds
    warm = fifo_engine()
    warm.submit([SceneRequest(i, _scene_with(9000 + i, 500))
                 for i in range(batch)])
    warm.serve()
    warm.submit([SceneRequest(batch + i, _scene_with(9500 + i, 500))
                 for i in range(batch)])
    warm.serve()
    st = warm.scheduler.stats[-1]
    wave_ms = st.plan_ms + st.device_ms
    warm.close()
    wb = bucketed_engine()
    wb.submit([SceneRequest(i, _scene_with(9000 + i, s))
               for i, s in enumerate((150, 150, 500, 500))])
    wb.serve()
    wb.close()
    deadlines = {"paid": 5.0 * wave_ms, "free": 12.0 * wave_ms}
    emit("admission/calibration", wave_ms * 1e3,
         f"warm full-capacity wave {wave_ms:.0f}ms; deadlines "
         f"paid={deadlines['paid']:.0f}ms free={deadlines['free']:.0f}ms")

    rng = np.random.default_rng(7)
    groups = _traffic(rng, n_ticks, base_rate, deadlines)
    n_submitted = sum(len(g) for g in groups)
    n_small = sum(1 for g in groups for r in g
                  if int(np.asarray(r[3].mask).sum()) <= SMALL_CAP)
    emit("admission/traffic", 0.0,
         f"{n_submitted} requests over {n_ticks} ticks "
         f"({n_small} small, {n_submitted - n_small} large; diurnal 1-3x)")

    fifo = fifo_engine()
    _drive(fifo, groups)
    slo_f = _emit_arm("fifo", fifo, n_submitted)
    fifo.close()

    buck = bucketed_engine()
    handles = _drive(buck, groups)
    slo_b = _emit_arm("bucketed", buck, n_submitted)
    # every submitted request is accounted for: completed or surfaced shed
    assert all(h.done() for h in handles)
    assert slo_b["n_completed"] + slo_b["n_shed"] == n_submitted
    assert buck.n_compilations <= family.n_buckets
    buck.close()

    p99_speedup = slo_f["p99_ms"] / max(slo_b["p99_ms"], 1e-9)
    emit("admission/bucketed_vs_fifo", 0.0,
         f"p99 {slo_f['p99_ms']:.0f}ms -> {slo_b['p99_ms']:.0f}ms "
         f"({p99_speedup:.2f}x) goodput {slo_f['goodput_frac']:.2f} -> "
         f"{slo_b['goodput_frac']:.2f} "
         f"goodput_rps {slo_f['goodput_rps']:.1f} -> "
         f"{slo_b['goodput_rps']:.1f}")


def main(argv=None) -> None:
    standalone_bench_main(run, "bench_admission",
                          "short ramp / fewer ticks (the CI smoke job)",
                          description=__doc__, argv=argv)


if __name__ == "__main__":
    main()
