"""Sync vs async scene serving: wave-pipeline throughput comparison.

Measures the ROADMAP "Async scene serving" item: ``SceneEngine`` with
``sync=False`` overlaps host-side plan building (AdMAC + SOAR + SPADE, the
paper's offline pass) with device execution of the previous wave. Three
arrival scenarios, each served by a sync and an async engine over the same
scenes:

* **cold/burst** — fresh scenes, all submitted up front: every wave pays a
  full plan build and the pipeline has maximal cross-wave overlap to mine.
* **cold/paced** — fresh scenes arriving in two-wave groups with a
  ``run()`` per group: overlap is limited to what each group exposes.
* **warm** — the cold/burst scenes resubmitted: plan-cache hits, the two
  modes should converge (there is no plan work left to hide).

Per-request logits are asserted bitwise identical between the modes before
any row is emitted. Rows report wall-clock per request; ``derived`` carries
the overlap stats and the async-vs-sync speedup.

Standalone CLI (what the CI smoke job runs):

    python -m benchmarks.bench_serving --quick --json BENCH_serving.json
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, standalone_bench_main
from repro import engine
from repro.data.scenes import N_CLASSES, make_scene
from repro.models.scn import UNetConfig, init_unet
from repro.serving.scene_engine import SceneEngine, SceneRequest
from repro.serving.scheduler import overlap_fraction
from repro.sparse.tensor import SparseVoxelTensor


def _load(seed, res, cap):
    coords, feats, _, mask = make_scene(seed, res, cap)
    return SparseVoxelTensor(jnp.asarray(coords), jnp.asarray(feats),
                             jnp.asarray(mask))


def _make_engine(cfg, params, batch, spec, sync):
    # planner_threads=1: on small hosts a single planner hides behind device
    # execution without GIL-fighting a second builder; depth=2 = double
    # buffering (wave k+1 plans while k executes and k-1 drains).
    # use_kernel=True serves the SSpNNA tiled path — device work is pure XLA
    # (GIL-free), which is what the host plan pass overlaps against.
    return SceneEngine(cfg, params, batch=batch, spec=spec, use_kernel=True,
                       sync=sync, depth=2, planner_threads=1)


def _serve(eng, scenes, base_rid, group=None):
    """Serve ``scenes``; ``group=None`` is one burst, else paced groups.

    Returns (wall_s, {rid: logits}, stats) with ``stats`` restricted to the
    waves of *this* serve (not warmup or earlier scenarios).
    """
    reqs = [SceneRequest(base_rid + i, s) for i, s in enumerate(scenes)]
    n0 = len(eng.wave_stats)
    t0 = time.perf_counter()
    if group is None:
        eng.submit(reqs)
        eng.serve()
    else:
        for i in range(0, len(reqs), group):
            eng.submit(reqs[i:i + group])
            eng.serve()
    wall = time.perf_counter() - t0
    return wall, {r.rid: r.logits for r in reqs}, eng.wave_stats[n0:]


def _assert_bitwise(name, a, b):
    assert a.keys() == b.keys(), name
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid], err_msg=f"{name}/{rid}")


def _emit_pair(name, n_reqs, sync_wall, async_wall, async_stats):
    plan = sum(s.plan_ms for s in async_stats)
    span = sum(s.plan_span_ms for s in async_stats)
    wait = sum(s.plan_wait_ms for s in async_stats)
    dev = sum(s.device_ms for s in async_stats)
    overlap = overlap_fraction(span, wait)
    emit(f"serving/{name}_sync", sync_wall / n_reqs * 1e6,
         f"wall={sync_wall:.3f}s n={n_reqs}")
    emit(f"serving/{name}_async", async_wall / n_reqs * 1e6,
         f"wall={async_wall:.3f}s n={n_reqs} overlap_frac={overlap:.2f} "
         f"plan_ms={plan:.0f} device_ms={dev:.0f} "
         f"speedup={sync_wall / max(async_wall, 1e-9):.2f}x")


def run(quick: bool = False):
    # scene size is NOT reduced in quick mode: tiny scenes make the numpy
    # plan pass GIL-dominated and the comparison noise-bound; quick trims
    # request counts/reps instead
    res, cap, widths, batch = 24, 2048, (16, 32), 2
    n_reqs, reps = (6, 2) if quick else (8, 3)
    cfg = UNetConfig(widths=widths, reps=1, resolution=res, capacity=cap,
                     n_classes=N_CLASSES)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    # pinned offline-SPADE spec: plan builds include SOAR + tile tables,
    # i.e. real host work for the pipeline to hide
    spec = engine.build_plan_spec([_load(900, res, cap), _load(901, res, cap)],
                                  cfg, mem_budget=16 * 1024)

    engines = {mode: _make_engine(cfg, params, batch, spec, mode == "sync")
               for mode in ("sync", "async")}
    # jit warmup on a throwaway wave so compile time doesn't skew either mode
    for eng in engines.values():
        _serve(eng, [_load(800 + i, res, cap) for i in range(batch)], 9000)

    # cold/burst: fresh scenes submitted at once, best-of-`reps` with a new
    # scene set per rep so the plan cache stays cold
    best = {"sync": float("inf"), "async": float("inf")}
    best_stats = []
    cold0 = None
    for rep in range(reps):
        cold = [_load(10_000 * rep + 100 + i, res, cap) for i in range(n_reqs)]
        cold0 = cold0 or cold
        sync_wall, sync_out, _ = _serve(engines["sync"], cold, rep * 1000)
        async_wall, async_out, a_st = _serve(engines["async"], cold,
                                             rep * 1000)
        _assert_bitwise(f"cold_burst/rep{rep}", sync_out, async_out)
        if async_wall < best["async"]:
            best["async"], best_stats = async_wall, a_st
        best["sync"] = min(best["sync"], sync_wall)
    _emit_pair("cold_burst", n_reqs, best["sync"], best["async"], best_stats)

    # warm: the first cold set again, plans cached in both engines
    sync_wall, sync_out, _ = _serve(engines["sync"], cold0, 90_000)
    async_wall, async_out, async_stats = _serve(engines["async"], cold0,
                                                90_000)
    _assert_bitwise("warm", sync_out, async_out)
    _emit_pair("warm", n_reqs, sync_wall, async_wall, async_stats)

    # cold/paced: fresh scenes in two-wave groups, run() per group
    paced = [_load(500_000 + i, res, cap) for i in range(n_reqs)]
    sync_wall, sync_out, _ = _serve(engines["sync"], paced, 0, group=2 * batch)
    async_wall, async_out, async_stats = _serve(
        engines["async"], paced, 0, group=2 * batch)
    _assert_bitwise("cold_paced", sync_out, async_out)
    _emit_pair("cold_paced", n_reqs, sync_wall, async_wall, async_stats)

    emit("serving/bitwise_match", 0.0,
         "sync and async logits identical across all scenarios")


def main(argv=None) -> None:
    standalone_bench_main(run, "bench_serving",
                          "small scenes/counts (the CI smoke job)",
                          description=__doc__, argv=argv)


if __name__ == "__main__":
    main()
