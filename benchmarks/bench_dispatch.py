"""Dispatch benchmarks: Table III savings + measured-vs-modeled dispatch.

Two arms:

* **Table III** (analytical): micro-op + data-access savings from coarse
  (M-V) dispatch. Per selected layer shape: uOps at scalar-MAC granularity
  (prior sparse accelerators) vs M-V granularity (SSpNNA) vs
  one-fused-einsum-per-tile (this repo's MXU mapping); data accesses
  with/without per-pair refetch.

* **Measured** (wall-clock): per scene shape, build the analytical SPADE
  dispatch under a deliberately small L1 budget (the regime where the model
  picks the tiled SSpNNA path even on hosts where the XLA gather-einsum
  wins), measure every registered backend on the realized plan via
  ``engine.autotune.measure_backends``, record the numbers into a
  ``CostTable`` (optionally seeded from earlier ``BENCH_*.json`` artifacts
  via ``--seed-from``), and compare the tuned choice against the analytical
  one. The tuned dispatcher picks the measured argmin, so it can never be
  measured slower than the analytical choice — asserted per case — and the
  ``dispatch/tuned_vs_analytical_geomean`` row quantifies the win.

Standalone CLI (what the CI smoke job runs):

    python -m benchmarks.bench_dispatch --quick \
        --seed-from BENCH_sspnna.json --json BENCH_dispatch.json
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_scene, emit, scene_metadata, standalone_bench_main

# (name, dC, dN) tile channel sizes echoing Table III's layers
LAYERS = [("L2-like", 16, 32), ("L12-like", 16, 32), ("L35-like", 8, 16)]

# measured-arm scene shapes: (name, resolution, capacity, channels)
SWEEP = [("r16_c8", 16, 512, 8), ("r24_c16", 24, 1024, 16),
         ("r32_c16", 32, 2048, 16)]

# small SPADE L1 budget: forces an actual tiling, i.e. the regime where the
# analytical model dispatches to sspnna (which the measured arm contests)
MEASURE_BUDGET = 16 * 1024


def _table_iii():
    t, _ = build_scene(0, 48, 16384)
    coir, nbr, order = scene_metadata(t, 48)
    idx = np.asarray(coir.indices)
    mask = np.asarray(t.mask)
    pairs = int((idx[mask] >= 0).sum())
    for name, dc, dn in LAYERS:
        total_macs = pairs * dc * dn
        uops_scalar = total_macs
        uops_mv = pairs                      # one M-V op per valid pair
        uops_saving = uops_scalar / uops_mv
        # data accesses: scalar dispatch refetches the input vector per MAC
        da_scalar = pairs * (dc + dn + dc * dn / min(dc, dn))
        da_mv = pairs * dc + pairs * dn      # vector in, vector out per pair
        emit(f"tableIII/{name}/uops_saving", 0.0,
             f"{uops_saving:.0f}x ({uops_scalar:.2e}->{uops_mv:.2e})")
        emit(f"tableIII/{name}/da_saving", 0.0,
             f"{da_scalar / da_mv:.2f}x")


def _measured_case(table, name, res, cap, c, k):
    """Measure all backends on one scene shape; returns the
    analytical-over-tuned wall-clock ratio (>= 1 by construction)."""
    import jax.numpy as jnp

    from repro.core import spade
    from repro.core.sparse_conv import SparseConvParams
    from repro.engine.autotune import measure_backends, signature
    from repro.engine.plan import (
        _layer_spec,
        conv_plan_for_layer,
        dispatch_from_dataflow,
    )

    t, _ = build_scene(seed=0, resolution=res, capacity=cap)
    coir, _, order = scene_metadata(t, res)
    mask = np.asarray(t.mask)
    n_active = int(mask.sum())
    density = n_active / res**3

    # analytical dispatch, exactly as _assemble_level derives it
    attrs = spade.extract_attributes(
        np.asarray(coir.indices), mask, order.order)
    layer = _layer_spec(name, n_active, c)
    df = spade.explore(layer, {"CIRF": attrs, "CORF": attrs}, MEASURE_BUDGET)
    analytical = dispatch_from_dataflow(df, attrs, n_active)
    d_o = analytical.delta_o or 32
    d_i = analytical.delta_i or 123

    # one realized tiled plan; the reference backend ignores the tiles and
    # runs the XLA gather-einsum on the same COIR, so every backend sees
    # the identical conv
    plan = conv_plan_for_layer(coir, order.order, d_o, d_i)
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(cap, c)), jnp.float32)
    params = SparseConvParams(
        jnp.asarray(rng.normal(size=(27, c, c)) * 0.1, jnp.float32),
        jnp.zeros((c,), jnp.float32))

    times = measure_backends(plan, feats, params, k=k)
    for bname, m in sorted(times.items()):
        sig = signature(n_active, n_active, c, c, density=density,
                        backend=bname)
        table.record(sig, m.median_us, spread_us=m.spread_us, k=m.k,
                     delta_o=d_o, delta_i=d_i)
        emit(f"dispatch/{name}_{bname}", m.median_us,
             f"sig={sig.encode()} delta_o={d_o} delta_i={d_i} "
             f"spread_us={m.spread_us:.1f}")

    tuned = table.adjust_dispatch(
        analytical, n_in=n_active, n_out=n_active, c_in=c, c_out=c,
        density=density)
    t_analytical = times[analytical.backend].median_us
    t_tuned = times[tuned.backend].median_us
    # the tuned winner is the measured argmin: never slower than analytical
    assert t_tuned <= t_analytical, (
        f"{name}: tuned {tuned.backend} ({t_tuned:.1f}us) measured slower "
        f"than analytical {analytical.backend} ({t_analytical:.1f}us)")
    ratio = t_analytical / max(t_tuned, 1e-9)
    emit(f"dispatch/{name}_choice", 0.0,
         f"analytical={analytical.backend} tuned={tuned.backend} "
         f"tuned_vs_analytical={ratio:.2f}x n_active={n_active} "
         f"density={density:.4f}")
    return ratio


def _measured_arm(quick: bool, seed_from):
    from repro.engine.autotune import CostTable, seed_cost_table

    table = CostTable()
    if seed_from:
        n = seed_cost_table(table, list(seed_from))
        emit("dispatch/seeded", 0.0,
             f"entries={n} from {len(list(seed_from))} artifact(s)")
    cases = SWEEP[:1] if quick else SWEEP
    k = 2 if quick else 3
    ratios = [_measured_case(table, name, res, cap, c, k)
              for name, res, cap, c in cases]
    geomean = float(np.exp(np.mean(np.log(ratios))))
    emit("dispatch/tuned_vs_analytical_geomean", 0.0,
         f"{geomean:.2f}x across {len(ratios)} scene shapes "
         f"(tuned dispatch picks the measured winner)")


def run(quick: bool = False, seed_from=()):
    _table_iii()
    _measured_arm(quick, seed_from)


def main(argv=None) -> None:
    standalone_bench_main(
        run, "bench_dispatch", "single small scene (the CI smoke job)",
        description=__doc__, argv=argv,
        configure=lambda ap: ap.add_argument(
            "--seed-from", nargs="*", default=[], metavar="JSON",
            help="seed the cost table from bench-rows/v1 artifacts "
                 "(e.g. BENCH_sspnna.json from a prior CI run)"),
        run_kw=lambda args: {"seed_from": args.seed_from})


if __name__ == "__main__":
    main()
